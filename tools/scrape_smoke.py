#!/usr/bin/env python
"""Live-scrape smoke test against a running `gsoft ... --listen` exporter.

Usage: scrape_smoke.py HOST:PORT [--expect-requests N] [--timeout SECS]
                       [--serve-api --d N]

Polls the exporter until it answers (the bench may still be binding),
then asserts the full endpoint surface documented in DESIGN.md §10:
  - /metrics        Prometheus text; per-path serve_requests_total lines
                    sum to --expect-requests (when given);
  - /metrics.json   same registry as JSON; counters agree with /metrics;
  - /healthz        HTTP 200 with "ok": true and named checks;
  - /tracez         newest-first JSON array of request traces (seq
                    non-increasing), non-empty once traffic has run;
                    unknown or malformed filter params answer 400;
  - /tenantz        per-tenant heavy hitters (DESIGN.md §12): JSON with
                    a sketch capacity k and per-dimension entries;
                    ?format=text renders a table, other formats 400;
  - /slo            burn-rate report with per-objective windows;
  - a malformed request line gets HTTP 400 without killing the server;
  - an unknown path gets HTTP 404.

With --serve-api the target is a `gsoft serve --listen` request front
(DESIGN.md §11) rather than a bare exporter, and the request endpoints
are driven first: GET /v1/tenants lists the fleet, POST /v1/query
serves an input of dimension --d (default 16), a malformed body answers
400, and an already-expired `deadline_ms` answers 504 — that traffic is
then visible in the obs assertions above (same listener, one registry).
Request-ID correlation is driven end to end: a query posted with a
client `req_id` echoes it in the 200 response, the 504 shed body echoes
it too, and `/tracez?req=ID` resolves the id to its stage trace.

Only the standard library is used (no requests/urllib3), matching the
zero-dependency exporter on the other side of the socket.
"""

import json
import re
import socket
import sys
import time


def http_get(host, port, target, timeout=2.0):
    """One HTTP/1.1 GET over a raw socket. Returns (status, body_str)."""
    with socket.create_connection((host, port), timeout=timeout) as s:
        s.sendall(f"GET {target} HTTP/1.1\r\nHost: {host}\r\n\r\n".encode())
        chunks = []
        while True:
            data = s.recv(65536)
            if not data:
                break
            chunks.append(data)
    raw = b"".join(chunks).decode("utf-8", "replace")
    head, _, body = raw.partition("\r\n\r\n")
    status = int(head.split(None, 2)[1])
    return status, body


def http_post(host, port, target, body, timeout=10.0):
    """One HTTP/1.1 POST with a JSON body. Returns (status, body_str)."""
    encoded = body.encode()
    head = (
        f"POST {target} HTTP/1.1\r\nHost: {host}\r\n"
        f"Content-Type: application/json\r\n"
        f"Content-Length: {len(encoded)}\r\n\r\n"
    ).encode()
    with socket.create_connection((host, port), timeout=timeout) as s:
        s.sendall(head + encoded)
        chunks = []
        while True:
            data = s.recv(65536)
            if not data:
                break
            chunks.append(data)
    raw = b"".join(chunks).decode("utf-8", "replace")
    head, _, body = raw.partition("\r\n\r\n")
    return int(head.split(None, 2)[1]), body


def http_raw(host, port, payload, timeout=2.0):
    """Send raw bytes, return the status code (0 = connection dropped)."""
    with socket.create_connection((host, port), timeout=timeout) as s:
        s.sendall(payload)
        data = s.recv(65536)
    if not data:
        return 0
    return int(data.split(None, 2)[1])


def fail(msg):
    print(f"[scrape_smoke] FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def wait_up(host, port, deadline):
    while time.time() < deadline:
        try:
            status, _ = http_get(host, port, "/healthz")
            print(f"[scrape_smoke] exporter up, /healthz -> {status}")
            return
        except OSError:
            time.sleep(0.25)
    fail(f"exporter at {host}:{port} did not come up in time")


def drive_serve_api(host, port, d):
    """Exercise the request front's endpoints (DESIGN.md §11)."""
    status, body = http_get(host, port, "/v1/tenants")
    if status != 200:
        fail(f"/v1/tenants -> HTTP {status}")
    tenants = json.loads(body).get("tenants", [])
    if not tenants:
        fail("/v1/tenants returned an empty fleet")
    tenant = tenants[0]
    print(f"[scrape_smoke] /v1/tenants ok ({len(tenants)} tenants)")

    query = json.dumps({"tenant": tenant, "input": [0.5] * d})
    status, body = http_post(host, port, "/v1/query", query)
    if status != 200:
        fail(f"/v1/query -> HTTP {status}: {body[:200]}")
    out = json.loads(body)
    if len(out.get("output", [])) != d or "path" not in out:
        fail(f"/v1/query malformed response: {body[:200]}")
    if int(out.get("req_id", 0)) < 1:
        fail(f"/v1/query did not mint a req_id: {body[:200]}")
    print(f"[scrape_smoke] /v1/query ok (path {out['path']}, {d} outputs)")

    # Request-ID correlation (DESIGN.md §12): a client-supplied id is
    # echoed in the response and resolvable through /tracez?req=.
    marked = json.dumps({"tenant": tenant, "input": [0.5] * d, "req_id": 424242})
    status, body = http_post(host, port, "/v1/query", marked)
    if status != 200 or int(json.loads(body).get("req_id", 0)) != 424242:
        fail(f"client req_id not echoed -> HTTP {status}: {body[:200]}")
    status, body = http_get(host, port, "/tracez?req=424242")
    hits = json.loads(body) if status == 200 else []
    if status != 200 or len(hits) != 1 or int(hits[0]["req_id"]) != 424242:
        fail(f"/tracez?req=424242 -> HTTP {status} with {body[:200]}")
    if "stage_ns" not in hits[0]:
        fail(f"correlated trace has no stage breakdown: {hits[0]}")
    print("[scrape_smoke] req_id round-trip ok (echoed in 200, found by /tracez?req=)")

    status, _ = http_post(host, port, "/v1/query", "{not json")
    if status != 400:
        fail(f"malformed query body -> HTTP {status}, expected 400")
    expired = json.dumps(
        {"tenant": tenant, "input": [0.5] * d, "deadline_ms": 0, "req_id": 515151}
    )
    status, body = http_post(host, port, "/v1/query", expired)
    if status != 504:
        fail(f"expired deadline -> HTTP {status}, expected 504")
    if int(json.loads(body).get("req_id", 0)) != 515151:
        fail(f"504 shed body does not echo req_id: {body[:200]}")
    status, _ = http_post(host, port, "/v1/tenants", "{}")
    if status != 405:
        fail(f"POST /v1/tenants -> HTTP {status}, expected 405")
    print("[scrape_smoke] serve API error paths ok (400 / 504 with req_id / 405)")


def main(argv):
    if len(argv) < 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    host, _, port = argv[1].partition(":")
    port = int(port or "9100")
    expect = None
    timeout = 30.0
    if "--expect-requests" in argv:
        expect = int(argv[argv.index("--expect-requests") + 1])
    if "--timeout" in argv:
        timeout = float(argv[argv.index("--timeout") + 1])
    d = int(argv[argv.index("--d") + 1]) if "--d" in argv else 16
    deadline = time.time() + timeout
    wait_up(host, port, deadline)

    # Request-front mode: drive the /v1 endpoints before the scrape
    # assertions so the traffic they generate is visible below.
    if "--serve-api" in argv:
        drive_serve_api(host, port, d)

    # The bench may still be mid-sweep when we connect; poll /metrics
    # until the per-path counters account for the whole configured trace.
    pat = re.compile(r'^serve_requests_total\{path="[a-z_]+"\} (\d+)$', re.M)
    text = ""
    while True:
        status, text = http_get(host, port, "/metrics")
        if status != 200:
            fail(f"/metrics -> HTTP {status}")
        total = sum(int(m) for m in pat.findall(text))
        if expect is None or total >= expect:
            break
        if time.time() > deadline:
            fail(f"per-path requests reached {total}, expected {expect}")
        time.sleep(0.25)
    if expect is not None and total != expect:
        fail(f"per-path requests sum to {total}, expected exactly {expect}")
    print(f"[scrape_smoke] /metrics ok ({total} requests across paths)")

    status, body = http_get(host, port, "/metrics.json")
    if status != 200:
        fail(f"/metrics.json -> HTTP {status}")
    snap = json.loads(body)
    json_total = sum(
        v
        for k, v in snap.get("counters", {}).items()
        if k.startswith("serve_requests_total{path=")
    )
    if json_total != total:
        fail(f"/metrics.json disagrees with /metrics: {json_total} != {total}")
    print("[scrape_smoke] /metrics.json agrees with the text exposition")

    status, body = http_get(host, port, "/healthz")
    health = json.loads(body)
    if status != 200 or health.get("ok") is not True:
        fail(f"/healthz -> HTTP {status}, body {body!r}")
    names = [c.get("name") for c in health.get("checks", [])]
    for required in ("accepting", "workers"):
        if required not in names:
            fail(f"/healthz missing check {required!r} (got {names})")
    print(f"[scrape_smoke] /healthz ok, checks: {', '.join(names)}")

    status, body = http_get(host, port, "/tracez")
    traces = json.loads(body)
    if status != 200 or not isinstance(traces, list) or not traces:
        fail(f"/tracez -> HTTP {status} with {len(traces)} traces")
    # u64 fields above 2^53 travel as decimal strings; int() reads both.
    seqs = [int(t["seq"]) for t in traces]
    if seqs != sorted(seqs, reverse=True):
        fail(f"/tracez not newest-first: {seqs[:8]}...")
    status, _ = http_get(host, port, "/tracez?bogus=1")
    if status != 400:
        fail(f"/tracez with unknown filter -> HTTP {status}, expected 400")
    print(f"[scrape_smoke] /tracez ok ({len(traces)} traces, newest first, strict params)")

    status, body = http_get(host, port, "/tenantz")
    hitters = json.loads(body) if status == 200 else {}
    dims = hitters.get("dims", {})
    if status != 200 or int(hitters.get("k", 0)) < 1 or "requests" not in dims:
        fail(f"/tenantz -> HTTP {status}, body {body[:200]!r}")
    k = int(hitters["k"])
    for name, dim in dims.items():
        if len(dim.get("entries", [])) > k:
            fail(f"/tenantz dim {name!r} exceeds its K={k} entry cap")
    status, body = http_get(host, port, "/tenantz?format=text")
    if status != 200 or "heavy hitters" not in body:
        fail(f"/tenantz?format=text -> HTTP {status}, body {body[:200]!r}")
    status, _ = http_get(host, port, "/tenantz?format=yaml")
    if status != 400:
        fail(f"/tenantz with unknown format -> HTTP {status}, expected 400")
    print(f"[scrape_smoke] /tenantz ok (K={k}, {len(dims)} dimensions, strict params)")

    status, body = http_get(host, port, "/slo")
    slo = json.loads(body)
    if status != 200 or "ok" not in slo or not slo.get("objectives"):
        fail(f"/slo -> HTTP {status}, body {body[:200]!r}")
    print(f"[scrape_smoke] /slo ok ({len(slo['objectives'])} objectives)")

    status = http_raw(host, port, b"NONSENSE\r\n\r\n")
    if status != 400:
        fail(f"malformed request line -> HTTP {status}, expected 400")
    status, _ = http_get(host, port, "/no-such-endpoint")
    if status != 404:
        fail(f"unknown path -> HTTP {status}, expected 404")
    # And the exporter must have survived both.
    status, _ = http_get(host, port, "/healthz")
    if status != 200:
        fail(f"exporter unhealthy after bad requests: HTTP {status}")
    print("[scrape_smoke] error paths ok (400 on garbage, 404 on unknown, still alive)")
    print("[scrape_smoke] PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
