#!/usr/bin/env python
"""Fill EXPERIMENTS.md placeholders from generated results/*.md tables."""

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SLOTS = {
    "<!--TABLE1-->": "results/table1.md",
    "<!--TABLE2-->": "results/table2.md",
    "<!--FIG6-->": "results/fig6.md",
    "<!--TABLE3-->": "results/table3.md",
    "<!--TABLE4-->": "results/table4.md",
    "<!--DENSITY-->": "results/density.md",
    "<!--PARAMS-->": "results/params_table.md",
}


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    for slot, rel in SLOTS.items():
        full = os.path.join(ROOT, rel)
        if slot not in text:
            continue
        if not os.path.exists(full):
            print(f"  [fill] missing {rel}; leaving placeholder")
            continue
        table = open(full).read()
        # strip the "### title" line (EXPERIMENTS.md has its own headers)
        table = re.sub(r"^### .*\n+", "", table)
        text = text.replace(slot, table.strip())
        print(f"  [fill] {rel} -> {slot}")
    # e2e summary from the loss curve if present
    curve = os.path.join(ROOT, "results/e2e_loss_curve.csv")
    if "<!--E2E-->" in text and os.path.exists(curve):
        rows = [l.split(",") for l in open(curve).read().strip().splitlines()[1:]]
        first, last = float(rows[0][1]), float(rows[-1][1])
        summary = (f"Measured: loss {first:.3f} → {last:.3f} over {len(rows)} "
                   f"GSOFT steps (full curve in results/e2e_loss_curve.csv); "
                   f"merge check passed with 0 prediction mismatches.")
        text = text.replace("<!--E2E-->", summary)
        print("  [fill] e2e summary")
    open(path, "w").write(text)
    print("filled EXPERIMENTS.md")


if __name__ == "__main__":
    sys.exit(main())
