#!/usr/bin/env python
"""Sanity-check the `obs` telemetry section of a BENCH_*.json record.

Usage: check_obs.py BENCH_serve.json [BENCH_kernels.json ...]

For each record this asserts that the obs section is well-formed:
  - `obs` exists with `counters` / `gauges` / `timings` objects;
  - the declared serve-side metric names are present (first file only is
    expected to be a serve-bench record; other records just need a
    structurally valid obs section);
  - per-path and per-family `serve_requests_total` counters each sum to
    the configured request count;
  - every histogram summary has monotone quantiles
    (p50 <= p95 <= p99 <= p999 <= max) and a non-negative count.

Exits non-zero with a message on the first violation, so CI fails loudly
instead of uploading a malformed artifact.
"""

import json
import sys

SERVE_COUNTERS = [
    'serve_requests_total{path="cached_dense"}',
    'serve_requests_total{path="cold_merge"}',
    'serve_requests_total{path="factorized"}',
    'serve_requests_total{path="spill_load"}',
    "serve_batches_total",
    "serve_merges_total",
]
SERVE_GAUGES = [
    "serve_policy_promote_after",
    "serve_policy_merge_flops_per_layer",
    "serve_cache_budget_bytes",
]
SERVE_TIMINGS = [
    'serve_stage_ns{stage="queue"}',
    'serve_stage_ns{stage="kernel"}',
]
QUANTS = ["p50", "p95", "p99", "p999"]


def fail(path, msg):
    print(f"[check_obs] {path}: {msg}", file=sys.stderr)
    sys.exit(1)


def check_timings(path, timings):
    for name, h in sorted(timings.items()):
        for key in ["count", "max"] + QUANTS:
            if key not in h:
                fail(path, f"timing {name!r} is missing {key!r}")
        if h["count"] < 0:
            fail(path, f"timing {name!r} has negative count")
        qs = [h[q] for q in QUANTS] + [h["max"]]
        if h["count"] > 0 and any(a > b for a, b in zip(qs, qs[1:])):
            fail(path, f"timing {name!r} quantiles not monotone: {qs}")


def check_serve(path, record, obs):
    for name in SERVE_COUNTERS:
        if name not in obs["counters"]:
            fail(path, f"declared counter {name!r} missing")
    for name in SERVE_GAUGES:
        if name not in obs["gauges"]:
            fail(path, f"declared gauge {name!r} missing")
    for name in SERVE_TIMINGS:
        if name not in obs["timings"]:
            fail(path, f"declared timing {name!r} missing")
    requests = int(record["config"]["requests"])
    # Store mode registers extra tenants mid-trace and queries each once.
    extra = obs["counters"].get('serve_requests_total{family="unknown"}', 0)
    by_path = sum(
        v
        for k, v in obs["counters"].items()
        if k.startswith("serve_requests_total{path=")
    )
    by_family = sum(
        v
        for k, v in obs["counters"].items()
        if k.startswith("serve_requests_total{family=")
    )
    if by_path != requests:
        fail(path, f"per-path requests sum to {by_path}, expected {requests}")
    if by_family != requests:
        fail(path, f"per-family requests sum to {by_family}, expected {requests}")
    if extra:
        fail(path, f"{extra} requests attributed to family 'unknown'")
    queue = obs["timings"]['serve_stage_ns{stage="queue"}']
    if queue["count"] != requests:
        fail(path, f"queue stage count {queue['count']} != requests {requests}")


def main(argv):
    if len(argv) < 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    for i, path in enumerate(argv[1:]):
        with open(path) as f:
            record = json.load(f)
        obs = record.get("obs")
        if obs is None:
            fail(path, "no 'obs' section in record")
        for section in ("counters", "gauges", "timings"):
            if not isinstance(obs.get(section), dict):
                fail(path, f"obs.{section} missing or not an object")
        check_timings(path, obs["timings"])
        if i == 0:
            check_serve(path, record, obs)
        n = len(obs["counters"]) + len(obs["gauges"]) + len(obs["timings"])
        print(f"[check_obs] {path}: OK ({n} metrics)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
