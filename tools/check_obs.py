#!/usr/bin/env python
"""Sanity-check the `obs`/`slo` telemetry sections of BENCH_*.json records
and (with --chrome) a Chrome trace-event export.

Usage: check_obs.py [--chrome trace.json] BENCH_serve.json [BENCH_*.json ...]

For each record this asserts that the telemetry is well-formed:
  - `obs` exists with `counters` / `gauges` / `timings` objects;
  - the declared serve-side metric names are present (first file only is
    expected to be a serve-bench record; other records just need a
    structurally valid obs section);
  - every `serve_stage_ns{stage="X"}` timing uses a stage name from the
    fixed pipeline taxonomy (queue/plan/merge/spill/kernel/reply);
  - per-path and per-family `serve_requests_total` counters each sum to
    the configured request count;
  - every histogram summary has monotone quantiles
    (p50 <= p95 <= p99 <= p999 <= max) and a non-negative count;
  - an `slo` section, when present, carries a boolean `ok` and
    objectives whose window statuses are pass/fail/no_data with numeric
    burn rates. The verdict itself is NOT gated on — a loaded CI box may
    legitimately burn the latency budget; structure must still hold;
  - the serve record's `tenants` section (per-tenant heavy hitters,
    DESIGN.md §12) stays within its cardinality contract: every tracked
    dimension is present, holds at most K entries sorted by descending
    count, conserves its total (SpaceSaving counts sum exactly to the
    observed total), bounds each entry's error by its count, and the
    requests dimension totals the configured request count. The
    synthesized serve_tenant_topk_* gauges obey the same <= K cap.

With `--chrome PATH` the trace-event JSON from `gsoft trace` is also
validated: a traceEvents array of M/X events with pid/tid/ts fields,
process+thread metadata, and every stage span inside a request span.

A listed record file that does not exist is skipped with a warning (the
bench that writes it may be disabled in this CI lane); any other
violation exits non-zero so CI fails loudly instead of uploading a
malformed artifact.
"""

import json
import os
import sys

SERVE_COUNTERS = [
    'serve_requests_total{path="cached_dense"}',
    'serve_requests_total{path="cold_merge"}',
    'serve_requests_total{path="factorized"}',
    'serve_requests_total{path="spill_load"}',
    "serve_batches_total",
    "serve_merges_total",
]
SERVE_GAUGES = [
    "serve_policy_promote_after",
    "serve_policy_merge_flops_per_layer",
    "serve_cache_budget_bytes",
]
SERVE_TIMINGS = [
    'serve_stage_ns{stage="queue"}',
    'serve_stage_ns{stage="kernel"}',
]
QUANTS = ["p50", "p95", "p99", "p999"]
# The engine's fixed stage pipeline (obs::trace::Stage::ALL). A new stage
# must be added here, in DESIGN.md §10 and in the Chrome exporter at once.
STAGES = {"queue", "plan", "merge", "spill", "kernel", "reply"}
SLO_STATUSES = {"pass", "fail", "no_data"}
# Heavy-hitter dimensions (obs::tenantstats::TENANT_DIMS). Keep in sync
# with DESIGN.md §12.
TENANT_DIMS = ["requests", "latency_ns_sum", "deadline_sheds", "admission_rejected"]
# Sharded-store + background-maintenance metrics (DESIGN.md §13). A
# store-bench record run with --obs must carry all of them — they are
# registered up front, so zero-valued series still appear.
STORE_COUNTERS = [
    "store_shard_appends_total",
    "store_shard_torn_tails_total",
    "store_maint_ticks_total",
    "store_maint_compactions_total",
    "store_maint_spill_writes_total",
]
STORE_GAUGES = ["store_shard_count", "store_maint_queue_depth"]
STORE_TIMINGS = ["store_shard_replay_ns", "store_maint_cycle_ns"]


def as_int(v):
    """u64 leaves above 2^53 travel as decimal strings (Json::u64)."""
    return int(v)


def fail(path, msg):
    print(f"[check_obs] {path}: {msg}", file=sys.stderr)
    sys.exit(1)


def check_timings(path, timings):
    for name, h in sorted(timings.items()):
        for key in ["count", "max"] + QUANTS:
            if key not in h:
                fail(path, f"timing {name!r} is missing {key!r}")
        if h["count"] < 0:
            fail(path, f"timing {name!r} has negative count")
        qs = [h[q] for q in QUANTS] + [h["max"]]
        if h["count"] > 0 and any(a > b for a, b in zip(qs, qs[1:])):
            fail(path, f"timing {name!r} quantiles not monotone: {qs}")
        if name.startswith('serve_stage_ns{stage="'):
            stage = name[len('serve_stage_ns{stage="'):].rstrip('"}')
            if stage not in STAGES:
                fail(path, f"stage {stage!r} not in taxonomy {sorted(STAGES)}")


def check_slo(path, slo):
    if not isinstance(slo.get("ok"), bool):
        fail(path, "slo.ok missing or not a boolean")
    objectives = slo.get("objectives")
    if not isinstance(objectives, list) or not objectives:
        fail(path, "slo.objectives missing or empty")
    for obj in objectives:
        name = obj.get("name")
        if not isinstance(name, str) or not name:
            fail(path, "slo objective with missing name")
        if obj.get("status") not in SLO_STATUSES:
            fail(path, f"slo {name!r} status {obj.get('status')!r} invalid")
        windows = obj.get("windows")
        if not isinstance(windows, list) or not windows:
            fail(path, f"slo {name!r} has no windows")
        for w in windows:
            if w.get("status") not in SLO_STATUSES:
                fail(path, f"slo {name!r} window status {w.get('status')!r} invalid")
            for key in ("burn_rate", "target"):
                if not isinstance(w.get(key), (int, float)):
                    fail(path, f"slo {name!r} window {key} not numeric")
            if w["status"] == "fail" and w["burn_rate"] <= 1.0:
                fail(path, f"slo {name!r} failed with burn_rate {w['burn_rate']} <= 1")
    summary = "ok" if slo["ok"] else "BURNED (informational, not gated)"
    print(f"[check_obs] {path}: slo {summary} ({len(objectives)} objectives)")


def check_tenants(path, record, obs, requests):
    tenants = record.get("tenants")
    if tenants is None:
        fail(path, "serve record has no 'tenants' section")
    k = as_int(tenants.get("k", 0))
    if k <= 0:
        fail(path, f"tenants.k must be a positive sketch capacity, got {tenants.get('k')!r}")
    dims = tenants.get("dims")
    if not isinstance(dims, dict):
        fail(path, "tenants.dims missing or not an object")
    for name in TENANT_DIMS:
        if name not in dims:
            fail(path, f"tenant dimension {name!r} missing from tenants.dims")
    for name, d in sorted(dims.items()):
        total = as_int(d.get("total", -1))
        entries = d.get("entries")
        if total < 0 or not isinstance(entries, list):
            fail(path, f"tenant dim {name!r} needs a total and an entries array")
        if len(entries) > k:
            fail(path, f"tenant dim {name!r} has {len(entries)} entries, cap is K={k}")
        counts = []
        for e in entries:
            count, err = as_int(e["count"]), as_int(e["err"])
            as_int(e["tenant"])
            if err > count:
                fail(path, f"tenant dim {name!r} entry err {err} exceeds count {count}")
            counts.append(count)
        if any(a < b for a, b in zip(counts, counts[1:])):
            fail(path, f"tenant dim {name!r} entries not sorted by descending count")
        # SpaceSaving conserves mass: tracked counts sum exactly to the
        # number of observations (every increment lands on one slot).
        if sum(counts) != total:
            fail(path, f"tenant dim {name!r} counts sum to {sum(counts)}, total says {total}")
    if as_int(dims["requests"]["total"]) != requests:
        fail(
            path,
            f"tenants requests total {dims['requests']['total']} != {requests} requests served",
        )
    # Synthesized gauges carry the same cardinality contract.
    gauges = obs["gauges"]
    if as_int(gauges.get("serve_tenant_topk_k", 0)) != k:
        fail(path, f"serve_tenant_topk_k gauge != tenants.k ({k})")
    for name in TENANT_DIMS:
        prefix = f"serve_tenant_topk_{name}{{"
        series = [g for g in gauges if g.startswith(prefix)]
        if len(series) > k:
            fail(path, f"{len(series)} {prefix}...}} gauge series exceed the K={k} cap")


def check_serve(path, record, obs):
    for name in SERVE_COUNTERS:
        if name not in obs["counters"]:
            fail(path, f"declared counter {name!r} missing")
    for name in SERVE_GAUGES:
        if name not in obs["gauges"]:
            fail(path, f"declared gauge {name!r} missing")
    for name in SERVE_TIMINGS:
        if name not in obs["timings"]:
            fail(path, f"declared timing {name!r} missing")
    requests = int(record["config"]["requests"])
    # The network-front probe (DESIGN.md §11) serves extra loopback
    # queries through the same engine after the trace; they land in the
    # same per-path/per-family counters and queue-stage timings.
    requests += int(record.get("front", {}).get("requests", 0))
    # Store mode registers extra tenants mid-trace and queries each once.
    extra = obs["counters"].get('serve_requests_total{family="unknown"}', 0)
    by_path = sum(
        v
        for k, v in obs["counters"].items()
        if k.startswith("serve_requests_total{path=")
    )
    by_family = sum(
        v
        for k, v in obs["counters"].items()
        if k.startswith("serve_requests_total{family=")
    )
    if by_path != requests:
        fail(path, f"per-path requests sum to {by_path}, expected {requests}")
    if by_family != requests:
        fail(path, f"per-family requests sum to {by_family}, expected {requests}")
    if extra:
        fail(path, f"{extra} requests attributed to family 'unknown'")
    queue = obs["timings"]['serve_stage_ns{stage="queue"}']
    if queue["count"] != requests:
        fail(path, f"queue stage count {queue['count']} != requests {requests}")
    if "slo" not in record:
        fail(path, "serve record has no 'slo' section")
    check_tenants(path, record, obs, requests)


def check_store(path, record, obs):
    for name in STORE_COUNTERS:
        if name not in obs["counters"]:
            fail(path, f"declared store counter {name!r} missing")
    for name in STORE_GAUGES:
        if name not in obs["gauges"]:
            fail(path, f"declared store gauge {name!r} missing")
    for name in STORE_TIMINGS:
        if name not in obs["timings"]:
            fail(path, f"declared store timing {name!r} missing")
    # Every config in the sweep must attribute all compactions and spill
    # writes to the maintenance thread — the request path owns neither.
    for i, cfg in enumerate(record.get("configs", [])):
        maint = cfg.get("maint")
        if not isinstance(maint, dict):
            fail(path, f"configs[{i}] has no 'maint' section")
        for key in ("request_path_compactions", "request_path_spill_writes"):
            if as_int(maint.get(key, -1)) != 0:
                fail(path, f"configs[{i}].maint.{key} = {maint.get(key)!r}, must be 0")


def check_chrome(path):
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(path, "traceEvents missing or empty")
    metas = [e for e in events if e.get("ph") == "M"]
    spans = [e for e in events if e.get("ph") == "X"]
    if len(metas) + len(spans) != len(events):
        fail(path, "unexpected event phase (only M and X are emitted)")
    if not any(m.get("name") == "process_name" for m in metas):
        fail(path, "no process_name metadata event")
    if not any(m.get("name") == "thread_name" for m in metas):
        fail(path, "no thread_name metadata event")
    for e in events:
        for key in ("pid", "tid", "name"):
            if key not in e:
                fail(path, f"event missing {key!r}: {e}")
    requests = [e for e in spans if e.get("cat") == "request"]
    stages = [e for e in spans if e.get("cat") == "stage"]
    if not requests:
        fail(path, "no request spans")
    for e in requests + stages:
        for key in ("ts", "dur"):
            if not isinstance(e.get(key), (int, float)) or e[key] < 0:
                fail(path, f"span {e.get('name')!r} has bad {key}")
    for s in stages:
        if s["name"] not in STAGES:
            fail(path, f"stage span {s['name']!r} not in taxonomy {sorted(STAGES)}")
        # Every stage span must nest (with float slack) inside a request
        # span on the same thread lane.
        inside = any(
            r["tid"] == s["tid"]
            and r["ts"] - 1e-3 <= s["ts"]
            and s["ts"] + s["dur"] <= r["ts"] + r["dur"] + 1e-3
            for r in requests
        )
        if not inside:
            fail(path, f"stage span {s['name']!r} at ts={s['ts']} outside any request span")
    print(
        f"[check_obs] {path}: chrome trace OK "
        f"({len(requests)} request spans, {len(stages)} stage spans)"
    )


def main(argv):
    args = argv[1:]
    chrome = None
    if "--chrome" in args:
        at = args.index("--chrome")
        if at + 1 >= len(args):
            print("[check_obs] --chrome needs a path", file=sys.stderr)
            return 2
        chrome = args[at + 1]
        args = args[:at] + args[at + 2:]
    if not args and chrome is None:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    for i, path in enumerate(args):
        if not os.path.exists(path):
            print(f"[check_obs] WARNING: {path} not found, skipping", file=sys.stderr)
            continue
        with open(path) as f:
            record = json.load(f)
        obs = record.get("obs")
        if obs is None:
            fail(path, "no 'obs' section in record")
        for section in ("counters", "gauges", "timings"):
            if not isinstance(obs.get(section), dict):
                fail(path, f"obs.{section} missing or not an object")
        check_timings(path, obs["timings"])
        if i == 0:
            check_serve(path, record, obs)
        if os.path.basename(path).startswith("BENCH_store"):
            check_store(path, record, obs)
        if "slo" in record:
            check_slo(path, record["slo"])
        n = len(obs["counters"]) + len(obs["gauges"]) + len(obs["timings"])
        print(f"[check_obs] {path}: OK ({n} metrics)")
    if chrome is not None:
        if os.path.exists(chrome):
            check_chrome(chrome)
        else:
            print(f"[check_obs] WARNING: {chrome} not found, skipping", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
