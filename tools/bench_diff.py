#!/usr/bin/env python
"""Bench-regression gate: diff a fresh BENCH_*.json against a baseline.

Usage:
  bench_diff.py BASE.json FRESH.json [--update-out DIR]
  bench_diff.py --self-test

Walks both records in parallel and compares every leaf under per-metric
noise rules, keyed on the dotted path of the leaf (first matching rule
wins):

  config.workers        ignored (machine-dependent core count)
  config.* / smoke      exact match — a config drift is a different
                        benchmark, not a regression
  *_ns *_s *_us wall_s  noisy timing/throughput metrics: fresh may
  throughput_rps mean   differ from base by up to 10x (relative) or
  max p50 p95 p99 ...   1e6 absolute, whichever is larger — CI boxes
  *_flops us_per*       are shared and slow, the gate catches order-of-
                        magnitude regressions, not jitter
  batches merges count  scheduling-dependent tallies: same 10x relative
  *_evictions ...       band, but a floor of 16 instead of 1e6 (these
                        live at count scale, not nanosecond scale)
  other numbers         near-exact: |fresh - base| <= max(8, 1.0*|base|)
                        (counts may drift slightly under batching races)
  strings / booleans    exact

Tolerance never blocks *improvement* reporting — both directions beyond
the threshold fail, because an impossible 10x "speedup" on an unchanged
workload usually means the benchmark broke.

A baseline leaf of null is "unseeded": the committed skeleton doesn't
pin that machine-dependent value yet. Unseeded leaves warn (never
fail), and --update-out DIR writes the fresh record next to the
skeleton's name for a human to review and commit as the new baseline.
Keys present in the base but missing from the fresh record fail; new
keys in the fresh record warn (additions need a baseline refresh, not a
red build).

--self-test runs a hermetic in-memory check of the rule table and exits.
"""

import json
import os
import re
import sys

# (pattern, kind) — first match on the dotted path wins.
RULES = [
    (re.compile(r"(^|\.)config\.workers$"), "ignore"),
    (re.compile(r"(^|\.)tile\."), "ignore"),  # autotuned per machine
    (re.compile(r"(^|\.)workers$"), "ignore"),
    # Telemetry sections are structure-checked by check_obs.py; their
    # hundreds of noisy leaves are not regression-gate material. The
    # trailing dot keeps scalar config fields (config.tenants) gated
    # while skipping the per-tenant heavy-hitter subtree ("tenants.").
    (re.compile(r"(^|\.)obs\."), "ignore"),
    (re.compile(r"(^|\.)slo\."), "ignore"),
    (re.compile(r"(^|\.)tenants\."), "ignore"),
    # Adaptive measurement-loop internals, not results.
    (re.compile(r"(^|\.)(iters|elements)$"), "ignore"),
    (re.compile(r"(^|\.)config\."), "exact"),
    (re.compile(r"(^|\.)smoke$"), "exact"),
    (re.compile(r"(^|\.)seed$"), "exact"),
    # Sweep-grid dimensions inside configs[i] entries (kernel/conv/store
    # benches): shape drift is a different benchmark.
    (
        re.compile(
            r"(^|\.)(d|b|m|batch|c|k|hw|groups|kind|tenants|hit_ratio|layers|block|shards)$"
        ),
        "exact",
    ),
    (
        re.compile(
            r"(_ns|_s|_us|_rps|_flops|mean|max|p50|p95|p99|p999|us_per\w*|burn_rate|observed)$"
        ),
        "noisy",
    ),
    # Scheduling-dependent tallies: batch formation, cache residency and
    # the cached/cold path split all move with worker timing. Same 10x
    # relative band as timings but a small absolute floor — these live
    # at count scale, not nanosecond scale.
    (
        re.compile(
            r"(^|\.)(batches|merges|count|traces_recorded|spill_loads|spill_hits"
            r"|spill_evictions|cache_evictions|cache_hit_rate)$"
        ),
        "tally",
    ),
    (re.compile(r"speedup"), "tally"),
    (re.compile(r""), "count"),
]
TOLERANCES = {
    "noisy": (10.0, 1e6),
    "tally": (10.0, 16),
    "count": (1.0, 8),
}


def classify(path):
    for pat, kind in RULES:
        if pat.search(path):
            return kind
    return "count"


def leaves(node, prefix=""):
    """Yield (dotted_path, leaf_value) pairs, recursing into dicts/lists."""
    if isinstance(node, dict):
        for k, v in node.items():
            yield from leaves(v, f"{prefix}.{k}" if prefix else k)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from leaves(v, f"{prefix}[{i}]")
    else:
        yield prefix, node


def compare(base, fresh):
    """Returns (failures, warnings) as lists of messages."""
    failures, warnings = [], []
    base_leaves = dict(leaves(base))
    fresh_leaves = dict(leaves(fresh))
    for path, bval in sorted(base_leaves.items()):
        kind = classify(path)
        if kind == "ignore":
            continue
        if path not in fresh_leaves:
            failures.append(f"{path}: present in base, missing from fresh record")
            continue
        fval = fresh_leaves[path]
        if bval is None:
            warnings.append(f"{path}: unseeded in baseline (fresh = {fval!r})")
            continue
        if isinstance(bval, bool) or isinstance(bval, str):
            if fval != bval:
                failures.append(f"{path}: {bval!r} -> {fval!r} (exact field changed)")
            continue
        if not isinstance(fval, (int, float)) or isinstance(fval, bool):
            failures.append(f"{path}: type changed ({bval!r} -> {fval!r})")
            continue
        if kind == "exact":
            if fval != bval:
                failures.append(f"{path}: {bval} -> {fval} (config/exact field changed)")
            continue
        rel, absolute = TOLERANCES[kind]
        tol = max(absolute, rel * abs(bval))
        if abs(fval - bval) > tol:
            failures.append(
                f"{path}: {bval} -> {fval} exceeds tolerance {tol:g} ({kind} metric)"
            )
    for path in sorted(set(fresh_leaves) - set(base_leaves)):
        if classify(path) != "ignore":
            warnings.append(f"{path}: new in fresh record (baseline refresh needed)")
    return failures, warnings


def self_test():
    base = {
        "config": {"requests": 192, "workers": 8, "smoke": True},
        "wall_s": 1.0,
        "p99_latency_ns": 4e6,
        "batches": 30,
        "registrations": 12,
        "cache_evictions": 2,
        "unseeded_metric": None,
        "tag": "zipf",
    }
    ok = dict(base, wall_s=3.0, p99_latency_ns=3.5e7, batches=33, unseeded_metric=17)
    ok["config"] = dict(base["config"], workers=2)
    f, w = compare(base, ok)
    assert not f, f"clean rerun flagged: {f}"
    assert any("unseeded" in m for m in w), w

    bad_cfg = dict(ok, config=dict(base["config"], requests=4096))
    f, _ = compare(base, bad_cfg)
    assert any("config.requests" in m for m in f), f

    bad_time = dict(ok, p99_latency_ns=4e6 * 11 + 2e6)
    f, _ = compare(base, bad_time)
    assert any("p99_latency_ns" in m for m in f), f

    bad_count = dict(ok, registrations=300)
    f, _ = compare(base, bad_count)
    assert any("registrations" in m for m in f), f

    bad_batches = dict(ok, batches=30 * 10 + 100)  # beyond even the 10x noisy band
    f, _ = compare(base, bad_batches)
    assert any("batches" in m for m in f), f

    missing = {k: v for k, v in ok.items() if k != "batches"}
    f, _ = compare(base, missing)
    assert any("missing from fresh" in m for m in f), f

    extra = dict(ok, brand_new=1)
    f, w = compare(base, extra)
    assert not f and any("brand_new" in m for m in w), (f, w)

    bad_str = dict(ok, tag="uniform")
    f, _ = compare(base, bad_str)
    assert any("tag" in m for m in f), f

    # The per-tenant heavy-hitter section is run-dependent (latency sums,
    # sketch order) — the whole subtree is ignored, but a scalar
    # config.tenants drift must still gate.
    hitters = lambda total: {  # noqa: E731 — shape of TenantSummary::to_json
        "k": 32,
        "dims": {"requests": {"total": total, "entries": [{"tenant": 0, "count": total, "err": 0}]}},
    }
    tbase = {"config": {"tenants": 24}, "tenants": hitters(192)}
    tfresh = {"config": {"tenants": 24}, "tenants": hitters(7)}
    f, w = compare(tbase, tfresh)
    assert not f and not w, (f, w)
    f, _ = compare(tbase, dict(tfresh, config={"tenants": 48}))
    assert any("config.tenants" in m for m in f), f

    nested = {"configs": [{"d": 64, "gemm_p50_us": 100.0}]}
    f, _ = compare(nested, {"configs": [{"d": 64, "gemm_p50_us": 900.0}]})
    assert not f, f
    f, _ = compare(nested, {"configs": [{"d": 128, "gemm_p50_us": 100.0}]})
    assert f, "config drift inside an array must fail"

    # Store-bench sweep: the shard count is a grid dimension (exact),
    # the registration-storm rate is noisy, and the maint section's
    # request-path attribution leaves are near-exact invariants.
    sbase = {
        "configs": [
            {
                "shards": 4,
                "reg_storm_rps": 1000.0,
                "maint": {"spill_writes": None, "request_path_spill_writes": 0},
            }
        ]
    }
    sfresh = {
        "configs": [
            {
                "shards": 4,
                "reg_storm_rps": 8000.0,
                "maint": {"spill_writes": 9, "request_path_spill_writes": 0},
            }
        ]
    }
    f, w = compare(sbase, sfresh)
    assert not f, f
    assert any("spill_writes" in m and "unseeded" in m for m in w), w
    bad_shards = json.loads(json.dumps(sfresh))
    bad_shards["configs"][0]["shards"] = 16
    f, _ = compare(sbase, bad_shards)
    assert any("shards" in m for m in f), "shard-count drift must fail"
    on_path = json.loads(json.dumps(sfresh))
    on_path["configs"][0]["maint"]["request_path_spill_writes"] = 40
    f, _ = compare(sbase, on_path)
    assert any("request_path_spill_writes" in m for m in f), f

    print("[bench_diff] self-test PASS")
    return 0


def main(argv):
    if "--self-test" in argv:
        return self_test()
    paths = [a for a in argv[1:] if not a.startswith("--")]
    if len(paths) != 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    base_path, fresh_path = paths
    update_out = None
    if "--update-out" in argv:
        update_out = argv[argv.index("--update-out") + 1]
    if not os.path.exists(base_path):
        print(f"[bench_diff] WARNING: baseline {base_path} missing, skipping", file=sys.stderr)
        return 0
    with open(base_path) as f:
        base = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)
    failures, warnings = compare(base, fresh)
    for msg in warnings:
        print(f"[bench_diff] WARNING {msg}", file=sys.stderr)
    for msg in failures:
        print(f"[bench_diff] FAIL {msg}", file=sys.stderr)
    if update_out and not failures:
        os.makedirs(update_out, exist_ok=True)
        out = os.path.join(update_out, os.path.basename(base_path))
        with open(out, "w") as f:
            json.dump(fresh, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"[bench_diff] refreshed baseline candidate written to {out}")
    n = len(dict(leaves(base)))
    if failures:
        print(f"[bench_diff] {base_path} vs {fresh_path}: {len(failures)} regression(s)")
        return 1
    print(
        f"[bench_diff] {base_path} vs {fresh_path}: OK "
        f"({n} baseline leaves, {len(warnings)} warnings)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
