"""Make `import compile...` work when pytest runs from the repo root
(tests live in python/tests/, the package in python/compile/)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
