"""L2 gs.py invariants: Newton–Schulz Cayley vs the exact solve oracle,
orthogonality of every parametrization, and AOT-compatibility guards."""

import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import compile.gs as G
from compile.kernels import ref


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([1, 4]), st.sampled_from([2, 8, 16]),
       st.sampled_from([0.1, 1.0, 3.0]), st.integers(0, 2 ** 31 - 1))
def test_newton_cayley_matches_solve(r, b, std, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((r, b, b)).astype(np.float32) * std)
    got = G.cayley(a)
    want = ref.cayley_ref(a)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_newton_cayley_extreme_magnitude():
    # Even far outside the training regime the clamped Newton iteration
    # must stay orthogonal (convergence is what the scaling guarantees).
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((2, 8, 8)).astype(np.float32) * 8.0)
    q = G.cayley(a, iters=30)
    eye = jnp.eye(8)
    err = jnp.abs(jnp.swapaxes(q, -1, -2) @ q - eye).max()
    assert float(err) < 1e-3, float(err)


def test_newton_cayley_is_differentiable():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((2, 4, 4)).astype(np.float32))

    def f(x):
        return (G.cayley(x) ** 2).sum()

    g = jax.grad(f)(a)
    assert np.isfinite(np.asarray(g)).all()
    # grad of sum of squares of an orthogonal matrix is ~0 only at
    # stationary points; just require a sane magnitude.
    assert float(jnp.abs(g).max()) < 100.0


@pytest.mark.parametrize("apply_fn", ["gsoft", "boft", "oft"])
def test_parametrizations_are_orthogonal_maps(apply_fn):
    """Applying the parametrization to I materializes Q; Q^T Q = I."""
    rng = np.random.default_rng(2)
    d, b = 32, 4
    r = d // b
    eye = jnp.eye(d, dtype=jnp.float32)
    if apply_fn == "gsoft":
        lp = jnp.asarray(rng.standard_normal((r, b, b)).astype(np.float32))
        rp = jnp.asarray(rng.standard_normal((r, b, b)).astype(np.float32))
        q = G.gsoft_apply(lp, rp, eye)
    elif apply_fn == "oft":
        kp = jnp.asarray(rng.standard_normal((r, b, b)).astype(np.float32))
        q = G.oft_apply(kp, eye)
    else:
        fs = [jnp.asarray(rng.standard_normal((r, b, b)).astype(np.float32))
              for _ in range(3)]
        q = G.boft_apply(fs, eye, b)
    q = np.asarray(q)
    np.testing.assert_allclose(q.T @ q, np.eye(d), atol=2e-4)


def test_double_gsoft_matches_dense_two_sided():
    rng = np.random.default_rng(3)
    dr, dc, b = 16, 8, 4
    lu = jnp.asarray(rng.standard_normal((dr // b, b, b)).astype(np.float32))
    ru = jnp.asarray(rng.standard_normal((dr // b, b, b)).astype(np.float32))
    lv = jnp.asarray(rng.standard_normal((dc // b, b, b)).astype(np.float32))
    rv = jnp.asarray(rng.standard_normal((dc // b, b, b)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((dr, dc)).astype(np.float32))
    got = G.double_gsoft_apply(lu, ru, lv, rv, w)
    qu = ref.gs_q_dense_ref(lu, ru)
    qv = ref.gs_q_dense_ref(lv, rv)
    want = qu @ w @ qv
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
                    reason="artifacts not built")
def test_artifacts_contain_no_custom_calls():
    """Regression guard: the runtime's XLA (xla_extension 0.5.1) rejects
    typed-FFI custom-calls (e.g. jnp.linalg.solve's LAPACK lowering); no
    artifact may contain any custom-call."""
    offenders = []
    for path in glob.glob(os.path.join(ARTIFACTS, "*.hlo.txt")):
        with open(path) as f:
            if "custom_call_target" in f.read():
                offenders.append(os.path.basename(path))
    assert not offenders, f"custom-calls in: {offenders}"


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
                    reason="artifacts not built")
def test_artifact_metadata_is_complete():
    import json
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    assert len(manifest["artifacts"]) >= 60
    for name in manifest["artifacts"]:
        with open(os.path.join(ARTIFACTS, f"{name}.meta.json")) as f:
            meta = json.load(f)
        assert os.path.exists(os.path.join(ARTIFACTS, meta["hlo"])), name
        assert meta["inputs"] and meta["outputs"], name
        for init_file in meta.get("inits", {}).values():
            assert os.path.exists(os.path.join(ARTIFACTS, init_file)), init_file
