"""L2 adapter invariants: identity at init, orthogonality, parameter
parity with the paper's accounting."""

import jax.numpy as jnp
import numpy as np
import pytest

import compile.gs as G
from compile.adapters import AdapterConfig, adapt_weight, adapter_entries, adapter_init
from compile.flat import ParamSpec
from compile.kernels import ref

METHODS = ["lora", "oft", "boft", "gsoft", "double_gsoft"]


def build_params(cfg, name, din, dout, seed, random=False):
    rng = np.random.default_rng(seed)
    params = adapter_init(cfg, name, din, dout, rng)
    if random:
        for k in params:
            params[k] = rng.standard_normal(params[k].shape).astype(np.float32)
    return {k: jnp.asarray(v) for k, v in params.items()}


@pytest.mark.parametrize("method", METHODS)
def test_identity_at_init(method):
    cfg = AdapterConfig(method, block=8, rank=4, boft_m=2)
    din, dout = 32, 16
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((din, dout)).astype(np.float32))
    params = build_params(cfg, "l", din, dout, 1)
    w2 = adapt_weight(cfg, "l", w, params)
    np.testing.assert_allclose(w2, w, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("method", ["oft", "boft", "gsoft"])
def test_orthogonal_methods_preserve_spectrum(method):
    cfg = AdapterConfig(method, block=4, boft_m=3)
    din, dout = 16, 8
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.standard_normal((din, dout)).astype(np.float32))
    params = build_params(cfg, "l", din, dout, 3, random=True)
    w2 = adapt_weight(cfg, "l", w, params)
    s1 = np.linalg.svd(np.asarray(w), compute_uv=False)
    s2 = np.linalg.svd(np.asarray(w2), compute_uv=False)
    np.testing.assert_allclose(s1, s2, rtol=1e-3, atol=1e-4)


def test_double_gsoft_preserves_spectrum_and_acts_both_sides():
    cfg = AdapterConfig("double_gsoft", block=4)
    din, dout = 16, 8
    rng = np.random.default_rng(4)
    w = jnp.asarray(rng.standard_normal((din, dout)).astype(np.float32))
    params = build_params(cfg, "l", din, dout, 5, random=True)
    w2 = adapt_weight(cfg, "l", w, params)
    s1 = np.linalg.svd(np.asarray(w), compute_uv=False)
    s2 = np.linalg.svd(np.asarray(w2), compute_uv=False)
    np.testing.assert_allclose(s1, s2, rtol=1e-3, atol=1e-4)
    # right factor differs from identity: W^T W rotated
    assert not np.allclose(np.asarray(w2), np.asarray(w), atol=1e-3)


def test_gsoft_q_is_orthogonal_and_dense():
    """The materialized Q must be orthogonal and fully dense (Thm 2, m=2
    with b >= r)."""
    rng = np.random.default_rng(6)
    r, b = 4, 8
    lp = jnp.asarray(rng.standard_normal((r, b, b)).astype(np.float32))
    rp = jnp.asarray(rng.standard_normal((r, b, b)).astype(np.float32))
    q = np.asarray(ref.gs_q_dense_ref(lp, rp))
    d = r * b
    np.testing.assert_allclose(q.T @ q, np.eye(d), atol=1e-4)
    assert (np.abs(q) > 1e-9).all(), "Q must be dense"


def test_boft_orthogonal_and_depth_limit():
    rng = np.random.default_rng(7)
    cfg = AdapterConfig("boft", block=4, boft_m=3)
    din = 32  # r = 8 blocks
    w = jnp.eye(din, dtype=jnp.float32)
    params = build_params(cfg, "l", din, din, 8, random=True)
    q = np.asarray(adapt_weight(cfg, "l", w, params))
    np.testing.assert_allclose(q.T @ q, np.eye(din), atol=1e-4)
    # m too deep must be rejected: stride 2^{m-2} exceeds r/2.
    with pytest.raises(AssertionError):
        adapter_entries(AdapterConfig("boft", block=4, boft_m=5), "l", 32, 32)


def test_param_counts_match_paper_accounting():
    d = 128
    counts = {}
    for method, kwargs in [
        ("lora", dict(rank=8)),
        ("oft", dict(block=16)),
        ("boft", dict(block=8, boft_m=2)),
        ("gsoft", dict(block=8)),
        ("double_gsoft", dict(block=8)),
    ]:
        cfg = AdapterConfig(method, **kwargs)
        spec = ParamSpec(adapter_entries(cfg, "l", d, d))
        counts[method] = spec.size
    assert counts["lora"] == 2 * d * 8
    assert counts["oft"] == d * 16
    assert counts["boft"] == 2 * d * 8        # m·d·b
    assert counts["gsoft"] == 2 * d * 8       # 2·r·b² = 2·d·b
    assert counts["gsoft"] == counts["lora"] == counts["boft"]
    assert counts["double_gsoft"] == 2 * counts["gsoft"]


def test_butterfly_gather_is_permutation_and_pairs_blocks():
    for r, b, stride in [(4, 4, 1), (8, 2, 2), (8, 8, 4)]:
        idx = G.butterfly_gather(r, b, stride)
        assert sorted(idx.tolist()) == list(range(r * b))
        # each gathered block draws from exactly two source blocks
        for p in range(r):
            src_blocks = {int(s) // b for s in idx[p * b:(p + 1) * b]}
            assert src_blocks == {p, p ^ stride}
