"""L2 model graphs: shapes, loss decrease under the train step, flat
pack/unpack round-trips."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import diffusion, lipconvnet, transformer
from compile.adapters import AdapterConfig
from compile.flat import ParamSpec


def tiny_cls_cfg():
    return transformer.TransformerConfig(
        vocab=32, d=16, layers=1, heads=2, ff=32, seq=8, classes=3, batch=4)


def test_flat_pack_unpack_round_trip():
    spec = ParamSpec([("a", (2, 3)), ("b", (4,)), ("c", (1, 1, 5))])
    rng = np.random.default_rng(0)
    params = {n: jnp.asarray(rng.standard_normal(s).astype(np.float32))
              for n, s in spec.entries}
    flat = spec.pack(params)
    assert flat.shape == (spec.size,)
    back = spec.unpack(flat)
    for n, _ in spec.entries:
        np.testing.assert_array_equal(back[n], params[n])


@pytest.mark.parametrize("method", ["ft", "lora", "gsoft", "boft"])
def test_cls_train_step_reduces_loss(method):
    cfg = tiny_cls_cfg()
    acfg = AdapterConfig(method, block=4, rank=2, boft_m=2)
    train, evalf, n_train, n_frozen = transformer.make_steps(cfg, acfg)
    base = jnp.asarray(cfg.init_base(1))
    if method == "ft":
        trainable, frozen = base, jnp.zeros((1,))
    else:
        trainable, frozen = jnp.asarray(cfg.init_adapters(acfg, 2)), base
    assert trainable.shape == (n_train,) and frozen.shape == (n_frozen,)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)), dtype=jnp.int32)
    # learnable rule: label = first token mod classes
    y = jnp.asarray(np.asarray(x[:, 0]) % cfg.classes, dtype=jnp.int32)
    m = jnp.zeros_like(trainable)
    v = jnp.zeros_like(trainable)
    first_loss = None
    loss = None
    for step in range(30):
        trainable, m, v, loss = train(trainable, m, v, jnp.float32(step),
                                      jnp.float32(5e-3), frozen, x, y)
        if first_loss is None:
            first_loss = float(loss)
    assert float(loss) < first_loss, (first_loss, float(loss))
    eloss, correct, preds = evalf(trainable, frozen, x, y)
    assert preds.shape == (cfg.batch,)
    assert 0 <= float(correct) <= cfg.batch


def test_cls_eval_matches_forward():
    cfg = tiny_cls_cfg()
    acfg = AdapterConfig("gsoft", block=4)
    _, evalf, n_train, n_frozen = transformer.make_steps(cfg, acfg)
    base = jnp.asarray(cfg.init_base(4))
    adapter = jnp.asarray(cfg.init_adapters(acfg, 5))
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)), dtype=jnp.int32)
    y = jnp.zeros((cfg.batch,), dtype=jnp.int32)
    loss, correct, _ = evalf(adapter, base, x, y)
    assert np.isfinite(float(loss))
    # identity-initialized adapter == ft forward on the same weights
    ft_train, ft_eval, _, _ = transformer.make_steps(cfg, AdapterConfig("ft"))
    loss_ft, _, _ = ft_eval(base, jnp.zeros((1,)), x, y)
    np.testing.assert_allclose(float(loss), float(loss_ft), rtol=1e-5)


def test_dn_train_step_reduces_loss():
    cfg = diffusion.DenoiserConfig(img=4, hidden=32, conds=4, tsteps=10, batch=8)
    acfg = AdapterConfig("gsoft", block=4)
    train, predict, n_train, n_frozen = diffusion.make_steps(cfg, acfg)
    frozen = jnp.asarray(cfg.init_base(7))
    trainable = jnp.asarray(cfg.init_adapters(acfg, 8))
    rng = np.random.default_rng(9)
    x0 = jnp.asarray(rng.standard_normal((cfg.batch, cfg.dim)).astype(np.float32))
    cond = jnp.asarray(rng.integers(0, cfg.conds, cfg.batch), dtype=jnp.int32)
    t = jnp.asarray(rng.integers(0, cfg.tsteps, cfg.batch), dtype=jnp.int32)
    eps = jnp.asarray(rng.standard_normal((cfg.batch, cfg.dim)).astype(np.float32))
    m = jnp.zeros_like(trainable)
    v = jnp.zeros_like(trainable)
    losses = []
    for step in range(25):
        trainable, m, v, loss = train(trainable, m, v, jnp.float32(step),
                                      jnp.float32(1e-2), frozen, x0, cond, t, eps)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    out = predict(trainable, frozen, x0, t, cond)
    assert out.shape == (cfg.batch, cfg.dim)


def test_alphas_bar_monotone():
    cfg = diffusion.DenoiserConfig()
    ab = cfg.alphas_bar()
    assert (np.diff(ab) < 0).all()
    assert 0 < ab[-1] < ab[0] < 1


@pytest.mark.parametrize("variant", [
    lipconvnet.LipVariant(groups_a=1, activation="maxmin"),
    lipconvnet.LipVariant(groups_a=4, groups_b=0, activation="maxmin_permuted", paired=True),
    lipconvnet.LipVariant(groups_a=4, groups_b=2, activation="maxmin_permuted", paired=False),
])
def test_lip_forward_shapes_and_training(variant):
    cfg = lipconvnet.LipConfig(img=8, in_ch=4, classes=4, channels=(8, 8), batch=4)
    train, evalf, n_train = lipconvnet.make_steps(cfg, variant)
    trainable = jnp.asarray(cfg.init(variant, 10))
    assert trainable.shape == (n_train,)
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((cfg.batch, 8, 8, 4)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 4, cfg.batch), dtype=jnp.int32)
    m = jnp.zeros_like(trainable)
    v = jnp.zeros_like(trainable)
    losses = []
    for step in range(15):
        trainable, m, v, loss = train(trainable, m, v, jnp.float32(step),
                                      jnp.float32(5e-3), jnp.zeros((1,)), x, y)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    loss, correct, robust = evalf(trainable, jnp.zeros((1,)), x, y)
    assert 0 <= float(robust) <= float(correct) <= cfg.batch


def test_lip_network_is_1_lipschitz_empirically():
    """Pairs of inputs: |f(x) - f(x')|_2 ≤ |x - x'|_2 per logit vector."""
    cfg = lipconvnet.LipConfig(img=8, in_ch=4, classes=4, channels=(8, 8), batch=2)
    v = lipconvnet.LipVariant(groups_a=4, groups_b=1,
                              activation="maxmin_permuted", paired=True)
    spec = cfg.spec(v)
    rng = np.random.default_rng(12)
    flat = jnp.asarray(rng.standard_normal(spec.size).astype(np.float32) * 0.2)
    params = spec.unpack(flat)
    for _ in range(5):
        x1 = jnp.asarray(rng.standard_normal((1, 8, 8, 4)).astype(np.float32))
        x2 = x1 + jnp.asarray(0.05 * rng.standard_normal((1, 8, 8, 4)).astype(np.float32))
        y1 = lipconvnet.forward(cfg, v, params, x1)
        y2 = lipconvnet.forward(cfg, v, params, x2)
        dy = float(jnp.linalg.norm(y1 - y2))
        dx = float(jnp.linalg.norm(x1 - x2))
        assert dy <= dx * 1.01, (dy, dx)


def test_conv_exp_jacobian_orthogonality():
    """The conv-exponential layer preserves norms (orthogonal Jacobian)."""
    rng = np.random.default_rng(13)
    kernel = jnp.asarray(rng.standard_normal((3, 3, 2, 8)).astype(np.float32) * 0.1)
    skew = lipconvnet._skew_grouped(kernel, 4)
    x = jnp.asarray(rng.standard_normal((1, 6, 6, 8)).astype(np.float32))
    y = lipconvnet.conv_exp(x, skew, 4)
    # zero-padding breaks exact norm preservation at the boundary only;
    # allow a small tolerance.
    nx, ny = float(jnp.linalg.norm(x)), float(jnp.linalg.norm(y))
    assert abs(nx - ny) / nx < 0.05, (nx, ny)


def test_maxmin_variants_preserve_norm_and_sets():
    rng = np.random.default_rng(14)
    x = jnp.asarray(rng.standard_normal((2, 3, 3, 8)).astype(np.float32))
    for permuted in (False, True):
        y = lipconvnet.maxmin(x, permuted)
        np.testing.assert_allclose(float(jnp.linalg.norm(x)),
                                   float(jnp.linalg.norm(y)), rtol=1e-6)
        # multiset of values preserved
        np.testing.assert_allclose(np.sort(np.asarray(x).ravel()),
                                   np.sort(np.asarray(y).ravel()), rtol=1e-6)


def test_space_to_depth_is_isometric_and_invertible():
    rng = np.random.default_rng(15)
    x = jnp.asarray(rng.standard_normal((2, 4, 4, 3)).astype(np.float32))
    y = lipconvnet.space_to_depth(x)
    assert y.shape == (2, 2, 2, 12)
    np.testing.assert_allclose(float(jnp.linalg.norm(x)), float(jnp.linalg.norm(y)),
                               rtol=1e-6)
