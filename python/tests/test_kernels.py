"""L1 correctness: Pallas kernels vs the pure-jnp oracle, swept over
shapes and dtypes with hypothesis — the CORE correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gs_kernels as K
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


# Shapes are drawn from small grids (not full ranges): every distinct
# shape forces an interpret-mode recompile, so grids keep the sweep broad
# in structure while hitting the jit cache.
shapes = st.tuples(
    st.sampled_from([1, 2, 4, 8]),    # r
    st.sampled_from([1, 3, 8, 16]),   # b
    st.sampled_from([1, 4, 8]),       # T
)


@settings(max_examples=25, deadline=None)
@given(shapes, st.integers(0, 2 ** 31 - 1))
def test_block_diag_matmul_matches_ref(shape, seed):
    r, b, t = shape
    rng = np.random.default_rng(seed)
    blocks = rand(rng, r, b, b)
    x = rand(rng, r * b, t)
    got = K.block_diag_matmul(blocks, x)
    want = ref.block_diag_matmul_ref(blocks, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    st.sampled_from([1, 2, 5]), st.sampled_from([1, 3, 6]),
    st.sampled_from([1, 4]), st.sampled_from([2, 6]),
    st.integers(0, 2 ** 31 - 1),
)
def test_bmm_rectangular(r, m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, r, m, k)
    b = rand(rng, r, k, n)
    got = K.bmm(a, b)
    want = jnp.einsum("rmk,rkn->rmn", a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(shapes, st.integers(0, 2 ** 31 - 1))
def test_gs_apply_matches_dense_ref(shape, seed):
    r, b, t = shape
    rng = np.random.default_rng(seed)
    lp = rand(rng, r, b, b)
    rp = rand(rng, r, b, b)
    x = rand(rng, r * b, t)
    got = K.gs_apply(ref.cayley_ref(lp), ref.cayley_ref(rp), x)
    want = ref.gs_apply_ref(lp, rp, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(shapes, st.integers(0, 2 ** 31 - 1))
def test_gs_apply_transpose_is_inverse(shape, seed):
    """Q is orthogonal, so Q^T (Q x) = x — checks both kernels jointly."""
    r, b, t = shape
    rng = np.random.default_rng(seed)
    lp = rand(rng, r, b, b)
    rp = rand(rng, r, b, b)
    x = rand(rng, r * b, t)
    lq, rq = ref.cayley_ref(lp), ref.cayley_ref(rp)
    y = K.gs_apply(lq, rq, x)
    back = K.gs_apply_transpose(lq, rq, y)
    np.testing.assert_allclose(back, x, rtol=1e-4, atol=1e-4)


def test_gs_apply_bf16():
    """dtype sweep: the kernels must lower in bf16 too (TPU path)."""
    rng = np.random.default_rng(0)
    r, b, t = 4, 8, 8
    lp = jnp.asarray(rng.standard_normal((r, b, b)), dtype=jnp.bfloat16)
    x = jnp.asarray(rng.standard_normal((r * b, t)), dtype=jnp.bfloat16)
    y = K.block_diag_matmul(lp, x)
    want = ref.block_diag_matmul_ref(lp.astype(jnp.float32), x.astype(jnp.float32))
    np.testing.assert_allclose(y.astype(jnp.float32), want, rtol=0.1, atol=0.1)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([1, 4]), st.sampled_from([2, 8]), st.sampled_from([1, 5]),
       st.integers(0, 2 ** 31 - 1))
def test_block_diag_matmul_grad_matches_jnp(r, b, t, seed):
    """custom_vjp vs autodiff of the dense oracle."""
    rng = np.random.default_rng(seed)
    blocks = rand(rng, r, b, b)
    x = rand(rng, r * b, t)

    def f_kernel(bl, xx):
        return (K.block_diag_matmul(bl, xx) ** 2).sum()

    def f_ref(bl, xx):
        return (ref.block_diag_matmul_ref(bl, xx) ** 2).sum()

    g1 = jax.grad(f_kernel, argnums=(0, 1))(blocks, x)
    g2 = jax.grad(f_ref, argnums=(0, 1))(blocks, x)
    for a, b2 in zip(g1, g2):
        np.testing.assert_allclose(a, b2, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([1, 4]), st.sampled_from([2, 8]), st.sampled_from([1, 4]),
       st.integers(0, 2 ** 31 - 1))
def test_gs_apply_grad_matches_dense(r, b, t, seed):
    rng = np.random.default_rng(seed)
    lp = rand(rng, r, b, b)
    rp = rand(rng, r, b, b)
    x = rand(rng, r * b, t)

    def f_kernel(l, rr):
        return (K.gs_apply(ref.cayley_ref(l), ref.cayley_ref(rr), x) ** 3).sum()

    def f_ref(l, rr):
        return (ref.gs_apply_ref(l, rr, x) ** 3).sum()

    g1 = jax.grad(f_kernel, argnums=(0, 1))(lp, rp)
    g2 = jax.grad(f_ref, argnums=(0, 1))(lp, rp)
    for a, b2 in zip(g1, g2):
        np.testing.assert_allclose(a, b2, rtol=2e-3, atol=2e-3)


def test_vmem_footprint_model():
    m = K.vmem_footprint_bytes(r=16, b=8, t=128)
    assert m["grid_steps"] == 16
    assert m["per_step_bytes"] == 4 * (64 + 2 * 8 * 128)
    assert m["flops"] == 2 * 16 * 8 * 8 * 128
    assert 0.0 < m["mxu_fill_fraction"] <= 1.0
