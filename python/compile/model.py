"""Artifact registry — every AOT compilation unit of the system.

Each artifact is a pure JAX function plus example (shape) arguments and a
JSON metadata record (input/output names+shapes, experiment constants,
initial flat buffers). `aot.py` lowers each to HLO text under
`artifacts/`, which the Rust runtime loads and executes.
"""

from typing import Callable, Dict, List

import jax.numpy as jnp
import numpy as np

from . import diffusion, lipconvnet, transformer
from .adapters import AdapterConfig
from . import gs
from .kernels import gs_kernels as K


class Artifact:
    def __init__(self, name: str, fn: Callable, args: List, extra: dict | None = None,
                 inits: Dict[str, np.ndarray] | None = None):
        self.name = name
        self.fn = fn
        self.args = args  # example arrays defining shapes/dtypes
        self.extra = extra or {}
        self.inits = inits or {}  # name -> f32 array, written as .f32 files


def f32(*shape):
    return jnp.zeros(shape, dtype=jnp.float32)


def i32(*shape):
    return jnp.zeros(shape, dtype=jnp.int32)


# ---- experiment configurations (single source of truth, mirrored into
# ---- artifact metadata for the Rust harness) --------------------------------

CLS_CFG = transformer.TransformerConfig(
    vocab=512, d=128, layers=2, heads=4, ff=256, seq=32, classes=4, batch=16)

CLS_BIG_CFG = transformer.TransformerConfig(
    vocab=2048, d=256, layers=4, heads=8, ff=512, seq=64, classes=4, batch=16)

# Table-1 method roster (paper hyperparameters, scaled block sizes).
CLS_METHODS: Dict[str, AdapterConfig] = {
    "ft": AdapterConfig("ft"),
    "lora": AdapterConfig("lora", rank=8),
    "oft": AdapterConfig("oft", block=16),
    "boft": AdapterConfig("boft", block=8, boft_m=2),
    "gsoft": AdapterConfig("gsoft", block=8),
    "double_gsoft": AdapterConfig("double_gsoft", block=8),
}

DN_CFG = diffusion.DenoiserConfig(img=8, hidden=128, conds=10, tsteps=50, batch=32)

# Table-2 roster: several parameter budgets per family.
DN_METHODS: Dict[str, AdapterConfig] = {
    "ft": AdapterConfig("ft"),
    "lora4": AdapterConfig("lora", rank=4),
    "lora32": AdapterConfig("lora", rank=32),
    "boft8m4": AdapterConfig("boft", block=8, boft_m=4),
    "gsoft8": AdapterConfig("gsoft", block=8),
    "gsoft16": AdapterConfig("gsoft", block=16),
    "dgsoft8": AdapterConfig("double_gsoft", block=8),
}

LIP_CFG = lipconvnet.LipConfig(img=16, in_ch=4, classes=8,
                               channels=(32, 64, 128, 128), batch=32)


def lip_variants() -> Dict[str, lipconvnet.LipVariant]:
    """Table 4's 17 rows: SOC + {(4,-),(4,1),(4,2),(4,4)} × {act} × {perm}."""
    out = {"soc": lipconvnet.LipVariant(groups_a=1, activation="maxmin")}
    for gb in (0, 1, 2, 4):
        for act in ("maxmin", "maxmin_permuted"):
            for paired in (True, False):
                v = lipconvnet.LipVariant(groups_a=4, groups_b=gb,
                                          activation=act, paired=paired)
                out[v.key()] = v
    return out


# ---- artifact construction --------------------------------------------------

def quickstart_artifacts() -> List[Artifact]:
    r, b, t = 8, 8, 16
    d = r * b

    def gs_apply_fn(lp, rp, x):
        return (K.gs_apply(gs.cayley(lp), gs.cayley(rp), x),)

    return [Artifact(
        "quickstart_gs_apply",
        gs_apply_fn,
        [f32(r, b, b), f32(r, b, b), f32(d, t)],
        extra={"family": "quickstart", "r": r, "b": b, "d": d, "t": t,
               "inputs": ["l_params", "r_params", "x"],
               "outputs": ["y"]},
    )]


def _cls_family(tag: str, cfg: transformer.TransformerConfig,
                methods: Dict[str, AdapterConfig], seed: int) -> List[Artifact]:
    arts: List[Artifact] = []
    base_init = cfg.init_base(seed)
    for mname, acfg in methods.items():
        train, evalf, n_train, n_frozen = transformer.make_steps(cfg, acfg)
        extra = {
            "family": "cls", "tag": tag, "method": mname,
            "n_train": n_train, "n_frozen": n_frozen,
            "batch": cfg.batch, "seq": cfg.seq, "classes": cfg.classes,
            "vocab": cfg.vocab, "d": cfg.d, "layers": cfg.layers,
            "label": acfg.label(),
            "block": acfg.block,
            # flat-buffer layouts, so the Rust coordinator can unpack,
            # merge adapters into base weights, and checkpoint by name
            "base_spec": cfg.base_spec().to_meta(),
            "adapter_spec": cfg.adapter_spec(acfg).to_meta(),
        }
        inits = {f"{tag}_base": base_init}
        if mname != "ft":
            inits[f"{tag}_{mname}_adapter"] = cfg.init_adapters(acfg, seed + 1)
        arts.append(Artifact(
            f"{tag}_{mname}_train", lambda *a, f=train: f(*a),
            [f32(n_train), f32(n_train), f32(n_train), f32(), f32(),
             f32(n_frozen), i32(cfg.batch, cfg.seq), i32(cfg.batch)],
            extra={**extra, "kind": "train",
                   "inputs": ["trainable", "adam_m", "adam_v", "step", "lr",
                              "frozen", "tokens", "labels"],
                   "outputs": ["trainable", "adam_m", "adam_v", "loss"]},
            inits=inits))
        arts.append(Artifact(
            f"{tag}_{mname}_eval", lambda *a, f=evalf: f(*a),
            [f32(n_train), f32(n_frozen), i32(cfg.batch, cfg.seq), i32(cfg.batch)],
            extra={**extra, "kind": "eval",
                   "inputs": ["trainable", "frozen", "tokens", "labels"],
                   "outputs": ["loss", "correct", "preds"]}))
    return arts


def cls_artifacts() -> List[Artifact]:
    return _cls_family("cls", CLS_CFG, CLS_METHODS, seed=100)


def cls_big_artifacts() -> List[Artifact]:
    methods = {"ft": CLS_METHODS["ft"], "gsoft": CLS_METHODS["gsoft"]}
    return _cls_family("clsbig", CLS_BIG_CFG, methods, seed=200)


def dn_artifacts() -> List[Artifact]:
    cfg = DN_CFG
    arts: List[Artifact] = []
    base_init = cfg.init_base(300)
    for mname, acfg in DN_METHODS.items():
        train, predict, n_train, n_frozen = diffusion.make_steps(cfg, acfg)
        extra = {
            "family": "dn", "method": mname,
            "n_train": n_train, "n_frozen": n_frozen,
            "batch": cfg.batch, "dim": cfg.dim, "img": cfg.img,
            "conds": cfg.conds, "tsteps": cfg.tsteps,
            "alphas_bar": [float(x) for x in cfg.alphas_bar()],
            "label": acfg.label(),
        }
        inits = {"dn_base": base_init}
        if mname != "ft":
            inits[f"dn_{mname}_adapter"] = cfg.init_adapters(acfg, 301)
        arts.append(Artifact(
            f"dn_{mname}_train", lambda *a, f=train: f(*a),
            [f32(n_train), f32(n_train), f32(n_train), f32(), f32(),
             f32(n_frozen), f32(cfg.batch, cfg.dim), i32(cfg.batch),
             i32(cfg.batch), f32(cfg.batch, cfg.dim)],
            extra={**extra, "kind": "train",
                   "inputs": ["trainable", "adam_m", "adam_v", "step", "lr",
                              "frozen", "x0", "cond", "t", "eps"],
                   "outputs": ["trainable", "adam_m", "adam_v", "loss"]},
            inits=inits))
        arts.append(Artifact(
            f"dn_{mname}_predict", lambda *a, f=predict: (f(*a),),
            [f32(n_train), f32(n_frozen), f32(cfg.batch, cfg.dim),
             i32(cfg.batch), i32(cfg.batch)],
            extra={**extra, "kind": "predict",
                   "inputs": ["trainable", "frozen", "x_t", "t", "cond"],
                   "outputs": ["eps_hat"]}))
    return arts


def lip_artifacts() -> List[Artifact]:
    cfg = LIP_CFG
    arts: List[Artifact] = []
    for key, v in lip_variants().items():
        train, evalf, n_train = lipconvnet.make_steps(cfg, v)
        extra = {
            "family": "lip", "variant": key, "label": v.label(),
            "n_train": n_train, "n_frozen": 1,
            "batch": cfg.batch, "img": cfg.img, "in_ch": cfg.in_ch,
            "classes": cfg.classes,
            "groups_a": v.groups_a, "groups_b": v.groups_b,
            "activation": v.activation, "paired": v.paired,
        }
        inits = {f"lip_{key}": cfg.init(v, 400)}
        arts.append(Artifact(
            f"lip_{key}_train", lambda *a, f=train: f(*a),
            [f32(n_train), f32(n_train), f32(n_train), f32(), f32(), f32(1),
             f32(cfg.batch, cfg.img, cfg.img, cfg.in_ch), i32(cfg.batch)],
            extra={**extra, "kind": "train",
                   "inputs": ["trainable", "adam_m", "adam_v", "step", "lr",
                              "frozen", "x", "y"],
                   "outputs": ["trainable", "adam_m", "adam_v", "loss"]},
            inits=inits))
        arts.append(Artifact(
            f"lip_{key}_eval", lambda *a, f=evalf: f(*a),
            [f32(n_train), f32(1),
             f32(cfg.batch, cfg.img, cfg.img, cfg.in_ch), i32(cfg.batch)],
            extra={**extra, "kind": "eval",
                   "inputs": ["trainable", "frozen", "x", "y"],
                   "outputs": ["loss", "correct", "robust_correct"]}))
    return arts


def all_artifacts(subset: str = "all") -> List[Artifact]:
    groups = {
        "quickstart": quickstart_artifacts,
        "cls": cls_artifacts,
        "clsbig": cls_big_artifacts,
        "dn": dn_artifacts,
        "lip": lip_artifacts,
    }
    if subset != "all":
        return groups[subset]()
    out: List[Artifact] = []
    for g in groups.values():
        out.extend(g())
    return out
