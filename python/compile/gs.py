"""L2 — structured orthogonal parametrizations as JAX transforms.

Builds on the L1 kernels: every `Q @ W` here goes through the Pallas
group-and-shuffle path (never a dense `d×d` materialization), exactly as
the paper's efficiency argument requires.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import gs_kernels as K
from .kernels import ref


def cayley(a: jnp.ndarray, iters: int = 18) -> jnp.ndarray:
    """Batched Cayley transform `(…, b, b) → (…, b, b)` in pure HLO ops.

    `jnp.linalg.solve` lowers to LAPACK typed-FFI custom-calls
    (`lapack_sgetrf_ffi` / `lapack_strsm_ffi`) that the runtime's XLA
    (xla_extension 0.5.1) cannot compile, so the AOT graphs invert
    `(I - K)` with Newton–Schulz iteration instead:

        X₀ = (I-K)ᵀ / s,  s = 1 + ‖K‖_F² ≥ σ_max(I-K)²
        X ← X (2I - (I-K) X)          (quadratic convergence)

    For skew-symmetric `K` the iteration is globally convergent with this
    scaling (σ(I-K)² = 1 + λ² ≤ s), and the whole transform is a chain of
    batched matmuls — differentiable and MXU-friendly. `ref.cayley_ref`
    (exact solve) remains the pytest oracle.
    """
    k = a - jnp.swapaxes(a, -1, -2)
    b = a.shape[-1]
    eye = jnp.eye(b, dtype=a.dtype)
    amat = eye - k
    s = 1.0 + (k * k).sum(axis=(-1, -2), keepdims=True)
    x0 = jnp.swapaxes(amat, -1, -2) / s

    def body(x, _):
        return x @ (2.0 * eye - amat @ x), None

    x, _ = jax.lax.scan(body, x0, None, length=iters)
    return (eye + k) @ x


def gsoft_apply(l_params: jnp.ndarray, r_params: jnp.ndarray, w: jnp.ndarray,
                scale: jnp.ndarray | None = None) -> jnp.ndarray:
    """`Q @ W` with `Q = P^T L P R`, Cayley-orthogonal blocks (§6.1).

    l_params, r_params: (r, b, b) unconstrained; w: (d, n), d = r*b.
    `scale` is the optional magnitude scaling the paper uses.
    """
    lq = cayley(l_params)
    rq = cayley(r_params)
    out = K.gs_apply(lq, rq, w)
    if scale is not None:
        out = out * scale
    return out


def double_gsoft_apply(lu, ru, lv, rv, w):
    """Double GSOFT (§6.2): `Q_U W Q_V` — both singular bases rotated.

    Q_V acts on the right: `W Q_V = (Q_V^T W^T)^T`, and for the GS class
    `Q^T = R^T P^T L^T P` is again group-and-shuffle; we evaluate it with
    the same kernels on the transpose.
    """
    wu = K.gs_apply(cayley(lu), cayley(ru), w)  # Q_U W
    # (Q_V^T W^T): Cayley(K)^T = Cayley(-K); negating params transposes Q.
    qvt_wt = K.gs_apply_transpose(cayley(lv), cayley(rv), wu.T)
    return qvt_wt.T


def oft_apply(blocks: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """OFT (§2): block-diagonal Cayley-orthogonal `Q @ W`."""
    return K.block_diag_matmul(cayley(blocks), w)


def butterfly_gather(r: int, b: int, stride: int) -> np.ndarray:
    """Index map for one BOFT butterfly factor (Remark 2: butterflies are
    GS chains with particular permutations).

    Each factor stays "a block-diagonal matrix up to a permutation of rows
    and columns, consisting of r block matrices of size b×b" (paper §2):
    for every block pair `(p, q = p XOR stride)` the gathered block `p`
    holds the first halves of `p` and `q`, and the gathered block `q` the
    second halves — so each b×b rotation mixes two blocks and `m` factors
    reach `b·2^{m-1}` inputs (dense at `m = 1 + ceil(log2 r)`).
    """
    assert b % 2 == 0, "butterfly interleave needs even block size"
    idx = np.zeros(r * b, dtype=np.int32)
    h = b // 2
    for p in range(r):
        if p & stride:
            continue
        q = p ^ stride
        idx[p * b:p * b + h] = np.arange(p * b, p * b + h)
        idx[p * b + h:(p + 1) * b] = np.arange(q * b, q * b + h)
        idx[q * b:q * b + h] = np.arange(p * b + h, (p + 1) * b)
        idx[q * b + h:(q + 1) * b] = np.arange(q * b + h, (q + 1) * b)
    return idx


def butterfly_shuffle(x: jnp.ndarray, r: int, b: int, stride: int) -> jnp.ndarray:
    """Apply the `butterfly_gather(r, b, stride)` permutation to the rows
    of `x: (r*b, T)` as a pure reshape–transpose (no gather op: `jnp.take`
    miscompiles to NaNs under the runtime's older XLA, and a relayout is
    what the permutation *is* — same argument as Def. 5.2).

    View the rows as (G, u, j, v, w) with G = r/(2·stride), u the stride
    bit of the block index, j the low bits, (v, w) the half/offset inside
    a block; the butterfly interleave is exactly `swapaxes(u, v)` — an
    involution, so the post-mix scatter is the same transform.
    """
    d, t = x.shape
    g = r // (2 * stride)
    h = b // 2
    v5 = x.reshape(g, 2, stride, 2, h, t)
    return v5.transpose(0, 3, 2, 1, 4, 5).reshape(d, t)


def boft_apply(factors: list[jnp.ndarray], w: jnp.ndarray, block: int) -> jnp.ndarray:
    """BOFT (§2): `B_m … B_1 @ W`, `B_1` block-diagonal with `r` blocks of
    `b×b`, `B_i` (i≥2) block-butterfly at stride `2^{i-2}` — every factor
    has `r` Cayley-orthogonal `b×b` blocks (`m·d·b` parameters total).
    """
    d = w.shape[0]
    r = d // block
    out = K.block_diag_matmul(cayley(factors[0]), w)
    for i, f in enumerate(factors[1:]):
        stride = 1 << i
        assert 2 * stride <= r, "butterfly deeper than log2(r)"
        gathered = butterfly_shuffle(out, r, block, stride)
        mixed = K.block_diag_matmul(cayley(f), gathered)
        out = butterfly_shuffle(mixed, r, block, stride)  # involution
    return out


def lora_apply(a: jnp.ndarray, b: jnp.ndarray, w: jnp.ndarray,
               scale: float = 1.0) -> jnp.ndarray:
    """LoRA: `W + scale · a @ b` (a: (d, rank) zero-init, b: (rank, n))."""
    return w + scale * (a @ b)
