"""Flat parameter buffers.

Every AOT train/eval artifact exchanges parameters with the Rust
coordinator as a single `f32[n]` vector, so the runtime is arity-stable
across methods and models. `ParamSpec` records the (name, shape) layout;
pack/unpack are pure reshapes+concats that XLA fuses away.
"""

from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

Shape = Tuple[int, ...]


class ParamSpec:
    """Ordered (name, shape) layout of a flat f32 buffer."""

    def __init__(self, entries: Sequence[Tuple[str, Shape]]):
        self.entries: List[Tuple[str, Shape]] = [(n, tuple(s)) for n, s in entries]
        names = [n for n, _ in self.entries]
        assert len(set(names)) == len(names), "duplicate param names"

    @property
    def size(self) -> int:
        return int(sum(int(np.prod(s)) for _, s in self.entries))

    def unpack(self, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        assert flat.shape == (self.size,), (flat.shape, self.size)
        out = {}
        off = 0
        for name, shape in self.entries:
            n = int(np.prod(shape))
            out[name] = flat[off:off + n].reshape(shape)
            off += n
        return out

    def pack(self, params: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        parts = []
        for name, shape in self.entries:
            p = params[name]
            assert tuple(p.shape) == shape, (name, p.shape, shape)
            parts.append(p.reshape(-1))
        if not parts:
            return jnp.zeros((0,), dtype=jnp.float32)
        return jnp.concatenate(parts).astype(jnp.float32)

    def pack_np(self, params: Dict[str, np.ndarray]) -> np.ndarray:
        parts = [np.asarray(params[n], dtype=np.float32).reshape(-1) for n, _ in self.entries]
        if not parts:
            return np.zeros((0,), dtype=np.float32)
        return np.concatenate(parts)

    def to_meta(self) -> list:
        return [{"name": n, "shape": list(s)} for n, s in self.entries]


def adam_update(flat, m, v, step, lr, grad,
                beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.0):
    """One Adam step on a flat buffer. `step` is the 0-based step count
    *before* this update (scalar f32)."""
    t = step + 1.0
    m2 = beta1 * m + (1.0 - beta1) * grad
    v2 = beta2 * v + (1.0 - beta2) * grad * grad
    mhat = m2 / (1.0 - beta1 ** t)
    vhat = v2 / (1.0 - beta2 ** t)
    upd = mhat / (jnp.sqrt(vhat) + eps)
    if weight_decay:
        upd = upd + weight_decay * flat
    return flat - lr * upd, m2, v2
