"""Pure-jnp reference oracles for the Pallas kernels.

Everything here is deliberately written in the most transparent way
possible (dense materialization, explicit permutation matrices) — these
are the correctness anchors the kernel tests and the L2 model tests
compare against.
"""

import jax.numpy as jnp
import numpy as np


def perm_kn_sigma(k: int, n: int) -> np.ndarray:
    """Definition 5.2: sigma(i) = (i mod k) * n/k + i // k."""
    assert n % k == 0, f"P_(k,n) requires k | n, got k={k} n={n}"
    i = np.arange(n)
    return (i % k) * (n // k) + i // k


def perm_paired_sigma(k: int, n: int) -> np.ndarray:
    """Appendix F paired permutation:
    sigma(i) = (floor(i/2) mod k) * n/k + 2*floor(i/(2k)) + (i mod 2)."""
    assert n % 2 == 0 and n % k == 0 and (n // k) % 2 == 0
    i = np.arange(n)
    return (i // 2 % k) * (n // k) + 2 * (i // (2 * k)) + i % 2


def apply_perm(sigma: np.ndarray, x: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """y[sigma[i]] = x[i] along `axis` — i.e. y = P x with P[sigma[i], i]=1."""
    inv = np.argsort(sigma)
    return jnp.take(x, jnp.asarray(inv), axis=axis)


def perm_matrix(sigma: np.ndarray) -> jnp.ndarray:
    n = len(sigma)
    p = np.zeros((n, n), dtype=np.float32)
    p[sigma, np.arange(n)] = 1.0
    return jnp.asarray(p)


def block_diag_matmul_ref(blocks: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """blocks: (r, b_out, b_in); x: (r*b_in, T) -> (r*b_out, T).

    Dense oracle: materialize diag(blocks) and multiply.
    """
    r, b_out, b_in = blocks.shape
    dense = jnp.zeros((r * b_out, r * b_in), dtype=blocks.dtype)
    for i in range(r):
        dense = dense.at[i * b_out:(i + 1) * b_out, i * b_in:(i + 1) * b_in].set(blocks[i])
    return dense @ x


def cayley_ref(a: jnp.ndarray) -> jnp.ndarray:
    """Cayley transform of a batch of unconstrained blocks (…, b, b):
    Q = (I + K)(I - K)^{-1}, K = A - A^T (batched)."""
    k = a - jnp.swapaxes(a, -1, -2)
    eye = jnp.eye(a.shape[-1], dtype=a.dtype)
    # (I+K) and (I-K)^{-1} commute, so left-solve equals the paper's form.
    return jnp.linalg.solve(eye - k, eye + k)


def gs_q_dense_ref(l_params: jnp.ndarray, r_params: jnp.ndarray) -> jnp.ndarray:
    """Dense GSOFT Q = P^T L P R for Cayley-parametrized blocks.

    l_params/r_params: (r, b, b) unconstrained. P = P_(r, d), d = r*b.
    """
    r, b, _ = l_params.shape
    d = r * b
    lq = cayley_ref(l_params)
    rq = cayley_ref(r_params)
    sigma = perm_kn_sigma(r, d)
    p = perm_matrix(sigma).astype(l_params.dtype)
    ldense = block_diag_matmul_ref(lq, jnp.eye(d, dtype=l_params.dtype))
    rdense = block_diag_matmul_ref(rq, jnp.eye(d, dtype=r_params.dtype))
    return p.T @ ldense @ p @ rdense


def gs_apply_ref(l_params, r_params, x):
    """y = Q x with Q = P^T L P R (dense oracle)."""
    return gs_q_dense_ref(l_params, r_params) @ x
