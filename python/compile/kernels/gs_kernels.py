"""L1 — Pallas kernels for the group-and-shuffle hot path.

The paper's compute hot-spot is applying `Q = P^T L P R` (two block-
diagonal GEMMs with reshape-transpose relayouts in between) without ever
materializing the dense `d×d` matrix. On TPU-shaped hardware:

* each block-diagonal factor is a batched `b×b @ b×T` MXU matmul — we grid
  over the `r` blocks with `BlockSpec((1, b, b))` so one block plus its
  input tile live in VMEM per grid step;
* the `P_(r,d)` shuffle is a `(r,b) → (b,r)` reshape-transpose — expressed
  through the *index_map* of the second kernel's input BlockSpec, so the
  HBM→VMEM transfer performs the shuffle (no gather);
* the final `P^T` relayout is left to XLA (a free bitcast-transpose).

All entry points carry `jax.custom_vjp` rules whose backward passes run
through the *same* batched-matmul kernel (the VJP of a block-diagonal
GEMM is two block-diagonal GEMMs), so the training graphs stay on the
kernel path end to end.

Kernels run with `interpret=True`: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is what lowers into the AOT HLO.
Real-TPU efficiency is estimated from the BlockSpec VMEM footprint in
DESIGN.md §Perf / EXPERIMENTS.md §Perf.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT requirement; see module docstring.


# ---- core batched-matmul kernel --------------------------------------------

def _bmm_kernel(a_ref, b_ref, o_ref):
    """One grid step: multiply batch element i."""
    o_ref[0] = jnp.dot(a_ref[0], b_ref[0], preferred_element_type=o_ref.dtype)


def bmm(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Batched matmul `(r, m, k) @ (r, k, n) -> (r, m, n)` — one MXU-sized
    block per grid step; the primitive every GS factor reduces to."""
    r, m, k = a.shape
    rb, kb, n = b.shape
    assert r == rb and k == kb, (a.shape, b.shape)
    return pl.pallas_call(
        _bmm_kernel,
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, m, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, k, n), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((r, m, n), a.dtype),
        interpret=INTERPRET,
    )(a, b)


def _shuffle_bmm_kernel(blocks_ref, z_ref, o_ref):
    """Fused-shuffle grid step: block i consumes the tile `(P z)_i`.

    The input BlockSpec's index_map selects z.reshape(b, r, T)[:, i, :],
    which *is* the `P_(r,d)` relayout — the body is a plain matmul.
    """
    zi = z_ref[:, 0, :]  # (b, T): the shuffled group for block i
    o_ref[0] = jnp.dot(blocks_ref[0], zi, preferred_element_type=o_ref.dtype)


def _shuffle_bmm(blocks: jnp.ndarray, z3: jnp.ndarray) -> jnp.ndarray:
    """`out[i] = blocks[i] @ z3[:, i, :]` — z3: (b, r, T)."""
    r, b, _ = blocks.shape
    bb, rr, t = z3.shape
    assert bb == b and rr == r
    return pl.pallas_call(
        _shuffle_bmm_kernel,
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, b, b), lambda i: (i, 0, 0)),
            pl.BlockSpec((b, 1, t), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, b, t), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((r, b, t), z3.dtype),
        interpret=INTERPRET,
    )(blocks, z3)


# ---- block-diagonal matmul (with VJP) ---------------------------------------

@jax.custom_vjp
def block_diag_matmul(blocks: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """`diag(blocks) @ x` — blocks: (r, b_out, b_in), x: (r*b_in, T) →
    (r*b_out, T), never materializing the dense form."""
    r, b_out, b_in = blocks.shape
    t = x.shape[1]
    out = bmm(blocks, x.reshape(r, b_in, t))
    return out.reshape(r * b_out, t)


def _bdmm_fwd(blocks, x):
    return block_diag_matmul(blocks, x), (blocks, x)


def _bdmm_bwd(res, dy):
    blocks, x = res
    r, b_out, b_in = blocks.shape
    t = x.shape[1]
    dy3 = dy.reshape(r, b_out, t)
    # dx = diag(blocks)^T dy: batched (b_in, b_out) @ (b_out, T).
    dx = bmm(jnp.swapaxes(blocks, -1, -2), dy3).reshape(r * b_in, t)
    # dblocks_i = dy_i @ x_i^T: batched (b_out, T) @ (T, b_in).
    dblocks = bmm(dy3, jnp.swapaxes(x.reshape(r, b_in, t), -1, -2))
    return dblocks, dx


block_diag_matmul.defvjp(_bdmm_fwd, _bdmm_bwd)


# ---- shuffled block-diagonal matmul (with VJP) -------------------------------

@jax.custom_vjp
def shuffled_block_diag_matmul(blocks: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """`diag(blocks) @ (P_(r,d) z)` with the shuffle fused into the
    BlockSpec index map. blocks: (r, b, b); z: (d, T), d = r·b.

    Derivation: with σ(i) = (i mod r)·b + i//r (Def. 5.2, k = r), row j of
    `P z` is z[σ^{-1}(j)] and block i of `P z` is z.reshape(b, r, T)[:, i, :]
    — a strided slice the HBM→VMEM DMA performs for free.
    """
    r, b, _ = blocks.shape
    d, t = z.shape
    assert d == r * b
    out = _shuffle_bmm(blocks, z.reshape(b, r, t))
    return out.reshape(d, t)


def _sbdmm_fwd(blocks, z):
    return shuffled_block_diag_matmul(blocks, z), (blocks, z)


def _sbdmm_bwd(res, dw):
    blocks, z = res
    r, b, _ = blocks.shape
    d, t = z.shape
    dw3 = dw.reshape(r, b, t)
    # dz = P^T diag(blocks)^T dw: batched transpose-matmul, then the
    # inverse relayout (the (r,b)->(b,r) transpose).
    dpz = bmm(jnp.swapaxes(blocks, -1, -2), dw3)  # (r, b, t) = d(Pz)
    dz = dpz.transpose(1, 0, 2).reshape(d, t)     # undo the shuffle
    # dblocks_i = dw_i @ (Pz)_i^T.
    pz = z.reshape(b, r, t).transpose(1, 0, 2)    # (r, b, t)
    dblocks = bmm(dw3, jnp.swapaxes(pz, -1, -2))
    return dblocks, dz


shuffled_block_diag_matmul.defvjp(_sbdmm_fwd, _sbdmm_bwd)


# ---- the GSOFT hot path ------------------------------------------------------

def gs_apply(l_blocks: jnp.ndarray, r_blocks: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """`y = P^T L P R x` — the structured orthogonal apply (§6.1).

    l_blocks, r_blocks: (r, b, b) (already Cayley-transformed); x: (d, T).
    Two Pallas stages + one XLA relayout for the outer `P^T`:
      y = w.reshape(r, b, T).transpose(1, 0, 2).reshape(d, T)
    since y[u·r+v] = w[σ(u·r+v)] = w[v·b+u].
    """
    r, b, _ = l_blocks.shape
    d = r * b
    assert x.shape[0] == d
    t = x.shape[1]
    z = block_diag_matmul(r_blocks, x)           # R x      (grouped GEMM 1)
    w = shuffled_block_diag_matmul(l_blocks, z)  # L (P z)  (grouped GEMM 2)
    return w.reshape(r, b, t).transpose(1, 0, 2).reshape(d, t)


def gs_apply_transpose(l_blocks: jnp.ndarray, r_blocks: jnp.ndarray,
                       x: jnp.ndarray) -> jnp.ndarray:
    """`y = Q^T x` for `Q = P^T L P R`, i.e. `y = R^T P^T L^T (P x)`.

    Reuses the same kernels: `L^T (P x)` is `shuffled_block_diag_matmul`
    with transposed blocks, the outer `P^T` is the same free relayout, and
    `R^T ·` is the plain block-diagonal kernel. Needed by Double GSOFT's
    right-side factor.
    """
    r, b, _ = l_blocks.shape
    d = r * b
    assert x.shape[0] == d
    t = x.shape[1]
    lt = jnp.swapaxes(l_blocks, -1, -2)
    rt = jnp.swapaxes(r_blocks, -1, -2)
    w = shuffled_block_diag_matmul(lt, x)  # L^T P x
    y = w.reshape(r, b, t).transpose(1, 0, 2).reshape(d, t)  # P^T ·
    return block_diag_matmul(rt, y)  # R^T ·


# ---- perf-model helpers ------------------------------------------------------

def vmem_footprint_bytes(r: int, b: int, t: int, dtype_bytes: int = 4) -> dict:
    """Per-grid-step VMEM usage estimate for the two kernels (DESIGN.md
    §Perf): one b×b block + b×T input tile + b×T output tile, plus an
    MXU-fill proxy for the (b, b, T) matmul against a 128³ MXU pass."""
    per_step = dtype_bytes * (b * b + 2 * b * t)
    mxu_fill = min(b / 128.0, 1.0) * min(b / 128.0, 1.0) * min(t / 128.0, 1.0)
    return {
        "per_step_bytes": per_step,
        "grid_steps": r,
        "mxu_fill_fraction": mxu_fill,
        "flops": 2 * r * b * b * t,
        "hbm_bytes": dtype_bytes * (r * b * b + 2 * r * b * t),
    }
