"""L2 — LipConvnet with SOC / GS-SOC orthogonal convolutions (§6.3,
Tables 3–4).

A 1-Lipschitz CNN: every convolution is a convolution *exponential* of a
skew-symmetric kernel (orthogonal Jacobian, Def. 6.1), activations are
gradient-norm-preserving (MaxMin / MaxMinPermuted), downsampling is
invertible space-to-depth followed by a channel truncation (1-Lipschitz),
and the final classifier rows are unit-normalized so the certified
robustness radius is `margin / sqrt(2)`.

GS-SOC (Eq. 3) replaces each full conv-exponential with
`GrExpConv_2(ChShuffle_2(GrExpConv_1(ChShuffle_1(x))))`: grouped
exponentials (block-diagonal Eq.-2 matrices) interleaved with channel
shuffles — fewer parameters and FLOPs per layer. Following §7.3, the
second grouped exponential uses a 1×1 kernel.
"""

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .flat import ParamSpec, adam_update
from .kernels.ref import perm_kn_sigma, perm_paired_sigma

EXP_TERMS = 6      # Taylor terms of the convolution exponential (SOC uses 6)
SKEW_CLAMP = 1.5   # Frobenius clamp keeping the truncated series accurate


class LipVariant:
    """One Table-3/4 row: conv structure + activation + shuffle choice."""

    def __init__(self, groups_a: int = 1, groups_b: int = 0,
                 activation: str = "maxmin", paired: bool = False):
        # groups_a == 1 => plain SOC layer (no shuffles); groups_b == 0 =>
        # only one grouped exponential, i.e. the "(4, -)" rows.
        assert activation in ("maxmin", "maxmin_permuted")
        self.groups_a = groups_a
        self.groups_b = groups_b
        self.activation = activation
        self.paired = paired

    def label(self) -> str:
        conv = "SOC" if self.groups_a == 1 else (
            f"GS-SOC({self.groups_a},{self.groups_b if self.groups_b else '-'})")
        act = "MaxMin" if self.activation == "maxmin" else "MaxMinPermuted"
        perm = "paired" if self.paired else "not-paired"
        return f"{conv}/{act}/{perm}"

    def key(self) -> str:
        conv = "soc" if self.groups_a == 1 else f"g{self.groups_a}_{self.groups_b}"
        act = "mm" if self.activation == "maxmin" else "mmp"
        perm = "p" if self.paired else "u"
        return f"{conv}_{act}_{perm}" if self.groups_a != 1 else "soc"


class LipConfig:
    def __init__(self, img: int = 16, in_ch: int = 4, classes: int = 8,
                 channels: Tuple[int, ...] = (32, 64, 128, 128), batch: int = 32):
        # len(channels) stages; each stage: variant conv layer + downsample
        # conv layer (2 convs/stage => LipConvnet-(2*stages)).
        self.img, self.in_ch, self.classes = img, in_ch, classes
        self.channels = tuple(channels)
        self.batch = batch

    # ---- parameter layout ------------------------------------------------

    def conv_entries(self, name: str, c_in: int, c_out: int,
                     v: LipVariant) -> List[Tuple[str, Tuple[int, ...]]]:
        c = max(c_in, c_out)  # square conv via channel pad/truncate
        if v.groups_a == 1:
            return [(f"{name}.k", (3, 3, c, c))]
        ga = v.groups_a
        entries = [(f"{name}.ka", (3, 3, c // ga, c))]
        if v.groups_b:
            gb = v.groups_b
            entries.append((f"{name}.kb", (1, 1, c // gb, c)))
        return entries

    def spec(self, v: LipVariant) -> ParamSpec:
        entries = []
        c_prev = self.in_ch
        for s, c_out in enumerate(self.channels):
            entries += self.conv_entries(f"s{s}.conv", c_prev, c_prev, v)
            entries += self.conv_entries(f"s{s}.down", 4 * c_prev, c_out, v)
            c_prev = c_out
        entries.append(("head", (self.channels[-1] * self.final_spatial() ** 2,
                                 self.classes)))
        return ParamSpec(entries)

    def final_spatial(self) -> int:
        return self.img // (2 ** len(self.channels))

    def init(self, v: LipVariant, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        spec = self.spec(v)
        out = {}
        for name, shape in spec.entries:
            std = 0.2 / np.sqrt(np.prod(shape[:-1])) if len(shape) == 4 else 1.0 / np.sqrt(shape[0])
            out[name] = (rng.standard_normal(shape) * std).astype(np.float32)
        return spec.pack_np(out)


# ---- 1-Lipschitz building blocks -------------------------------------------

def _skew_grouped(kernel: jnp.ndarray, groups: int) -> jnp.ndarray:
    """`L = M - ConvTranspose(M)` per group, then Frobenius-clamped.

    kernel: HWIO `(kh, kw, c/groups, c)` with outputs ordered group-major.
    """
    kh, kw, cpg, c = kernel.shape
    assert c % groups == 0 and c // groups == cpg
    m = kernel.reshape(kh, kw, cpg, groups, cpg)
    mt = jnp.flip(m, axis=(0, 1)).transpose(0, 1, 4, 3, 2)  # swap in/out per group
    l = (m - mt).reshape(kh, kw, cpg, c)
    # Clamp the skew mass so EXP_TERMS Taylor terms stay accurate (the
    # spectral norm of the Eq.-2 matrix is bounded by kh*kw*||L||_F).
    fro = jnp.sqrt((l ** 2).sum()) * (kh * kw) ** 0.5
    return l / jnp.maximum(1.0, fro / SKEW_CLAMP)


def _grouped_conv(x: jnp.ndarray, kernel: jnp.ndarray, groups: int) -> jnp.ndarray:
    """Same-padded NHWC grouped convolution.

    Implemented as per-group convs + concat rather than
    `feature_group_count`: XLA-CPU's grouped-conv kernel is slower than
    `groups` separate dense convs at these sizes (measured 28ms vs 15ms at
    C=32 and 56ms vs 45ms at C=256 for 6 chained convs), while the math is
    identical. On TPU this choice is neutral — each group is still a
    block-diagonal channel GEMM.
    """
    if groups == 1:
        return jax.lax.conv_general_dilated(
            x, kernel, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    cpg = x.shape[-1] // groups
    outs = []
    for g in range(groups):
        kg = kernel[:, :, :, g * cpg:(g + 1) * cpg]
        xg = x[..., g * cpg:(g + 1) * cpg]
        outs.append(jax.lax.conv_general_dilated(
            xg, kg, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")))
    return jnp.concatenate(outs, axis=-1)


def conv_exp(x: jnp.ndarray, skew_kernel: jnp.ndarray, groups: int) -> jnp.ndarray:
    """Definition 6.1 truncated at EXP_TERMS: orthogonal-Jacobian conv."""
    acc = x
    term = x
    fact = 1.0
    for t in range(1, EXP_TERMS + 1):
        term = _grouped_conv(term, skew_kernel, groups)
        fact *= t
        acc = acc + term / fact
    return acc


def _apply_perm_kn(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Apply `P_(k,n)` along the last axis as a reshape-transpose:
    y[σ(i)] = x[i] with σ(i) = (i mod k)·n/k + i//k  ⇔
    y = x.reshape(…, n/k, k).swapaxes(-1, -2).flatten(-2) — no gather op
    (`jnp.take` miscompiles under the runtime's older XLA, and Def. 5.2
    explicitly describes the permutation as this relayout)."""
    n = x.shape[-1]
    y = x.reshape(*x.shape[:-1], n // k, k)
    return jnp.swapaxes(y, -1, -2).reshape(*x.shape[:-1], n)


def channel_shuffle(x: jnp.ndarray, k: int, paired: bool) -> jnp.ndarray:
    """ChShuffle: permute channels with P_(k,c) (or the paired variant of
    Appendix F, which moves adjacent channel *pairs* together)."""
    c = x.shape[-1]
    if k <= 1 or k >= c:
        return x
    if not paired:
        return _apply_perm_kn(x, k)
    # paired: apply P_(k, c/2) on the pair index, keeping pairs intact.
    pairs = x.reshape(*x.shape[:-1], c // 2, 2)
    shuffled = jnp.swapaxes(_apply_perm_kn(jnp.swapaxes(pairs, -1, -2), k), -1, -2)
    return shuffled.reshape(*x.shape[:-1], c)


def maxmin(x: jnp.ndarray, permuted: bool) -> jnp.ndarray:
    """MaxMin (Def. F.1) or MaxMinPermuted (Def. F.2) — both 1-Lipschitz
    and gradient-norm preserving."""
    c = x.shape[-1]
    if permuted:
        a, b = x[..., 0::2], x[..., 1::2]
        out = jnp.stack([jnp.maximum(a, b), jnp.minimum(a, b)], axis=-1)
        return out.reshape(x.shape)
    half = c // 2
    a, b = x[..., :half], x[..., half:]
    return jnp.concatenate([jnp.maximum(a, b), jnp.minimum(a, b)], axis=-1)


def space_to_depth(x: jnp.ndarray) -> jnp.ndarray:
    """Invertible 2×2 downsampling (norm preserving)."""
    b, h, w, c = x.shape
    x = x.reshape(b, h // 2, 2, w // 2, 2, c)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(b, h // 2, w // 2, 4 * c)


def _pad_channels(x: jnp.ndarray, c: int) -> jnp.ndarray:
    if x.shape[-1] == c:
        return x
    pad = c - x.shape[-1]
    return jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad)))


def gs_soc_layer(x: jnp.ndarray, params: Dict[str, jnp.ndarray], name: str,
                 c_in: int, c_out: int, v: LipVariant) -> jnp.ndarray:
    """One orthogonal conv layer (SOC or Eq.-3 GS-SOC), `c_in -> c_out`
    via channel pad + square exponential + truncate (all 1-Lipschitz)."""
    c = max(c_in, c_out)
    h = _pad_channels(x, c)
    if v.groups_a == 1:
        k = _skew_grouped(params[f"{name}.k"], 1)
        h = conv_exp(h, k, 1)
    else:
        h = channel_shuffle(h, v.groups_a, v.paired)
        ka = _skew_grouped(params[f"{name}.ka"], v.groups_a)
        h = conv_exp(h, ka, v.groups_a)
        if v.groups_b:
            h = channel_shuffle(h, v.groups_b, v.paired)
            kb = _skew_grouped(params[f"{name}.kb"], v.groups_b)
            h = conv_exp(h, kb, v.groups_b)
    return h[..., :c_out]


def forward(cfg: LipConfig, v: LipVariant, params: Dict[str, jnp.ndarray],
            x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, img, img, in_ch) → logits (B, classes); 1-Lipschitz."""
    h = x
    c_prev = cfg.in_ch
    for s, c_out in enumerate(cfg.channels):
        h = gs_soc_layer(h, params, f"s{s}.conv", c_prev, c_prev, v)
        h = maxmin(h, v.activation == "maxmin_permuted")
        h = space_to_depth(h)
        h = gs_soc_layer(h, params, f"s{s}.down", 4 * c_prev, c_out, v)
        h = maxmin(h, v.activation == "maxmin_permuted")
        c_prev = c_out
    hflat = h.reshape(h.shape[0], -1)
    w = params["head"]
    w = w / jnp.linalg.norm(w, axis=0, keepdims=True)  # unit class vectors
    return hflat @ w


def make_steps(cfg: LipConfig, v: LipVariant, eps: float = 36.0 / 255.0):
    """(train_step, eval_step, n_train) for AOT lowering.

    train(trainable, m, v, step, lr, frozen, x, y) -> (t', m', v', loss)
    eval(trainable, frozen, x, y) -> (loss, correct, robust_correct)
      robust: margin > sqrt(2)*eps (1-Lipschitz certificate).
    """
    spec = cfg.spec(v)

    def loss_fn(trainable, x, y):
        params = spec.unpack(trainable)
        logits = forward(cfg, v, params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()

    def train_step(trainable, m, vv, step, lr, frozen, x, y):
        del frozen
        loss, grad = jax.value_and_grad(loss_fn)(trainable, x, y)
        new_t, new_m, new_v = adam_update(trainable, m, vv, step, lr, grad)
        return new_t, new_m, new_v, loss

    def eval_step(trainable, frozen, x, y):
        del frozen
        params = spec.unpack(trainable)
        logits = forward(cfg, v, params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        loss = -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()
        pred = logits.argmax(-1)
        correct = (pred == y)
        top2 = jnp.sort(logits, axis=-1)
        margin = top2[:, -1] - top2[:, -2]
        robust = correct & (margin > np.sqrt(2.0) * eps)
        return loss, correct.sum().astype(jnp.float32), robust.sum().astype(jnp.float32)

    return train_step, eval_step, spec.size
