"""PEFT adapters over frozen linear weights — the six methods compared in
the paper's experiments (Tables 1–2):

  full / ft      — every base weight trains (no adapter params)
  lora           — additive low-rank update
  oft            — multiplicative block-diagonal orthogonal factor
  boft           — multiplicative block-butterfly orthogonal product
  gsoft          — multiplicative GS orthogonal factor (ours, §6.1)
  double_gsoft   — two-sided GS orthogonal factors (ours, §6.2)

Convention: a linear layer computes `y = x @ W` with `W: (din, dout)`.
Multiplicative adapters act on the input dimension, `W' = Q @ W`
(Double GSOFT additionally on the output: `W' = Q_U W Q_V`) — the same
convention as `gsoft::gs::orthogonal` on the Rust side. Every adapter is
the identity at zero initialization, so fine-tuning starts exactly at the
pretrained model.
"""

from typing import Callable, Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from . import gs

Shape = Tuple[int, ...]

ADAPTED = ("wq", "wk", "wv", "wo", "w1", "w2")  # attention + MLP linears


class AdapterConfig:
    """Hyperparameters of one method (Table 1 defaults)."""

    def __init__(self, method: str, block: int = 8, rank: int = 8,
                 boft_m: int = 2, scale: bool = True):
        assert method in ("ft", "lora", "oft", "boft", "gsoft", "double_gsoft")
        self.method = method
        self.block = block
        self.rank = rank
        self.boft_m = boft_m
        self.scale = scale

    def label(self) -> str:
        m = self.method
        if m == "lora":
            return f"LoRA(r={self.rank})"
        if m == "oft":
            return f"OFT(b={self.block})"
        if m == "boft":
            return f"BOFT(b={self.block},m={self.boft_m})"
        if m == "gsoft":
            return f"GSOFT(b={self.block})"
        if m == "double_gsoft":
            return f"DoubleGSOFT(b={self.block})"
        return "FT"


def adapter_entries(cfg: AdapterConfig, name: str, din: int, dout: int
                    ) -> List[Tuple[str, Shape]]:
    """ParamSpec entries for adapting one (din, dout) linear layer."""
    b = cfg.block
    if cfg.method == "ft":
        return []
    if cfg.method == "lora":
        return [
            (f"{name}.lora_a", (din, cfg.rank)),
            (f"{name}.lora_b", (cfg.rank, dout)),
        ]
    if cfg.method == "oft":
        assert din % b == 0
        return [(f"{name}.oft_k", (din // b, b, b))]
    if cfg.method == "boft":
        assert din % b == 0
        r = din // b
        out: List[Tuple[str, Shape]] = []
        for i in range(cfg.boft_m):
            if i >= 1:
                assert 2 * (1 << (i - 1)) <= r, "boft_m too deep for r blocks"
            out.append((f"{name}.boft_k{i}", (r, b, b)))
        return out
    if cfg.method == "gsoft":
        assert din % b == 0
        r = din // b
        return [
            (f"{name}.gs_l", (r, b, b)),
            (f"{name}.gs_r", (r, b, b)),
        ]
    if cfg.method == "double_gsoft":
        assert din % b == 0 and dout % b == 0
        ru, rv = din // b, dout // b
        return [
            (f"{name}.gsu_l", (ru, b, b)),
            (f"{name}.gsu_r", (ru, b, b)),
            (f"{name}.gsv_l", (rv, b, b)),
            (f"{name}.gsv_r", (rv, b, b)),
        ]
    raise ValueError(cfg.method)


def adapter_init(cfg: AdapterConfig, name: str, din: int, dout: int,
                 rng: np.random.Generator) -> Dict[str, np.ndarray]:
    """Initial adapter params: identity transform for every method.

    Orthogonal methods: zero Cayley pre-images ⇒ Q = I.
    LoRA: `b = 0` ⇒ additive term vanishes (`a` is random, as usual).
    """
    out: Dict[str, np.ndarray] = {}
    for pname, shape in adapter_entries(cfg, name, din, dout):
        if pname.endswith("lora_a"):
            out[pname] = (rng.standard_normal(shape) / np.sqrt(din)).astype(np.float32)
        else:
            out[pname] = np.zeros(shape, dtype=np.float32)
    return out


def adapt_weight(cfg: AdapterConfig, name: str, w: jnp.ndarray,
                 params: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Apply the method's transform to a frozen weight."""
    if cfg.method == "ft":
        return w
    if cfg.method == "lora":
        return gs.lora_apply(params[f"{name}.lora_a"], params[f"{name}.lora_b"], w)
    if cfg.method == "oft":
        return gs.oft_apply(params[f"{name}.oft_k"], w)
    if cfg.method == "boft":
        factors = [params[f"{name}.boft_k{i}"] for i in range(cfg.boft_m)]
        return gs.boft_apply(factors, w, cfg.block)
    if cfg.method == "gsoft":
        return gs.gsoft_apply(params[f"{name}.gs_l"], params[f"{name}.gs_r"], w)
    if cfg.method == "double_gsoft":
        return gs.double_gsoft_apply(
            params[f"{name}.gsu_l"], params[f"{name}.gsu_r"],
            params[f"{name}.gsv_l"], params[f"{name}.gsv_r"], w)
    raise ValueError(cfg.method)
