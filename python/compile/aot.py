"""AOT lowering: JAX → HLO *text* → `artifacts/`.

Python runs exactly once (`make artifacts`); the Rust binary is
self-contained afterwards. The interchange format is HLO text, NOT a
serialized HloModuleProto: jax ≥ 0.5 emits protos with 64-bit instruction
ids that xla_extension 0.5.1 (what the `xla` crate binds) rejects; the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Per artifact we emit
  artifacts/<name>.hlo.txt    — the lowered module
  artifacts/<name>.meta.json  — shapes, dtypes, io names, experiment data
plus shared initial-value buffers `artifacts/<init>.f32` (raw LE f32) and
a global `artifacts/manifest.json`.
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from .model import all_artifacts


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def arg_meta(x) -> dict:
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


def lower_one(art, outdir: str, force: bool) -> dict:
    hlo_path = os.path.join(outdir, f"{art.name}.hlo.txt")
    meta_path = os.path.join(outdir, f"{art.name}.meta.json")
    t0 = time.time()
    lowered = jax.jit(art.fn, keep_unused=True).lower(*art.args)
    text = to_hlo_text(lowered)
    with open(hlo_path, "w") as f:
        f.write(text)
    # Output shapes from the lowered signature.
    out_shapes = [arg_meta(o) for o in jax.eval_shape(art.fn, *art.args)]
    meta = {
        "name": art.name,
        "hlo": os.path.basename(hlo_path),
        "inputs": [
            {"name": n, **arg_meta(a)}
            for n, a in zip(art.extra.get("inputs", [f"arg{i}" for i in range(len(art.args))]),
                            art.args)
        ],
        "outputs": [
            {"name": n, **m}
            for n, m in zip(art.extra.get("outputs",
                                          [f"out{i}" for i in range(len(out_shapes))]),
                            out_shapes)
        ],
        "extra": {k: v for k, v in art.extra.items() if k not in ("inputs", "outputs")},
        "inits": {k: f"{k}.f32" for k in art.inits},
        "lower_seconds": round(time.time() - t0, 2),
    }
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1, sort_keys=True)
    for iname, arr in art.inits.items():
        ipath = os.path.join(outdir, f"{iname}.f32")
        if force or not os.path.exists(ipath):
            np.asarray(arr, dtype="<f4").tofile(ipath)
    print(f"  [aot] {art.name}: {len(text) / 1e6:.2f} MB HLO "
          f"({meta['lower_seconds']}s)", flush=True)
    return meta


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--subset", default="all",
                    help="all|quickstart|cls|clsbig|dn|lip")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    outdir = args.out
    os.makedirs(outdir, exist_ok=True)
    arts = all_artifacts(args.subset)
    t0 = time.time()
    names = []
    for art in arts:
        meta = lower_one(art, outdir, args.force)
        names.append(meta["name"])
    # Merge with any existing manifest so `--subset` rebuilds never drop
    # artifacts lowered by other subsets.
    mpath = os.path.join(outdir, "manifest.json")
    existing = []
    if os.path.exists(mpath):
        try:
            with open(mpath) as f:
                existing = json.load(f).get("artifacts", [])
        except Exception:
            existing = []
    merged = sorted(set(existing) | set(names),
                    key=lambda n: (existing + names).index(n) if n in existing + names else 0)
    manifest = {"artifacts": merged, "subset": args.subset,
                "total_seconds": round(time.time() - t0, 1)}
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] {len(arts)} artifacts in {manifest['total_seconds']}s -> {outdir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
