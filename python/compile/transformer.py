"""L2 — a small pre-LN transformer encoder classifier.

The workload stand-in for the paper's GLUE/RoBERTa experiments
(Table 1): base weights are *frozen inputs* to the AOT graphs; adapter
parameters (or, for full fine-tuning, the base weights themselves) are
the trainable flat buffer. All attention and MLP linears are adapted,
matching the paper's "adapters for all linear layers in the attention
and MLP" setup.
"""

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .adapters import ADAPTED, AdapterConfig, adapt_weight, adapter_entries, adapter_init
from .flat import ParamSpec, adam_update


class TransformerConfig:
    def __init__(self, vocab: int = 512, d: int = 128, layers: int = 2,
                 heads: int = 4, ff: int = 256, seq: int = 32,
                 classes: int = 4, batch: int = 16):
        assert d % heads == 0
        self.vocab, self.d, self.layers = vocab, d, layers
        self.heads, self.ff, self.seq = heads, ff, seq
        self.classes, self.batch = classes, batch

    def base_spec(self) -> ParamSpec:
        c = self
        entries = [("embed", (c.vocab, c.d)), ("pos", (c.seq, c.d))]
        for i in range(c.layers):
            p = f"layer{i}."
            entries += [
                (p + "ln1_g", (c.d,)), (p + "ln1_b", (c.d,)),
                (p + "wq", (c.d, c.d)), (p + "wk", (c.d, c.d)),
                (p + "wv", (c.d, c.d)), (p + "wo", (c.d, c.d)),
                (p + "ln2_g", (c.d,)), (p + "ln2_b", (c.d,)),
                (p + "w1", (c.d, c.ff)), (p + "w2", (c.ff, c.d)),
            ]
        entries += [("lnf_g", (c.d,)), ("lnf_b", (c.d,)), ("head", (c.d, c.classes))]
        return ParamSpec(entries)

    def adapter_spec(self, cfg: AdapterConfig) -> ParamSpec:
        entries = []
        for i in range(self.layers):
            p = f"layer{i}."
            dims = {"wq": (self.d, self.d), "wk": (self.d, self.d),
                    "wv": (self.d, self.d), "wo": (self.d, self.d),
                    "w1": (self.d, self.ff), "w2": (self.ff, self.d)}
            for lname in ADAPTED:
                din, dout = dims[lname]
                entries += adapter_entries(cfg, p + lname, din, dout)
        return ParamSpec(entries)

    def init_base(self, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        spec = self.base_spec()
        out = {}
        for name, shape in spec.entries:
            if name.endswith(("_g",)):
                out[name] = np.ones(shape, dtype=np.float32)
            elif name.endswith(("_b",)):
                out[name] = np.zeros(shape, dtype=np.float32)
            else:
                fan_in = shape[0] if len(shape) > 1 else shape[0]
                out[name] = (rng.standard_normal(shape) / np.sqrt(fan_in)).astype(np.float32)
        return spec.pack_np(out)

    def init_adapters(self, cfg: AdapterConfig, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        spec = self.adapter_spec(cfg)
        out = {}
        for i in range(self.layers):
            p = f"layer{i}."
            dims = {"wq": (self.d, self.d), "wk": (self.d, self.d),
                    "wv": (self.d, self.d), "wo": (self.d, self.d),
                    "w1": (self.d, self.ff), "w2": (self.ff, self.d)}
            for lname in ADAPTED:
                din, dout = dims[lname]
                out.update(adapter_init(cfg, p + lname, din, dout, rng))
        return spec.pack_np(out)


def _layernorm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def forward(cfg: TransformerConfig, acfg: AdapterConfig,
            base: Dict[str, jnp.ndarray], adapt: Dict[str, jnp.ndarray],
            tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens: (B, S) int32 → logits (B, classes)."""
    c = cfg
    B, S = tokens.shape

    def w(layer_prefix: str, lname: str) -> jnp.ndarray:
        base_w = base[layer_prefix + lname]
        if acfg.method == "ft":
            return base_w
        return adapt_weight(acfg, layer_prefix + lname, base_w, adapt)

    h = base["embed"][tokens] + base["pos"][None, :S, :]
    for i in range(c.layers):
        p = f"layer{i}."
        x = _layernorm(h, base[p + "ln1_g"], base[p + "ln1_b"])
        q = x @ w(p, "wq")
        k = x @ w(p, "wk")
        v = x @ w(p, "wv")
        hd = c.d // c.heads
        q = q.reshape(B, S, c.heads, hd).transpose(0, 2, 1, 3)
        k = k.reshape(B, S, c.heads, hd).transpose(0, 2, 1, 3)
        v = v.reshape(B, S, c.heads, hd).transpose(0, 2, 1, 3)
        att = jax.nn.softmax(q @ k.transpose(0, 1, 3, 2) / np.sqrt(hd), axis=-1)
        o = (att @ v).transpose(0, 2, 1, 3).reshape(B, S, c.d)
        h = h + o @ w(p, "wo")
        x = _layernorm(h, base[p + "ln2_g"], base[p + "ln2_b"])
        h = h + jax.nn.gelu(x @ w(p, "w1")) @ w(p, "w2")
    h = _layernorm(h, base["lnf_g"], base["lnf_b"])
    pooled = h.mean(axis=1)
    return pooled @ base["head"]


def _ce_loss(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def make_steps(cfg: TransformerConfig, acfg: AdapterConfig):
    """Build (train_step, eval_step) pure functions for AOT lowering.

    Signatures (flat f32 buffers; `frozen` is size-1 dummy for ft):
      train(trainable, m, v, step, lr, frozen, tokens, labels)
        -> (trainable', m', v', loss)
      eval(trainable, frozen, tokens, labels) -> (loss, correct)
    """
    base_spec = cfg.base_spec()
    adapt_spec = cfg.adapter_spec(acfg)
    is_ft = acfg.method == "ft"

    def unpack(trainable, frozen):
        if is_ft:
            base = base_spec.unpack(trainable)
            adapt = {}
        else:
            base = base_spec.unpack(frozen)
            adapt = adapt_spec.unpack(trainable)
        return base, adapt

    def loss_fn(trainable, frozen, tokens, labels):
        base, adapt = unpack(trainable, frozen)
        logits = forward(cfg, acfg, base, adapt, tokens)
        return _ce_loss(logits, labels)

    def train_step(trainable, m, v, step, lr, frozen, tokens, labels):
        loss, grad = jax.value_and_grad(loss_fn)(trainable, frozen, tokens, labels)
        new_t, new_m, new_v = adam_update(trainable, m, v, step, lr, grad)
        return new_t, new_m, new_v, loss

    def eval_step(trainable, frozen, tokens, labels):
        base, adapt = unpack(trainable, frozen)
        logits = forward(cfg, acfg, base, adapt, tokens)
        loss = _ce_loss(logits, labels)
        preds = logits.argmax(-1).astype(jnp.int32)
        correct = (preds == labels).sum().astype(jnp.float32)
        return loss, correct, preds

    n_train = base_spec.size if is_ft else adapt_spec.size
    n_frozen = 1 if is_ft else base_spec.size
    return train_step, eval_step, n_train, n_frozen
