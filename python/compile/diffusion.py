"""L2 — a tiny conditional denoiser: the subject-driven-generation
stand-in (Table 2 / Figure 6).

The paper fine-tunes Stable Diffusion on a handful of concept images
(DreamBooth); we cannot run SD on this testbed, so we reproduce the
*experimental structure* on a conditional DDPM over 8×8 synthetic
"images": a base model pretrained on context classes, then fine-tuned on
a new concept with a few examples under each PEFT method. The overfitting
vs. editability tradeoff (CLIP-I vs CLIP-T) is probed with feature-space
similarities computed by the Rust harness (see `rust/src/coordinator/`).

Model: MLP denoiser `eps_hat = f(x_t, t, cond)` with two adapted square
hidden layers — the layers every method in Table 2 adapts.
"""

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .adapters import AdapterConfig, adapt_weight, adapter_entries, adapter_init
from .flat import ParamSpec, adam_update

ADAPTED_DN = ("h1", "h2")


class DenoiserConfig:
    def __init__(self, img: int = 8, hidden: int = 128, conds: int = 10,
                 tsteps: int = 50, batch: int = 32):
        self.img = img          # images are img*img
        self.dim = img * img
        self.hidden = hidden
        self.conds = conds      # context classes + 1 concept token (last id)
        self.tsteps = tsteps
        self.batch = batch

    def base_spec(self) -> ParamSpec:
        c = self
        return ParamSpec([
            ("temb", (c.tsteps, c.hidden)),
            ("cemb", (c.conds, c.hidden)),
            ("win", (c.dim, c.hidden)),
            ("h1", (c.hidden, c.hidden)),
            ("h2", (c.hidden, c.hidden)),
            ("wout", (c.hidden, c.dim)),
        ])

    def adapter_spec(self, acfg: AdapterConfig) -> ParamSpec:
        entries = []
        for lname in ADAPTED_DN:
            entries += adapter_entries(acfg, lname, self.hidden, self.hidden)
        return ParamSpec(entries)

    def init_base(self, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        spec = self.base_spec()
        out = {}
        for name, shape in spec.entries:
            out[name] = (rng.standard_normal(shape) / np.sqrt(shape[0])).astype(np.float32)
        return spec.pack_np(out)

    def init_adapters(self, acfg: AdapterConfig, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        spec = self.adapter_spec(acfg)
        out = {}
        for lname in ADAPTED_DN:
            out.update(adapter_init(acfg, lname, self.hidden, self.hidden, rng))
        return spec.pack_np(out)

    # Linear (DDPM) noise schedule, matching the Rust sampler.
    def alphas_bar(self) -> np.ndarray:
        betas = np.linspace(1e-3, 0.2, self.tsteps, dtype=np.float64)
        return np.cumprod(1.0 - betas).astype(np.float32)


def predict_eps(cfg: DenoiserConfig, acfg: AdapterConfig,
                base: Dict[str, jnp.ndarray], adapt: Dict[str, jnp.ndarray],
                x_t: jnp.ndarray, t: jnp.ndarray, cond: jnp.ndarray) -> jnp.ndarray:
    """x_t: (B, dim); t: (B,) int32; cond: (B,) int32 → eps_hat (B, dim)."""
    def w(lname):
        bw = base[lname]
        if acfg.method == "ft":
            return bw
        return adapt_weight(acfg, lname, bw, adapt)

    h = x_t @ base["win"] + base["temb"][t] + base["cemb"][cond]
    h = jax.nn.silu(h)
    h = h + jax.nn.silu(h @ w("h1"))
    h = h + jax.nn.silu(h @ w("h2"))
    return h @ base["wout"]


def make_steps(cfg: DenoiserConfig, acfg: AdapterConfig):
    """(train_step, predict, n_train, n_frozen) for AOT lowering.

    train(trainable, m, v, step, lr, frozen, x0, cond, t, eps)
      -> (trainable', m', v', loss)                 [eps-prediction MSE]
    predict(trainable, frozen, x_t, t, cond) -> eps_hat
      (the Rust coordinator runs the DDIM reverse loop around this)
    """
    base_spec = cfg.base_spec()
    adapt_spec = cfg.adapter_spec(acfg)
    is_ft = acfg.method == "ft"
    abar = jnp.asarray(cfg.alphas_bar())

    def unpack(trainable, frozen):
        if is_ft:
            return base_spec.unpack(trainable), {}
        return base_spec.unpack(frozen), adapt_spec.unpack(trainable)

    def loss_fn(trainable, frozen, x0, cond, t, eps):
        base, adapt = unpack(trainable, frozen)
        a = abar[t][:, None]
        x_t = jnp.sqrt(a) * x0 + jnp.sqrt(1.0 - a) * eps
        eps_hat = predict_eps(cfg, acfg, base, adapt, x_t, t, cond)
        return ((eps_hat - eps) ** 2).mean()

    def train_step(trainable, m, v, step, lr, frozen, x0, cond, t, eps):
        loss, grad = jax.value_and_grad(loss_fn)(trainable, frozen, x0, cond, t, eps)
        new_t, new_m, new_v = adam_update(trainable, m, v, step, lr, grad)
        return new_t, new_m, new_v, loss

    def predict(trainable, frozen, x_t, t, cond):
        base, adapt = unpack(trainable, frozen)
        return predict_eps(cfg, acfg, base, adapt, x_t, t, cond)

    n_train = base_spec.size if is_ft else adapt_spec.size
    n_frozen = 1 if is_ft else base_spec.size
    return train_step, predict, n_train, n_frozen
