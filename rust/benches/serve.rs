//! Bench: the multi-tenant serving engine's three paths and the end-to-end
//! Zipf workload. Isolates what the `serve-bench` CLI measures in vivo:
//!   merge_cold      — full adapter merge (the cost the cache amortizes)
//!   gemm_hot        — dense forward through cached merged layers
//!   apply_factorized— structured Q apply on top of the base GEMM
//!   engine_zipf     — whole engine under a Zipf-popular request trace

use std::sync::Arc;
use std::time::Duration;

use gsoft::data::zipf::Zipf;
use gsoft::linalg::Mat;
use gsoft::serve::{synthetic, CachedModel, Engine, EngineOpts, MergedCache, TenantId};
use gsoft::util::bench::{black_box, Bench};
use gsoft::util::rng::Rng;

fn main() {
    let mut bench = Bench::new("serve");
    let mut rng = Rng::new(7);

    let (tenants, layers, d, block) = (64usize, 4usize, 64usize, 8usize);
    let registry = synthetic(tenants, layers, d, block, 1).expect("synthetic registry");
    let spec = Arc::clone(&registry.base().spec);
    let layer_names: Vec<String> = spec
        .entries
        .iter()
        .filter(|(_, s)| s.len() == 2 && s[0] == s[1])
        .map(|(n, _)| n.clone())
        .collect();

    // Cold merge (tenant 0 = GSOFT).
    bench.bench("merge_cold/gsoft_d64_b8_l4", || {
        black_box(registry.merge(0).unwrap())
    });

    // Hot path: dense GEMM through the merged layers, batch of 16.
    let merged = registry.merge(0).unwrap();
    let layer_mats: Vec<Mat> = layer_names
        .iter()
        .map(|n| Mat::from_f32(d, d, spec.view(&merged, n).unwrap()))
        .collect();
    let x = Mat::randn(d, 16, 0.5, &mut rng);
    bench.bench_with_elements("gemm_hot/d64_t16", Some((layers * d * d * 16) as f64), || {
        let mut z = x.clone();
        for w in &layer_mats {
            z = w.matmul(&z);
        }
        black_box(z)
    });

    // Cache ops at serving granularity.
    let mut cache = MergedCache::new(64 << 20);
    cache.insert(
        0,
        CachedModel {
            flat: Arc::new(merged.clone()),
            layers: layer_mats.clone(),
            params_crc: 0,
        },
    );
    bench.bench("cache_hit_lookup", || black_box(cache.get(0)));

    // Registry construction cost on its own (not part of serving).
    bench.bench("registry_build/64t_l4_d64", || {
        black_box(synthetic(tenants, layers, d, block, 1).unwrap())
    });

    // Steady-state engine throughput under Zipf traffic: one long-lived
    // engine, so the first pass pays the cold merges and later passes
    // measure the warmed cache — the deployment regime serve-bench's
    // end-to-end numbers complement.
    let zipf = Zipf::new(tenants, 1.1);
    let trace = zipf.trace(512, &mut rng);
    let inputs: Vec<Vec<f32>> = (0..512).map(|_| rng.normal_vec(d, 0.5)).collect();
    let engine = Engine::new(
        synthetic(tenants, layers, d, block, 1).unwrap(),
        EngineOpts {
            workers: 4,
            max_batch: 16,
            max_wait: Duration::from_micros(500),
            ..EngineOpts::default()
        },
    )
    .unwrap();
    bench.measure_time(Duration::from_millis(1500));
    bench.bench_with_elements("engine_zipf_steady/64t_512req", Some(512.0), || {
        let handles: Vec<_> = trace
            .iter()
            .zip(inputs.iter())
            .map(|(&t, input)| engine.submit(t as TenantId, input.clone()).unwrap())
            .collect();
        for h in handles {
            h.wait().unwrap();
        }
        black_box(())
    });
    let report = engine.finish();
    println!(
        "[serve bench] steady-state cache hit-rate: {:.3} ({} merges)",
        report.cache.hit_rate(),
        report.metrics.merges
    );

    bench.finish();
}
