//! Bench: the persistent tiered adapter store's primitives. Isolates what
//! the `store-bench` CLI measures in vivo:
//!   gsad_encode     — GSAD adapter record serialization
//!   store_put       — durable (synced) append of one adapter
//!   store_get       — indexed read + CRC verify of one adapter
//!   log_replay      — cold-boot open of an N-tenant log
//!   compaction      — rewrite of a half-garbage log
//!   spill_round_trip— merged-weight spill write + read-back

use gsoft::serve::synthetic;
use gsoft::store::gsad;
use gsoft::store::{AdapterStore, LogOpts, SegmentLog, SpillTier};
use gsoft::util::bench::{black_box, Bench};
use gsoft::util::tmp::unique_temp_dir;

fn main() {
    let mut bench = Bench::new("store");
    let dir = unique_temp_dir("bench_store");

    let (tenants, layers, d, block) = (64usize, 4usize, 64usize, 8usize);
    let registry = synthetic(tenants, layers, d, block, 1).expect("synthetic registry");
    let entries: Vec<_> = registry
        .tenant_ids()
        .into_iter()
        .map(|t| (t, registry.get(t).unwrap()))
        .collect();

    bench.bench("gsad_encode/gsoft_d64_b8_l4", || {
        black_box(gsad::encode_adapter(entries[0].0, &entries[0].1))
    });

    // Durable single-adapter append (the synced write is the cost).
    let mut put_store = AdapterStore::open(dir.join("put")).unwrap();
    let mut i = 0usize;
    bench.bench("store_put/synced_append", || {
        // Rotate tenants so compaction churn stays realistic.
        let (t, e) = &entries[i % entries.len()];
        i += 1;
        put_store.put(*t, e).unwrap();
    });

    let mut get_store = AdapterStore::open(dir.join("get")).unwrap();
    for (t, e) in &entries {
        get_store.put(*t, e).unwrap();
    }
    bench.bench("store_get/indexed_read", || {
        black_box(get_store.get(entries[7].0).unwrap())
    });

    // Cold-boot replay of the 64-tenant log.
    bench.bench("log_replay/64t", || {
        black_box(AdapterStore::open(dir.join("get")).unwrap().len())
    });

    // Compaction of a log that is half superseded versions.
    bench.bench("compaction/64t_half_garbage", || {
        let cdir = unique_temp_dir("bench_store_compact");
        let opts = LogOpts {
            garbage_threshold: 1.1, // manual trigger only
            min_compact_bytes: u64::MAX,
        };
        let mut log = SegmentLog::open(cdir.join("adapters.log"), opts).unwrap();
        for (t, e) in &entries {
            let payload = gsad::encode_adapter(*t, e);
            log.append(*t, &payload).unwrap();
            log.append(*t, &payload).unwrap(); // superseded duplicate
        }
        log.compact().unwrap();
        let bytes = log.file_bytes();
        drop(log);
        let _ = std::fs::remove_dir_all(&cdir);
        black_box(bytes)
    });

    // Spill tier round trip at merged-model size.
    let merged = registry.merge(0).unwrap();
    let mut tier = SpillTier::open(dir.join("spill"), 1 << 30).unwrap();
    let crc = gsad::params_crc(&entries[0].1);
    bench.bench_with_elements(
        "spill_round_trip/d64_l4",
        Some(merged.len() as f64),
        || {
            tier.put(0, crc, &merged).unwrap();
            black_box(tier.get(0, crc).unwrap())
        },
    );

    let _ = std::fs::remove_dir_all(&dir);
    bench.finish();
}
