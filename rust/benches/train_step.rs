//! Bench: end-to-end PJRT train/eval step latency per method — the
//! systems counterpart of Table 2's "training time" column and the §7.1
//! efficiency discussion (GSOFT m=2 vs BOFT's deeper product), measured
//! through the real artifact path (Pallas kernels in HLO, executed by the
//! Rust runtime). Requires `make artifacts`.

use std::time::Duration;

use gsoft::runtime::{Runtime, Tensor};
use gsoft::util::bench::{black_box, Bench};
use gsoft::util::rng::Rng;

fn inputs_for(exe: &gsoft::runtime::Executable, rng: &mut Rng) -> Vec<Tensor> {
    exe.meta
        .inputs
        .iter()
        .map(|m| {
            let n: usize = m.shape.iter().product();
            if m.dtype == "float32" {
                Tensor::f32(m.shape.clone(), (0..n).map(|_| rng.normal_f32(0.01)).collect())
            } else {
                Tensor::i32(m.shape.clone(), vec![1; n])
            }
        })
        .collect()
}

fn main() {
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping train_step bench (no artifacts): {e}");
            return;
        }
    };
    let mut bench = Bench::new("train_step");
    bench.measure_time(Duration::from_secs(3));
    let mut rng = Rng::new(3);

    // Table-1 family: the per-step cost of each fine-tuning method.
    for method in ["ft", "lora", "oft", "boft", "gsoft", "double_gsoft"] {
        let exe = rt.load(&format!("cls_{method}_train")).unwrap();
        let inputs = inputs_for(&exe, &mut rng);
        bench.bench(&format!("cls_train/{method}"), || {
            black_box(exe.run(&inputs).unwrap())
        });
        let exe = rt.load(&format!("cls_{method}_eval")).unwrap();
        let inputs = inputs_for(&exe, &mut rng);
        bench.bench(&format!("cls_eval/{method}"), || {
            black_box(exe.run(&inputs).unwrap())
        });
    }

    // Table-2 family (denoiser).
    for method in ["ft", "lora4", "boft8m4", "gsoft8", "dgsoft8"] {
        let exe = rt.load(&format!("dn_{method}_train")).unwrap();
        let inputs = inputs_for(&exe, &mut rng);
        bench.bench(&format!("dn_train/{method}"), || {
            black_box(exe.run(&inputs).unwrap())
        });
    }

    // Table-3 family: SOC vs GS-SOC per-step (the Speedup column).
    for variant in ["soc", "g4_0_mmp_p", "g4_1_mmp_p", "g4_2_mmp_p", "g4_4_mmp_p"] {
        let exe = rt.load(&format!("lip_{variant}_train")).unwrap();
        let inputs = inputs_for(&exe, &mut rng);
        bench.bench(&format!("lip_train/{variant}"), || {
            black_box(exe.run(&inputs).unwrap())
        });
    }

    bench.finish();
}
