//! Bench: the CPU kernel subsystem in isolation (DESIGN.md §Perf) —
//!   gemm_naive      — the reference ikj loop (pre-kernel Mat::matmul)
//!   gemm_blocked    — cache-blocked register-tiled GEMM, 1 thread
//!   gemm_parallel   — same, row panels across the persistent pool
//!   dense_merged    — dispatched dense Q·X (the merged-adapter path)
//!   fused_chain     — fused group-and-shuffle factorized apply
//!   fused_batched   — batched multi-RHS fused apply
//! `gsoft kernel-bench` sweeps the same paths across a (d, b, m, batch)
//! grid and writes BENCH_kernels.json.

use gsoft::gs::GsChain;
use gsoft::kernel::{self, KernelCtx};
use gsoft::linalg::Mat;
use gsoft::util::bench::{black_box, Bench};
use gsoft::util::rng::Rng;

fn main() {
    let mut bench = Bench::new("kernels");
    let mut rng = Rng::new(11);
    let ctx = KernelCtx::default();

    for (d, t) in [(128usize, 32usize), (256, 32)] {
        let a = Mat::randn(d, d, 1.0, &mut rng);
        let x = Mat::randn(d, t, 1.0, &mut rng);
        let elems = Some((d * d * t) as f64);
        bench.bench_with_elements(&format!("gemm_naive/d{d}_t{t}"), elems, || {
            black_box(kernel::gemm_naive(&a, &x))
        });
        bench.bench_with_elements(&format!("gemm_blocked/d{d}_t{t}"), elems, || {
            black_box(kernel::gemm_blocked(&a, &x, ctx.tile, 1))
        });
        bench.bench_with_elements(&format!("gemm_parallel/d{d}_t{t}"), elems, || {
            black_box(kernel::gemm_blocked(&a, &x, ctx.tile, ctx.workers))
        });
    }

    for (d, b, t) in [(256usize, 8usize, 32usize), (256, 16, 32)] {
        let chain = GsChain::gs_kn(d, b, 2, &mut rng, true);
        let q = chain.to_dense();
        let x = Mat::randn(d, t, 1.0, &mut rng);
        let fused_elems = (2 * d * b * t) as f64; // m·d·b MACs per column
        bench.bench_with_elements(
            &format!("dense_merged/d{d}_b{b}_t{t}"),
            Some((d * d * t) as f64),
            || black_box(ctx.gemm(&q, &x)),
        );
        bench.bench_with_elements(
            &format!("fused_chain/d{d}_b{b}_t{t}"),
            Some(fused_elems),
            || black_box(kernel::chain_apply(&chain, &x, &ctx)),
        );
        let xs: Vec<Mat> = (0..8).map(|_| Mat::randn(d, t, 1.0, &mut rng)).collect();
        bench.bench_with_elements(
            &format!("fused_batched_x8/d{d}_b{b}_t{t}"),
            Some(fused_elems * 8.0),
            || black_box(kernel::chain_apply_batch(&chain, &xs, &ctx)),
        );
    }

    bench.finish();
}
