//! Bench: applying a d×d orthogonal transform to a d×T activation batch —
//! the paper's central computational-efficiency claim (§5.2 / Table 2's
//! training-time column). Compares, in the exact Rust algebra:
//!   dense Q · X                      (full fine-tune / merged inference)
//!   OFT (1 block-diagonal factor)
//!   GSOFT (2 factors + shuffles)     — ours, m = 2
//!   BOFT-style butterfly (m = 1 + log2 r factors)
//! plus the AOT kernel path (`quickstart_gs_apply`) through PJRT.

use gsoft::gs::{GsChain, GsSpec};
use gsoft::linalg::Mat;
use gsoft::util::bench::{black_box, Bench};
use gsoft::util::rng::Rng;

fn main() {
    let mut bench = Bench::new("gs_apply");
    let mut rng = Rng::new(42);

    for (d, b, t) in [(256usize, 8usize, 32usize), (1024, 32, 32)] {
        let r = d / b;
        let x = Mat::randn(d, t, 1.0, &mut rng);

        // Dense baseline.
        let q_dense = GsSpec::gsoft(d, b)
            .random_orthogonal_member(&mut rng)
            .to_dense();
        bench.bench_with_elements(
            &format!("dense_qx/d{d}_t{t}"),
            Some((d * d * t) as f64),
            || black_box(q_dense.matmul(&x)),
        );

        // OFT: single block-diagonal factor.
        let oft = GsChain::gs_kn(d, b, 1, &mut rng, true);
        bench.bench_with_elements(
            &format!("oft_m1/d{d}_b{b}_t{t}"),
            Some((r * b * b * t) as f64),
            || black_box(oft.apply(&x)),
        );

        // GSOFT: m = 2 (ours).
        let gs = GsChain::gs_kn(d, b, 2, &mut rng, true);
        bench.bench_with_elements(
            &format!("gsoft_m2/d{d}_b{b}_t{t}"),
            Some((2 * r * b * b * t) as f64),
            || black_box(gs.apply(&x)),
        );

        // Butterfly at full density depth (what BOFT needs).
        let m_bf = 1 + (r as f64).log2().ceil() as usize;
        let bf = GsChain::butterfly(d, b, m_bf, &mut rng, true);
        bench.bench_with_elements(
            &format!("butterfly_m{m_bf}/d{d}_b{b}_t{t}"),
            Some((bf.param_count() * t) as f64),
            || black_box(bf.apply(&x)),
        );

        // GS chain at butterfly depth (isolates the factor-count effect).
        let gs6 = GsChain::gs_kn(d, b, m_bf, &mut rng, true);
        bench.bench(&format!("gs_m{m_bf}/d{d}_b{b}_t{t}"), || {
            black_box(gs6.apply(&x))
        });
    }

    // AOT kernel path (if artifacts are built).
    if let Ok(rt) = gsoft::runtime::Runtime::new("artifacts") {
        if let Ok(exe) = rt.load("quickstart_gs_apply") {
            let r = exe.meta.extra_usize("r").unwrap();
            let b = exe.meta.extra_usize("b").unwrap();
            let d = exe.meta.extra_usize("d").unwrap();
            let t = exe.meta.extra_usize("t").unwrap();
            let lp: Vec<f32> = (0..r * b * b).map(|_| rng.normal_f32(0.3)).collect();
            let rp: Vec<f32> = (0..r * b * b).map(|_| rng.normal_f32(0.3)).collect();
            let x: Vec<f32> = (0..d * t).map(|_| rng.normal_f32(1.0)).collect();
            let inputs = [
                gsoft::runtime::Tensor::f32(vec![r, b, b], lp),
                gsoft::runtime::Tensor::f32(vec![r, b, b], rp),
                gsoft::runtime::Tensor::f32(vec![d, t], x),
            ];
            bench.bench(&format!("pjrt_kernel/d{d}_b{b}_t{t}"), || {
                black_box(exe.run(&inputs).unwrap())
            });
        }
    }

    bench.finish();
}
