//! Bench + verification sweep for Theorem 2 (Figure 5): cost of the exact
//! support computation and the (b, r) → minimal-m landscape for GS vs
//! block-butterfly permutations.

use gsoft::gs::density::{
    butterfly_min_factors, chain_support, empirical_min_factors, gs_min_factors, PermFamily,
};
use gsoft::util::bench::{black_box, Bench};

fn main() {
    let mut bench = Bench::new("density");

    for (d, b) in [(256usize, 8usize), (1024, 32), (4096, 64)] {
        bench.bench(&format!("support_m2/d{d}_b{b}"), || {
            black_box(chain_support(d, b, 2, PermFamily::GsKn).nnz())
        });
    }

    // The Theorem-2 landscape (also printed as a verification table).
    println!("\n(b, r) -> minimal m for density, measured vs formula:");
    println!("{:>6} {:>6} {:>10} {:>10} {:>12} {:>12}", "b", "r", "GS meas", "GS form", "BF meas", "BF form");
    for (b, r) in [
        (2usize, 8usize),
        (4, 16),
        (8, 8),
        (8, 64),
        (16, 16),
        (32, 32),
    ] {
        let d = b * r;
        let gs_meas = empirical_min_factors(d, b, PermFamily::GsKn, 16).unwrap();
        let bf_meas = empirical_min_factors(d, b, PermFamily::Butterfly, 16).unwrap();
        let gs_form = gs_min_factors(b, r);
        let bf_form = butterfly_min_factors(r);
        println!(
            "{b:>6} {r:>6} {gs_meas:>10} {gs_form:>10} {bf_meas:>12} {bf_form:>12}"
        );
        assert_eq!(gs_meas, gs_form, "Theorem 2 (GS) violated at b={b}, r={r}");
        assert_eq!(bf_meas, bf_form, "butterfly formula violated at b={b}, r={r}");
    }

    bench.finish();
}
