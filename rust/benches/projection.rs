//! Bench: Algorithm 1 (projection onto the GS class) — blockwise Jacobi
//! SVD over the permutation-routed blocks — plus its SVD/QR/Cayley
//! substrate primitives.

use gsoft::gs::{project, GsSpec};
use gsoft::linalg::{cayley_unconstrained, qr, svd, Mat};
use gsoft::util::bench::{black_box, Bench};
use gsoft::util::rng::Rng;

fn main() {
    let mut bench = Bench::new("projection");
    let mut rng = Rng::new(7);

    for (d, b) in [(64usize, 8usize), (128, 8), (256, 16)] {
        let spec = GsSpec::gsoft(d, b);
        let a = Mat::randn(d, d, 1.0, &mut rng);
        bench.bench(&format!("algorithm1/d{d}_b{b}"), || {
            black_box(project(&a, &spec))
        });
    }

    for n in [8usize, 16, 32, 64] {
        let a = Mat::randn(n, n, 1.0, &mut rng);
        bench.bench(&format!("jacobi_svd/{n}x{n}"), || {
            black_box(svd::svd(&a))
        });
        bench.bench(&format!("householder_qr/{n}x{n}"), || {
            black_box(qr::qr(&a))
        });
        bench.bench(&format!("cayley/{n}x{n}"), || {
            black_box(cayley_unconstrained(&a))
        });
    }

    bench.finish();
}
