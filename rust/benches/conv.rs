//! Bench: the direct GS-SOC convolution runtime in isolation
//! (DESIGN.md §Perf) —
//!   conv_direct     — fused AXPY tap loop
//!   conv_im2col     — patch gather into the cache-blocked GEMM
//!   conv_dispatch   — KernelCtx-chosen path
//!   conv_exp        — streaming truncated convolution exponential
//!   gs_soc_layer    — full P_out · exp(grouped skew conv) · P_in pass
//!   dense_apply     — materialized (c·H·W)² operator baseline
//! `gsoft conv-bench` sweeps the same paths across a (c, k, H·W, groups,
//! batch) grid and writes BENCH_conv.json.

use gsoft::kernel::{conv_apply, conv_exp_apply, GsSocLayer, KernelCtx};
use gsoft::linalg::Mat;
use gsoft::util::bench::{black_box, Bench};
use gsoft::util::rng::Rng;

fn main() {
    let mut bench = Bench::new("conv");
    let mut rng = Rng::new(19);
    let ctx = KernelCtx::default();
    let direct_ctx = KernelCtx {
        naive_below_flops: usize::MAX,
        ..ctx
    };
    let im2col_ctx = KernelCtx {
        naive_below_flops: 0,
        ..ctx
    };
    let terms = 6;

    for (c, hw, groups, t) in [
        (8usize, 8usize, 2usize, 8usize), // small: dense baseline feasible
        (16, 16, 1, 8),
        (32, 16, 4, 8),
    ] {
        let layer = GsSocLayer::random(c, 3, groups, hw, hw, terms, 0.02, &mut rng);
        let kern = layer.kern.clone();
        let d = c * hw * hw;
        let x = Mat::randn(d, t, 1.0, &mut rng);
        let tag = format!("c{c}_{hw}x{hw}_g{groups}_t{t}");
        // One conv pass moves c·(c/g)·k²·hw² MACs per column.
        let elems = Some((c * (c / groups) * 9 * hw * hw * t) as f64);
        bench.bench_with_elements(&format!("conv_direct/{tag}"), elems, || {
            black_box(conv_apply(&kern, &x, hw, hw, &direct_ctx))
        });
        bench.bench_with_elements(&format!("conv_im2col/{tag}"), elems, || {
            black_box(conv_apply(&kern, &x, hw, hw, &im2col_ctx))
        });
        bench.bench_with_elements(&format!("conv_dispatch/{tag}"), elems, || {
            black_box(conv_apply(&kern, &x, hw, hw, &ctx))
        });
        bench.bench(&format!("conv_exp/{tag}"), || {
            black_box(conv_exp_apply(&kern, &x, hw, hw, terms, &ctx))
        });
        bench.bench(&format!("gs_soc_layer/{tag}"), || {
            black_box(layer.apply(&x, &ctx))
        });
        if d <= 1024 {
            let q = kern.to_dense().to_matrix(hw, hw);
            bench.bench_with_elements(
                &format!("dense_apply/{tag}"),
                Some((d * d * t) as f64),
                || black_box(ctx.gemm(&q, &x)),
            );
        }
    }

    bench.finish();
}
