//! Integration tests over the full stack: artifact loading, PJRT
//! execution, training-loop behaviour, adapter merging and cross-layer
//! agreement (Rust exact algebra vs the Pallas/HLO kernel path).
//!
//! These tests require `make artifacts`; they are skipped (pass
//! trivially, with a note) when `artifacts/manifest.json` is absent so
//! `cargo test` works on a fresh checkout.

use gsoft::coordinator::flatspec::FlatSpec;
use gsoft::coordinator::merge::{gsoft_q, merge_gsoft};
use gsoft::coordinator::schedule::LrSchedule;
use gsoft::coordinator::trainer::{Trainer, TrainState};
use gsoft::data::synglue::{Task, TaskGen};
use gsoft::linalg::Mat;
use gsoft::runtime::{Runtime, Tensor};
use gsoft::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    match Runtime::new("artifacts") {
        Ok(rt) => Some(rt),
        Err(_) => {
            eprintln!("NOTE: artifacts/ not built; integration test skipped");
            None
        }
    }
}

#[test]
fn quickstart_kernel_matches_exact_algebra() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("quickstart_gs_apply").unwrap();
    let r = exe.meta.extra_usize("r").unwrap();
    let b = exe.meta.extra_usize("b").unwrap();
    let d = exe.meta.extra_usize("d").unwrap();
    let t = exe.meta.extra_usize("t").unwrap();
    let mut rng = Rng::new(5);
    let lp: Vec<f32> = (0..r * b * b).map(|_| rng.normal_f32(0.4)).collect();
    let rp: Vec<f32> = (0..r * b * b).map(|_| rng.normal_f32(0.4)).collect();
    let x: Vec<f32> = (0..d * t).map(|_| rng.normal_f32(1.0)).collect();
    let out = exe
        .run(&[
            Tensor::f32(vec![r, b, b], lp.clone()),
            Tensor::f32(vec![r, b, b], rp.clone()),
            Tensor::f32(vec![d, t], x.clone()),
        ])
        .unwrap();
    let y = out[0].as_f32().unwrap();

    let q = gsoft_q(&lp, &rp, d, b);
    let yx = q.apply(&Mat::from_f32(d, t, &x));
    for i in 0..d {
        for j in 0..t {
            assert!(
                (yx[(i, j)] - y[i * t + j] as f64).abs() < 1e-4,
                "kernel/exact mismatch at ({i},{j})"
            );
        }
    }
    // And Q is orthogonal.
    assert!(q.to_dense().is_orthogonal(1e-6));
}

#[test]
fn training_reduces_loss_and_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("cls_gsoft_train").unwrap();
    let base = rt.load_init("cls_base").unwrap();
    let adapter = rt.load_init("cls_gsoft_adapter").unwrap();
    let vocab = exe.meta.extra_usize("vocab").unwrap();
    let seq = exe.meta.extra_usize("seq").unwrap();
    let batch = exe.meta.extra_usize("batch").unwrap();
    let gen = TaskGen::new(Task::Qnli, vocab, seq);

    let run = |steps: usize| -> Vec<f32> {
        let trainer = Trainer::new(exe.clone(), base.clone());
        let mut state = TrainState::new(adapter.clone());
        let mut rng = Rng::new(99);
        trainer
            .run(&mut state, steps, LrSchedule::Const(3e-3), &mut rng, |_, r| {
                let (xs, ys) = gen.batch(batch, r);
                vec![
                    Tensor::i32(vec![batch, seq], xs),
                    Tensor::i32(vec![batch], ys),
                ]
            })
            .unwrap()
            .losses
    };
    let losses = run(30);
    assert!(losses.iter().all(|l| l.is_finite()));
    let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let tail: f32 = losses[25..].iter().sum::<f32>() / 5.0;
    assert!(tail < head, "loss should drop: {head} -> {tail}");
    // Bitwise determinism of the whole loop (seeded data + PJRT CPU).
    let again = run(30);
    assert_eq!(losses, again, "training loop must be deterministic");
}

#[test]
fn identity_adapter_matches_ft_eval() {
    let Some(rt) = runtime() else { return };
    // GSOFT adapter at zero-init must produce exactly the frozen model's
    // predictions (identity Q) — checked through two different artifacts.
    let eval_gs = rt.load("cls_gsoft_eval").unwrap();
    let eval_ft = rt.load("cls_ft_eval").unwrap();
    let base = rt.load_init("cls_base").unwrap();
    let adapter = rt.load_init("cls_gsoft_adapter").unwrap();
    let batch = eval_gs.meta.extra_usize("batch").unwrap();
    let seq = eval_gs.meta.extra_usize("seq").unwrap();
    let gen = TaskGen::new(Task::Mnli, 512, seq);
    let mut rng = Rng::new(3);
    let (xs, ys) = gen.batch(batch, &mut rng);
    let a = eval_gs
        .run(&[
            Tensor::f32(vec![adapter.len()], adapter.clone()),
            Tensor::f32(vec![base.len()], base.clone()),
            Tensor::i32(vec![batch, seq], xs.clone()),
            Tensor::i32(vec![batch], ys.clone()),
        ])
        .unwrap();
    let b = eval_ft
        .run(&[
            Tensor::f32(vec![base.len()], base.clone()),
            Tensor::f32(vec![1], vec![0.0]),
            Tensor::i32(vec![batch, seq], xs),
            Tensor::i32(vec![batch], ys),
        ])
        .unwrap();
    let la = a[0].scalar().unwrap();
    let lb = b[0].scalar().unwrap();
    assert!(
        (la - lb).abs() < 2e-4 * lb.abs().max(1.0),
        "identity adapter loss {la} vs ft loss {lb}"
    );
    assert_eq!(a[2].as_i32().unwrap(), b[2].as_i32().unwrap(), "predictions");
}

#[test]
fn merged_adapter_reproduces_adapted_model() {
    let Some(rt) = runtime() else { return };
    let train = rt.load("cls_gsoft_train").unwrap();
    let base = rt.load_init("cls_base").unwrap();
    let block = train.meta.extra_usize("block").unwrap();
    let base_spec = FlatSpec::from_json(train.meta.extra.get("base_spec").unwrap()).unwrap();
    let adapter_spec =
        FlatSpec::from_json(train.meta.extra.get("adapter_spec").unwrap()).unwrap();
    // Random (non-trivial) adapter.
    let mut rng = Rng::new(21);
    let adapter: Vec<f32> = (0..adapter_spec.size()).map(|_| rng.normal_f32(0.2)).collect();
    let merged = merge_gsoft(&base, &adapter, &base_spec, &adapter_spec, block).unwrap();

    let eval_gs = rt.load("cls_gsoft_eval").unwrap();
    let eval_ft = rt.load("cls_ft_eval").unwrap();
    let batch = eval_gs.meta.extra_usize("batch").unwrap();
    let seq = eval_gs.meta.extra_usize("seq").unwrap();
    let gen = TaskGen::new(Task::Rte, 512, seq);
    for trial in 0..3 {
        let (xs, ys) = gen.batch(batch, &mut rng);
        let a = eval_gs
            .run(&[
                Tensor::f32(vec![adapter.len()], adapter.clone()),
                Tensor::f32(vec![base.len()], base.clone()),
                Tensor::i32(vec![batch, seq], xs.clone()),
                Tensor::i32(vec![batch], ys.clone()),
            ])
            .unwrap();
        let b = eval_ft
            .run(&[
                Tensor::f32(vec![merged.len()], merged.clone()),
                Tensor::f32(vec![1], vec![0.0]),
                Tensor::i32(vec![batch, seq], xs),
                Tensor::i32(vec![batch], ys),
            ])
            .unwrap();
        assert_eq!(
            a[2].as_i32().unwrap(),
            b[2].as_i32().unwrap(),
            "trial {trial}: merged model must predict identically"
        );
        let (la, lb) = (a[0].scalar().unwrap(), b[0].scalar().unwrap());
        assert!((la - lb).abs() < 5e-3 * lb.abs().max(1.0), "{la} vs {lb}");
    }
}

#[test]
fn lip_eval_outputs_are_consistent() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("lip_g4_1_mmp_p_eval").unwrap();
    let init = rt.load_init("lip_g4_1_mmp_p").unwrap();
    let batch = exe.meta.extra_usize("batch").unwrap();
    let img = exe.meta.extra_usize("img").unwrap();
    let in_ch = exe.meta.extra_usize("in_ch").unwrap();
    let (xs, ys) = gsoft::data::vision::batch(batch, &mut Rng::new(8));
    let out = exe
        .run(&[
            Tensor::f32(vec![init.len()], init),
            Tensor::f32(vec![1], vec![0.0]),
            Tensor::f32(vec![batch, img, img, in_ch], xs),
            Tensor::i32(vec![batch], ys),
        ])
        .unwrap();
    let loss = out[0].scalar().unwrap();
    let correct = out[1].scalar().unwrap();
    let robust = out[2].scalar().unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!(robust <= correct, "certified ⊆ correct");
    assert!(correct <= batch as f32);
}

#[test]
fn serving_engine_end_to_end_zipf_workload() {
    // Pure-Rust path — runs without artifacts: a synthetic multi-tenant
    // registry served under a Zipf trace must complete every request,
    // agree across serving paths, and show cache reuse for hot tenants.
    use gsoft::data::zipf::Zipf;
    use gsoft::serve::{synthetic, Engine, EngineOpts, TenantId};

    let tenants = 16usize;
    let registry = synthetic(tenants, 2, 16, 4, 33).unwrap();
    let engine = Engine::new(
        registry,
        EngineOpts {
            workers: 4,
            max_batch: 8,
            max_wait: std::time::Duration::from_micros(300),
            promote_after: Some(4),
            ..EngineOpts::default()
        },
    )
    .unwrap();
    let d = engine.input_dim();
    assert_eq!(d, 16);

    let zipf = Zipf::new(tenants, 1.2);
    let mut rng = Rng::new(4);
    let trace = zipf.trace(400, &mut rng);
    let handles: Vec<_> = trace
        .iter()
        .map(|&t| {
            let input = rng.normal_vec(d, 0.5);
            engine.submit(t as TenantId, input).unwrap()
        })
        .collect();
    let mut by_path = std::collections::HashMap::new();
    for h in handles {
        let out = h.wait().unwrap();
        assert_eq!(out.output.len(), d);
        assert!(out.output.iter().all(|x| x.is_finite()));
        *by_path.entry(out.path.name()).or_insert(0usize) += 1;
    }
    let report = engine.finish();
    assert_eq!(report.metrics.requests, 400);
    assert!(report.metrics.merges >= 1, "hot tenants must get promoted");
    assert!(
        report.cache.hits > 0,
        "Zipf head traffic must produce cache hits"
    );
    assert!(
        by_path.get("cached_dense").copied().unwrap_or(0) > 0,
        "paths seen: {by_path:?}"
    );
    assert_eq!(by_path.values().sum::<usize>(), 400);
}

#[test]
fn gs_soc_scenario_random_kernel_to_orthogonal_jacobian() {
    // End-to-end GS-SOC scenario, artifact-free: random grouped kernel →
    // skew-symmetrize → streaming conv_exp through the direct runtime →
    // the Jacobian agrees with the exact Eq. 2 `to_matrix` oracle and is
    // orthogonal at converged truncation.
    use gsoft::gs::conv::mat_exp;
    use gsoft::kernel::{conv_exp_apply, GroupedConv, KernelCtx};

    let ctx = KernelCtx::default();
    let mut rng = Rng::new(71);
    for &(c, k, groups, h, w) in &[(8usize, 3usize, 2usize, 3usize, 4usize), (6, 3, 3, 4, 3)] {
        let kern = GroupedConv::randn(c, c, k, groups, 0.03, &mut rng).skew_symmetrize();
        let d = c * h * w;
        let x = Mat::randn(d, 3, 1.0, &mut rng);
        let got = conv_exp_apply(&kern, &x, h, w, 18, &ctx);
        // Oracle: dense matrix exponential of the exact Eq. 2 matrix.
        let m = kern.to_dense().to_matrix(h, w);
        let j = mat_exp(&m, 24);
        assert!(
            j.is_orthogonal(1e-8),
            "skew Eq.2 exponential must be orthogonal: err={}",
            j.orthogonality_error()
        );
        assert!(
            got.fro_dist(&j.matmul(&x)) < 1e-7 * (1.0 + got.fro_norm()),
            "streaming conv_exp diverged from the dense oracle"
        );
    }
}

#[test]
fn gs_soc_layer_and_lipschitz_net_certify() {
    // Full GS-SOC layers (shuffle → exp → shuffle) against their dense
    // matrices, then a LipschitzNet stack certified ≤ 1 + 1e-6 by the
    // power-iteration bound and empirically non-expansive.
    use gsoft::kernel::{GsSocLayer, KernelCtx};
    use gsoft::runtime::LipschitzNet;

    let ctx = KernelCtx::default();
    let mut rng = Rng::new(72);
    let layer = GsSocLayer::random(8, 3, 2, 4, 3, 16, 0.03, &mut rng);
    let x = Mat::randn(layer.d(), 2, 1.0, &mut rng);
    let want = layer.to_matrix().matmul(&x);
    assert!(layer.apply(&x, &ctx).fro_dist(&want) < 1e-9 * (1.0 + want.fro_norm()));

    let net = LipschitzNet::random(3, 8, 3, 2, 4, 4, 16, 0.02, 99);
    let bound = net.lipschitz_bound(10, 5, &ctx);
    assert!(bound <= 1.0 + 1e-6, "certified bound {bound} exceeds 1");
    assert!(bound >= 1.0 - 1e-3, "degenerate bound {bound}");
    let a = Mat::randn(net.d(), 1, 1.0, &mut rng);
    let b = Mat::randn(net.d(), 1, 1.0, &mut rng);
    let num = (&net.forward(&a, &ctx) - &net.forward(&b, &ctx)).fro_norm();
    let den = (&a - &b).fro_norm();
    assert!(num <= den * (1.0 + 1e-6), "forward expanded: {num} vs {den}");
}

#[test]
fn serving_engine_round_trips_a_conv_gssoc_tenant() {
    // Serve-engine round trip for the ConvGsSoc adapter kind: the same
    // tenant must agree across factorized, cold-merge and cached paths,
    // and hot traffic must end on the cached path.
    use gsoft::serve::{synthetic_conv, Engine, EngineOpts, ServePath};

    let reg = synthetic_conv(3, 2, 4, 3, 2, 3, 3, 55).unwrap();
    let engine = Engine::new(
        reg,
        EngineOpts {
            workers: 2,
            max_batch: 4,
            max_wait: std::time::Duration::from_micros(200),
            poll_interval: std::time::Duration::from_micros(200),
            promote_after: Some(2),
            ..EngineOpts::default()
        },
    )
    .unwrap();
    let d = engine.input_dim();
    assert_eq!(d, 4 * 3 * 3);
    let input: Vec<f32> = (0..d).map(|i| ((i * 5 % 11) as f32) * 0.05 - 0.2).collect();
    let mut outputs = Vec::new();
    let mut paths = Vec::new();
    for _ in 0..4 {
        let out = engine.submit(1, input.clone()).unwrap().wait().unwrap();
        assert_eq!(out.output.len(), d);
        assert!(out.output.iter().all(|v| v.is_finite()));
        paths.push(out.path);
        outputs.push(out.output);
    }
    assert_eq!(paths[0], ServePath::Factorized);
    assert_eq!(paths[1], ServePath::ColdMerge);
    assert_eq!(*paths.last().unwrap(), ServePath::CachedDense);
    for out in &outputs[1..] {
        for (a, b) in out.iter().zip(outputs[0].iter()) {
            assert!((a - b).abs() < 1e-3, "serving paths disagree: {a} vs {b}");
        }
    }
    let report = engine.finish();
    assert_eq!(report.metrics.requests, 4);
    assert_eq!(report.metrics.merges, 1);
}

#[test]
fn monarch_family_serves_through_the_open_adapter_api() {
    // Acceptance scenario for the open AdapterFamily API: Monarch
    // (`P_1 L P_2 R`) exists only as `gsoft::adapter::monarch` plus one
    // registration line — yet the full serving ladder (factorized →
    // cold merge → cached dense) runs it, all paths agree, and the
    // GSAD fleet snapshot round-trips it bit-exactly.
    use gsoft::adapter::monarch;
    use gsoft::serve::{synthetic_of, Engine, EngineOpts, Registry, ServePath};
    use gsoft::util::tmp::unique_temp_dir;

    let reg = synthetic_of(&monarch::desc(4), 3, 2, 16, 4, 91).unwrap();
    // Fleet snapshot round-trip before the engine consumes the registry.
    let dir = unique_temp_dir("itest_monarch");
    reg.snapshot(dir.join("fleet.gsad")).unwrap();
    let restored = Registry::restore(dir.join("fleet.gsad")).unwrap();
    assert_eq!(restored.tenant_ids(), reg.tenant_ids());
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    for t in reg.tenant_ids() {
        assert_eq!(bits(&restored.merge(t).unwrap()), bits(&reg.merge(t).unwrap()));
    }

    let engine = Engine::new(
        reg,
        EngineOpts {
            workers: 2,
            max_batch: 4,
            max_wait: std::time::Duration::from_micros(200),
            poll_interval: std::time::Duration::from_micros(200),
            promote_after: Some(2),
            ..EngineOpts::default()
        },
    )
    .unwrap();
    let d = engine.input_dim();
    assert_eq!(d, 16);
    let input: Vec<f32> = (0..d).map(|i| ((i * 7 % 9) as f32) * 0.1 - 0.4).collect();
    let mut outputs = Vec::new();
    let mut paths = Vec::new();
    for _ in 0..4 {
        let out = engine.submit(1, input.clone()).unwrap().wait().unwrap();
        assert!(out.output.iter().all(|v| v.is_finite()));
        paths.push(out.path);
        outputs.push(out.output);
    }
    assert_eq!(paths[0], ServePath::Factorized);
    assert_eq!(paths[1], ServePath::ColdMerge);
    assert_eq!(*paths.last().unwrap(), ServePath::CachedDense);
    for out in &outputs[1..] {
        for (a, b) in out.iter().zip(outputs[0].iter()) {
            assert!((a - b).abs() < 1e-3, "monarch serving paths disagree: {a} vs {b}");
        }
    }
    let report = engine.finish();
    assert_eq!(report.metrics.merges, 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn conv_bench_record_is_deterministic_modulo_timing() {
    // Same seed ⇒ bit-identical BENCH_conv.json content once the timing
    // fields are stripped — configs, dimensions and numeric output
    // checksums included (the kernels are deterministic even on the
    // parallel paths).
    use gsoft::kernel::convbench::{record, strip_timing, ConvBenchOpts};
    use gsoft::kernel::KernelCtx;

    // `measure` shortens both bench windows (no process-global env
    // mutation — setenv races with getenv in a threaded test binary).
    let opts = ConvBenchOpts {
        smoke: true,
        seed: 9,
        measure: Some(std::time::Duration::from_millis(8)),
    };
    let ctx = KernelCtx::default();
    let (_, r1) = record(&opts, &ctx);
    let (_, r2) = record(&opts, &ctx);
    assert_eq!(
        strip_timing(&r1),
        strip_timing(&r2),
        "conv-bench record must be deterministic modulo timings"
    );
    // The stripped record still carries the meaningful payload.
    let cfgs = strip_timing(&r1);
    let cfgs = cfgs.get("configs").unwrap().as_arr().unwrap();
    assert!(!cfgs.is_empty());
    for c in cfgs {
        assert!(c.get("checksum").unwrap().as_f64().unwrap().is_finite());
        assert!(c.get("timings").is_none(), "timings must be stripped");
    }
}

#[test]
fn dn_predict_shapes_and_determinism() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("dn_gsoft8_predict").unwrap();
    let base = rt.load_init("dn_base").unwrap();
    let adapter = rt.load_init("dn_gsoft8_adapter").unwrap();
    let batch = exe.meta.extra_usize("batch").unwrap();
    let dim = exe.meta.extra_usize("dim").unwrap();
    let mut rng = Rng::new(9);
    let x: Vec<f32> = (0..batch * dim).map(|_| rng.normal_f32(1.0)).collect();
    let inputs = [
        Tensor::f32(vec![adapter.len()], adapter.clone()),
        Tensor::f32(vec![base.len()], base.clone()),
        Tensor::f32(vec![batch, dim], x),
        Tensor::i32(vec![batch], vec![3; batch]),
        Tensor::i32(vec![batch], vec![1; batch]),
    ];
    let a = exe.run(&inputs).unwrap();
    let b = exe.run(&inputs).unwrap();
    assert_eq!(a[0], b[0], "PJRT CPU must be deterministic");
    assert_eq!(a[0].shape(), &[batch, dim]);
}

#[test]
fn store_backed_engine_round_trips_bit_identically() {
    // Acceptance scenario for the persistent tiered adapter store: a
    // fleet registered through the store, with every in-memory structure
    // dropped and the store re-opened from disk, must serve bit-identical
    // outputs to the pre-restart in-memory engine on both the factorized
    // and the merged-dense path — for the mixed GSOFT/OFT/LoRA registry
    // and for ConvGsSoc orthogonal-conv tenants.
    use gsoft::adapter::monarch;
    use gsoft::serve::{
        synthetic, synthetic_conv, synthetic_of, Engine, EngineOpts, Registry, ServePath,
        TenantId,
    };
    use gsoft::store::AdapterStore;
    use gsoft::util::tmp::unique_temp_dir;

    let opts = || EngineOpts {
        workers: 1, // deterministic path sequence
        max_batch: 2,
        max_wait: std::time::Duration::from_micros(200),
        promote_after: Some(2),
        ..EngineOpts::default()
    };
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();

    let registries = vec![
        ("mixed", synthetic(4, 2, 8, 2, 61).unwrap()),
        ("conv", synthetic_conv(2, 2, 4, 3, 2, 2, 3, 62).unwrap()),
        // Monarch: registered via the open AdapterFamily API only — the
        // whole store/serve restart loop below runs it with zero
        // family-specific code anywhere in serve/ or store/.
        ("monarch", synthetic_of(&monarch::desc(3), 2, 2, 9, 3, 63).unwrap()),
    ];
    for (label, donor) in registries {
        let base_w = donor.base().weights.as_ref().clone();
        let base_spec = donor.base().spec.as_ref().clone();
        let tenants: Vec<TenantId> = donor.tenant_ids();
        let entries: Vec<_> = tenants
            .iter()
            .map(|&t| (t, donor.get(t).unwrap()))
            .collect();

        // Pre-restart, in-memory engine: factorized (request 1) then
        // cold-merged dense (request 2) per tenant.
        let engine = Engine::new(donor, opts()).unwrap();
        let d = engine.input_dim();
        let input: Vec<f32> = (0..d).map(|i| ((i * 5 % 11) as f32) * 0.07 - 0.3).collect();
        let mut before = Vec::new();
        for &t in &tenants {
            let a = engine.submit(t, input.clone()).unwrap().wait().unwrap();
            let b = engine.submit(t, input.clone()).unwrap().wait().unwrap();
            assert_eq!(
                (a.path, b.path),
                (ServePath::Factorized, ServePath::ColdMerge),
                "{label} tenant {t}: unexpected pre-restart paths"
            );
            before.push((bits(&a.output), bits(&b.output)));
        }
        engine.finish();

        // Register the fleet through the store, then drop every
        // in-memory structure.
        let dir = unique_temp_dir("itest_store");
        {
            let store = AdapterStore::open(&dir).unwrap();
            for (t, e) in &entries {
                store.put(*t, e).unwrap();
            }
        }
        drop(entries);

        // Re-open from disk: the store-backed registry hydrates lazily as
        // the engine touches tenants.
        let registry = Registry::with_store(
            base_w,
            base_spec,
            AdapterStore::open(&dir).unwrap(),
        )
        .unwrap();
        assert_eq!(registry.hydrated_len(), 0, "{label}: cold boot must be lazy");
        assert_eq!(registry.len(), tenants.len());
        let engine = Engine::new(registry, opts()).unwrap();
        for (i, &t) in tenants.iter().enumerate() {
            let a = engine.submit(t, input.clone()).unwrap().wait().unwrap();
            let b = engine.submit(t, input.clone()).unwrap().wait().unwrap();
            assert_eq!(
                (a.path, b.path),
                (ServePath::Factorized, ServePath::ColdMerge),
                "{label} tenant {t}: unexpected post-restart paths"
            );
            assert_eq!(
                bits(&a.output),
                before[i].0,
                "{label} tenant {t}: factorized output drifted across restart"
            );
            assert_eq!(
                bits(&b.output),
                before[i].1,
                "{label} tenant {t}: merged-dense output drifted across restart"
            );
        }
        engine.finish();
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn re_registered_tenant_survives_restart_bit_identically() {
    // Acceptance scenario for safe live re-registration over the sharded
    // store: a tenant whose adapter is replaced *while the engine serves
    // traffic* must (a) immediately serve the new model (stale-CRC hit
    // demotes the cached entry to a re-merge), and (b) after a full
    // restart — engine dropped, sharded log re-opened from disk — serve
    // bit-identical post-update outputs, because the registration
    // durably appended v2 before acknowledging.
    use gsoft::serve::{synthetic, Engine, EngineOpts, Registry, ServePath, TenantId};
    use gsoft::store::AdapterStore;
    use gsoft::util::tmp::unique_temp_dir;

    let opts = || EngineOpts {
        workers: 1, // deterministic path sequence
        max_batch: 2,
        max_wait: std::time::Duration::from_micros(200),
        promote_after: Some(1),
        ..EngineOpts::default()
    };
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();

    let donor = synthetic(4, 2, 8, 2, 71).unwrap();
    let base_w = donor.base().weights.as_ref().clone();
    let base_spec = donor.base().spec.as_ref().clone();
    let tenants: Vec<TenantId> = donor.tenant_ids();
    let entries: Vec<_> = tenants
        .iter()
        .map(|&t| (t, donor.get(t).unwrap()))
        .collect();
    // Same shapes, different params: the v2 adapter for tenant 0.
    let v2 = synthetic(4, 2, 8, 2, 72).unwrap().get(tenants[0]).unwrap();

    let dir = unique_temp_dir("itest_rereg");
    let registry = Registry::with_store(
        base_w.clone(),
        base_spec.clone(),
        AdapterStore::open_sharded(&dir, 4).unwrap(),
    )
    .unwrap();
    for (t, e) in &entries {
        registry.register(*t, e.clone()).unwrap();
    }
    drop(entries);

    let engine = Engine::new(registry, opts()).unwrap();
    let d = engine.input_dim();
    let input: Vec<f32> = (0..d).map(|i| ((i * 7 % 13) as f32) * 0.05 - 0.2).collect();
    let serve = |t: TenantId| engine.submit(t, input.clone()).unwrap().wait().unwrap();

    // Traffic before the update: tenant 0 merged and hot, the rest warm.
    assert_eq!(serve(tenants[0]).path, ServePath::ColdMerge);
    let old_hot = serve(tenants[0]);
    assert_eq!(old_hot.path, ServePath::CachedDense);
    let mut others_before = Vec::new();
    for &t in &tenants[1..] {
        others_before.push(bits(&serve(t).output));
    }

    // Live replacement under traffic: next hit detects the stale CRC and
    // re-merges v2 instead of serving the cached v1 model.
    engine.registry().register(tenants[0], v2).unwrap();
    let post = serve(tenants[0]);
    assert_eq!(post.path, ServePath::ColdMerge, "stale hit must demote to a merge");
    assert_ne!(post.output, old_hot.output, "post-update outputs must be v2's");
    let post_hot = serve(tenants[0]);
    assert_eq!(post_hot.path, ServePath::CachedDense);
    assert_eq!(bits(&post_hot.output), bits(&post.output));
    let post_bits = bits(&post.output);
    let report = engine.finish();
    assert_eq!(report.obs.counters["serve_cache_stale_crc_total"], 1);

    // Restart: every in-memory structure dropped, sharded log re-opened
    // from disk (the on-disk layout dictates the shard count).
    let registry =
        Registry::with_store(base_w, base_spec, AdapterStore::open(&dir).unwrap()).unwrap();
    assert_eq!(registry.hydrated_len(), 0, "cold boot must be lazy");
    assert_eq!(registry.len(), tenants.len());
    let engine = Engine::new(registry, opts()).unwrap();
    let serve = |t: TenantId| engine.submit(t, input.clone()).unwrap().wait().unwrap();
    let a = serve(tenants[0]);
    assert_eq!(a.path, ServePath::ColdMerge);
    assert_eq!(
        bits(&a.output),
        post_bits,
        "re-registered tenant's post-update output drifted across restart"
    );
    let b = serve(tenants[0]);
    assert_eq!(b.path, ServePath::CachedDense);
    assert_eq!(bits(&b.output), post_bits);
    for (i, &t) in tenants[1..].iter().enumerate() {
        assert_eq!(
            bits(&serve(t).output),
            others_before[i],
            "tenant {t}: v1 output drifted across restart"
        );
    }
    engine.finish();
    let _ = std::fs::remove_dir_all(&dir);
}
