//! Result tables: markdown rendering + JSON persistence under `results/`.

use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;

/// A simple results table (strings, pre-formatted numbers).
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// GitHub-flavored markdown.
    pub fn markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row.iter()) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = format!("### {}\n\n", self.title);
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths.iter()) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::Str(self.title.clone())),
            (
                "headers",
                Json::Arr(self.headers.iter().map(|h| Json::Str(h.clone())).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                        .collect(),
                ),
            ),
        ])
    }

    /// Print to stdout and persist markdown + JSON under `results/`.
    pub fn emit(&self, slug: &str) -> Result<()> {
        println!("\n{}", self.markdown());
        let dir = Path::new("results");
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{slug}.md")), self.markdown())?;
        std::fs::write(dir.join(format!("{slug}.json")), self.to_json().pretty())?;
        println!("[results] wrote results/{slug}.md and results/{slug}.json");
        Ok(())
    }
}

/// Print a preformatted text figure and persist it under `results/` —
/// the text-artifact counterpart of [`Table::emit`], so every subcommand
/// goes through one report path.
pub fn emit_text(slug: &str, text: &str) -> Result<()> {
    println!("{text}");
    let dir = Path::new("results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{slug}.txt"));
    std::fs::write(&path, text)?;
    println!("[results] wrote {}", path.display());
    Ok(())
}

/// Persist a machine-readable JSON record (benchmark/perf results) at an
/// explicit path, creating parent directories as needed.
pub fn emit_json_record(path: &Path, record: &Json) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, record.pretty())?;
    println!("[results] wrote {}", path.display());
    Ok(())
}

/// Format a float with fixed decimals.
pub fn fmt(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

/// Human-readable parameter counts ("1.42M").
pub fn fmt_params(n: usize) -> String {
    if n >= 1_000_000 {
        format!("{:.2}M", n as f64 / 1e6)
    } else if n >= 1_000 {
        format!("{:.1}k", n as f64 / 1e3)
    } else {
        format!("{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_is_aligned_and_parsable() {
        let mut t = Table::new("Demo", &["Method", "Acc"]);
        t.row(vec!["GSOFT".into(), "86.67".into()]);
        t.row(vec!["LoRA-with-long-name".into(), "86.53".into()]);
        let md = t.markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| Method"));
        let lines: Vec<&str> = md.lines().filter(|l| l.starts_with('|')).collect();
        assert_eq!(lines.len(), 4);
        // all rows same width
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    fn json_round_trip() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into()]);
        let j = t.to_json();
        assert_eq!(j.req_str("title").unwrap(), "T");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn emit_text_and_json_record_write_files() {
        emit_text("fig_emit_text_selftest", "hello\nfigure").unwrap();
        let read = std::fs::read_to_string("results/fig_emit_text_selftest.txt").unwrap();
        assert_eq!(read, "hello\nfigure");
        let _ = std::fs::remove_file("results/fig_emit_text_selftest.txt");

        let dir = std::env::temp_dir().join(format!("gsoft_report_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("nested/BENCH_test.json");
        emit_json_record(&path, &Json::obj(vec![("ok", Json::Bool(true))])).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("ok").unwrap(), &Json::Bool(true));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt(1.23456, 2), "1.23");
        assert_eq!(fmt_params(1_420_000), "1.42M");
        assert_eq!(fmt_params(3_100), "3.1k");
        assert_eq!(fmt_params(42), "42");
    }
}
