//! The Monarch family (`P_1 L P_2 R`, Appendix C) — the fifth adapter
//! family, and the openness proof for the [`super::AdapterFamily`] API:
//! this module plus its one registration line in the [`super`] built-in
//! list is *everything* Monarch needed; `serve/engine.rs`,
//! `serve/registry.rs`, and `store/gsad.rs` were not touched.
//!
//! Monarch matrices are the GS subclass with the hard structural coupling
//! `k_L = b_R¹ ∧ k_R = b_L²` ([`crate::gs::monarch`]): for square `d×d`
//! with square `b×b` blocks this forces `d = b²` (`r = b`), which
//! [`MonarchFamily::validate_slab`] enforces — the constraint GS drops
//! and the paper's Appendix C is about. Within that coupling the
//! orthogonal parametrization is the same Cayley-block construction as
//! GSOFT (`Q = P_1 L P_2 R` with `P_1 = P_(b,d)^T`, `P_2 = P_(b,d)`), so
//! the factorized path reuses the prepared two-pass
//! [`crate::kernel::GsOp`] and the cost model is the Theorem-2 GS model
//! at `r = b` (dense at `m = 2`).
//!
//! Slabs: `<layer>.mon_l` and `<layer>.mon_r`, each `[b, b, b]` (paired).

use anyhow::Result;

use crate::coordinator::flatspec::FlatSpec;
use crate::coordinator::merge::gsoft_q;
use crate::gs::monarch::{is_monarch_expressible, square_config_is_monarch};
use crate::gs::GsMatrix;
use crate::kernel::GsOp;
use crate::linalg::Mat;

use super::gsoft::{gs_cost_model, validate_block_slab, validate_paired_slab, GsLayerOp};
use super::{AdapterDesc, AdapterFamily, Config, CostModel, LayerOp, SlabCx};

/// The process-wide Monarch family instance.
pub static MONARCH: MonarchFamily = MonarchFamily;

pub struct MonarchFamily;

/// Descriptor constructor: a `d = block²` Monarch adapter.
pub fn desc(block: usize) -> AdapterDesc {
    AdapterDesc::new("monarch", &[("block", block)])
        .expect("monarch is a registered built-in family")
}

/// Build the orthogonal Monarch `Q = P_1 L P_2 R` (d×d, `d = b²`) from
/// the two flat Cayley slabs. Structurally this is the GSOFT spec pinned
/// to the Monarch coupling point `r = b`.
pub fn monarch_q(l_raw: &[f32], r_raw: &[f32], d: usize, b: usize) -> GsMatrix {
    assert!(
        square_config_is_monarch(d, b),
        "Monarch coupling requires d = block² (got d={d}, block={b})"
    );
    let q = gsoft_q(l_raw, r_raw, d, b);
    debug_assert!(is_monarch_expressible(&q.spec));
    q
}

impl AdapterFamily for MonarchFamily {
    fn tag(&self) -> &'static str {
        "monarch"
    }

    fn hp_keys(&self) -> &'static [&'static str] {
        &["block"]
    }

    fn suffixes(&self) -> &'static [&'static str] {
        &["mon_l", "mon_r"]
    }

    fn validate_slab(&self, cfg: &Config, cx: &SlabCx) -> Result<()> {
        let block = validate_block_slab(cfg, cx)?;
        anyhow::ensure!(
            square_config_is_monarch(cx.din, block),
            "tenant {}: Monarch coupling requires d = block² \
             (layer '{}' has d={}, block={block} ⇒ block²={})",
            cx.tenant,
            cx.layer,
            cx.din,
            block * block
        );
        validate_paired_slab(cx, "mon_l", "mon_r")
    }

    fn synthetic_spec(
        &self,
        cfg: &Config,
        layers: &[String],
        d: usize,
        _hint: usize,
    ) -> Result<FlatSpec> {
        let block = cfg.req("block")?;
        anyhow::ensure!(
            square_config_is_monarch(d, block),
            "Monarch needs d = block² (got d={d}, block={block})"
        );
        let r = d / block;
        Ok(FlatSpec {
            entries: layers
                .iter()
                .flat_map(|n| {
                    [
                        (format!("{n}.mon_l"), vec![r, block, block]),
                        (format!("{n}.mon_r"), vec![r, block, block]),
                    ]
                })
                .collect(),
        })
    }

    fn merge(
        &self,
        cfg: &Config,
        base: &[f32],
        adapter: &[f32],
        base_spec: &FlatSpec,
        adapter_spec: &FlatSpec,
    ) -> Result<Vec<f32>> {
        let block = cfg.req("block")?;
        let mut merged = base.to_vec();
        for lname in adapter_spec.names_with_suffix(".mon_l") {
            let layer = lname
                .strip_suffix(".mon_l")
                .ok_or_else(|| anyhow::anyhow!("bad adapter name {lname}"))?;
            let l_raw = adapter_spec.view(adapter, &lname)?;
            let r_raw = adapter_spec.view(adapter, &format!("{layer}.mon_r"))?;
            let (_, wshape) = base_spec.locate(layer)?;
            anyhow::ensure!(wshape.len() == 2, "adapted entry {layer} is not a matrix");
            let (din, dout) = (wshape[0], wshape[1]);
            anyhow::ensure!(
                square_config_is_monarch(din, block),
                "Monarch coupling requires d = block² at layer '{layer}' (d={din})"
            );
            let q = monarch_q(l_raw, r_raw, din, block);
            let w = Mat::from_f32(din, dout, base_spec.view(base, layer)?);
            let wq = q.apply(&w); // Q @ W via the structured path
            base_spec
                .view_mut(&mut merged, layer)?
                .copy_from_slice(&wq.to_f32());
        }
        Ok(merged)
    }

    fn plan_layer(
        &self,
        cfg: &Config,
        params: &[f32],
        spec: &FlatSpec,
        layer: &str,
        d: usize,
    ) -> Result<Option<Box<dyn LayerOp>>> {
        let lname = format!("{layer}.mon_l");
        if spec.locate(&lname).is_err() {
            return Ok(None);
        }
        let block = cfg.req("block")?;
        anyhow::ensure!(
            square_config_is_monarch(d, block),
            "Monarch coupling requires d = block² (served d={d}, block={block})"
        );
        let l_raw = spec.view(params, &lname)?;
        let r_raw = spec.view(params, &format!("{layer}.mon_r"))?;
        let q = monarch_q(l_raw, r_raw, d, block);
        Ok(Some(Box::new(GsLayerOp(GsOp::new(q)))))
    }

    fn cost_model(&self, cfg: &Config, d: usize) -> Option<CostModel> {
        // At the coupling point r = b the GS model gives m = 2 factors of
        // nnz d·b each, and a dense merged support (Theorem 2).
        cfg.req("block").ok().map(|b| gs_cost_model(d, b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn monarch_q_is_orthogonal_and_coupled() {
        let (d, b) = (16usize, 4usize);
        let mut rng = Rng::new(31);
        let l: Vec<f32> = (0..b * b * b).map(|_| rng.normal_f32(0.5)).collect();
        let r: Vec<f32> = (0..b * b * b).map(|_| rng.normal_f32(0.5)).collect();
        let q = monarch_q(&l, &r, d, b);
        assert!(is_monarch_expressible(&q.spec), "coupling must hold");
        let dense = q.to_dense();
        assert!(
            dense.is_orthogonal(1e-8),
            "‖QᵀQ−I‖ = {}",
            dense.orthogonality_error()
        );
    }

    #[test]
    #[should_panic(expected = "Monarch coupling")]
    fn uncoupled_geometry_is_rejected() {
        // d = 16, b = 2 ⇒ r = 8 ≠ b: expressible in GS, not in Monarch.
        let raw = vec![0.0f32; 8 * 2 * 2];
        monarch_q(&raw, &raw, 16, 2);
    }

    #[test]
    fn zero_slabs_give_the_identity() {
        let (d, b) = (9usize, 3usize);
        let raw = vec![0.0f32; 3 * 3 * 3];
        let q = monarch_q(&raw, &raw, d, b).to_dense();
        assert!(q.fro_dist(&Mat::eye(d)) < 1e-12);
    }
}
