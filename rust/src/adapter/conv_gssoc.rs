//! The GS-SOC orthogonal-convolution family (§6.3): `W' = Q W` with
//! `Q = P⁻¹ · exp(grouped skew conv) · P` acting on activations viewed as
//! `[c, h, w]` tensors (`d = c·h·w`). The slab per layer is the raw
//! grouped kernel `<layer>.soc_k` `[c, c/groups, k, k]`;
//! skew-symmetrization and the `P_(groups, c)` channel shuffles are
//! applied at build time, so `Q` is orthogonal by construction (up to the
//! `terms`-term series truncation).
//!
//! The factorized operator is the direct convolution runtime's
//! [`crate::kernel::GsSocLayer`] (streaming exponential + channel-plane
//! shuffles) — the dense `(c·h·w)²` operator is never materialized.

use anyhow::Result;

use crate::coordinator::flatspec::FlatSpec;
use crate::coordinator::merge::{conv_gssoc_layer, merge_conv_gssoc};
use crate::kernel::{GsSocLayer, KernelCtx};
use crate::linalg::Mat;

use super::{AdapterFamily, Config, CostModel, LayerOp, SlabCx};

/// The process-wide GS-SOC conv family instance.
pub static CONV_GSSOC: ConvGsSocFamily = ConvGsSocFamily;

pub struct ConvGsSocFamily;

struct SocLayerOp(GsSocLayer);

impl LayerOp for SocLayerOp {
    fn apply(&self, base_y: Mat, _x: &Mat, ctx: &KernelCtx) -> Mat {
        self.0.apply(&base_y, ctx)
    }
}

/// The conv geometry, pulled from a config in one shot.
struct Geo {
    c: usize,
    k: usize,
    groups: usize,
    h: usize,
    w: usize,
    terms: usize,
}

fn geo(cfg: &Config) -> Result<Geo> {
    Ok(Geo {
        c: cfg.req("c")?,
        k: cfg.req("k")?,
        groups: cfg.req("groups")?,
        h: cfg.req("h")?,
        w: cfg.req("w")?,
        terms: cfg.req("terms")?,
    })
}

impl AdapterFamily for ConvGsSocFamily {
    fn tag(&self) -> &'static str {
        "conv_gssoc"
    }

    fn hp_keys(&self) -> &'static [&'static str] {
        &["c", "k", "groups", "h", "w", "terms"]
    }

    fn suffixes(&self) -> &'static [&'static str] {
        &["soc_k"]
    }

    fn validate_slab(&self, cfg: &Config, cx: &SlabCx) -> Result<()> {
        let g = geo(cfg)?;
        anyhow::ensure!(
            g.k % 2 == 1,
            "tenant {}: same-padded conv needs an odd kernel (got k={})",
            cx.tenant,
            g.k
        );
        anyhow::ensure!(
            g.terms >= 1,
            "tenant {}: conv exponential needs at least one Taylor term",
            cx.tenant
        );
        anyhow::ensure!(
            g.groups > 0 && g.c % g.groups == 0,
            "tenant {}: groups {} must divide channels {}",
            cx.tenant,
            g.groups,
            g.c
        );
        anyhow::ensure!(
            g.c * g.h * g.w == cx.din,
            "tenant {}: adapted layer '{}' has input dim {}, but the conv geometry gives \
             c·h·w = {}·{}·{} = {}",
            cx.tenant,
            cx.layer,
            cx.din,
            g.c,
            g.h,
            g.w,
            g.c * g.h * g.w
        );
        anyhow::ensure!(
            *cx.shape == [g.c, g.c / g.groups, g.k, g.k],
            "tenant {}: '{}' has shape {:?}, expected {:?}",
            cx.tenant,
            cx.name,
            cx.shape,
            [g.c, g.c / g.groups, g.k, g.k]
        );
        Ok(())
    }

    fn synthetic_spec(
        &self,
        cfg: &Config,
        layers: &[String],
        _d: usize,
        _hint: usize,
    ) -> Result<FlatSpec> {
        let g = geo(cfg)?;
        anyhow::ensure!(g.groups > 0 && g.c % g.groups == 0, "groups must divide c");
        Ok(FlatSpec {
            entries: layers
                .iter()
                .map(|n| (format!("{n}.soc_k"), vec![g.c, g.c / g.groups, g.k, g.k]))
                .collect(),
        })
    }

    fn synthetic_std(&self, _cfg: &Config) -> f32 {
        // Small kernel magnitude keeps the truncated exponential
        // converged, so factorized and merged serving agree tightly.
        0.05
    }

    fn merge(
        &self,
        cfg: &Config,
        base: &[f32],
        adapter: &[f32],
        base_spec: &FlatSpec,
        adapter_spec: &FlatSpec,
    ) -> Result<Vec<f32>> {
        let g = geo(cfg)?;
        merge_conv_gssoc(
            base,
            adapter,
            base_spec,
            adapter_spec,
            g.c,
            g.k,
            g.groups,
            g.h,
            g.w,
            g.terms,
        )
    }

    fn plan_layer(
        &self,
        cfg: &Config,
        params: &[f32],
        spec: &FlatSpec,
        layer: &str,
        d: usize,
    ) -> Result<Option<Box<dyn LayerOp>>> {
        let sname = format!("{layer}.soc_k");
        if spec.locate(&sname).is_err() {
            return Ok(None);
        }
        let g = geo(cfg)?;
        anyhow::ensure!(
            g.c * g.h * g.w == d,
            "conv_gssoc geometry c·h·w = {} does not match served dim {d}",
            g.c * g.h * g.w
        );
        let raw = spec.view(params, &sname)?;
        Ok(Some(Box::new(SocLayerOp(conv_gssoc_layer(
            raw, g.c, g.k, g.groups, g.h, g.w, g.terms,
        )))))
    }

    fn cost_model(&self, cfg: &Config, _d: usize) -> Option<CostModel> {
        // One Q·column is `terms` grouped convs over the [c, h, w] plane.
        // The merged support is spatially banded (k² taps widened by
        // `terms` applications), not the Theorem-2 dense guarantee.
        let g = geo(cfg).ok()?;
        Some(CostModel {
            q_col_flops: (2 * g.terms * g.c * (g.c / g.groups.max(1)) * g.k * g.k * g.h * g.w)
                as u64,
            q_dense: false,
        })
    }
}
