//! The LoRA family: `W' = W + A B` — the additive low-rank baseline the
//! paper compares against. Not orthogonal (singular values move), and no
//! structured Theorem-2 cost model (the engine's generic default
//! applies).
//!
//! Slabs: `<layer>.lora_a` `[d, rank]` and `<layer>.lora_b` `[rank, d]`
//! (paired). The factorized path serves `W X + A (B X)`.

use anyhow::{anyhow, Result};

use crate::coordinator::flatspec::FlatSpec;
use crate::coordinator::merge::merge_lora;
use crate::kernel::KernelCtx;
use crate::linalg::Mat;

use super::{AdapterFamily, Config, LayerOp, SlabCx};

/// The process-wide LoRA family instance.
pub static LORA: LoraFamily = LoraFamily;

pub struct LoraFamily;

struct LowRankOp {
    a: Mat,
    b: Mat,
}

impl LayerOp for LowRankOp {
    fn apply(&self, base_y: Mat, x: &Mat, ctx: &KernelCtx) -> Mat {
        &base_y + &ctx.gemm(&self.a, &ctx.gemm(&self.b, x))
    }
}

impl AdapterFamily for LoraFamily {
    fn tag(&self) -> &'static str {
        "lora"
    }

    fn is_orthogonal(&self) -> bool {
        false
    }

    fn suffixes(&self) -> &'static [&'static str] {
        &["lora_a", "lora_b"]
    }

    fn validate_slab(&self, _cfg: &Config, cx: &SlabCx) -> Result<()> {
        match cx.suffix {
            "lora_a" => {
                anyhow::ensure!(
                    cx.shape.len() == 2 && cx.shape[0] == cx.din,
                    "tenant {}: '{}' has shape {:?}, expected [{}, rank]",
                    cx.tenant,
                    cx.name,
                    cx.shape,
                    cx.din
                );
                let (_, bshape) = cx
                    .spec
                    .locate(&format!("{}.lora_b", cx.layer))
                    .map_err(|_| {
                        anyhow!("tenant {}: '{}' has no paired lora_b", cx.tenant, cx.name)
                    })?;
                anyhow::ensure!(
                    bshape.len() == 2 && bshape[0] == cx.shape[1] && bshape[1] == cx.dout,
                    "tenant {}: '{}.lora_b' has shape {bshape:?}, expected [{}, {}]",
                    cx.tenant,
                    cx.layer,
                    cx.shape[1],
                    cx.dout
                );
            }
            _ => {
                // Shape details are checked from the lora_a side; here
                // just reject an unpaired lora_b (it would be silently
                // ignored by merge and serve).
                anyhow::ensure!(
                    cx.spec.locate(&format!("{}.lora_a", cx.layer)).is_ok(),
                    "tenant {}: '{}' has no matching '{}.lora_a'",
                    cx.tenant,
                    cx.name,
                    cx.layer
                );
            }
        }
        Ok(())
    }

    fn synthetic_spec(
        &self,
        _cfg: &Config,
        layers: &[String],
        d: usize,
        hint: usize,
    ) -> Result<FlatSpec> {
        let rank = hint.min(d / 2).max(1);
        Ok(FlatSpec {
            entries: layers
                .iter()
                .flat_map(|n| {
                    [
                        (format!("{n}.lora_a"), vec![d, rank]),
                        (format!("{n}.lora_b"), vec![rank, d]),
                    ]
                })
                .collect(),
        })
    }

    fn synthetic_std(&self, _cfg: &Config) -> f32 {
        0.05
    }

    fn merge(
        &self,
        _cfg: &Config,
        base: &[f32],
        adapter: &[f32],
        base_spec: &FlatSpec,
        adapter_spec: &FlatSpec,
    ) -> Result<Vec<f32>> {
        merge_lora(base, adapter, base_spec, adapter_spec)
    }

    fn plan_layer(
        &self,
        _cfg: &Config,
        params: &[f32],
        spec: &FlatSpec,
        layer: &str,
        d: usize,
    ) -> Result<Option<Box<dyn LayerOp>>> {
        let aname = format!("{layer}.lora_a");
        let Ok((_, ashape)) = spec.locate(&aname) else {
            return Ok(None);
        };
        let rank = ashape[1];
        let a = Mat::from_f32(d, rank, spec.view(params, &aname)?);
        let b = Mat::from_f32(rank, d, spec.view(params, &format!("{layer}.lora_b"))?);
        Ok(Some(Box::new(LowRankOp { a, b })))
    }
}
