//! Dispatch-equivalence tests: the open-trait API must be *bit-identical*
//! to the legacy closed-enum behavior for every pre-existing kind (merge
//! output, factorized operators, GSAD wire form — the wire form is pinned
//! in `store/gsad.rs` tests), and the registry itself must behave like a
//! proper open set (unknown tags are clean errors, duplicate tags are
//! rejected).

use crate::coordinator::flatspec::FlatSpec;
use crate::coordinator::merge::{
    conv_gssoc_layer, gsoft_q, merge_conv_gssoc, merge_gsoft, merge_lora, merge_oft, oft_q,
    AdapterKind,
};
use crate::kernel::{fused_apply, GsOp, KernelCtx};
use crate::linalg::Mat;
use crate::util::prop;
use crate::util::rng::Rng;

use super::{monarch, AdapterDesc, AdapterFamily, FamilyRegistry};

/// One randomized scenario: a family descriptor, a base the adapter is
/// valid for, and the adapter layout (params drawn separately so the
/// shrinker can minimize them).
#[derive(Clone, Debug)]
struct Setup {
    desc: AdapterDesc,
    d: usize,
    base_spec: FlatSpec,
    adapter_spec: FlatSpec,
}

fn random_setup(rng: &mut Rng, which: usize) -> Setup {
    let layers = prop::size_in(rng, 1, 2);
    let names: Vec<String> = (0..layers).map(|i| format!("layer{i}.w")).collect();
    let (desc, d, hint) = match which % 5 {
        0 => {
            let b = 2usize;
            let r = prop::size_in(rng, 2, 4);
            (AdapterKind::Gsoft { block: b }.desc(), b * r, b)
        }
        1 => {
            let b = 2usize;
            let r = prop::size_in(rng, 2, 4);
            (AdapterKind::Oft { block: b }.desc(), b * r, b)
        }
        2 => {
            let d = prop::size_in(rng, 2, 8);
            (AdapterKind::Lora.desc(), d, prop::size_in(rng, 1, d))
        }
        3 => {
            let groups = [1usize, 2][rng.below(2)];
            let c = 2 * groups;
            let (h, w) = (prop::size_in(rng, 1, 3), prop::size_in(rng, 1, 3));
            (
                AdapterKind::ConvGsSoc {
                    c,
                    k: 3,
                    groups,
                    h,
                    w,
                    terms: prop::size_in(rng, 2, 8),
                }
                .desc(),
                c * h * w,
                0,
            )
        }
        _ => {
            let b = [2usize, 3][rng.below(2)];
            (monarch::desc(b), b * b, b)
        }
    };
    let mut base_entries: Vec<(String, Vec<usize>)> =
        names.iter().cloned().map(|n| (n, vec![d, d])).collect();
    base_entries.push(("head".to_string(), vec![d, 2]));
    let adapter_spec = desc
        .family()
        .synthetic_spec(desc.cfg(), &names, d, hint)
        .expect("synthetic spec");
    Setup {
        desc,
        d,
        base_spec: FlatSpec {
            entries: base_entries,
        },
        adapter_spec,
    }
}

fn param_std(desc: &AdapterDesc) -> f32 {
    desc.family().synthetic_std(desc.cfg())
}

/// The pre-trait closed-enum dispatch, reproduced verbatim: one match arm
/// per legacy kind, calling the kind-specific merge function directly.
fn legacy_merge(s: &Setup, base: &[f32], params: &[f32]) -> Vec<f32> {
    let (bs, asp) = (&s.base_spec, &s.adapter_spec);
    match s.desc.tag() {
        "gsoft" => merge_gsoft(base, params, bs, asp, s.desc.hp("block").unwrap()),
        "oft" => merge_oft(base, params, bs, asp, s.desc.hp("block").unwrap()),
        "lora" => merge_lora(base, params, bs, asp),
        "conv_gssoc" => merge_conv_gssoc(
            base,
            params,
            bs,
            asp,
            s.desc.hp("c").unwrap(),
            s.desc.hp("k").unwrap(),
            s.desc.hp("groups").unwrap(),
            s.desc.hp("h").unwrap(),
            s.desc.hp("w").unwrap(),
            s.desc.hp("terms").unwrap(),
        ),
        other => panic!("no legacy dispatch for family '{other}'"),
    }
    .expect("legacy merge")
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn trait_merge_is_bit_identical_to_legacy_dispatch() {
    // Property (shrinking on params): for every legacy kind,
    // `merge_entry` through the family trait produces the same bytes the
    // closed-enum `match` produced — hyperparameters must survive the
    // Config round-trip exactly.
    prop::check_shrunk(
        "trait merge == legacy enum merge",
        1301,
        24,
        |rng| {
            let which = rng.below(4);
            let s = random_setup(rng, which);
            let base = rng.normal_vec(s.base_spec.size(), 1.0);
            let params = rng.normal_vec(s.adapter_spec.size(), param_std(&s.desc));
            (s, base, params)
        },
        |(s, base, params)| {
            prop::shrink_vec_f32(params)
                .into_iter()
                .map(|p| (s.clone(), base.clone(), p))
                .collect()
        },
        |(s, base, params)| {
            let via_trait =
                super::merge_entry(&s.desc, base, params, &s.base_spec, &s.adapter_spec)
                    .expect("trait merge");
            assert_eq!(
                bits(&via_trait),
                bits(&legacy_merge(s, base, params)),
                "family '{}' drifted from the legacy enum dispatch",
                s.desc.tag()
            );
        },
    );
}

#[test]
fn trait_plan_is_bit_identical_to_legacy_operators() {
    // Property (shrinking on params): the factorized operator each family
    // plans applies exactly like the legacy per-kind `LayerQ`
    // construction (GsOp / bare block-diagonal / low-rank / GS-SOC conv).
    prop::check_shrunk(
        "trait layer op == legacy factorized operator",
        1302,
        24,
        |rng| {
            let which = rng.below(4);
            let s = random_setup(rng, which);
            let t = prop::size_in(rng, 1, 3);
            let params = rng.normal_vec(s.adapter_spec.size(), param_std(&s.desc));
            let x = (0..s.d * t).map(|_| rng.normal()).collect::<Vec<f64>>();
            let base_y = (0..s.d * t).map(|_| rng.normal()).collect::<Vec<f64>>();
            (s, params, x, base_y)
        },
        |(s, params, x, base_y)| {
            prop::shrink_vec_f32(params)
                .into_iter()
                .map(|p| (s.clone(), p, x.clone(), base_y.clone()))
                .collect()
        },
        |(s, params, x, base_y)| {
            let ctx = KernelCtx::default();
            let t = x.len() / s.d;
            let x = Mat::from_rows(s.d, t, x);
            let base_y = Mat::from_rows(s.d, t, base_y);
            let layer = "layer0.w";
            let op = s
                .desc
                .family()
                .plan_layer(s.desc.cfg(), params, &s.adapter_spec, layer, s.d)
                .expect("plan")
                .expect("layer0 is adapted");
            let got = op.apply(base_y.clone(), &x, &ctx);

            // Legacy construction, one arm per pre-trait kind.
            let spec = &s.adapter_spec;
            let want = match s.desc.tag() {
                "gsoft" => {
                    let l = spec.view(params, &format!("{layer}.gs_l")).unwrap();
                    let r = spec.view(params, &format!("{layer}.gs_r")).unwrap();
                    GsOp::new(gsoft_q(l, r, s.d, s.desc.hp("block").unwrap()))
                        .apply(&base_y, &ctx)
                }
                "oft" => {
                    let k = spec.view(params, &format!("{layer}.oft_k")).unwrap();
                    let bd = oft_q(k, s.d, s.desc.hp("block").unwrap());
                    fused_apply(&bd, None, None, &base_y, &ctx)
                }
                "lora" => {
                    let (_, ashape) = spec.locate(&format!("{layer}.lora_a")).unwrap();
                    let a = Mat::from_f32(
                        s.d,
                        ashape[1],
                        spec.view(params, &format!("{layer}.lora_a")).unwrap(),
                    );
                    let b = Mat::from_f32(
                        ashape[1],
                        s.d,
                        spec.view(params, &format!("{layer}.lora_b")).unwrap(),
                    );
                    &base_y + &ctx.gemm(&a, &ctx.gemm(&b, &x))
                }
                "conv_gssoc" => {
                    let raw = spec.view(params, &format!("{layer}.soc_k")).unwrap();
                    let soc = conv_gssoc_layer(
                        raw,
                        s.desc.hp("c").unwrap(),
                        s.desc.hp("k").unwrap(),
                        s.desc.hp("groups").unwrap(),
                        s.desc.hp("h").unwrap(),
                        s.desc.hp("w").unwrap(),
                        s.desc.hp("terms").unwrap(),
                    );
                    soc.apply(&base_y, &ctx)
                }
                other => panic!("no legacy operator for family '{other}'"),
            };
            assert_eq!(
                got.data, want.data,
                "family '{}' factorized apply drifted",
                s.desc.tag()
            );
        },
    );
}

#[test]
fn monarch_merge_and_plan_match_the_dense_oracle() {
    // Monarch has no legacy arm to compare against; its correctness
    // oracle is the dense `Q W` / `Q y` product of the materialized
    // `P_1 L P_2 R`.
    prop::check_shrunk(
        "monarch trait dispatch == dense oracle",
        1303,
        16,
        |rng| {
            let s = random_setup(rng, 4);
            let base = rng.normal_vec(s.base_spec.size(), 1.0);
            let params = rng.normal_vec(s.adapter_spec.size(), 0.4);
            (s, base, params)
        },
        |(s, base, params)| {
            prop::shrink_vec_f32(params)
                .into_iter()
                .map(|p| (s.clone(), base.clone(), p))
                .collect()
        },
        |(s, base, params)| {
            let b = s.desc.hp("block").unwrap();
            let merged = super::merge_entry(&s.desc, base, params, &s.base_spec, &s.adapter_spec)
                .expect("monarch merge");
            let spec = &s.adapter_spec;
            for (name, _) in &s.base_spec.entries {
                if s.base_spec.locate(name).unwrap().1 != [s.d, s.d].as_slice() {
                    continue; // head
                }
                let w = Mat::from_f32(s.d, s.d, s.base_spec.view(base, name).unwrap());
                let got = Mat::from_f32(s.d, s.d, s.base_spec.view(&merged, name).unwrap());
                if spec.locate(&format!("{name}.mon_l")).is_err() {
                    assert_eq!(got.data, w.data, "unadapted layer must be untouched");
                    continue;
                }
                let l = spec.view(params, &format!("{name}.mon_l")).unwrap();
                let r = spec.view(params, &format!("{name}.mon_r")).unwrap();
                let q = monarch::monarch_q(l, r, s.d, b).to_dense();
                let want = q.matmul(&w);
                assert!(
                    got.fro_dist(&want) < 1e-5,
                    "monarch merged layer '{name}' off by {}",
                    got.fro_dist(&want)
                );
            }
            // Planned operator vs the same dense oracle.
            let ctx = KernelCtx::default();
            let y = Mat::from_f32(s.d, 1, &base[..s.d]);
            let op = s
                .desc
                .family()
                .plan_layer(s.desc.cfg(), params, spec, "layer0.w", s.d)
                .unwrap()
                .unwrap();
            let l = spec.view(params, "layer0.w.mon_l").unwrap();
            let r = spec.view(params, "layer0.w.mon_r").unwrap();
            let q = monarch::monarch_q(l, r, s.d, b).to_dense();
            let got = op.apply(y.clone(), &y, &ctx);
            assert!(got.fro_dist(&q.matmul(&y)) < 1e-9);
        },
    );
}

#[test]
fn registry_resolves_builtins_and_rejects_junk() {
    for tag in ["gsoft", "oft", "lora", "conv_gssoc", "monarch"] {
        let family = FamilyRegistry::family(tag).expect("builtin registered");
        assert_eq!(family.tag(), tag);
        assert!(FamilyRegistry::tags().contains(&tag));
    }
    let err = FamilyRegistry::family("butterfly").expect_err("unknown tag");
    assert!(format!("{err:#}").contains("unknown adapter family 'butterfly'"));
    // Tags are wire-stable: shadowing a registered one is refused.
    assert!(FamilyRegistry::register(&super::gsoft::GSOFT).is_err());
    // Descriptor constructor surfaces the same clean error.
    assert!(AdapterDesc::new("butterfly", &[]).is_err());
    // Missing and unknown hyperparameters are errors, not panics.
    assert!(AdapterDesc::new("gsoft", &[]).is_err(), "missing 'block'");
    assert!(
        AdapterDesc::new("gsoft", &[("blok", 2)]).is_err(),
        "misspelled key must be rejected at construction, not at the wire"
    );
    assert!(
        AdapterDesc::new("lora", &[("rank", 4)]).is_err(),
        "lora has no hyperparameters"
    );
}

#[test]
fn desc_construction_is_canonical_in_key_order() {
    // Caller-supplied hp order must not leak into equality or the wire:
    // a shuffled construction equals the canonical one and survives a
    // wire round-trip as the identity.
    let shuffled = AdapterDesc::new(
        "conv_gssoc",
        &[("terms", 8), ("k", 3), ("w", 3), ("c", 4), ("groups", 2), ("h", 2)],
    )
    .unwrap();
    let canonical = AdapterKind::ConvGsSoc {
        c: 4,
        k: 3,
        groups: 2,
        h: 2,
        w: 3,
        terms: 8,
    }
    .desc();
    assert_eq!(shuffled, canonical);
    let back = super::desc_from_json(&super::desc_to_json(&shuffled)).unwrap();
    assert_eq!(back, shuffled, "decode must invert encode for any construction");
}

#[test]
fn adapter_kind_constructors_resolve_to_their_families() {
    let mut rng = Rng::new(3);
    let cases = [
        AdapterKind::Gsoft { block: 4 },
        AdapterKind::Oft { block: 8 },
        AdapterKind::Lora,
        AdapterKind::ConvGsSoc {
            c: 4,
            k: 3,
            groups: 2,
            h: 2,
            w: 3,
            terms: 6,
        },
    ];
    for kind in cases {
        let desc = kind.desc();
        assert_eq!(desc.tag(), kind.name());
        assert_eq!(desc.is_orthogonal(), kind.is_orthogonal());
        assert_eq!(desc, kind.desc(), "desc construction is deterministic");
    }
    assert_eq!(
        AdapterKind::Gsoft { block: 4 }.desc().hp("block").unwrap(),
        4
    );
    // Distinct configs compare unequal even within a family.
    assert_ne!(
        AdapterKind::Gsoft { block: 4 }.desc(),
        AdapterKind::Gsoft { block: 8 }.desc()
    );
    assert_ne!(
        AdapterKind::Gsoft { block: 4 }.desc(),
        AdapterKind::Oft { block: 4 }.desc()
    );
    // And a smoke check that the resolved family actually works.
    let s = random_setup(&mut rng, 0);
    let base = rng.normal_vec(s.base_spec.size(), 1.0);
    let params = vec![0.0; s.adapter_spec.size()];
    let merged =
        super::merge_entry(&s.desc, &base, &params, &s.base_spec, &s.adapter_spec).unwrap();
    for (a, b) in merged.iter().zip(base.iter()) {
        assert!((a - b).abs() < 1e-6, "zero adapter must be a no-op");
    }
}
