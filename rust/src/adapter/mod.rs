//! The open adapter-family API (DESIGN.md §8).
//!
//! The paper's central claim is that GS matrices *unify* prior structured
//! classes (OFT block-diagonals, Monarch `P_1 L P_2 R`, butterfly/BOFT
//! chains) — so the serving stack must not hard-code a closed enum of
//! adapter kinds. This module turns the adapter abstraction into a
//! capability trait plus a process-wide registry:
//!
//! - [`AdapterFamily`] — everything the serving/store stack needs from a
//!   structured adapter class: slab validation, synthetic generation,
//!   dense merge (`W' = Q W`), a *planned* factorized-apply operator
//!   (prepared [`crate::kernel::FusedPlan`]/[`crate::kernel::GsOp`]-style
//!   state built once per tenant layer), the Theorem-2 density/FLOP cost
//!   model that drives [`crate::serve::Policy`] promotion, and a stable
//!   GSAD wire tag + version;
//! - [`Config`] — a family's per-tenant hyperparameters (block size, conv
//!   geometry, …) as an ordered `key → usize` list, encoded generically
//!   into the GSAD header (byte-identical to the v1 enum encoding);
//! - [`AdapterDesc`] — a resolved `(family, config)` pair; this is what
//!   [`crate::serve::AdapterEntry`] carries instead of the old enum;
//! - [`FamilyRegistry`] — tag → `&'static dyn AdapterFamily`, seeded with
//!   the built-ins; external families join with one
//!   [`FamilyRegistry::register`] call and need **zero** edits in
//!   `serve/engine.rs`, `serve/registry.rs`, or `store/gsad.rs` (proven
//!   by [`monarch`], which lives entirely in its own module).
//!
//! Built-in families: [`gsoft`], [`oft`], [`lora`], [`conv_gssoc`],
//! [`monarch`].

pub mod conv_gssoc;
pub mod gsoft;
pub mod lora;
pub mod monarch;
pub mod oft;

use std::collections::HashMap;
use std::sync::{OnceLock, RwLock};

use anyhow::{anyhow, Result};

use crate::coordinator::flatspec::FlatSpec;
use crate::kernel::KernelCtx;
use crate::linalg::Mat;
use crate::util::json::Json;

/// A family's per-tenant hyperparameters: an ordered list of
/// `key → usize` pairs (keys come from the family's
/// [`AdapterFamily::hp_keys`], so they are `'static`). Encodes
/// generically to/from the GSAD `"kind"` JSON object.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Config {
    hp: Vec<(&'static str, usize)>,
}

impl Config {
    /// Canonicalize a caller-supplied hyperparameter list against a
    /// family: unknown keys are rejected, missing keys are rejected, and
    /// the stored order is the family's [`AdapterFamily::hp_keys`] order
    /// regardless of how the caller wrote them — so `Config` equality is
    /// order-insensitive in practice and `decode(encode(desc))` is the
    /// identity for *every* construction, not just the canonical one.
    fn canonical(family: &dyn AdapterFamily, hp: &[(&'static str, usize)]) -> Result<Config> {
        for (k, _) in hp {
            anyhow::ensure!(
                family.hp_keys().contains(k),
                "adapter family '{}' has no hyperparameter '{k}'",
                family.tag()
            );
        }
        let mut out = Vec::with_capacity(family.hp_keys().len());
        for &key in family.hp_keys() {
            let val = hp
                .iter()
                .find(|(k, _)| *k == key)
                .map(|&(_, v)| v)
                .ok_or_else(|| {
                    anyhow!(
                        "adapter family '{}' requires hyperparameter '{key}'",
                        family.tag()
                    )
                })?;
            out.push((key, val));
        }
        Ok(Config { hp: out })
    }

    /// Look up a hyperparameter; families call this with their own keys,
    /// so a miss is a construction bug, reported as an error (never a
    /// panic — configs can come off the wire).
    pub fn req(&self, key: &str) -> Result<usize> {
        self.hp
            .iter()
            .find(|(k, _)| *k == key)
            .map(|&(_, v)| v)
            .ok_or_else(|| anyhow!("adapter config is missing hyperparameter '{key}'"))
    }

    pub fn iter(&self) -> impl Iterator<Item = (&'static str, usize)> + '_ {
        self.hp.iter().copied()
    }
}

/// Context handed to [`AdapterFamily::validate_slab`] for one entry of an
/// adapter's [`FlatSpec`]: the slab plus the base layer it adapts. The
/// generic scaffolding (buffer length, layer existence, 2-D base entry,
/// suffix ownership) is already checked by the caller.
pub struct SlabCx<'a> {
    /// Tenant id, for error messages.
    pub tenant: u64,
    /// Full entry name, e.g. `layer0.w.gs_l`.
    pub name: &'a str,
    /// Adapted base layer, e.g. `layer0.w`.
    pub layer: &'a str,
    /// Entry suffix, e.g. `gs_l` (guaranteed ∈ the family's
    /// [`AdapterFamily::suffixes`]).
    pub suffix: &'a str,
    /// The slab's declared shape.
    pub shape: &'a [usize],
    /// Base layer input dimension.
    pub din: usize,
    /// Base layer output dimension.
    pub dout: usize,
    /// The whole adapter spec (for pairing checks like `gs_l`/`gs_r`).
    pub spec: &'a FlatSpec,
}

/// A prepared per-layer operator for the factorized (unmerged) serving
/// path. Built once per tenant layer by [`AdapterFamily::plan_layer`]
/// (the expensive part — Cayley solves, relayout planning — happens
/// there), then applied per batch.
pub trait LayerOp: Send + Sync {
    /// Combine the base product `base_y = W·x` with the adapter:
    /// orthogonal families return `Q·base_y`; additive families (LoRA)
    /// also need the layer input `x`.
    fn apply(&self, base_y: Mat, x: &Mat, ctx: &KernelCtx) -> Mat;
}

/// Theorem-2 style cost-model inputs for the engine's promotion policy:
/// merging one layer costs `q_col_flops · d` (apply Q to every column of
/// W), the factorized path costs `q_col_flops` per served column.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Flops to apply the structured `Q` to one column.
    pub q_col_flops: u64,
    /// Whether the merged `Q` support is fully dense at this config
    /// (Theorem 2) — what makes the cached path a plain dense GEMM.
    pub q_dense: bool,
}

/// One structured adapter class. Implementations are stateless statics
/// (per-tenant state lives in [`Config`] + the flat parameter slabs), so
/// the registry hands out `&'static dyn AdapterFamily`.
pub trait AdapterFamily: Send + Sync {
    /// Stable wire tag — the GSAD `"kind"` discriminator and the
    /// [`FamilyRegistry`] key. Never reuse a tag for a different layout.
    fn tag(&self) -> &'static str;

    /// Hyperparameter keys, in canonical order.
    fn hp_keys(&self) -> &'static [&'static str] {
        &[]
    }

    /// Family wire version; bump on any slab-layout change. Records
    /// written at version 1 omit the field, keeping the v1 byte format.
    fn wire_version(&self) -> usize {
        1
    }

    /// Whether `W' = Q W` preserves the singular values of every adapted
    /// layer (true for every orthogonal parametrization; false for
    /// additive families like LoRA).
    fn is_orthogonal(&self) -> bool {
        true
    }

    /// Adapter-spec entry suffixes this family owns (e.g.
    /// `["gs_l", "gs_r"]`); foreign suffixes are rejected generically.
    fn suffixes(&self) -> &'static [&'static str];

    /// Config-only sanity checks (key presence beyond [`Config::req`],
    /// cross-key constraints that need no base layer).
    fn validate_config(&self, _cfg: &Config) -> Result<()> {
        Ok(())
    }

    /// Validate one slab against the base layer it adapts — a malformed
    /// entry must be rejected at registration/hydration, never panic
    /// inside a serving worker.
    fn validate_slab(&self, cfg: &Config, cx: &SlabCx) -> Result<()>;

    /// Adapter [`FlatSpec`] adapting `layers` square `d×d` base layers —
    /// the synthetic-registry generator for benches and tests.
    /// `hint` carries the caller's block-size hint for families whose
    /// config does not determine every shape (e.g. the LoRA rank).
    fn synthetic_spec(
        &self,
        cfg: &Config,
        layers: &[String],
        d: usize,
        hint: usize,
    ) -> Result<FlatSpec>;

    /// Parameter-init std for synthetic adapters (families with truncated
    /// series or additive updates want smaller magnitudes).
    fn synthetic_std(&self, _cfg: &Config) -> f32 {
        0.3
    }

    /// Merge the adapter into a copy of the base buffer
    /// (`W' = Q W` per adapted layer, or the family's equivalent).
    fn merge(
        &self,
        cfg: &Config,
        base: &[f32],
        adapter: &[f32],
        base_spec: &FlatSpec,
        adapter_spec: &FlatSpec,
    ) -> Result<Vec<f32>>;

    /// Build the prepared factorized operator for one layer, or `None`
    /// if this adapter does not touch the layer.
    fn plan_layer(
        &self,
        cfg: &Config,
        params: &[f32],
        spec: &FlatSpec,
        layer: &str,
        d: usize,
    ) -> Result<Option<Box<dyn LayerOp>>>;

    /// Density/FLOP cost model for [`crate::serve::Policy`] promotion,
    /// or `None` when the family has no structured model (the engine
    /// falls back to its generic Theorem-2 default).
    fn cost_model(&self, _cfg: &Config, _d: usize) -> Option<CostModel> {
        None
    }

    /// Upgrade a persisted record written at wire version `old_fv`
    /// (strictly below the current [`AdapterFamily::wire_version`]) to
    /// the current slab layout, rewriting `params`/`spec` in place. The
    /// store calls this during decode, so a family that bumps its wire
    /// version keeps reading every tenant it ever persisted — live
    /// re-registration then rewrites the record at the new version on the
    /// next `put`. The default declines: a family that bumps its version
    /// without a migration path fails loudly at hydration, not silently
    /// at serve time. Hyperparameter keys must stay decodable across
    /// versions (layout changes go in the slabs, not the header).
    fn migrate(
        &self,
        _cfg: &Config,
        old_fv: usize,
        _params: &mut Vec<f32>,
        _spec: &mut FlatSpec,
    ) -> Result<()> {
        Err(anyhow!(
            "adapter family '{}' has no migration path from wire version {old_fv} to v{}",
            self.tag(),
            self.wire_version()
        ))
    }
}

/// A resolved `(family, config)` pair — what an adapter entry carries.
#[derive(Clone)]
pub struct AdapterDesc {
    family: &'static dyn AdapterFamily,
    cfg: Config,
}

impl AdapterDesc {
    /// Resolve `tag` in the [`FamilyRegistry`] and build a validated
    /// descriptor. Hyperparameters are canonicalized (family key order;
    /// unknown or missing keys are clean errors), so equal descriptors
    /// compare equal however they were written.
    pub fn new(tag: &str, hp: &[(&'static str, usize)]) -> Result<AdapterDesc> {
        let family = FamilyRegistry::family(tag)?;
        let cfg = Config::canonical(family, hp)?;
        family.validate_config(&cfg)?;
        Ok(AdapterDesc { family, cfg })
    }

    pub fn family(&self) -> &'static dyn AdapterFamily {
        self.family
    }

    pub fn cfg(&self) -> &Config {
        &self.cfg
    }

    pub fn tag(&self) -> &'static str {
        self.family.tag()
    }

    pub fn is_orthogonal(&self) -> bool {
        self.family.is_orthogonal()
    }

    /// Convenience hyperparameter lookup.
    pub fn hp(&self, key: &str) -> Result<usize> {
        self.cfg.req(key)
    }
}

impl PartialEq for AdapterDesc {
    fn eq(&self, other: &Self) -> bool {
        self.tag() == other.tag() && self.cfg == other.cfg
    }
}

impl Eq for AdapterDesc {}

impl std::fmt::Debug for AdapterDesc {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AdapterDesc")
            .field("tag", &self.tag())
            .field("cfg", &self.cfg)
            .finish()
    }
}

// ---- family registry -------------------------------------------------------

/// Process-wide tag → family map. Built-ins are seeded on first access;
/// external families join at runtime with [`FamilyRegistry::register`].
pub struct FamilyRegistry;

type FamilyMap = HashMap<&'static str, &'static dyn AdapterFamily>;

fn registry() -> &'static RwLock<FamilyMap> {
    static REG: OnceLock<RwLock<FamilyMap>> = OnceLock::new();
    REG.get_or_init(|| {
        let builtins: [&'static dyn AdapterFamily; 5] = [
            &gsoft::GSOFT,
            &oft::OFT,
            &lora::LORA,
            &conv_gssoc::CONV_GSSOC,
            &monarch::MONARCH, // the one registration line a new family needs
        ];
        RwLock::new(builtins.into_iter().map(|f| (f.tag(), f)).collect())
    })
}

impl FamilyRegistry {
    /// Register an external family. Errors on a tag collision (tags are
    /// wire-stable identifiers; shadowing one would corrupt decode).
    pub fn register(family: &'static dyn AdapterFamily) -> Result<()> {
        let mut map = registry().write().unwrap();
        anyhow::ensure!(
            !map.contains_key(family.tag()),
            "adapter family tag '{}' is already registered",
            family.tag()
        );
        map.insert(family.tag(), family);
        Ok(())
    }

    /// Resolve a tag, with a clean error for unknown families — this is
    /// what turns a foreign GSAD record into an error instead of a
    /// panic.
    pub fn family(tag: &str) -> Result<&'static dyn AdapterFamily> {
        registry()
            .read()
            .unwrap()
            .get(tag)
            .copied()
            .ok_or_else(|| anyhow!("unknown adapter family '{tag}'"))
    }

    /// Registered tags, sorted (for help text and reports).
    pub fn tags() -> Vec<&'static str> {
        let mut tags: Vec<&'static str> = registry().read().unwrap().keys().copied().collect();
        tags.sort_unstable();
        tags
    }
}

// ---- GSAD wire form --------------------------------------------------------

/// Encode a descriptor as the GSAD `"kind"` JSON object:
/// `{"kind": tag, <hp…>}`, plus `"fv"` when the family's wire version is
/// past 1 — byte-identical to the legacy enum encoding for v1 families
/// (JSON objects serialize with sorted keys).
pub fn desc_to_json(desc: &AdapterDesc) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![("kind", Json::Str(desc.tag().into()))];
    for (k, v) in desc.cfg.iter() {
        fields.push((k, Json::Num(v as f64)));
    }
    let fv = desc.family.wire_version();
    if fv != 1 {
        fields.push(("fv", Json::Num(fv as f64)));
    }
    Json::obj(fields)
}

/// Decode a GSAD `"kind"` object back into a descriptor. Unknown tags
/// and future family versions are clean errors; older versions decode
/// fine here (the slab migration, if any, is the store decoder's job via
/// [`desc_from_json_versioned`] + [`AdapterFamily::migrate`]).
pub fn desc_from_json(v: &Json) -> Result<AdapterDesc> {
    Ok(desc_from_json_versioned(v)?.0)
}

/// [`desc_from_json`], but also returning the record's wire version so
/// store decoders can route `fv < wire_version()` records through the
/// family's [`AdapterFamily::migrate`] hook. Versions *above* the
/// build's are rejected here — a layout we have never seen must not be
/// guessed at.
pub fn desc_from_json_versioned(v: &Json) -> Result<(AdapterDesc, usize)> {
    let tag = v.req_str("kind").map_err(|e| anyhow!("{e}"))?;
    let family = FamilyRegistry::family(tag)?;
    let fv = match v.get("fv") {
        Some(x) => x
            .as_usize()
            .ok_or_else(|| anyhow!("adapter family '{tag}': 'fv' is not an integer"))?,
        None => 1,
    };
    anyhow::ensure!(
        fv <= family.wire_version(),
        "adapter family '{tag}' record is wire version {fv}, this build reads up to v{}",
        family.wire_version()
    );
    let mut hp = Vec::with_capacity(family.hp_keys().len());
    for &key in family.hp_keys() {
        let val = v
            .req_usize(key)
            .map_err(|e| anyhow!("adapter family '{tag}': {e}"))?;
        hp.push((key, val));
    }
    let cfg = Config { hp };
    family.validate_config(&cfg)?;
    Ok((AdapterDesc { family, cfg }, fv))
}

/// Merge an adapter through trait dispatch — the single entry point the
/// registry, engine, and `merge-demo` share.
pub fn merge_entry(
    desc: &AdapterDesc,
    base: &[f32],
    adapter: &[f32],
    base_spec: &FlatSpec,
    adapter_spec: &FlatSpec,
) -> Result<Vec<f32>> {
    desc.family()
        .merge(desc.cfg(), base, adapter, base_spec, adapter_spec)
}

#[cfg(test)]
mod tests;
