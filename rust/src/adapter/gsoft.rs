//! The GSOFT family (§6.1): `W' = Q W` with `Q = P^T L P R` — two Cayley
//! block-diagonal factors of block size `block`, shuffled by `P_(r,d)`.
//!
//! Slabs: `<layer>.gs_l` and `<layer>.gs_r`, each `[d/block, block,
//! block]` (they must come in pairs). The factorized operator is a
//! prepared [`crate::kernel::GsOp`] (two fused passes with the relayouts
//! planned once per tenant layer).

use anyhow::Result;

use crate::coordinator::flatspec::FlatSpec;
use crate::coordinator::merge::{gsoft_q, merge_gsoft};
use crate::gs::density::{chain_support, gs_min_factors, BitMatrix, PermFamily};
use crate::kernel::{GsOp, KernelCtx};
use crate::linalg::Mat;

use super::{AdapterFamily, Config, CostModel, LayerOp, SlabCx};

/// The process-wide GSOFT family instance.
pub static GSOFT: GsoftFamily = GsoftFamily;

pub struct GsoftFamily;

/// A prepared GS operator as a [`LayerOp`] — shared with every family
/// whose `Q` is a two-factor GS matrix (e.g. [`super::monarch`]).
pub struct GsLayerOp(pub GsOp);

impl LayerOp for GsLayerOp {
    fn apply(&self, base_y: Mat, _x: &Mat, ctx: &KernelCtx) -> Mat {
        self.0.apply(&base_y, ctx)
    }
}

/// Theorem-2 cost model for an `m`-factor group-and-shuffle `Q` at
/// `(d, block)`: one block-diagonal factor has `nnz = d·b`, GS applies
/// `m = 1 + ⌈log_b r⌉` of them per column; the merged support is dense
/// exactly when the chain support analysis says so.
pub(crate) fn gs_cost_model(d: usize, block: usize) -> CostModel {
    let b = block.clamp(2, d.max(2));
    let r = (d / b).max(1);
    let m = gs_min_factors(b, r);
    let factor_nnz = BitMatrix::block_diag(r, b, b).nnz();
    CostModel {
        q_col_flops: (m * factor_nnz).max(1) as u64,
        q_dense: chain_support(r * b, b, m, PermFamily::GsKn).is_dense(),
    }
}

/// Shared GSOFT/OFT/Monarch slab shape check: `[din/block, block, block]`
/// with `block | din`.
pub(crate) fn validate_block_slab(cfg: &Config, cx: &SlabCx) -> Result<usize> {
    let block = cfg.req("block")?;
    anyhow::ensure!(
        block > 0 && cx.din % block == 0,
        "tenant {}: block {block} does not divide layer dim {}",
        cx.tenant,
        cx.din
    );
    anyhow::ensure!(
        *cx.shape == [cx.din / block, block, block],
        "tenant {}: '{}' has shape {:?}, expected {:?}",
        cx.tenant,
        cx.name,
        cx.shape,
        [cx.din / block, block, block]
    );
    Ok(block)
}

/// Shared pairing check for families whose factors come in L/R pairs
/// (a lone left slab errors at serve time, a lone right slab is silently
/// ignored — both must be rejected at validation).
pub(crate) fn validate_paired_slab(cx: &SlabCx, left: &str, right: &str) -> Result<()> {
    let other = if cx.suffix == left { right } else { left };
    let paired = cx
        .spec
        .locate(&format!("{}.{other}", cx.layer))
        .map(|(_, s)| s == cx.shape)
        .unwrap_or(false);
    anyhow::ensure!(
        paired,
        "tenant {}: '{}' has no matching '{}.{other}'",
        cx.tenant,
        cx.name,
        cx.layer
    );
    Ok(())
}

impl AdapterFamily for GsoftFamily {
    fn tag(&self) -> &'static str {
        "gsoft"
    }

    fn hp_keys(&self) -> &'static [&'static str] {
        &["block"]
    }

    fn suffixes(&self) -> &'static [&'static str] {
        &["gs_l", "gs_r"]
    }

    fn validate_slab(&self, cfg: &Config, cx: &SlabCx) -> Result<()> {
        validate_block_slab(cfg, cx)?;
        validate_paired_slab(cx, "gs_l", "gs_r")
    }

    fn synthetic_spec(
        &self,
        cfg: &Config,
        layers: &[String],
        d: usize,
        _hint: usize,
    ) -> Result<FlatSpec> {
        let block = cfg.req("block")?;
        anyhow::ensure!(block > 0 && d % block == 0, "block must divide d");
        let r = d / block;
        Ok(FlatSpec {
            entries: layers
                .iter()
                .flat_map(|n| {
                    [
                        (format!("{n}.gs_l"), vec![r, block, block]),
                        (format!("{n}.gs_r"), vec![r, block, block]),
                    ]
                })
                .collect(),
        })
    }

    fn merge(
        &self,
        cfg: &Config,
        base: &[f32],
        adapter: &[f32],
        base_spec: &FlatSpec,
        adapter_spec: &FlatSpec,
    ) -> Result<Vec<f32>> {
        merge_gsoft(base, adapter, base_spec, adapter_spec, cfg.req("block")?)
    }

    fn plan_layer(
        &self,
        cfg: &Config,
        params: &[f32],
        spec: &FlatSpec,
        layer: &str,
        d: usize,
    ) -> Result<Option<Box<dyn LayerOp>>> {
        let lname = format!("{layer}.gs_l");
        if spec.locate(&lname).is_err() {
            return Ok(None);
        }
        let l_raw = spec.view(params, &lname)?;
        let r_raw = spec.view(params, &format!("{layer}.gs_r"))?;
        let q = gsoft_q(l_raw, r_raw, d, cfg.req("block")?);
        Ok(Some(Box::new(GsLayerOp(GsOp::new(q)))))
    }

    fn cost_model(&self, cfg: &Config, d: usize) -> Option<CostModel> {
        cfg.req("block").ok().map(|b| gs_cost_model(d, b))
    }
}
