//! The OFT family: `W' = Q W` with a single Cayley block-diagonal `Q`
//! (the `P = I` degenerate point of the GS class).
//!
//! Slab: `<layer>.oft_k`, `[d/block, block, block]`. The factorized
//! operator is a bare block-diagonal fused pass (no relayouts to plan).

use anyhow::Result;

use crate::coordinator::flatspec::FlatSpec;
use crate::coordinator::merge::{merge_oft, oft_q};
use crate::gs::BlockDiag;
use crate::kernel::{fused_apply, KernelCtx};
use crate::linalg::Mat;

use super::gsoft::{gs_cost_model, validate_block_slab};
use super::{AdapterFamily, Config, CostModel, LayerOp, SlabCx};

/// The process-wide OFT family instance.
pub static OFT: OftFamily = OftFamily;

pub struct OftFamily;

struct BlockLayerOp(BlockDiag);

impl LayerOp for BlockLayerOp {
    fn apply(&self, base_y: Mat, _x: &Mat, ctx: &KernelCtx) -> Mat {
        fused_apply(&self.0, None, None, &base_y, ctx)
    }
}

impl AdapterFamily for OftFamily {
    fn tag(&self) -> &'static str {
        "oft"
    }

    fn hp_keys(&self) -> &'static [&'static str] {
        &["block"]
    }

    fn suffixes(&self) -> &'static [&'static str] {
        &["oft_k"]
    }

    fn validate_slab(&self, cfg: &Config, cx: &SlabCx) -> Result<()> {
        validate_block_slab(cfg, cx).map(|_| ())
    }

    fn synthetic_spec(
        &self,
        cfg: &Config,
        layers: &[String],
        d: usize,
        _hint: usize,
    ) -> Result<FlatSpec> {
        let block = cfg.req("block")?;
        anyhow::ensure!(block > 0 && d % block == 0, "block must divide d");
        let r = d / block;
        Ok(FlatSpec {
            entries: layers
                .iter()
                .map(|n| (format!("{n}.oft_k"), vec![r, block, block]))
                .collect(),
        })
    }

    fn merge(
        &self,
        cfg: &Config,
        base: &[f32],
        adapter: &[f32],
        base_spec: &FlatSpec,
        adapter_spec: &FlatSpec,
    ) -> Result<Vec<f32>> {
        merge_oft(base, adapter, base_spec, adapter_spec, cfg.req("block")?)
    }

    fn plan_layer(
        &self,
        cfg: &Config,
        params: &[f32],
        spec: &FlatSpec,
        layer: &str,
        d: usize,
    ) -> Result<Option<Box<dyn LayerOp>>> {
        let kname = format!("{layer}.oft_k");
        if spec.locate(&kname).is_err() {
            return Ok(None);
        }
        let k_raw = spec.view(params, &kname)?;
        Ok(Some(Box::new(BlockLayerOp(oft_q(
            k_raw,
            d,
            cfg.req("block")?,
        )))))
    }

    fn cost_model(&self, cfg: &Config, d: usize) -> Option<CostModel> {
        cfg.req("block").ok().map(|b| gs_cost_model(d, b))
    }
}
