//! 1-Lipschitz GS-SOC network runtime (§6.3) — the pure-Rust serving-side
//! counterpart of the L2 JAX `lipconvnet.py` models, executing through
//! the direct convolution runtime ([`crate::kernel::conv`]) instead of
//! PJRT artifacts: a stack of [`GsSocLayer`]s (each an orthogonal
//! `P_out · exp(grouped skew conv) · P_in` Jacobian) interleaved with the
//! gradient-norm-preserving GroupSort/MaxMin activation.
//!
//! [`LipschitzNet::lipschitz_bound`] estimates the network's Lipschitz
//! constant by power iteration on each layer's `LᵀL` (the adjoint is
//! exact — [`GsSocLayer::transposed`] transposes the truncated series
//! term by term) and multiplies the per-layer spectral norms; GroupSort
//! contributes a factor of exactly 1 (per pair it is either the identity
//! or a swap, so it preserves the ℓ₂ norm of differences). For the
//! orthogonal GS-SOC layers this runtime serves, the spectrum is fully
//! degenerate, which makes the power-iteration estimate tight (any unit
//! vector attains it) and the reported bound ≈ 1; see the method docs
//! for why it is only an estimate on general, non-orthogonal stacks.

use crate::kernel::conv::GsSocLayer;
use crate::kernel::KernelCtx;
use crate::linalg::Mat;
use crate::util::rng::Rng;

/// GroupSort (MaxMin) activation on channel pairs: channels `(2t, 2t+1)`
/// become `(max, min)` elementwise across the spatial/batch plane. A
/// 1-Lipschitz, norm-preserving map (Def. F.1 of the paper).
pub fn group_sort(x: &Mat, c: usize, hw: usize) -> Mat {
    assert!(c % 2 == 0, "GroupSort pairs channels: channel count {c} must be even");
    assert_eq!(
        x.rows,
        c * hw,
        "group_sort shape mismatch: X has {} rows, expected c·h·w = {}·{} = {}",
        x.rows,
        c,
        hw,
        c * hw
    );
    let t = x.cols;
    let plane = hw * t;
    let mut out = Mat::zeros(x.rows, t);
    for pair in 0..c / 2 {
        let p0 = 2 * pair * plane;
        let p1 = p0 + plane;
        for j in 0..plane {
            let (a, b) = (x.data[p0 + j], x.data[p1 + j]);
            out.data[p0 + j] = a.max(b);
            out.data[p1 + j] = a.min(b);
        }
    }
    out
}

/// A stack of GS-SOC layers + GroupSort: the runtime model the Table-3/4
/// experiments train in JAX, reconstructed as a servable Rust type.
pub struct LipschitzNet {
    pub layers: Vec<GsSocLayer>,
    /// Shared geometry (the stack keeps resolution and channel count).
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl LipschitzNet {
    pub fn new(layers: Vec<GsSocLayer>) -> LipschitzNet {
        assert!(!layers.is_empty(), "LipschitzNet needs at least one layer");
        let (c, h, w) = (layers[0].c(), layers[0].h, layers[0].w);
        assert!(c % 2 == 0, "GroupSort needs an even channel count (got {c})");
        for (i, l) in layers.iter().enumerate() {
            assert_eq!(
                (l.c(), l.h, l.w),
                (c, h, w),
                "layer {i} geometry ({}, {}, {}) differs from layer 0 ({c}, {h}, {w})",
                l.c(),
                l.h,
                l.w
            );
        }
        LipschitzNet { layers, c, h, w }
    }

    /// Random stack of `depth` GS-SOC layers (grouped skew kernels,
    /// `P_(groups, c)` shuffles).
    #[allow(clippy::too_many_arguments)]
    pub fn random(
        depth: usize,
        c: usize,
        k: usize,
        groups: usize,
        h: usize,
        w: usize,
        terms: usize,
        std: f64,
        seed: u64,
    ) -> LipschitzNet {
        let mut rng = Rng::new(seed);
        LipschitzNet::new(
            (0..depth.max(1))
                .map(|_| GsSocLayer::random(c, k, groups, h, w, terms, std, &mut rng))
                .collect(),
        )
    }

    /// Flat activation dimension `c·h·w`.
    pub fn d(&self) -> usize {
        self.c * self.h * self.w
    }

    /// Forward pass on a `[c·h·w, t]` batch: each GS-SOC layer followed
    /// by GroupSort.
    pub fn forward(&self, x: &Mat, ctx: &KernelCtx) -> Mat {
        let hw = self.h * self.w;
        let mut cur = x.clone();
        for layer in &self.layers {
            cur = layer.apply(&cur, ctx);
            cur = group_sort(&cur, self.c, hw);
        }
        cur
    }

    /// Estimate the network's Lipschitz constant: power iteration on
    /// `LᵀL` per layer (Rayleigh quotient of a unit iterate), multiplied
    /// across layers; GroupSort factors are exactly 1.
    ///
    /// **Semantics — read before trusting the number.** Power iteration
    /// converges to `σ_max²` *from below*, so in general this is an
    /// estimate, not a sound upper-bound certificate; a few random
    /// restarts per layer (taking the max) guard against an unlucky start
    /// vector with small overlap with the top singular direction. For the
    /// intended GS-SOC workload the estimate *is* tight and certifying:
    /// `exp(skew)` is orthogonal up to series truncation, the spectrum is
    /// fully degenerate (every singular value ≈ 1), and therefore **any**
    /// unit vector attains the Rayleigh quotient `σ_max² ± truncation
    /// error` in the very first iteration — there is no direction to
    /// miss. Certifying a deliberately non-orthogonal stack would need a
    /// genuine upper bound instead.
    pub fn lipschitz_bound(&self, iters: usize, seed: u64, ctx: &KernelCtx) -> f64 {
        const RESTARTS: usize = 3;
        let mut rng = Rng::new(seed);
        let mut bound = 1.0;
        for layer in &self.layers {
            let adj = layer.transposed();
            let d = layer.d();
            let mut best_sigma2 = 0.0f64;
            for _ in 0..RESTARTS {
                let mut v = Mat::randn(d, 1, 1.0, &mut rng);
                let n0 = v.fro_norm();
                if n0 == 0.0 {
                    continue;
                }
                v = v.scale(1.0 / n0);
                let mut sigma2 = 0.0;
                for _ in 0..iters.max(1) {
                    let u = layer.apply(&v, ctx);
                    let w = adj.apply(&u, ctx);
                    // Rayleigh quotient vᵀ(LᵀL)v = ‖Lv‖² for unit v.
                    sigma2 = v
                        .data
                        .iter()
                        .zip(w.data.iter())
                        .map(|(a, b)| a * b)
                        .sum::<f64>();
                    let n = w.fro_norm();
                    if n == 0.0 {
                        break;
                    }
                    v = w.scale(1.0 / n);
                }
                best_sigma2 = best_sigma2.max(sigma2);
            }
            bound *= best_sigma2.max(0.0).sqrt();
        }
        bound
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn group_sort_sorts_pairs_and_preserves_norm() {
        prop::check("GroupSort: pairwise max/min, norm-preserving", 1401, |rng| {
            let c = 2 * prop::size_in(rng, 1, 4);
            let (h, w) = (prop::size_in(rng, 1, 3), prop::size_in(rng, 1, 3));
            let t = prop::size_in(rng, 1, 3);
            let x = Mat::randn(c * h * w, t, 1.0, rng);
            let y = group_sort(&x, c, h * w);
            let hw = h * w;
            for pair in 0..c / 2 {
                for s in 0..hw {
                    for j in 0..t {
                        let a = x[((2 * pair) * hw + s, j)];
                        let b = x[((2 * pair + 1) * hw + s, j)];
                        assert_eq!(y[((2 * pair) * hw + s, j)], a.max(b));
                        assert_eq!(y[((2 * pair + 1) * hw + s, j)], a.min(b));
                    }
                }
            }
            assert!((y.fro_norm() - x.fro_norm()).abs() < 1e-12, "norm-preserving");
        });
    }

    #[test]
    fn group_sort_is_1_lipschitz() {
        prop::check("‖GS(x) − GS(y)‖ ≤ ‖x − y‖", 1402, |rng| {
            let c = 2 * prop::size_in(rng, 1, 3);
            let hw = prop::size_in(rng, 1, 6);
            let x = Mat::randn(c * hw, 2, 1.0, rng);
            let y = Mat::randn(c * hw, 2, 1.0, rng);
            let dx = (&x - &y).fro_norm();
            let dy = (&group_sort(&x, c, hw) - &group_sort(&y, c, hw)).fro_norm();
            assert!(dy <= dx + 1e-12, "{dy} > {dx}");
        });
    }

    #[test]
    fn certifier_reports_a_tight_bound_on_random_stacks() {
        // The acceptance bar: random GS-SOC stacks certify ≤ 1 + 1e-6
        // (orthogonal layers, converged truncation), and the power
        // iteration is not vacuous (bound near 1, not near 0).
        let ctx = KernelCtx::default();
        for (seed, depth, c, groups) in [(21u64, 2usize, 8usize, 2usize), (22, 3, 4, 1)] {
            let net = LipschitzNet::random(depth, c, 3, groups, 4, 3, 16, 0.02, seed);
            let bound = net.lipschitz_bound(8, seed ^ 1, &ctx);
            assert!(bound <= 1.0 + 1e-6, "certified bound {bound} exceeds 1");
            assert!(bound >= 1.0 - 1e-3, "degenerate bound {bound}");
        }
    }

    #[test]
    fn forward_is_empirically_1_lipschitz() {
        let ctx = KernelCtx::default();
        let net = LipschitzNet::random(2, 4, 3, 2, 3, 4, 14, 0.03, 31);
        let mut rng = Rng::new(32);
        let d = net.d();
        for _ in 0..10 {
            let x = Mat::randn(d, 1, 1.0, &mut rng);
            let y = Mat::randn(d, 1, 1.0, &mut rng);
            let fx = net.forward(&x, &ctx);
            let fy = net.forward(&y, &ctx);
            assert!(fx.data.iter().all(|v| v.is_finite()));
            let (num, den) = ((&fx - &fy).fro_norm(), (&x - &y).fro_norm());
            assert!(
                num <= den * (1.0 + 1e-6),
                "forward expanded a difference: {num} vs {den}"
            );
        }
    }

    #[test]
    fn certifier_detects_a_non_orthogonal_layer() {
        // Scale a layer's kernel without re-skewing: the exponential is no
        // longer orthogonal and the certifier must notice (bound ≠ 1).
        let mut rng = Rng::new(41);
        let mut layer = GsSocLayer::random(4, 3, 2, 3, 3, 16, 0.3, &mut rng);
        // Break skewness: zero the transpose contribution of one tap.
        layer.kern.w[0] += 1.5;
        let net = LipschitzNet::new(vec![layer]);
        let bound = net.lipschitz_bound(30, 7, &KernelCtx::default());
        assert!(
            (bound - 1.0).abs() > 1e-3,
            "tampered layer still certified as isometric: {bound}"
        );
    }

    #[test]
    #[should_panic(expected = "group_sort shape mismatch")]
    fn group_sort_shape_mismatch_is_a_hard_assert() {
        group_sort(&Mat::zeros(9, 1), 2, 4);
    }
}
