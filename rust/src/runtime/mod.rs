//! PJRT runtime: load AOT artifacts (HLO text) and execute them on the
//! request path — Python is never involved here.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Artifacts are compiled once and cached in the [`Runtime`] registry;
//! train loops re-enter through [`Executable::run`] with host tensors.
//!
//! [`lipnet`] is the artifact-free sibling: the 1-Lipschitz GS-SOC
//! network as a pure-Rust runtime type executing through the direct
//! convolution kernels, with a power-iteration Lipschitz certifier.

pub mod lipnet;
pub mod meta;
pub mod tensor;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

pub use lipnet::{group_sort, LipschitzNet};
pub use meta::{ArtifactMeta, TensorMeta};
pub use tensor::Tensor;

/// A compiled artifact plus its metadata.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host tensors; returns host tensors (the lowered
    /// modules use `return_tuple=True`, so the single output buffer is a
    /// tuple that we decompose).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        anyhow::ensure!(
            inputs.len() == self.meta.inputs.len(),
            "artifact {}: expected {} inputs, got {}",
            self.meta.name,
            self.meta.inputs.len(),
            inputs.len()
        );
        for (t, m) in inputs.iter().zip(self.meta.inputs.iter()) {
            anyhow::ensure!(
                t.shape() == m.shape && t.dtype_name() == m.dtype,
                "artifact {}: input '{}' expects {:?} {}, got {:?} {}",
                self.meta.name,
                m.name,
                m.shape,
                m.dtype,
                t.shape(),
                t.dtype_name()
            );
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let buf = &result[0][0];
        let lit = buf.to_literal_sync()?;
        let parts = lit.to_tuple()?;
        anyhow::ensure!(
            parts.len() == self.meta.outputs.len(),
            "artifact {}: expected {} outputs, got {}",
            self.meta.name,
            self.meta.outputs.len(),
            parts.len()
        );
        parts
            .into_iter()
            .zip(self.meta.outputs.iter())
            .map(|(l, m)| Tensor::from_literal(&l, m))
            .collect()
    }
}

/// Artifact registry: loads HLO text + metadata from `artifacts/`,
/// compiles lazily, caches compiled executables and init buffers.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// CPU-PJRT runtime over an artifacts directory.
    pub fn new(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        anyhow::ensure!(
            dir.join("manifest.json").exists(),
            "artifacts directory {} missing manifest.json — run `make artifacts`",
            dir.display()
        );
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
            dir,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Names listed in the manifest.
    pub fn manifest(&self) -> Result<Vec<String>> {
        let text = std::fs::read_to_string(self.dir.join("manifest.json"))?;
        let v = crate::util::json::Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        Ok(v.req("artifacts")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("artifacts not an array"))?
            .iter()
            .filter_map(|x| x.as_str().map(String::from))
            .collect())
    }

    /// Load + compile (cached) an artifact by name.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = ArtifactMeta::load(&self.dir.join(format!("{name}.meta.json")))?;
        let hlo_path = self.dir.join(&meta.hlo_file);
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let exec = std::sync::Arc::new(Executable { meta, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Read a raw little-endian f32 init buffer (`artifacts/<name>.f32`).
    pub fn load_init(&self, name: &str) -> Result<Vec<f32>> {
        let path = self.dir.join(format!("{name}.f32"));
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading init buffer {}", path.display()))?;
        anyhow::ensure!(bytes.len() % 4 == 0, "init buffer not f32-aligned");
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}
