//! Host tensors crossing the PJRT boundary (f32 / i32 only — everything
//! the artifacts exchange).

use anyhow::{anyhow, Result};

use super::meta::TensorMeta;

/// A host tensor: shape + typed data.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::I32 { shape, data }
    }

    pub fn scalar_f32(x: f32) -> Tensor {
        Tensor::F32 {
            shape: vec![],
            data: vec![x],
        }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::F32 {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype_name(&self) -> &'static str {
        match self {
            Tensor::F32 { .. } => "float32",
            Tensor::I32 { .. } => "int32",
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    /// Scalar f32 value (0-d or 1-element tensors).
    pub fn scalar(&self) -> Result<f32> {
        let d = self.as_f32()?;
        anyhow::ensure!(d.len() == 1, "tensor is not a scalar");
        Ok(d[0])
    }

    /// Convert to an xla Literal with this tensor's shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data.as_slice()),
            Tensor::I32 { data, .. } => xla::Literal::vec1(data.as_slice()),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Read a Literal back into a host tensor, checking against metadata.
    pub fn from_literal(lit: &xla::Literal, meta: &TensorMeta) -> Result<Tensor> {
        match meta.dtype.as_str() {
            "float32" => Ok(Tensor::F32 {
                shape: meta.shape.clone(),
                data: lit.to_vec::<f32>()?,
            }),
            "int32" => Ok(Tensor::I32 {
                shape: meta.shape.clone(),
                data: lit.to_vec::<i32>()?,
            }),
            other => Err(anyhow!("unsupported artifact dtype {other}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_accessors() {
        let t = Tensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype_name(), "float32");
        assert_eq!(t.len(), 6);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
        assert_eq!(Tensor::scalar_f32(2.5).scalar().unwrap(), 2.5);
    }

    #[test]
    fn literal_round_trip() {
        let t = Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let meta = TensorMeta {
            name: "x".into(),
            shape: vec![2, 2],
            dtype: "float32".into(),
        };
        let back = Tensor::from_literal(&lit, &meta).unwrap();
        assert_eq!(back, t);

        let ti = Tensor::i32(vec![3], vec![7, 8, 9]);
        let lit = ti.to_literal().unwrap();
        let meta = TensorMeta {
            name: "y".into(),
            shape: vec![3],
            dtype: "int32".into(),
        };
        assert_eq!(Tensor::from_literal(&lit, &meta).unwrap(), ti);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::f32(vec![2, 2], vec![0.0; 3]);
    }
}
