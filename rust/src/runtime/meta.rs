//! Artifact metadata (`artifacts/<name>.meta.json`) — the contract
//! between the Python compile path and the Rust runtime.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One input or output tensor of an artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorMeta {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<TensorMeta> {
        Ok(TensorMeta {
            name: v.req_str("name")?.to_string(),
            shape: v
                .req("shape")?
                .usize_vec()
                .ok_or_else(|| anyhow!("bad shape"))?,
            dtype: v.req_str("dtype")?.to_string(),
        })
    }
}

/// Parsed `<name>.meta.json`.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub hlo_file: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
    pub extra: Json,
    /// init-buffer name -> file name under artifacts/.
    pub inits: Vec<(String, String)>,
}

impl ArtifactMeta {
    pub fn load(path: &Path) -> Result<ArtifactMeta> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let inputs = v
            .req("inputs")?
            .as_arr()
            .ok_or_else(|| anyhow!("inputs not an array"))?
            .iter()
            .map(TensorMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        let outputs = v
            .req("outputs")?
            .as_arr()
            .ok_or_else(|| anyhow!("outputs not an array"))?
            .iter()
            .map(TensorMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        let mut inits = Vec::new();
        if let Some(obj) = v.get("inits").and_then(|j| j.as_obj()) {
            for (k, f) in obj {
                inits.push((
                    k.clone(),
                    f.as_str().ok_or_else(|| anyhow!("init not a string"))?.to_string(),
                ));
            }
        }
        Ok(ArtifactMeta {
            name: v.req_str("name")?.to_string(),
            hlo_file: v.req_str("hlo")?.to_string(),
            inputs,
            outputs,
            extra: v.get("extra").cloned().unwrap_or(Json::Obj(Default::default())),
            inits,
        })
    }

    pub fn input(&self, name: &str) -> Result<&TensorMeta> {
        self.inputs
            .iter()
            .find(|t| t.name == name)
            .ok_or_else(|| anyhow!("artifact {} has no input '{name}'", self.name))
    }

    /// Usize field from the `extra` record.
    pub fn extra_usize(&self, key: &str) -> Result<usize> {
        self.extra
            .get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("artifact {}: extra.{key} missing", self.name))
    }

    pub fn extra_str(&self, key: &str) -> Result<&str> {
        self.extra
            .get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("artifact {}: extra.{key} missing", self.name))
    }

    /// f64 array field from `extra` (e.g. the diffusion noise schedule).
    pub fn extra_f64_vec(&self, key: &str) -> Result<Vec<f64>> {
        self.extra
            .get(key)
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
            .ok_or_else(|| anyhow!("artifact {}: extra.{key} missing", self.name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_meta_file() {
        let dir = std::env::temp_dir().join("gsoft_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.meta.json");
        std::fs::write(
            &path,
            r#"{"name":"x","hlo":"x.hlo.txt",
               "inputs":[{"name":"a","shape":[2,3],"dtype":"float32"}],
               "outputs":[{"name":"y","shape":[],"dtype":"float32"}],
               "extra":{"batch":4,"label":"L","sched":[0.5,0.25]},
               "inits":{"base":"base.f32"}}"#,
        )
        .unwrap();
        let m = ArtifactMeta::load(&path).unwrap();
        assert_eq!(m.name, "x");
        assert_eq!(m.inputs[0].shape, vec![2, 3]);
        assert_eq!(m.inputs[0].element_count(), 6);
        assert_eq!(m.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(m.extra_usize("batch").unwrap(), 4);
        assert_eq!(m.extra_str("label").unwrap(), "L");
        assert_eq!(m.extra_f64_vec("sched").unwrap(), vec![0.5, 0.25]);
        assert_eq!(m.inits, vec![("base".to_string(), "base.f32".to_string())]);
        assert!(m.input("a").is_ok());
        assert!(m.input("zz").is_err());
    }
}
