//! Minimal JSON parser and writer.
//!
//! The offline environment has no `serde_json`, so we implement the subset
//! of JSON we need for artifact metadata (`artifacts/*.meta.json`),
//! experiment configs (`configs/*.json`) and result files
//! (`results/*.json`). This is a complete JSON implementation (objects,
//! arrays, strings with escapes, numbers, bools, null) minus only exotic
//! corners (`\u` surrogate pairs are joined; numbers parse via Rust's f64
//! parser).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept in a `BTreeMap` so output is
/// deterministic (stable diffs for generated result files).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|x| if x.fract() == 0.0 { Some(x as i64) } else { None })
    }

    /// Read a u64 written by [`Json::u64`]: a non-negative integral
    /// number inside the exact-f64 range, or a decimal string for
    /// values beyond it.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= U64_EXACT_MAX as f64 => {
                Some(*x as u64)
            }
            Json::Str(s) => s.parse().ok(),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// `get` that errors with a useful message — for required fields.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError(format!("missing required field '{key}'")))
    }

    /// Convenience: required usize field.
    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| JsonError(format!("field '{key}' is not a non-negative integer")))
    }

    /// Convenience: required string field.
    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| JsonError(format!("field '{key}' is not a string")))
    }

    /// Convenience: array of usize.
    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Lossless u64 emission. `Json::Num` is an f64, so identifiers above
    /// 2^53 (ring sequence numbers, epoch nanoseconds past ~104 days,
    /// request ids) would silently round; those are emitted as decimal
    /// strings instead. [`Json::as_u64`] reads both shapes back.
    pub fn u64(v: u64) -> Json {
        if v <= U64_EXACT_MAX {
            Json::Num(v as f64)
        } else {
            Json::Str(v.to_string())
        }
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Largest u64 that round-trips exactly through an f64 (2^53). Above it,
/// [`Json::u64`] switches to string emission.
pub const U64_EXACT_MAX: u64 = 1 << 53;

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    // JSON has no NaN/Infinity literals; emitting them (as `{x}` would)
    // produces a document our own parser rejects. Non-finite values come
    // from empty-histogram quantiles and 0/0 SLO ratios — degrade to null.
    if !x.is_finite() {
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Error from JSON parsing / field extraction.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error: {}", self.0)
    }
}

impl std::error::Error for JsonError {}

/// Maximum container nesting accepted by [`Json::parse`]. The parser is
/// recursive-descent, so without a cap a short `[[[[…` document drives the
/// call stack as deep as the input is long — a stack overflow (abort, not
/// unwind) reachable from any untrusted body. 128 is far beyond any document
/// this codebase produces.
pub const MAX_PARSE_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ') | Some(b'\t') | Some(b'\n') | Some(b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.nested(Parser::object),
            Some(b'[') => self.nested(Parser::array),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    /// Run a container parser one nesting level down, erroring (not
    /// overflowing the stack) past [`MAX_PARSE_DEPTH`].
    fn nested(
        &mut self,
        f: fn(&mut Parser<'a>) -> Result<Json, JsonError>,
    ) -> Result<Json, JsonError> {
        if self.depth >= MAX_PARSE_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.depth += 1;
        let v = f(self);
        self.depth -= 1;
        v
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        if (0xD800..0xDC00).contains(&cp) {
                            // High surrogate: must be followed by \uXXXX low.
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else {
                            s.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode the UTF-8 multibyte sequence.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("invalid UTF-8")),
                    };
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated UTF-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    s.push_str(chunk);
                    self.pos = start + len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-12e2").unwrap(), Json::Num(-1200.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn escapes_round_trip() {
        let original = Json::Str("quote\" back\\ tab\t nl\n unicode£λ".into());
        let text = original.to_string();
        assert_eq!(Json::parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""£😀""#).unwrap(),
            Json::Str("£😀".into())
        );
    }

    #[test]
    fn reject_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("{}extra").is_err());
    }

    #[test]
    fn pretty_and_compact_agree() {
        let v = Json::obj(vec![
            ("name", Json::Str("gsoft".into())),
            ("dims", Json::arr_usize(&[256, 256])),
            ("lr", Json::Num(1e-3)),
        ]);
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn req_fields() {
        let v = Json::parse(r#"{"n": 4, "s": "x"}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 4);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!(v.req("missing").is_err());
        assert!(v.req_usize("s").is_err());
    }

    #[test]
    fn object_key_order_is_stable() {
        let v = Json::parse(r#"{"z":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        for x in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let v = Json::obj(vec![("q", Json::Num(x))]);
            assert_eq!(v.to_string(), r#"{"q":null}"#);
            // The acceptance case: a snapshot containing NaN must re-parse.
            let back = Json::parse(&v.pretty()).unwrap();
            assert_eq!(back.get("q").unwrap(), &Json::Null);
        }
    }

    #[test]
    fn encode_parse_round_trip_over_arbitrary_f64() {
        crate::util::prop::check_named("json_num_round_trip", 17, 256, |rng| {
            // Mix magnitudes: subnormals through 1e300, exact integers,
            // and the non-finite specials.
            let x = match rng.below(6) {
                0 => f64::NAN,
                1 => f64::INFINITY * if rng.flip(0.5) { 1.0 } else { -1.0 },
                2 => (rng.normal() * 1e15).trunc(),
                3 => rng.normal() * 10f64.powi(rng.below(600) as i32 - 300),
                4 => rng.normal(),
                _ => f64::from_bits(rng.next_u64()),
            };
            let text = Json::Num(x).to_string();
            let parsed = Json::parse(&text)
                .unwrap_or_else(|e| panic!("encode of {x:?} produced invalid JSON {text:?}: {e}"));
            match parsed {
                Json::Null => assert!(!x.is_finite(), "{x:?} encoded as null"),
                Json::Num(y) => {
                    assert!(x.is_finite());
                    assert!(
                        y == x || (y - x).abs() <= x.abs() * 1e-15,
                        "round trip {x:?} -> {text} -> {y:?}"
                    );
                }
                other => panic!("number {x:?} round-tripped to {other:?}"),
            }
        });
    }

    #[test]
    fn u64_encode_parse_round_trip_is_lossless() {
        // The f64 path silently corrupts integers above 2^53; Json::u64
        // must round-trip every u64 exactly, including the corruption
        // zone the old `as f64` cast lived in.
        crate::util::prop::check_named("json_u64_round_trip", 23, 256, |rng| {
            let v = match rng.below(4) {
                0 => rng.next_u64() % 1000,
                1 => U64_EXACT_MAX - rng.next_u64() % 3,
                2 => U64_EXACT_MAX + 1 + rng.next_u64() % 1000,
                _ => rng.next_u64(),
            };
            let text = Json::u64(v).to_string();
            let back = Json::parse(&text).unwrap().as_u64();
            assert_eq!(back, Some(v), "u64 {v} -> {text} -> {back:?}");
        });
        // Pin the boundary: 2^53 is the last numeric emission, 2^53 + 1
        // is the first value an f64 cannot represent.
        assert_eq!(Json::u64(U64_EXACT_MAX), Json::Num(U64_EXACT_MAX as f64));
        assert_eq!(Json::u64(U64_EXACT_MAX + 1), Json::Str("9007199254740993".into()));
        assert_eq!(Json::u64(u64::MAX).as_u64(), Some(u64::MAX));
        // Non-integers and negatives are not u64s.
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(-2.0).as_u64(), None);
        assert_eq!(Json::Str("pony".into()).as_u64(), None);
    }

    #[test]
    fn deep_nesting_errors_instead_of_overflowing() {
        // Well past any real document, and far past what a recursive
        // parse could survive without the cap (~100k frames).
        let hostile = "[".repeat(100_000);
        let err = Json::parse(&hostile).unwrap_err();
        assert!(err.0.contains("nesting too deep"), "{err}");
        let hostile_obj = r#"{"a":"#.repeat(100_000);
        assert!(Json::parse(&hostile_obj).is_err());

        // At and just under the cap both directions behave.
        let ok = format!(
            "{}1{}",
            "[".repeat(MAX_PARSE_DEPTH),
            "]".repeat(MAX_PARSE_DEPTH)
        );
        assert!(Json::parse(&ok).is_ok());
        let over = format!(
            "{}1{}",
            "[".repeat(MAX_PARSE_DEPTH + 1),
            "]".repeat(MAX_PARSE_DEPTH + 1)
        );
        assert!(Json::parse(&over).is_err());
    }
}
