//! Self-describing on-disk container framing, shared by every durable
//! format in this repo (checkpoints' `GSCK`, the adapter store's `GSAD`).
//!
//! Layout: 4-byte magic, u32 (LE) header length, JSON header, then raw
//! little-endian f32 payload sections back to back. The header is the
//! caller's schema plus a framing-owned `"sections"` array
//! (`[{"name":…, "len":…, "crc"?:…}, …]`); when a section declares a
//! `crc`, the payload is verified against CRC32 (IEEE) on decode. No
//! external serialization crates — the offline environment has none.
//!
//! Decoding is hardened: magic, header length, and every declared section
//! length are validated against the actual byte count *before* any
//! allocation, so a truncated file or an absurd header length returns a
//! clean `Err` instead of panicking or attempting a huge allocation.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::json::Json;

/// Upper bound on a sane JSON header — enforced on BOTH sides: decode
/// rejects it (alongside the actual-byte-count check, which is the
/// binding limit for small files), and encode refuses to produce a
/// container its own reader could not load. 1 GiB of header is a few
/// million fleet-snapshot tenants; past that the fleet needs a streamed
/// format, not a bigger JSON blob (see ROADMAP).
pub const MAX_HEADER_BYTES: usize = 1 << 30;

/// Streaming CRC32 (IEEE 802.3, reflected, poly 0xEDB88320).
///
/// Table-driven (one lazily built 256-entry table) rather than bitwise:
/// this runs on the spill tier's serving path over multi-MB merged
/// models, where a shift-loop CRC alone would eat the entire
/// flop-per-byte budget the load-vs-remerge break-even assumes.
#[derive(Clone, Copy, Debug)]
pub struct Crc32(u32);

fn crc_table() -> &'static [u32; 256] {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = (c >> 1) ^ (0xEDB8_8320 & (0u32.wrapping_sub(c & 1)));
            }
            *e = c;
        }
        t
    })
}

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32(0xFFFF_FFFF)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let table = crc_table();
        let mut c = self.0;
        for &b in bytes {
            c = (c >> 8) ^ table[((c ^ b as u32) & 0xFF) as usize];
        }
        self.0 = c;
    }

    pub fn finish(self) -> u32 {
        self.0 ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

/// CRC32 of one byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

/// CRC32 of an f32 buffer's little-endian byte image — the checksum the
/// container stores for its payload sections.
pub fn crc32_f32(data: &[f32]) -> u32 {
    let mut c = Crc32::new();
    for x in data {
        c.update(&x.to_le_bytes());
    }
    c.finish()
}

/// The framing-owned header: the caller's meta plus the `sections`
/// declaration array. Refuses (loudly, at write time) a header the
/// decoder could not load — a snapshot that silently cannot be restored
/// is worse than a failed save.
fn header_string(
    meta: &BTreeMap<String, Json>,
    sections: &[(&str, &[f32])],
    with_crc: bool,
) -> String {
    let mut header = meta.clone();
    header.insert(
        "sections".to_string(),
        Json::Arr(
            sections
                .iter()
                .map(|&(n, v)| {
                    let mut fields = vec![
                        ("name", Json::Str(n.to_string())),
                        ("len", Json::Num(v.len() as f64)),
                    ];
                    if with_crc {
                        fields.push(("crc", Json::Num(crc32_f32(v) as f64)));
                    }
                    Json::obj(fields)
                })
                .collect(),
        ),
    );
    let header = Json::Obj(header).to_string();
    assert!(
        header.len() <= MAX_HEADER_BYTES,
        "container header of {} bytes exceeds MAX_HEADER_BYTES ({MAX_HEADER_BYTES}); \
         this fleet needs a streamed format",
        header.len()
    );
    header
}

/// Stream a container straight to disk without cloning any payload — the
/// writer-side twin of [`Container::save`] for large section sets
/// (checkpoints hold several full model-sized buffers; buffering the
/// whole encoded file would transiently triple their memory).
pub fn write_file(
    path: impl AsRef<Path>,
    magic: &[u8; 4],
    meta: Vec<(&str, Json)>,
    sections: &[(&str, &[f32])],
    with_crc: bool,
) -> Result<()> {
    use std::io::Write;
    let meta: BTreeMap<String, Json> =
        meta.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
    let header = header_string(&meta, sections, with_crc);
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("writing {}", path.display()))?,
    );
    f.write_all(magic)?;
    f.write_all(&(header.len() as u32).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for &(_, v) in sections {
        for x in v {
            f.write_all(&x.to_le_bytes())?;
        }
    }
    f.flush()?;
    Ok(())
}

/// A decoded (or to-be-encoded) container: the caller's header object
/// (without the framing-owned `"sections"` key) plus named f32 sections.
#[derive(Clone, Debug, PartialEq)]
pub struct Container {
    pub meta: BTreeMap<String, Json>,
    pub sections: Vec<(String, Vec<f32>)>,
}

impl Container {
    pub fn new(meta: Vec<(&str, Json)>) -> Container {
        Container {
            meta: meta.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
            sections: Vec::new(),
        }
    }

    pub fn push(&mut self, name: &str, data: Vec<f32>) {
        self.sections.push((name.to_string(), data));
    }

    pub fn get(&self, name: &str) -> Result<&[f32]> {
        self.sections
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_slice())
            .ok_or_else(|| anyhow!("container has no section '{name}'"))
    }

    pub fn meta_req(&self, key: &str) -> Result<&Json> {
        self.meta
            .get(key)
            .ok_or_else(|| anyhow!("container header missing field '{key}'"))
    }

    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta_req(key)?
            .as_usize()
            .ok_or_else(|| anyhow!("container header field '{key}' is not a non-negative integer"))
    }

    pub fn meta_str(&self, key: &str) -> Result<&str> {
        self.meta_req(key)?
            .as_str()
            .ok_or_else(|| anyhow!("container header field '{key}' is not a string"))
    }

    /// Serialize to bytes. `with_crc` stores a CRC32 per section (the
    /// durable `GSAD` formats set this; checkpoints keep the legacy
    /// CRC-less layout byte-identical to what older files contain).
    pub fn encode(&self, magic: &[u8; 4], with_crc: bool) -> Vec<u8> {
        let views: Vec<(&str, &[f32])> = self
            .sections
            .iter()
            .map(|(n, v)| (n.as_str(), v.as_slice()))
            .collect();
        let header = header_string(&self.meta, &views, with_crc);
        let payload_len: usize = self.sections.iter().map(|(_, v)| v.len() * 4).sum();
        let mut out = Vec::with_capacity(8 + header.len() + payload_len);
        out.extend_from_slice(magic);
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        for (_, v) in &self.sections {
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        out
    }

    /// Parse a container from bytes, validating magic, header length, and
    /// every declared section length against `bytes.len()` before
    /// allocating payload buffers. Sections that declare a `crc` are
    /// checksum-verified.
    pub fn decode(bytes: &[u8], magic: &[u8; 4]) -> Result<Container> {
        anyhow::ensure!(
            bytes.len() >= 8,
            "container too short: {} bytes, need at least 8",
            bytes.len()
        );
        anyhow::ensure!(
            &bytes[..4] == magic,
            "bad container magic: expected {:?}, got {:?}",
            std::str::from_utf8(magic).unwrap_or("?"),
            &bytes[..4]
        );
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        anyhow::ensure!(
            hlen <= MAX_HEADER_BYTES && hlen <= bytes.len() - 8,
            "container header declares {hlen} bytes but only {} remain",
            bytes.len() - 8
        );
        let header = Json::parse(
            std::str::from_utf8(&bytes[8..8 + hlen]).context("container header is not UTF-8")?,
        )
        .map_err(|e| anyhow!("container header: {e}"))?;
        let mut meta = header
            .as_obj()
            .ok_or_else(|| anyhow!("container header is not a JSON object"))?
            .clone();
        let sections_decl = meta
            .remove("sections")
            .ok_or_else(|| anyhow!("container header has no 'sections' array"))?;
        let sections_decl = sections_decl
            .as_arr()
            .ok_or_else(|| anyhow!("container 'sections' is not an array"))?;

        let payload = &bytes[8 + hlen..];
        let mut off = 0usize;
        let mut sections = Vec::with_capacity(sections_decl.len());
        for s in sections_decl {
            let name = s.req_str("name").map_err(|e| anyhow!("{e}"))?.to_string();
            let n = s.req_usize("len").map_err(|e| anyhow!("{e}"))?;
            // Checked end-offset: a crafted length must not wrap around
            // usize and sneak past the bounds test.
            let end = n
                .checked_mul(4)
                .and_then(|nb| off.checked_add(nb))
                .filter(|&e| e <= payload.len())
                .ok_or_else(|| {
                    anyhow!(
                        "section '{name}' declares {n} floats but only {} payload bytes \
                         remain (truncated file?)",
                        payload.len() - off
                    )
                })?;
            let data: Vec<f32> = payload[off..end]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            if let Some(want) = s.get("crc").and_then(|v| v.as_f64()) {
                let got = crc32(&payload[off..end]);
                anyhow::ensure!(
                    got as f64 == want,
                    "section '{name}' failed its CRC32 check (corrupt payload)"
                );
            }
            off = end;
            sections.push((name, data));
        }
        anyhow::ensure!(
            off == payload.len(),
            "container has {} trailing payload bytes beyond the declared sections",
            payload.len() - off
        );
        Ok(Container { meta, sections })
    }

    pub fn save(&self, path: impl AsRef<Path>, magic: &[u8; 4], with_crc: bool) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.encode(magic, with_crc))
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>, magic: &[u8; 4]) -> Result<Container> {
        let path = path.as_ref();
        let bytes =
            std::fs::read(path).with_context(|| format!("opening {}", path.display()))?;
        Container::decode(&bytes, magic).with_context(|| format!("decoding {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::unique_temp_dir;

    const MAGIC: &[u8; 4] = b"GSTC";

    fn sample() -> Container {
        let mut c = Container::new(vec![("v", Json::Num(1.0)), ("tag", Json::Str("x".into()))]);
        c.push("a", vec![1.0, -2.5, 3.25]);
        c.push("b", vec![0.0; 5]);
        c
    }

    #[test]
    fn round_trip_with_and_without_crc() {
        for with_crc in [false, true] {
            let c = sample();
            let bytes = c.encode(MAGIC, with_crc);
            let back = Container::decode(&bytes, MAGIC).unwrap();
            assert_eq!(back, c);
            assert_eq!(back.meta_usize("v").unwrap(), 1);
            assert_eq!(back.meta_str("tag").unwrap(), "x");
            assert_eq!(back.get("a").unwrap()[1], -2.5);
            assert!(back.get("missing").is_err());
        }
    }

    #[test]
    fn file_round_trip() {
        let dir = unique_temp_dir("container");
        let path = dir.join("sub/c.bin");
        let c = sample();
        c.save(&path, MAGIC, true).unwrap();
        assert_eq!(Container::load(&path, MAGIC).unwrap(), c);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_wrong_magic_and_garbage() {
        let bytes = sample().encode(MAGIC, false);
        assert!(Container::decode(&bytes, b"NOPE").is_err());
        assert!(Container::decode(b"", MAGIC).is_err());
        assert!(Container::decode(b"GST", MAGIC).is_err());
        assert!(Container::decode(b"GSTCxxxx", MAGIC).is_err());
    }

    #[test]
    fn truncation_anywhere_is_a_clean_error() {
        // Every strict prefix must fail decode without panicking — the
        // durability story depends on torn writes being detectable.
        let bytes = sample().encode(MAGIC, true);
        for cut in 0..bytes.len() {
            assert!(
                Container::decode(&bytes[..cut], MAGIC).is_err(),
                "prefix of {cut}/{} bytes decoded",
                bytes.len()
            );
        }
    }

    #[test]
    fn absurd_header_length_is_rejected_before_allocating() {
        // Declare a 4 GiB header in an 8+4-byte file: must be a clean Err.
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        bytes.extend_from_slice(b"{}{}");
        assert!(Container::decode(&bytes, MAGIC).is_err());
    }

    #[test]
    fn oversized_section_declaration_is_rejected() {
        // Header claims more floats than the payload holds: encode, then
        // chop payload bytes only (the header still declares full lengths).
        let full = sample().encode(MAGIC, false);
        let chopped = &full[..full.len() - 4];
        assert!(Container::decode(chopped, MAGIC).is_err());
        // And trailing extra payload is rejected too.
        let mut padded = full.clone();
        padded.extend_from_slice(&[0u8; 4]);
        assert!(Container::decode(&padded, MAGIC).is_err());
    }

    #[test]
    fn crc_detects_payload_corruption() {
        let mut bytes = sample().encode(MAGIC, true);
        let n = bytes.len();
        bytes[n - 1] ^= 0x40; // flip a payload bit
        let err = Container::decode(&bytes, MAGIC).unwrap_err();
        assert!(err.to_string().contains("CRC32"), "{err}");
        // Without CRC the same corruption goes unnoticed by framing.
        let mut bytes = sample().encode(MAGIC, false);
        let n = bytes.len();
        bytes[n - 1] ^= 0x40;
        assert!(Container::decode(&bytes, MAGIC).is_ok());
    }

    #[test]
    fn crc32_known_vector() {
        // IEEE CRC32 of "123456789" is 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        let mut c = Crc32::new();
        c.update(b"1234");
        c.update(b"56789");
        assert_eq!(c.finish(), 0xCBF4_3926);
    }
}
