//! Shared hardened HTTP/1.1 plumbing (DESIGN.md §11).
//!
//! One pure-std listener implementation behind both wire surfaces — the
//! telemetry exporter ([`crate::obs::http::ObsServer`]) and the request
//! front ([`crate::serve::front::ServeFront`]). Handlers get a parsed
//! [`Request`] (method, path, body) and return a [`Response`]; everything
//! untrusted-input-shaped lives here, once:
//!
//! - **Bounded reads.** Head (request line + headers) is capped at
//!   [`ServerOpts::max_head_bytes`] → 400; the body is read only up to a
//!   `Content-Length` that must not exceed
//!   [`ServerOpts::max_body_bytes`] → 413.
//! - **Wall-clock request deadline.** Every read is clamped to the time
//!   remaining until `accept + request_deadline`, so a client trickling
//!   one byte per second cannot hold a connection open indefinitely
//!   (each successful read no longer resets the budget) → 408.
//! - **O(n) head scanning.** The `\r\n\r\n` terminator search resumes
//!   where the previous chunk left off instead of rescanning the whole
//!   buffer per read.
//! - **Worker-pool handling.** Connections are fanned out over a
//!   [`WorkQueue`] to a fixed pool, so one slow peer stalls one worker,
//!   not the accept loop.
//! - **Panic isolation.** A panicking handler answers 500 and the worker
//!   lives on.
//!
//! Connections that close without sending anything are dropped silently —
//! that is also how [`HttpServer::shutdown`] wakes the accept loop.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::pool::WorkQueue;

/// Default bound on the request head (line + headers). A scrape GET or a
/// JSON POST preamble is well under 1 KiB; anything larger is a 400.
pub const DEFAULT_MAX_HEAD_BYTES: usize = 8192;

/// Default bound on a request body. Register payloads carry whole adapter
/// parameter buffers as JSON arrays, so this is generous; past it is 413.
pub const DEFAULT_MAX_BODY_BYTES: usize = 16 << 20;

/// Default wall-clock budget for reading one request.
pub const DEFAULT_REQUEST_DEADLINE: Duration = Duration::from_secs(5);

/// Per-read socket timeout ceiling (the effective timeout is the minimum
/// of this and the time left until the request deadline).
const CHUNK_TIMEOUT: Duration = Duration::from_secs(2);

/// A parsed inbound request.
pub struct Request {
    pub method: String,
    /// Target with any `?query` stripped.
    pub path: String,
    /// Raw query string after the first `?` (empty if the target had
    /// none). Parse with [`Request::query_params`].
    pub query: String,
    pub body: Vec<u8>,
}

impl Request {
    /// Parse the body as a JSON document (depth-capped, see
    /// [`crate::util::json::MAX_PARSE_DEPTH`]). `Err` carries a
    /// client-facing message for a 400.
    pub fn body_json(&self) -> std::result::Result<Json, String> {
        let text = std::str::from_utf8(&self.body).map_err(|_| "body is not UTF-8".to_string())?;
        Json::parse(text).map_err(|e| e.to_string())
    }

    /// Parse the query string as `key=value` pairs. Strict on shape —
    /// every non-empty `&`-separated piece must contain `=` with a
    /// non-empty key — so handlers can answer a clean 400 instead of
    /// silently ignoring a mistyped filter. No percent-decoding: the
    /// obs/serve query surface is numeric ids and flags only.
    pub fn query_params(&self) -> std::result::Result<Vec<(String, String)>, String> {
        let mut out = Vec::new();
        for piece in self.query.split('&').filter(|p| !p.is_empty()) {
            match piece.split_once('=') {
                Some((k, v)) if !k.is_empty() => out.push((k.to_string(), v.to_string())),
                _ => return Err(format!("malformed query parameter '{piece}'")),
            }
        }
        Ok(out)
    }
}

/// What a handler answers with.
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: String,
}

impl Response {
    pub fn text(status: u16, body: &str) -> Response {
        Response {
            status,
            content_type: "text/plain",
            body: body.to_string(),
        }
    }

    pub fn json(status: u16, v: &Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: v.pretty(),
        }
    }
}

/// Handler invoked per request on a pool worker. Panics are caught and
/// answered with a 500.
pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// Listener configuration; [`ServerOpts::default`] matches the exporter's
/// historical hardening bounds.
#[derive(Clone, Copy)]
pub struct ServerOpts {
    pub workers: usize,
    pub max_head_bytes: usize,
    pub max_body_bytes: usize,
    pub request_deadline: Duration,
}

impl Default for ServerOpts {
    fn default() -> ServerOpts {
        ServerOpts {
            workers: 4,
            max_head_bytes: DEFAULT_MAX_HEAD_BYTES,
            max_body_bytes: DEFAULT_MAX_BODY_BYTES,
            request_deadline: DEFAULT_REQUEST_DEADLINE,
        }
    }
}

/// A running listener: accept thread + handler pool. Dropping it (or
/// calling [`HttpServer::shutdown`]) stops the listener and joins every
/// thread.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (port 0 for ephemeral) and start serving `handler`
    /// on `opts.workers` pool threads. `what` names the surface in bind
    /// errors.
    pub fn bind(addr: &str, what: &str, opts: ServerOpts, handler: Handler) -> Result<HttpServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding {what} on {addr}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let queue: Arc<WorkQueue<TcpStream>> = Arc::new(WorkQueue::new());
        let workers: Vec<JoinHandle<()>> = (0..opts.workers.max(1))
            .map(|_| {
                let queue = Arc::clone(&queue);
                let handler = Arc::clone(&handler);
                std::thread::spawn(move || {
                    while let Some(stream) = queue.pop() {
                        handle_conn(stream, &opts, &handler);
                    }
                })
            })
            .collect();
        let accept = {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                for conn in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    queue.push(stream);
                }
                // Drain-and-join so shutdown returns only once every
                // in-flight request has been answered.
                queue.close();
                for w in workers {
                    let _ = w.join();
                }
            })
        };
        Ok(HttpServer {
            addr,
            stop,
            accept: Some(accept),
        })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn url(&self) -> String {
        format!("http://{}", self.addr)
    }

    /// Stop accepting, wake the blocked accept loop with a self-connect,
    /// and join the accept thread (which joins the pool).
    pub fn shutdown(mut self) {
        self.stop_now();
    }

    fn stop_now(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // The accept loop blocks in `incoming()`; an empty connection is
        // read as zero bytes by whichever worker pops it and dropped
        // silently.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop_now();
    }
}

fn handle_conn(mut stream: TcpStream, opts: &ServerOpts, handler: &Handler) {
    let deadline = Instant::now() + opts.request_deadline;
    let _ = stream.set_write_timeout(Some(CHUNK_TIMEOUT));
    let req = match read_request(&mut stream, opts, deadline) {
        Ok(Some(req)) => req,
        // Nothing sent (shutdown wake, port probe): close silently.
        Ok(None) => return,
        Err(status) => {
            let body = match status {
                408 => "request deadline exceeded\n",
                413 => "body too large\n",
                _ => "bad request\n",
            };
            write_response(&mut stream, status, "text/plain", body);
            return;
        }
    };
    // A panicking handler must answer 500 and leave the worker alive.
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| handler(&req)));
    match outcome {
        Ok(resp) => write_response(&mut stream, resp.status, resp.content_type, &resp.body),
        Err(_) => write_response(&mut stream, 500, "text/plain", "internal error\n"),
    }
}

/// Read one chunk, clamping the socket timeout to the time left before
/// `deadline`. `Err(408)` once the wall-clock budget is spent.
fn read_chunk(
    stream: &mut TcpStream,
    chunk: &mut [u8],
    deadline: Instant,
) -> std::result::Result<usize, u16> {
    let now = Instant::now();
    if now >= deadline {
        return Err(408);
    }
    let _ = stream.set_read_timeout(Some((deadline - now).min(CHUNK_TIMEOUT)));
    stream.read(chunk).map_err(|_| 408)
}

/// Read and parse one full request (head + Content-Length body).
/// `Ok(None)` = the peer sent nothing at all.
fn read_request(
    stream: &mut TcpStream,
    opts: &ServerOpts,
    deadline: Instant,
) -> std::result::Result<Option<Request>, u16> {
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut scanned = 0usize; // head bytes already checked for \r\n\r\n
    let head_end = loop {
        // Resume the terminator scan 3 bytes back: a split "\r\n\r\n"
        // straddling a chunk boundary is still found, without rescanning
        // the whole head per read.
        let from = scanned.saturating_sub(3);
        if let Some(i) = buf[from..].windows(4).position(|w| w == b"\r\n\r\n") {
            break from + i + 4;
        }
        scanned = buf.len();
        if buf.len() > opts.max_head_bytes {
            return Err(400);
        }
        match read_chunk(stream, &mut chunk, deadline) {
            Ok(0) if buf.is_empty() => return Ok(None),
            Ok(0) => return Err(400), // EOF mid-head
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(_) if buf.is_empty() => return Ok(None),
            Err(status) => return Err(status),
        }
    };

    let head = std::str::from_utf8(&buf[..head_end]).map_err(|_| 400u16)?;
    let mut lines = head.split("\r\n");
    let (method, path, query) = parse_request_line(lines.next().unwrap_or(""))?;
    let mut content_length = 0usize;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().map_err(|_| 400u16)?;
            }
        }
    }
    if content_length > opts.max_body_bytes {
        return Err(413);
    }

    let mut body = buf[head_end..].to_vec();
    if body.len() > content_length {
        return Err(400); // more bytes than the declared body
    }
    while body.len() < content_length {
        match read_chunk(stream, &mut chunk, deadline)? {
            0 => return Err(400), // EOF before the declared length
            n => body.extend_from_slice(&chunk[..n]),
        }
        if body.len() > content_length {
            return Err(400);
        }
    }
    Ok(Some(Request {
        method,
        path,
        query,
        body,
    }))
}

/// `METHOD /path?query HTTP/1.1` → `(METHOD, /path, query)`. 400 on
/// shape violations; method policy (405) is the handler's call.
fn parse_request_line(line: &str) -> std::result::Result<(String, String, String), u16> {
    let mut parts = line.split_whitespace();
    let (Some(method), Some(target), Some(version), None) =
        (parts.next(), parts.next(), parts.next(), parts.next())
    else {
        return Err(400);
    };
    if !version.starts_with("HTTP/") || !target.starts_with('/') {
        return Err(400);
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    Ok((method.to_string(), path.to_string(), query.to_string()))
}

/// Minimal one-shot HTTP client for loopback benches, smoke drivers and
/// tests: write one request, read to EOF (our servers always close the
/// connection), return `(status, body)`.
pub fn http_request(
    addr: SocketAddr,
    method: &str,
    target: &str,
    body: Option<&str>,
) -> Result<(u16, String)> {
    let mut s = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    let _ = s.set_read_timeout(Some(Duration::from_secs(30)));
    let _ = s.set_write_timeout(Some(Duration::from_secs(30)));
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {target} HTTP/1.1\r\nHost: gsoft\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes())?;
    let mut text = String::new();
    s.read_to_string(&mut text)?;
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|t| t.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("no HTTP status line in response: {text:?}"))?;
    let resp_body = text
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    Ok((status, resp_body))
}

pub(crate) fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "",
    }
}

fn write_response(stream: &mut TcpStream, status: u16, ctype: &str, body: &str) {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        status_reason(status),
        body.len()
    );
    let _ = stream
        .write_all(head.as_bytes())
        .and_then(|_| stream.write_all(body.as_bytes()))
        .and_then(|_| stream.flush());
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server(opts: ServerOpts) -> HttpServer {
        let handler: Handler = Arc::new(|req: &Request| {
            Response::text(
                200,
                &format!("{} {} {}b\n", req.method, req.path, req.body.len()),
            )
        });
        HttpServer::bind("127.0.0.1:0", "test server", opts, handler).unwrap()
    }

    fn raw(addr: SocketAddr, request: &[u8]) -> (u16, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(request).unwrap();
        let mut text = String::new();
        s.read_to_string(&mut text).unwrap();
        let status: u16 = text
            .split_whitespace()
            .nth(1)
            .and_then(|t| t.parse().ok())
            .unwrap_or_else(|| panic!("no status line in {text:?}"));
        let body = text.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
        (status, body)
    }

    #[test]
    fn parses_method_path_and_content_length_body() {
        let server = echo_server(ServerOpts::default());
        let (status, body) = raw(
            server.addr(),
            b"POST /v1/query?x=1 HTTP/1.1\r\nHost: t\r\nContent-Length: 5\r\n\r\nhello",
        );
        assert_eq!(status, 200);
        assert_eq!(body, "POST /v1/query 5b\n");
        let (status, body) = raw(server.addr(), b"GET / HTTP/1.1\r\n\r\n");
        assert_eq!(status, 200);
        assert_eq!(body, "GET / 0b\n");
        server.shutdown();
    }

    #[test]
    fn query_string_is_carried_and_parses_strictly() {
        let handler: Handler = Arc::new(|req: &Request| match req.query_params() {
            Ok(params) => {
                let rendered: Vec<String> =
                    params.iter().map(|(k, v)| format!("{k}={v}")).collect();
                Response::text(200, &format!("{}|{}\n", req.path, rendered.join(",")))
            }
            Err(e) => Response::text(400, &e),
        });
        let server =
            HttpServer::bind("127.0.0.1:0", "test server", ServerOpts::default(), handler)
                .unwrap();
        let (status, body) = raw(server.addr(), b"GET /tracez?req=7&tenant=3 HTTP/1.1\r\n\r\n");
        assert_eq!(status, 200);
        assert_eq!(body, "/tracez|req=7,tenant=3\n");
        let (status, body) = raw(server.addr(), b"GET /tracez HTTP/1.1\r\n\r\n");
        assert_eq!((status, body.as_str()), (200, "/tracez|\n"), "no query = no params");
        // Only the first '?' splits; later ones belong to the value.
        let (status, body) = raw(server.addr(), b"GET /a?k=v?w HTTP/1.1\r\n\r\n");
        assert_eq!((status, body.as_str()), (200, "/a|k=v?w\n"));
        for bad in ["/tracez?req", "/tracez?=5", "/tracez?a=1&bare"] {
            let line = format!("GET {bad} HTTP/1.1\r\n\r\n");
            let (status, _) = raw(server.addr(), line.as_bytes());
            assert_eq!(status, 400, "{bad} must parse as malformed");
        }
        server.shutdown();
    }

    #[test]
    fn body_split_across_reads_is_reassembled() {
        let server = echo_server(ServerOpts::default());
        let mut s = TcpStream::connect(server.addr()).unwrap();
        s.write_all(b"POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\n12345").unwrap();
        s.flush().unwrap();
        std::thread::sleep(Duration::from_millis(30));
        s.write_all(b"67890").unwrap();
        let mut text = String::new();
        s.read_to_string(&mut text).unwrap();
        assert!(text.contains("POST /x 10b"), "{text}");
        server.shutdown();
    }

    #[test]
    fn oversized_head_and_body_are_rejected() {
        let server = echo_server(ServerOpts::default());
        let oversized = format!(
            "GET /{} HTTP/1.1\r\n\r\n",
            "a".repeat(2 * DEFAULT_MAX_HEAD_BYTES)
        );
        let (status, _) = raw(server.addr(), oversized.as_bytes());
        assert_eq!(status, 400);
        let huge = format!(
            "POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            DEFAULT_MAX_BODY_BYTES + 1
        );
        let (status, _) = raw(server.addr(), huge.as_bytes());
        assert_eq!(status, 413, "declared body over the bound is refused unread");
        let (status, _) = raw(server.addr(), b"POST /x HTTP/1.1\r\nContent-Length: pony\r\n\r\n");
        assert_eq!(status, 400);
        server.shutdown();
    }

    #[test]
    fn slow_trickling_client_is_cut_off_at_the_wall_clock_deadline() {
        let opts = ServerOpts {
            request_deadline: Duration::from_millis(300),
            ..ServerOpts::default()
        };
        let server = echo_server(opts);
        let start = Instant::now();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        // Keep every individual read fast (defeating a per-read timeout)
        // while never finishing the request.
        let mut text = String::new();
        let mut buf = [0u8; 1024];
        for _ in 0..100 {
            let dead_peer = s.write_all(b"G").is_err();
            std::thread::sleep(Duration::from_millis(20));
            // Poll for the server's answer without blocking forever.
            s.set_read_timeout(Some(Duration::from_millis(5))).unwrap();
            match s.read(&mut buf) {
                Ok(0) => break,
                Ok(n) => text.push_str(&String::from_utf8_lossy(&buf[..n])),
                Err(_) if dead_peer => break,
                Err(_) => {}
            }
            if text.contains("\r\n\r\n") {
                break;
            }
        }
        // Drain whatever the server sent before closing on us.
        s.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
        while let Ok(n) = s.read(&mut buf) {
            if n == 0 {
                break;
            }
            text.push_str(&String::from_utf8_lossy(&buf[..n]));
        }
        let elapsed = start.elapsed();
        assert!(
            text.starts_with("HTTP/1.1 408"),
            "trickler should get 408, got {text:?}"
        );
        assert!(
            elapsed < Duration::from_secs(2),
            "deadline must be wall-clock, not per-read: took {elapsed:?}"
        );
        // The pool survives and other clients are served.
        let (status, _) = raw(server.addr(), b"GET /ok HTTP/1.1\r\n\r\n");
        assert_eq!(status, 200);
        server.shutdown();
    }

    #[test]
    fn worker_pool_serves_while_one_connection_stalls() {
        let opts = ServerOpts {
            workers: 4,
            request_deadline: Duration::from_secs(5),
            ..ServerOpts::default()
        };
        let server = echo_server(opts);
        // Open a connection and send nothing: it pins one worker until
        // its deadline, but the pool keeps answering.
        let stall = TcpStream::connect(server.addr()).unwrap();
        for _ in 0..4 {
            let (status, _) = raw(server.addr(), b"GET /live HTTP/1.1\r\n\r\n");
            assert_eq!(status, 200);
        }
        // Release the pinned worker (silent EOF) before shutdown joins
        // the pool, so the join does not wait out the request deadline.
        drop(stall);
        server.shutdown();
    }

    #[test]
    fn handler_panic_answers_500_and_the_worker_survives() {
        let handler: Handler = Arc::new(|req: &Request| {
            if req.path == "/boom" {
                panic!("handler exploded");
            }
            Response::text(200, "ok\n")
        });
        let server =
            HttpServer::bind("127.0.0.1:0", "test server", ServerOpts::default(), handler)
                .unwrap();
        let (status, _) = raw(server.addr(), b"GET /boom HTTP/1.1\r\n\r\n");
        assert_eq!(status, 500);
        let (status, _) = raw(server.addr(), b"GET /fine HTTP/1.1\r\n\r\n");
        assert_eq!(status, 200);
        server.shutdown();
    }

    #[test]
    fn shutdown_joins_and_releases_the_port() {
        let server = echo_server(ServerOpts::default());
        let addr = server.addr();
        let (status, _) = raw(addr, b"GET / HTTP/1.1\r\n\r\n");
        assert_eq!(status, 200);
        server.shutdown();
        match TcpStream::connect(addr) {
            Err(_) => {}
            Ok(mut s) => {
                let mut buf = String::new();
                let _ = s.read_to_string(&mut buf);
                assert!(buf.is_empty(), "no server should answer after shutdown");
            }
        }
    }
}
