//! Threading primitives (no tokio/rayon/crossbeam offline): a *persistent*
//! worker pool behind [`parallel_map`], and the blocking [`WorkQueue`] the
//! serving engine's workers drain.
//!
//! The pool is spawned lazily on first use and reused by every subsequent
//! [`parallel_map`] call, so hot paths — the kernel subsystem's parallel
//! GEMM driver ([`crate::kernel`]), the serving engine, the table
//! harnesses — never pay thread-spawn cost per call. Callers participate
//! in their own work (the submitting thread drains items alongside the
//! pool), and nested `parallel_map` calls from inside a pool worker run
//! inline, so the pool cannot deadlock on its own helpers.

use std::any::Any;
use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A lifetime-erased unit of work queued on the pool.
type Task = Box<dyn FnOnce() + Send>;

/// A panic payload caught in a worker, replayed on the submitting thread.
type PanicPayload = Box<dyn Any + Send>;

thread_local! {
    /// Set inside pool workers so nested [`parallel_map`] calls run inline
    /// instead of enqueueing helpers that could sit behind the very tasks
    /// waiting on them.
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Persistent worker pool: `size` threads spawned once for the process
/// lifetime, each draining lifetime-erased tasks from a shared
/// [`WorkQueue`].
pub struct WorkerPool {
    queue: Arc<WorkQueue<Task>>,
    size: usize,
}

impl WorkerPool {
    fn start(size: usize) -> WorkerPool {
        let size = size.max(1);
        let queue: Arc<WorkQueue<Task>> = Arc::new(WorkQueue::new());
        for _ in 0..size {
            let q = Arc::clone(&queue);
            std::thread::Builder::new()
                .name("gsoft-pool".into())
                .spawn(move || {
                    IN_POOL_WORKER.with(|w| w.set(true));
                    while let Some(task) = q.pop() {
                        // A panicking task must not kill the worker; the
                        // panic is recorded task-side and replayed by the
                        // submitter.
                        let _ = std::panic::catch_unwind(AssertUnwindSafe(task));
                    }
                })
                .expect("failed to spawn pool worker");
        }
        WorkerPool { queue, size }
    }

    /// Number of persistent workers.
    pub fn size(&self) -> usize {
        self.size
    }

    fn submit(&self, task: Task) {
        self.queue.push(task);
    }
}

/// The process-wide pool, started on first use with [`default_workers`]
/// threads.
pub fn global_pool() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool::start(default_workers()))
}

/// Erase the lifetime of a boxed task so it can ride the `'static` pool
/// queue.
///
/// SAFETY: the task must never dereference caller-owned state after the
/// caller returns. [`parallel_map`] guarantees this with a [`Gate`]: tasks
/// touch the caller's stack only inside a lease, and the caller closes the
/// gate (waiting out active leases) before returning, turning any
/// not-yet-scheduled task into a no-op.
unsafe fn erase_task<'a>(task: Box<dyn FnOnce() + Send + 'a>) -> Task {
    // Lifetime-only cast: same principal trait, same auto traits, same
    // vtable — spelled as a raw-pointer cast rather than a transmute.
    Box::from_raw(Box::into_raw(task) as *mut (dyn FnOnce() + Send))
}

/// Raw-pointer wrapper handing the caller-stack control block to pool
/// tasks.
///
/// SAFETY (of the `Send` impl): the pointee is only dereferenced inside a
/// [`Gate`] lease, while the submitting thread is blocked in
/// [`Gate::close`] or has not yet reached it — so the pointee (whose
/// fields are `Sync` under `parallel_map`'s `F: Sync`/`T: Send` bounds)
/// is alive and shareable for every access.
struct SendPtr<T>(*const T);
unsafe impl<T> Send for SendPtr<T> {}

/// Lease gate between one `parallel_map` caller and its pool helpers.
/// Helpers [`Gate::enter`] before touching caller state and [`Gate::exit`]
/// after; the caller's [`Gate::close`] waits out active leases, then bars
/// new ones — so queued helpers that run later (possibly behind unrelated
/// long pool tasks) become no-ops instead of stalling the caller.
struct Gate {
    state: Mutex<GateState>,
    idle: Condvar,
}

struct GateState {
    open: bool,
    active: usize,
}

impl Gate {
    fn new() -> Gate {
        Gate {
            state: Mutex::new(GateState {
                open: true,
                active: 0,
            }),
            idle: Condvar::new(),
        }
    }

    /// Take a lease; `false` once the gate is closed.
    fn enter(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        if !st.open {
            return false;
        }
        st.active += 1;
        true
    }

    fn exit(&self) {
        let mut st = self.state.lock().unwrap();
        st.active -= 1;
        if st.active == 0 {
            self.idle.notify_all();
        }
    }

    /// Wait for active leases to finish, then bar new ones. Idempotent.
    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        while st.active > 0 {
            st = self.idle.wait(st).unwrap();
        }
        st.open = false;
    }
}

/// Drop guard closing a [`Gate`]: makes the erased-lifetime task contract
/// hold by construction — even if the caller unwinds between submitting
/// helpers and its normal close, the gate is closed (waiting out active
/// leases) before the stack frame dies.
struct GateCloser<'a>(&'a Gate);

impl Drop for GateCloser<'_> {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// Shared control block for one `parallel_map` call. Plain references into
/// the caller's stack frame — helpers reach it through a [`SendPtr`] and
/// only inside a [`Gate`] lease, so the frame is alive for every access.
struct Ctl<'a, F, T> {
    f: &'a F,
    n: usize,
    next: &'a AtomicUsize,
    results: &'a [Mutex<Option<T>>],
    panic: &'a Mutex<Option<PanicPayload>>,
}

impl<F: Fn(usize) -> T + Sync, T> Ctl<'_, F, T> {
    /// Claim and run items until the index space is exhausted. The first
    /// panic is recorded and stops this drainer; peers keep going.
    fn drain(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                break;
            }
            match std::panic::catch_unwind(AssertUnwindSafe(|| (self.f)(i))) {
                Ok(v) => *self.results[i].lock().unwrap() = Some(v),
                Err(p) => {
                    let mut first = self.panic.lock().unwrap();
                    if first.is_none() {
                        *first = Some(p);
                    }
                    break;
                }
            }
        }
    }
}

/// Run `f(i)` for `i in 0..n` across up to `workers` threads of the
/// persistent pool (the caller participates), collecting results in index
/// order. Panics in workers propagate to the caller.
pub fn parallel_map<T: Send, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 || IN_POOL_WORKER.with(|w| w.get()) {
        return (0..n).map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let panic_slot: Mutex<Option<PanicPayload>> = Mutex::new(None);
    let ctl = Ctl {
        f: &f,
        n,
        next: &next,
        results: &results,
        panic: &panic_slot,
    };

    let helpers = (workers - 1).min(global_pool().size());
    let gate = Arc::new(Gate::new());
    let closer = GateCloser(&gate);
    for _ in 0..helpers {
        let g = Arc::clone(&gate);
        let ptr: SendPtr<Ctl<'_, F, T>> = SendPtr(&ctl);
        let task = Box::new(move || {
            if g.enter() {
                // SAFETY: the lease keeps the caller blocked in
                // `Gate::close`, so `ctl` and everything it borrows are
                // alive for the whole drain.
                unsafe { (*ptr.0).drain() };
                g.exit();
            }
        });
        // SAFETY: the task touches caller state only inside a gate lease,
        // and `gate.close()` below runs before this function returns — a
        // helper scheduled after that observes the closed gate and
        // becomes a no-op, so the erased lifetime cannot dangle into an
        // actual access.
        global_pool().submit(unsafe { erase_task(task) });
    }
    ctl.drain(); // the submitting thread works instead of just waiting

    // Our own drain returning means every item was claimed; helpers
    // mid-item hold a lease, and closing waits those out. Helpers still
    // sitting in the queue (possibly behind unrelated long-running pool
    // tasks) are NOT waited for — they no-op whenever they surface. The
    // guard also closes on any unwinding path above.
    drop(closer);

    if let Some(p) = panic_slot.into_inner().unwrap() {
        std::panic::resume_unwind(p);
    }
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker did not fill slot"))
        .collect()
}

/// Blocking multi-producer / multi-consumer FIFO queue (Mutex + Condvar —
/// no crossbeam offline). Producers [`WorkQueue::push`]; consumers block in
/// [`WorkQueue::pop`] until an item arrives or the queue is closed.
pub struct WorkQueue<T> {
    state: Mutex<QueueState<T>>,
    ready: Condvar,
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> Default for WorkQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> WorkQueue<T> {
    pub fn new() -> WorkQueue<T> {
        WorkQueue {
            state: Mutex::new(QueueState {
                items: VecDeque::new(),
                closed: false,
            }),
            ready: Condvar::new(),
        }
    }

    /// Enqueue an item. Returns `false` (dropping the item) if the queue
    /// has been closed.
    pub fn push(&self, item: T) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return false;
        }
        st.items.push_back(item);
        self.ready.notify_one();
        true
    }

    /// Block until an item is available. Returns `None` once the queue is
    /// closed *and* drained — the worker-shutdown signal.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.ready.wait(st).unwrap();
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        self.state.lock().unwrap().items.pop_front()
    }

    /// Close the queue: pending items still drain, new pushes are refused,
    /// and blocked consumers wake up with `None` once empty.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.ready.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Reasonable default worker count for this host.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_and_empty() {
        assert_eq!(parallel_map(5, 1, |i| i + 1), vec![1, 2, 3, 4, 5]);
        assert!(parallel_map(0, 4, |i| i).is_empty());
    }

    #[test]
    fn panics_propagate_and_pool_survives() {
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            parallel_map(16, 4, |i| {
                assert!(i != 7, "boom at {i}");
                i
            })
        }));
        assert!(caught.is_err(), "worker panic must reach the caller");
        // The persistent pool is still serviceable afterwards.
        assert_eq!(parallel_map(4, 4, |i| i * 2), vec![0, 2, 4, 6]);
    }

    #[test]
    fn nested_parallel_map_completes_without_deadlock() {
        // Outer items running on pool workers execute their inner maps
        // inline; outer items on the caller thread fan out normally.
        let out = parallel_map(8, 4, |i| parallel_map(8, 4, |j| i * j).iter().sum::<usize>());
        assert_eq!(out, (0..8).map(|i| i * 28).collect::<Vec<_>>());
    }

    #[test]
    fn pool_threads_are_reused_across_calls() {
        use std::collections::HashSet;
        let ids: Mutex<HashSet<std::thread::ThreadId>> = Mutex::new(HashSet::new());
        for _ in 0..4 {
            parallel_map(64, 4, |i| {
                ids.lock().unwrap().insert(std::thread::current().id());
                i
            });
        }
        // Persistent workers, not spawn-per-call: the set of serving
        // threads is bounded by pool size plus the participating caller.
        let distinct = ids.lock().unwrap().len();
        assert!(
            distinct <= global_pool().size() + 1,
            "expected ≤ {} distinct threads, saw {distinct}",
            global_pool().size() + 1
        );
    }

    #[test]
    fn work_queue_fifo_and_close() {
        let q: WorkQueue<usize> = WorkQueue::new();
        assert!(q.push(1));
        assert!(q.push(2));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.try_pop(), Some(2));
        assert_eq!(q.try_pop(), None);
        q.close();
        assert!(!q.push(3), "push after close is refused");
        assert_eq!(q.pop(), None, "closed+empty pop returns None");
    }

    #[test]
    fn work_queue_across_threads() {
        let q: WorkQueue<usize> = WorkQueue::new();
        let total = 1000usize;
        let consumed = AtomicUsize::new(0);
        let sum = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    while let Some(v) = q.pop() {
                        consumed.fetch_add(1, Ordering::Relaxed);
                        sum.fetch_add(v, Ordering::Relaxed);
                    }
                });
            }
            for i in 0..total {
                assert!(q.push(i));
            }
            q.close();
        });
        assert_eq!(consumed.load(Ordering::Relaxed), total);
        assert_eq!(sum.load(Ordering::Relaxed), total * (total - 1) / 2);
    }

    #[test]
    fn workers_share_the_queue() {
        // With more tasks than workers every task still runs exactly once.
        let counter = AtomicUsize::new(0);
        let out = parallel_map(1000, 7, |_| counter.fetch_add(1, Ordering::Relaxed));
        assert_eq!(out.len(), 1000);
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }
}
