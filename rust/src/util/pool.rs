//! Scoped thread pool built on `std::thread::scope` (no tokio offline).
//!
//! Used by the coordinator to overlap synthetic-batch generation and
//! evaluation with the PJRT hot loop, by the table harnesses to run
//! independent (method × task) cells in parallel, and by the serving
//! engine ([`crate::serve`]), whose worker threads drain a [`WorkQueue`]
//! of micro-batches.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// Run `f(i)` for `i in 0..n` across up to `workers` threads, collecting
/// results in index order. Panics in workers propagate.
pub fn parallel_map<T: Send, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                results.lock().unwrap()[i] = Some(v);
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|v| v.expect("worker did not fill slot"))
        .collect()
}

/// Blocking multi-producer / multi-consumer FIFO queue (Mutex + Condvar —
/// no crossbeam offline). Producers [`WorkQueue::push`]; consumers block in
/// [`WorkQueue::pop`] until an item arrives or the queue is closed.
pub struct WorkQueue<T> {
    state: Mutex<QueueState<T>>,
    ready: Condvar,
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> Default for WorkQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> WorkQueue<T> {
    pub fn new() -> WorkQueue<T> {
        WorkQueue {
            state: Mutex::new(QueueState {
                items: VecDeque::new(),
                closed: false,
            }),
            ready: Condvar::new(),
        }
    }

    /// Enqueue an item. Returns `false` (dropping the item) if the queue
    /// has been closed.
    pub fn push(&self, item: T) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return false;
        }
        st.items.push_back(item);
        self.ready.notify_one();
        true
    }

    /// Block until an item is available. Returns `None` once the queue is
    /// closed *and* drained — the worker-shutdown signal.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.ready.wait(st).unwrap();
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        self.state.lock().unwrap().items.pop_front()
    }

    /// Close the queue: pending items still drain, new pushes are refused,
    /// and blocked consumers wake up with `None` once empty.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.ready.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Reasonable default worker count for this host.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_and_empty() {
        assert_eq!(parallel_map(5, 1, |i| i + 1), vec![1, 2, 3, 4, 5]);
        assert!(parallel_map(0, 4, |i| i).is_empty());
    }

    #[test]
    fn work_queue_fifo_and_close() {
        let q: WorkQueue<usize> = WorkQueue::new();
        assert!(q.push(1));
        assert!(q.push(2));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.try_pop(), Some(2));
        assert_eq!(q.try_pop(), None);
        q.close();
        assert!(!q.push(3), "push after close is refused");
        assert_eq!(q.pop(), None, "closed+empty pop returns None");
    }

    #[test]
    fn work_queue_across_threads() {
        let q: WorkQueue<usize> = WorkQueue::new();
        let total = 1000usize;
        let consumed = AtomicUsize::new(0);
        let sum = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    while let Some(v) = q.pop() {
                        consumed.fetch_add(1, Ordering::Relaxed);
                        sum.fetch_add(v, Ordering::Relaxed);
                    }
                });
            }
            for i in 0..total {
                assert!(q.push(i));
            }
            q.close();
        });
        assert_eq!(consumed.load(Ordering::Relaxed), total);
        assert_eq!(sum.load(Ordering::Relaxed), total * (total - 1) / 2);
    }

    #[test]
    fn workers_share_the_queue() {
        // With more tasks than workers every task still runs exactly once.
        let counter = AtomicUsize::new(0);
        let out = parallel_map(1000, 7, |_| counter.fetch_add(1, Ordering::Relaxed));
        assert_eq!(out.len(), 1000);
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }
}
