//! Scoped thread pool built on `std::thread::scope` (no tokio offline).
//!
//! Used by the coordinator to overlap synthetic-batch generation and
//! evaluation with the PJRT hot loop, and by the table harnesses to run
//! independent (method × task) cells in parallel.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f(i)` for `i in 0..n` across up to `workers` threads, collecting
/// results in index order. Panics in workers propagate.
pub fn parallel_map<T: Send, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                results.lock().unwrap()[i] = Some(v);
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|v| v.expect("worker did not fill slot"))
        .collect()
}

/// Reasonable default worker count for this host.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_and_empty() {
        assert_eq!(parallel_map(5, 1, |i| i + 1), vec![1, 2, 3, 4, 5]);
        assert!(parallel_map(0, 4, |i| i).is_empty());
    }

    #[test]
    fn workers_share_the_queue() {
        // With more tasks than workers every task still runs exactly once.
        let counter = AtomicUsize::new(0);
        let out = parallel_map(1000, 7, |_| counter.fetch_add(1, Ordering::Relaxed));
        assert_eq!(out.len(), 1000);
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }
}
