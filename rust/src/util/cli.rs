//! Tiny command-line argument parser (no `clap` offline).
//!
//! Supports the shapes the `gsoft` launcher needs:
//! `gsoft <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, `--key value` options, `--flag`
/// booleans and positional arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    ///
    /// `known_flags` lists options that take no value; everything else
    /// starting with `--` consumes the next token as its value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, known_flags: &[&str]) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if known_flags.contains(&name) {
                    args.flags.push(name.to_string());
                } else if let Some(v) = it.next() {
                    args.options.insert(name.to_string(), v);
                } else {
                    // Trailing --key with no value: treat as a flag.
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env(known_flags: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{s}'")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{s}'")),
        }
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{s}'")),
        }
    }

    /// Optional integer option with no default: `Ok(None)` when absent,
    /// so callers can distinguish "not given" from any in-band value.
    pub fn opt_u64_opt(&self, name: &str) -> anyhow::Result<Option<u64>> {
        self.opt(name)
            .map(|s| {
                s.parse()
                    .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{s}'"))
            })
            .transpose()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str, flags: &[&str]) -> Args {
        Args::parse(s.split_whitespace().map(String::from), flags)
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = parse("table1 --steps 300 --quiet extra1 extra2", &["quiet"]);
        assert_eq!(a.subcommand.as_deref(), Some("table1"));
        assert_eq!(a.opt("steps"), Some("300"));
        assert!(a.flag("quiet"));
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn typed_options() {
        let a = parse("run --n 8 --lr 0.5", &[]);
        assert_eq!(a.opt_usize("n", 1).unwrap(), 8);
        assert_eq!(a.opt_f64("lr", 0.0).unwrap(), 0.5);
        assert_eq!(a.opt_usize("missing", 7).unwrap(), 7);
        let bad = parse("run --n x", &[]);
        assert!(bad.opt_usize("n", 1).is_err());
    }

    #[test]
    fn optional_typed_option_distinguishes_absent_from_given() {
        let a = parse("serve --capture-slow-ms 40", &[]);
        assert_eq!(a.opt_u64_opt("capture-slow-ms").unwrap(), Some(40));
        assert_eq!(a.opt_u64_opt("topk").unwrap(), None);
        let bad = parse("serve --capture-slow-ms soon", &[]);
        assert!(bad.opt_u64_opt("capture-slow-ms").is_err());
    }

    #[test]
    fn trailing_option_becomes_flag() {
        let a = parse("run --verbose", &[]);
        assert!(a.flag("verbose"));
    }
}
