//! Deterministic pseudo-random number generation.
//!
//! The environment is offline, so we implement our own generators instead
//! of depending on the `rand` crate: [`SplitMix64`] for seeding and
//! [`Rng`] (xoshiro256**) as the workhorse generator. Both are
//! well-studied, tiny, and fully reproducible across platforms — every
//! synthetic workload in this repo is derived from explicit seeds so that
//! EXPERIMENTS.md numbers regenerate exactly.

/// SplitMix64: used to expand a single `u64` seed into a full xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality non-cryptographic PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box–Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed deterministically via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    /// Derive an independent child generator (for per-task / per-shard
    /// streams). Mixing the label through SplitMix64 keeps streams
    /// decorrelated.
    pub fn fork(&mut self, label: u64) -> Rng {
        let base = self.next_u64();
        Rng::new(base ^ label.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our needs).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply avoids modulo bias for all practical n.
        let x = self.next_u64();
        ((x as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Standard normal as f32 scaled by `std`.
    pub fn normal_f32(&mut self, std: f32) -> f32 {
        (self.normal() as f32) * std
    }

    /// Vector of normals.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32(std)).collect()
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample an element uniformly.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Bernoulli trial.
    pub fn flip(&mut self, p: f64) -> bool {
        self.uniform() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference values from the public-domain SplitMix64 implementation
        // with seed 1234567.
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(v[0], 6457827717110365317);
        assert_eq!(v[1], 3203168211198807973);
        assert_eq!(v[2], 9817491932198370423);
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn forked_streams_differ() {
        let mut r = Rng::new(9);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
