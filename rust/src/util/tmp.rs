//! Unique temporary directories for tests and benches.
//!
//! `cargo test` runs test binaries (and threads within them) in parallel;
//! any two tests sharing a fixed temp path flake. Every filesystem-touching
//! test takes a fresh directory from here instead: pid + a process-wide
//! counter make collisions impossible within a machine's temp dir.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// Create (and return) a directory unique to this call.
pub fn unique_temp_dir(tag: &str) -> PathBuf {
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir().join(format!("gsoft_{tag}_{}_{n}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create unique temp dir");
    dir
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dirs_are_distinct_and_exist() {
        let a = unique_temp_dir("tmptest");
        let b = unique_temp_dir("tmptest");
        assert_ne!(a, b);
        assert!(a.is_dir() && b.is_dir());
        let _ = std::fs::remove_dir_all(&a);
        let _ = std::fs::remove_dir_all(&b);
    }
}
