//! Mini-criterion: a self-contained benchmark harness.
//!
//! `criterion` is unavailable offline, so `cargo bench` targets
//! (declared `harness = false`) use this module instead. It mirrors the
//! parts of criterion we rely on: warmup, timed iterations, robust
//! statistics (mean / p50 / p95), throughput reporting and a
//! machine-readable JSON dump under `results/bench/`.

use std::time::{Duration, Instant};

use super::json::Json;

/// One benchmark measurement summary. Times in nanoseconds.
#[derive(Debug, Clone)]
pub struct Summary {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    /// Optional elements-per-iteration for throughput reporting.
    pub elements: Option<f64>,
}

impl Summary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_ns", Json::Num(self.mean_ns)),
            ("p50_ns", Json::Num(self.p50_ns)),
            ("p95_ns", Json::Num(self.p95_ns)),
            ("min_ns", Json::Num(self.min_ns)),
            (
                "elements",
                self.elements.map(Json::Num).unwrap_or(Json::Null),
            ),
        ])
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:7.1} ns")
    } else if ns < 1e6 {
        format!("{:7.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:7.2} ms", ns / 1e6)
    } else {
        format!("{:7.2} s ", ns / 1e9)
    }
}

/// Benchmark runner for one `cargo bench` target.
pub struct Bench {
    target: String,
    warmup: Duration,
    measure: Duration,
    max_iters: usize,
    summaries: Vec<Summary>,
}

impl Bench {
    pub fn new(target: &str) -> Self {
        // Honor the same quick-run env var our CI scripts use.
        let quick = std::env::var("GSOFT_BENCH_QUICK").is_ok();
        Self {
            target: target.to_string(),
            warmup: if quick {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(300)
            },
            measure: if quick {
                Duration::from_millis(200)
            } else {
                Duration::from_millis(1500)
            },
            max_iters: 100_000,
            summaries: Vec::new(),
        }
    }

    /// Override the measurement window (for very slow end-to-end cases).
    pub fn measure_time(&mut self, d: Duration) -> &mut Self {
        self.measure = d;
        self
    }

    /// Override the warmup window. Lets threaded test binaries shorten
    /// runs without the process-global `GSOFT_BENCH_QUICK` env mutation
    /// (setenv races with concurrent getenv).
    pub fn warmup_time(&mut self, d: Duration) -> &mut Self {
        self.warmup = d;
        self
    }

    /// Run one benchmark case. `f` is the unit of work; its return value is
    /// black-boxed to prevent the optimizer from deleting the work.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, f: F) -> &Summary {
        self.bench_with_elements(name, None, f)
    }

    /// Like [`Bench::bench`], reporting `elements` of throughput per iter.
    pub fn bench_with_elements<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        elements: Option<f64>,
        mut f: F,
    ) -> &Summary {
        // Warmup.
        let start = Instant::now();
        let mut warm_iters = 0usize;
        while start.elapsed() < self.warmup && warm_iters < self.max_iters {
            black_box(f());
            warm_iters += 1;
        }

        // Measure individual iteration times.
        let mut times: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && times.len() < self.max_iters {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_nanos() as f64);
        }
        if times.is_empty() {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_nanos() as f64);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = times.len();
        let mean = times.iter().sum::<f64>() / n as f64;
        let pct = |q: f64| times[((n as f64 - 1.0) * q) as usize];
        let summary = Summary {
            name: name.to_string(),
            iters: n,
            mean_ns: mean,
            p50_ns: pct(0.50),
            p95_ns: pct(0.95),
            min_ns: times[0],
            elements,
        };
        let throughput = summary
            .elements
            .map(|e| format!("  {:9.2} Melem/s", e / summary.mean_ns * 1e3))
            .unwrap_or_default();
        println!(
            "{:<52} mean {}  p50 {}  p95 {}  ({} iters){}",
            format!("{}/{}", self.target, name),
            fmt_ns(summary.mean_ns),
            fmt_ns(summary.p50_ns),
            fmt_ns(summary.p95_ns),
            n,
            throughput,
        );
        self.summaries.push(summary);
        self.summaries.last().unwrap()
    }

    /// Write all collected summaries under `results/bench/<target>.json`.
    pub fn finish(&self) {
        let dir = std::path::Path::new("results/bench");
        if std::fs::create_dir_all(dir).is_err() {
            return;
        }
        let json = Json::Arr(self.summaries.iter().map(|s| s.to_json()).collect());
        let path = dir.join(format!("{}.json", self.target));
        let _ = std::fs::write(&path, json.pretty());
        println!("[bench] wrote {}", path.display());
    }
}

/// Optimizer barrier (stable-Rust `black_box` equivalent semantics).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        std::env::set_var("GSOFT_BENCH_QUICK", "1");
        let mut b = Bench::new("selftest");
        b.measure_time(Duration::from_millis(30));
        let s = b
            .bench("sum", || (0..1000u64).sum::<u64>())
            .clone();
        assert!(s.iters > 0);
        assert!(s.mean_ns > 0.0);
        assert!(s.p50_ns <= s.p95_ns);
        assert!(s.min_ns <= s.p50_ns);
    }
}
