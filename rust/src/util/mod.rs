//! Self-contained utility substrates (the environment is offline, so the
//! usual crates — rand, serde_json, clap, criterion, proptest, rayon — are
//! re-implemented here at the scale this project needs).

pub mod bench;
pub mod cli;
pub mod container;
pub mod json;
pub mod net;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod tmp;
