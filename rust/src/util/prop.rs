//! Property-based testing harness (proptest is unavailable offline).
//!
//! A property test here is a closure over a seeded [`crate::util::rng::Rng`]
//! run for many cases; on failure we re-run with the failing case index so
//! the panic message pinpoints a deterministic reproduction. Strategies are
//! plain functions drawing structured values from the RNG — enough to
//! express the invariants DESIGN.md §5 lists (permutation algebra, GS
//! reconstruction, projection optimality, orthogonality, ...).

use super::rng::Rng;

/// Number of cases per property (overridable for expensive properties).
pub const DEFAULT_CASES: usize = 64;

/// Extract a readable message from a caught panic payload (shared with
/// any `catch_unwind` site, e.g. the serving engine's worker isolation).
pub fn panic_message(panic: &(dyn std::any::Any + Send)) -> String {
    panic
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "<non-string panic>".to_string())
}

/// Run `prop` for `cases` seeded cases. On the first failure, panics with
/// the property name, the failing `(seed, case)` pair, and an exact
/// reproduction recipe (`Rng::new(seed).fork(case)`), so every failure is
/// deterministic to replay. `prop` gets a fresh forked RNG per case.
pub fn check_named(name: &str, seed: u64, cases: usize, mut prop: impl FnMut(&mut Rng)) {
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let mut case_rng = root.fork(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut case_rng)
        }));
        if let Err(panic) = result {
            let msg = panic_message(panic.as_ref());
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed={seed}): {msg}\n\
                 reproduce with: prop(&mut Rng::new({seed}).fork({case}))"
            );
        }
    }
}

/// Property check with *shrinking*: inputs are drawn by `gen`, tested by
/// `prop`, and on failure greedily shrunk via `shrink` (which returns
/// simpler candidate inputs; return an empty vec to stop). The final panic
/// reports the seed, the case index, the original failing input, and the
/// shrunk minimal input — a reproducible counterexample instead of a bare
/// panic deep inside the property body.
pub fn check_shrunk<T, G, S, P>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: G,
    shrink: S,
    prop: P,
) where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T),
{
    let fails = |input: &T| -> Option<String> {
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(input)))
            .err()
            .map(|p| panic_message(p.as_ref()))
    };
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let mut case_rng = root.fork(case as u64);
        let original = gen(&mut case_rng);
        let Some(first_msg) = fails(&original) else {
            continue;
        };
        // Greedy shrink: repeatedly replace the counterexample with the
        // first simpler candidate that still fails (bounded, so a cyclic
        // shrinker cannot loop forever).
        let mut minimal = original.clone();
        let mut msg = first_msg;
        for _ in 0..1000 {
            let next = shrink(&minimal)
                .into_iter()
                .find_map(|c| fails(&c).map(|m| (c, m)));
            match next {
                Some((c, m)) => {
                    minimal = c;
                    msg = m;
                }
                None => break,
            }
        }
        panic!(
            "property '{name}' failed at case {case}/{cases} (seed={seed}): {msg}\n\
             original input: {original:?}\n\
             shrunk input:   {minimal:?}\n\
             reproduce with: prop(&{minimal:?})"
        );
    }
}

/// Run with default case count.
pub fn check(name: &str, seed: u64, prop: impl FnMut(&mut Rng)) {
    check_named(name, seed, DEFAULT_CASES, prop);
}

// ---- common strategies ----------------------------------------------------

/// Draw a size in `[lo, hi]`.
pub fn size_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

/// Draw a (block_size, num_blocks) pair with `b*r <= max_dim`, both ≥ 1.
pub fn block_shape(rng: &mut Rng, max_dim: usize) -> (usize, usize) {
    let b = size_in(rng, 1, 8);
    let max_r = (max_dim / b).max(1);
    let r = size_in(rng, 1, max_r.min(8));
    (b, r)
}

/// Draw `n` f32s from N(0, std).
pub fn normal_vec(rng: &mut Rng, n: usize, std: f32) -> Vec<f32> {
    rng.normal_vec(n, std)
}

// ---- common shrinkers ------------------------------------------------------

/// Length-preserving shrinker for f32 buffers whose size is fixed by
/// structure (flat adapter slabs): candidates zero out halves and damp
/// magnitudes, driving counterexamples toward the all-zero (identity)
/// input without breaking shape invariants.
pub fn shrink_vec_f32(x: &[f32]) -> Vec<Vec<f32>> {
    if x.iter().all(|&v| v == 0.0) {
        return Vec::new();
    }
    let mut out = Vec::new();
    out.push(vec![0.0; x.len()]);
    let half = x.len() / 2;
    if half > 0 {
        let mut front = x.to_vec();
        front[..half].fill(0.0);
        out.push(front);
        let mut back = x.to_vec();
        back[half..].fill(0.0);
        out.push(back);
    }
    out.push(x.iter().map(|&v| v * 0.5).collect());
    out
}

/// Shrink a usize toward `lo` (halving steps, then decrement).
pub fn shrink_usize(x: usize, lo: usize) -> Vec<usize> {
    let mut out = Vec::new();
    if x > lo {
        out.push(lo);
        let mid = lo + (x - lo) / 2;
        if mid != lo && mid != x {
            out.push(mid);
        }
        out.push(x - 1);
    }
    out.dedup();
    out
}

/// Assert two slices are elementwise close.
#[track_caller]
pub fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert!(
            (x - y).abs() <= tol + tol * x.abs().max(y.abs()),
            "{what}: mismatch at {i}: {x} vs {y} (tol={tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check_named("trivial", 1, 10, |_| count += 1);
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports_case() {
        check_named("fails", 1, 10, |rng| {
            assert!(rng.below(10) < 9, "hit the 10%% case");
        });
    }

    #[test]
    #[should_panic(expected = "reproduce with")]
    fn failure_reports_reproduction_recipe() {
        check_named("recipe", 3, 4, |_| panic!("boom"));
    }

    #[test]
    fn shrinking_finds_minimal_counterexample() {
        // Property "all entries are zero" fails for any nonzero vec; the
        // shrinker must drive the reported counterexample to a vector with
        // a single minimal nonzero structure (here: half-zeroed).
        let caught = std::panic::catch_unwind(|| {
            check_shrunk(
                "needs zero",
                5,
                8,
                |rng| normal_vec(rng, 8, 1.0),
                |v| shrink_vec_f32(v),
                |v| assert!(v.iter().all(|&x| x == 0.0), "nonzero entry"),
            );
        });
        let msg = caught
            .expect_err("property must fail")
            .downcast_ref::<String>()
            .cloned()
            .unwrap();
        assert!(msg.contains("original input"), "msg: {msg}");
        assert!(msg.contains("shrunk input"), "msg: {msg}");
        assert!(msg.contains("seed=5"), "msg: {msg}");
    }

    #[test]
    fn shrunk_passing_property_is_silent() {
        let mut count = 0;
        check_shrunk(
            "always passes",
            6,
            5,
            |rng| rng.below(100),
            |&n| shrink_usize(n, 0),
            |_| {},
        );
        // Separate counter check: generator runs once per case.
        check_shrunk(
            "counts",
            7,
            5,
            |rng| {
                count += 1;
                rng.below(10)
            },
            |_| Vec::new(),
            |_| {},
        );
        assert_eq!(count, 5);
    }

    #[test]
    fn shrinkers_preserve_invariants() {
        let v = vec![1.0f32, -2.0, 3.0, 4.0];
        for cand in shrink_vec_f32(&v) {
            assert_eq!(cand.len(), v.len(), "shrinker must preserve length");
        }
        assert!(shrink_vec_f32(&[0.0, 0.0]).is_empty(), "zero vec is minimal");
        assert!(shrink_usize(5, 0).contains(&0));
        assert!(shrink_usize(3, 3).is_empty());
    }

    #[test]
    fn strategies_in_bounds() {
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let n = size_in(&mut rng, 3, 9);
            assert!((3..=9).contains(&n));
            let (b, r) = block_shape(&mut rng, 32);
            assert!(b * r <= 32 || r == 1);
        }
    }

    #[test]
    fn assert_close_tolerates_roundoff() {
        assert_close(&[1.0, 2.0], &[1.0 + 1e-7, 2.0 - 1e-7], 1e-5, "roundoff");
    }
}
