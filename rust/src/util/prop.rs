//! Property-based testing harness (proptest is unavailable offline).
//!
//! A property test here is a closure over a seeded [`crate::util::rng::Rng`]
//! run for many cases; on failure we re-run with the failing case index so
//! the panic message pinpoints a deterministic reproduction. Strategies are
//! plain functions drawing structured values from the RNG — enough to
//! express the invariants DESIGN.md §5 lists (permutation algebra, GS
//! reconstruction, projection optimality, orthogonality, ...).

use super::rng::Rng;

/// Number of cases per property (overridable for expensive properties).
pub const DEFAULT_CASES: usize = 64;

/// Run `prop` for `cases` seeded cases. Panics with the case seed on the
/// first failure. `prop` gets a fresh forked RNG per case so failures
/// reproduce from `(seed, case_index)` alone.
pub fn check_named(name: &str, seed: u64, cases: usize, mut prop: impl FnMut(&mut Rng)) {
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let mut case_rng = root.fork(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut case_rng)
        }));
        if let Err(panic) = result {
            let msg = panic
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed={seed}): {msg}"
            );
        }
    }
}

/// Run with default case count.
pub fn check(name: &str, seed: u64, prop: impl FnMut(&mut Rng)) {
    check_named(name, seed, DEFAULT_CASES, prop);
}

// ---- common strategies ----------------------------------------------------

/// Draw a size in `[lo, hi]`.
pub fn size_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

/// Draw a (block_size, num_blocks) pair with `b*r <= max_dim`, both ≥ 1.
pub fn block_shape(rng: &mut Rng, max_dim: usize) -> (usize, usize) {
    let b = size_in(rng, 1, 8);
    let max_r = (max_dim / b).max(1);
    let r = size_in(rng, 1, max_r.min(8));
    (b, r)
}

/// Draw `n` f32s from N(0, std).
pub fn normal_vec(rng: &mut Rng, n: usize, std: f32) -> Vec<f32> {
    rng.normal_vec(n, std)
}

/// Assert two slices are elementwise close.
#[track_caller]
pub fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert!(
            (x - y).abs() <= tol + tol * x.abs().max(y.abs()),
            "{what}: mismatch at {i}: {x} vs {y} (tol={tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check_named("trivial", 1, 10, |_| count += 1);
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports_case() {
        check_named("fails", 1, 10, |rng| {
            assert!(rng.below(10) < 9, "hit the 10%% case");
        });
    }

    #[test]
    fn strategies_in_bounds() {
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let n = size_in(&mut rng, 3, 9);
            assert!((3..=9).contains(&n));
            let (b, r) = block_shape(&mut rng, 32);
            assert!(b * r <= 32 || r == 1);
        }
    }

    #[test]
    fn assert_close_tolerates_roundoff() {
        assert_close(&[1.0, 2.0], &[1.0 + 1e-7, 2.0 - 1e-7], 1e-5, "roundoff");
    }
}
