//! Synthetic workload generators — the offline stand-ins for the paper's
//! datasets (substitutions documented in DESIGN.md §3 and EXPERIMENTS.md):
//!
//! - [`synglue`] — 8-task sequence-classification suite (GLUE stand-in)
//! - [`concept`] — few-shot concept adaptation set (DreamBooth stand-in)
//! - [`vision`]  — image classification (CIFAR-100 stand-in)
//! - [`zipf`]    — Zipf tenant-popularity traces for the serving engine
//!
//! All generators are seeded and platform-deterministic, so every number
//! in EXPERIMENTS.md regenerates exactly.

pub mod concept;
pub mod synglue;
pub mod vision;
pub mod zipf;

pub use zipf::Zipf;
