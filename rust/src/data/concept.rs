//! Concept dataset — the DreamBooth stand-in (Table 2 / Figure 6).
//!
//! 8×8 grayscale "images" from 8 procedural context classes (blobs,
//! stripes, rings, ...) plus one held-out *concept* (a pattern mixture
//! never seen in pretraining) with only a handful of examples — the same
//! few-shot fine-tuning regime as subject-driven generation. Feature-space
//! similarity against a fixed random-projection encoder plays the role of
//! CLIP embeddings (deterministic, frozen, and shared by all methods, so
//! comparisons between methods are meaningful even though absolute values
//! are not CLIP scores).

use crate::util::rng::Rng;

pub const IMG: usize = 8;
pub const DIM: usize = IMG * IMG;
/// Context classes 0..7; the concept conditions on token 8.
pub const NUM_CONTEXTS: usize = 8;
pub const CONCEPT_COND: i32 = 8;

/// Render one context-class image with per-sample jitter.
pub fn context_image(class: usize, rng: &mut Rng) -> Vec<f32> {
    assert!(class < NUM_CONTEXTS);
    let mut img = vec![0.0f32; DIM];
    let jx = rng.uniform_in(-1.0, 1.0);
    let jy = rng.uniform_in(-1.0, 1.0);
    for y in 0..IMG {
        for x in 0..IMG {
            let fx = x as f32 + jx;
            let fy = y as f32 + jy;
            let v = match class {
                // gaussian blob, center varies by jitter
                0 => {
                    let dx = fx - 3.5;
                    let dy = fy - 3.5;
                    (-(dx * dx + dy * dy) / 6.0).exp() * 2.0 - 0.5
                }
                // vertical stripes
                1 => ((fx * std::f32::consts::PI / 2.0).sin()) * 0.9,
                // horizontal stripes
                2 => ((fy * std::f32::consts::PI / 2.0).sin()) * 0.9,
                // diagonal gradient
                3 => (fx + fy) / 14.0 * 2.0 - 1.0,
                // ring
                4 => {
                    let r = ((fx - 3.5).powi(2) + (fy - 3.5).powi(2)).sqrt();
                    (-(r - 2.5).powi(2)).exp() * 1.8 - 0.4
                }
                // checker (coarse)
                5 => {
                    if ((x / 2) + (y / 2)) % 2 == 0 {
                        0.8
                    } else {
                        -0.8
                    }
                }
                // corner blob
                6 => {
                    let dx = fx - 1.0;
                    let dy = fy - 1.0;
                    (-(dx * dx + dy * dy) / 4.0).exp() * 2.0 - 0.5
                }
                // diagonal stripes
                _ => (((fx - fy) * std::f32::consts::PI / 2.5).sin()) * 0.9,
            };
            img[y * IMG + x] = v + rng.normal_f32(0.08);
        }
    }
    img
}

/// The held-out concept: fine checkerboard modulated by a corner gradient
/// — a combination no context class produces.
pub fn concept_image(rng: &mut Rng) -> Vec<f32> {
    let mut img = vec![0.0f32; DIM];
    // Fixed identity (same "subject" in every shot), small per-sample
    // amplitude jitter + noise (different "shots").
    let amp = 1.0 + rng.uniform_in(-0.1, 0.1);
    for y in 0..IMG {
        for x in 0..IMG {
            let checker = if (x + y) % 2 == 0 { 1.0 } else { -1.0 };
            let grad = (x as f32) / 7.0; // left-to-right ramp
            img[y * IMG + x] = amp * checker * (0.4 + 0.6 * grad) + rng.normal_f32(0.05);
        }
    }
    img
}

/// Pretraining batch: (x0, cond) pairs over the context classes.
pub fn pretrain_batch(n: usize, rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
    let mut xs = Vec::with_capacity(n * DIM);
    let mut conds = Vec::with_capacity(n);
    for _ in 0..n {
        let class = rng.below(NUM_CONTEXTS);
        xs.extend_from_slice(&context_image(class, rng));
        conds.push(class as i32);
    }
    (xs, conds)
}

/// The few-shot concept set (like DreamBooth's 4–6 photos). Fixed count,
/// jittered instances.
pub fn concept_examples(n: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
    (0..n).map(|_| concept_image(rng)).collect()
}

/// Fine-tuning batch: concept examples (resampled with jitter) with the
/// concept condition token.
pub fn finetune_batch(n: usize, examples: &[Vec<f32>], rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
    let mut xs = Vec::with_capacity(n * DIM);
    let conds = vec![CONCEPT_COND; n];
    for _ in 0..n {
        xs.extend_from_slice(rng.choice(examples).as_slice());
    }
    (xs, conds)
}

// ---- frozen feature encoder (the CLIP stand-in) ------------------------------

/// Deterministic random-projection + tanh feature encoder. All methods
/// share it, like all methods share CLIP in the paper.
pub struct Encoder {
    w: Vec<f32>, // (FEAT, DIM) row-major
}

pub const FEAT: usize = 32;

impl Encoder {
    pub fn new() -> Encoder {
        let mut rng = Rng::new(0xC11A);
        Encoder {
            w: (0..FEAT * DIM)
                .map(|_| rng.normal_f32(1.0 / (DIM as f32).sqrt()))
                .collect(),
        }
    }

    pub fn embed(&self, img: &[f32]) -> Vec<f32> {
        assert_eq!(img.len(), DIM);
        let mut out = vec![0.0f32; FEAT];
        for (i, o) in out.iter_mut().enumerate() {
            let row = &self.w[i * DIM..(i + 1) * DIM];
            let dot: f32 = row.iter().zip(img).map(|(a, b)| a * b).sum();
            *o = dot.tanh();
        }
        out
    }

    /// Cosine similarity of embeddings.
    pub fn similarity(&self, a: &[f32], b: &[f32]) -> f64 {
        let ea = self.embed(a);
        let eb = self.embed(b);
        cosine(&ea, &eb)
    }
}

impl Default for Encoder {
    fn default() -> Self {
        Self::new()
    }
}

pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
    let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        (dot / (na * nb)) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn images_have_expected_scale() {
        let mut rng = Rng::new(1);
        for class in 0..NUM_CONTEXTS {
            let img = context_image(class, &mut rng);
            assert_eq!(img.len(), DIM);
            let maxabs = img.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            assert!(maxabs < 4.0 && maxabs > 0.1, "class {class}: {maxabs}");
        }
        let c = concept_image(&mut rng);
        assert_eq!(c.len(), DIM);
    }

    #[test]
    fn classes_are_separable_in_feature_space() {
        // Same-class similarity must exceed cross-class similarity — else
        // the "CLIP" metric would be meaningless.
        let enc = Encoder::new();
        let mut rng = Rng::new(2);
        let mut same = 0.0;
        let mut cross = 0.0;
        let mut n = 0.0;
        for class in 0..NUM_CONTEXTS {
            let a = context_image(class, &mut rng);
            let b = context_image(class, &mut rng);
            let c = context_image((class + 3) % NUM_CONTEXTS, &mut rng);
            same += enc.similarity(&a, &b);
            cross += enc.similarity(&a, &c);
            n += 1.0;
        }
        assert!(
            same / n > cross / n + 0.2,
            "same {} vs cross {}",
            same / n,
            cross / n
        );
    }

    #[test]
    fn concept_is_distinct_from_contexts() {
        let enc = Encoder::new();
        let mut rng = Rng::new(3);
        let concept = concept_image(&mut rng);
        let concept2 = concept_image(&mut rng);
        let self_sim = enc.similarity(&concept, &concept2);
        for class in 0..NUM_CONTEXTS {
            let ctx = context_image(class, &mut rng);
            let sim = enc.similarity(&concept, &ctx);
            assert!(self_sim > sim + 0.1, "class {class}: {self_sim} vs {sim}");
        }
    }

    #[test]
    fn batches_shapes_and_determinism() {
        let mut r1 = Rng::new(4);
        let mut r2 = Rng::new(4);
        let (x1, c1) = pretrain_batch(16, &mut r1);
        let (x2, c2) = pretrain_batch(16, &mut r2);
        assert_eq!(x1, x2);
        assert_eq!(c1, c2);
        assert_eq!(x1.len(), 16 * DIM);
        assert!(c1.iter().all(|&c| (0..NUM_CONTEXTS as i32).contains(&c)));

        let ex = concept_examples(4, &mut r1);
        let (fx, fc) = finetune_batch(8, &ex, &mut r1);
        assert_eq!(fx.len(), 8 * DIM);
        assert!(fc.iter().all(|&c| c == CONCEPT_COND));
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!((cosine(&[1.0, 0.0], &[0.0, 1.0])).abs() < 1e-6);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-6);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 0.0]), 0.0);
    }
}
