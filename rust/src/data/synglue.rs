//! SynGLUE — a synthetic eight-task sequence-classification suite.
//!
//! Stand-in for the paper's GLUE benchmark (Table 1): the real GLUE data
//! and RoBERTa-base are unavailable offline, so we generate eight tasks
//! whose *structure* mirrors the originals (single-sentence judgments,
//! sentence-pair similarity, entailment, an ordinal-similarity task whose
//! metric is a Pearson correlation, a grammaticality task scored with
//! Matthews correlation) at difficulties a small pretrained transformer
//! separates meaningfully. Every task shares the vocabulary and sequence
//! format of the `cls` artifacts, and the pretraining corpus is a mixture
//! of all tasks — so fine-tuning sees genuine transfer, and adapter
//! methods are compared on equal footing with the paper's protocol
//! (same pretrained base, same budget, only the adapter differs).

use crate::util::rng::Rng;

/// Vocabulary layout (within the artifact's `vocab` size):
/// 0 = PAD, 1 = SEP, 2..10 task-id prefix tokens, 16.. content tokens.
pub const SEP: i32 = 1;
const TASK_TOKEN0: i32 = 2;
const CONTENT0: i32 = 16;

/// The eight tasks, their paper counterparts and metrics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// SST-2-like: balance of "positive" vs "negative" token sets.
    Sent,
    /// CoLA-like: token bigrams follow a parity chain (metric: Matthews).
    Cola,
    /// MRPC-like: is the second segment a shuffled copy of the first?
    Para,
    /// QQP-like: duplicate detection with harder distractors.
    Qqp,
    /// QNLI-like: does the passage contain the query token?
    Qnli,
    /// RTE-like: binary entailment (subset relation of token sets).
    Rte,
    /// MNLI-like: 3-way entailment / neutral / contradiction.
    Mnli,
    /// STS-B-like: ordinal similarity bucket 0..3 (metric: Pearson).
    Stsb,
}

pub const ALL_TASKS: [Task; 8] = [
    Task::Mnli,
    Task::Sent,
    Task::Cola,
    Task::Qqp,
    Task::Qnli,
    Task::Rte,
    Task::Para,
    Task::Stsb,
];

impl Task {
    pub fn name(&self) -> &'static str {
        match self {
            Task::Sent => "SST-2*",
            Task::Cola => "CoLA*",
            Task::Para => "MRPC*",
            Task::Qqp => "QQP*",
            Task::Qnli => "QNLI*",
            Task::Rte => "RTE*",
            Task::Mnli => "MNLI*",
            Task::Stsb => "STS-B*",
        }
    }

    pub fn num_classes(&self) -> usize {
        match self {
            Task::Mnli => 3,
            Task::Stsb => 4,
            _ => 2,
        }
    }

    /// Metric used in the Table-1 reproduction.
    pub fn metric(&self) -> &'static str {
        match self {
            Task::Cola => "matthews",
            Task::Stsb => "pearson",
            _ => "accuracy",
        }
    }

    pub fn id(&self) -> usize {
        ALL_TASKS.iter().position(|t| t == self).unwrap()
    }

    fn prefix_token(&self) -> i32 {
        TASK_TOKEN0 + self.id() as i32
    }
}

/// Generator for one task at fixed (vocab, seq) geometry.
pub struct TaskGen {
    pub task: Task,
    pub vocab: usize,
    pub seq: usize,
}

impl TaskGen {
    pub fn new(task: Task, vocab: usize, seq: usize) -> TaskGen {
        assert!(vocab >= 64 && seq >= 16);
        TaskGen { task, vocab, seq }
    }

    fn content(&self, rng: &mut Rng) -> i32 {
        CONTENT0 + rng.below(self.vocab - CONTENT0 as usize) as i32
    }

    /// One (tokens, label) example.
    pub fn sample(&self, rng: &mut Rng) -> (Vec<i32>, i32) {
        let mut toks = vec![0i32; self.seq];
        toks[0] = self.task.prefix_token();
        let body = self.seq - 1;
        let half = body / 2;
        match self.task {
            Task::Sent => {
                // positive tokens are even content ids, negative odd; the
                // label is the majority sign with noise tokens mixed in.
                let label = rng.below(2) as i32;
                for i in 0..body {
                    let tok = self.content(rng);
                    let signal = rng.flip(0.6);
                    toks[1 + i] = if signal {
                        let t = tok & !1; // even
                        if label == 1 {
                            t
                        } else {
                            t | 1
                        }
                    } else {
                        tok
                    };
                }
                (toks, label)
            }
            Task::Cola => {
                // grammatical = strictly increasing within 8-token clauses.
                let label = rng.below(2) as i32;
                let mut i = 0;
                while i < body {
                    let clause = (body - i).min(8);
                    let mut vals: Vec<i32> = (0..clause).map(|_| self.content(rng)).collect();
                    vals.sort_unstable();
                    if label == 0 {
                        // corrupt: swap a random adjacent pair
                        if clause >= 2 {
                            let j = rng.below(clause - 1);
                            vals.swap(j, j + 1);
                            if vals.windows(2).all(|w| w[0] <= w[1]) {
                                vals.reverse(); // ensure actually broken
                            }
                        }
                    }
                    for (k, v) in vals.iter().enumerate() {
                        toks[1 + i + k] = *v;
                    }
                    i += clause;
                }
                (toks, label)
            }
            Task::Para | Task::Qqp => {
                let label = rng.below(2) as i32;
                let first: Vec<i32> = (0..half - 1).map(|_| self.content(rng)).collect();
                let mut second = first.clone();
                if label == 1 {
                    rng.shuffle(&mut second); // paraphrase = shuffled copy
                } else if self.task == Task::Para {
                    // unrelated second segment
                    for v in second.iter_mut() {
                        *v = self.content(rng);
                    }
                } else {
                    // QQP hard negatives: copy with a few substitutions
                    let subs = 2 + rng.below(3);
                    for _ in 0..subs {
                        let j = rng.below(second.len());
                        second[j] = self.content(rng);
                    }
                    rng.shuffle(&mut second);
                }
                for (k, v) in first.iter().enumerate() {
                    toks[1 + k] = *v;
                }
                toks[half] = SEP;
                for (k, v) in second.iter().enumerate() {
                    toks[half + 1 + k] = *v;
                }
                (toks, label)
            }
            Task::Qnli => {
                let label = rng.below(2) as i32;
                let query = self.content(rng);
                toks[1] = query;
                toks[2] = SEP;
                for i in 3..self.seq {
                    toks[i] = self.content(rng);
                }
                if label == 1 {
                    let j = 3 + rng.below(self.seq - 3);
                    toks[j] = query;
                } else {
                    for i in 3..self.seq {
                        if toks[i] == query {
                            toks[i] = query ^ 1;
                        }
                    }
                }
                (toks, label)
            }
            Task::Rte | Task::Mnli => {
                // premise = token multiset; hypothesis: subset (entail),
                // disjoint (contradict), mixed (neutral; MNLI only).
                let classes = self.task.num_classes();
                let label = rng.below(classes) as i32;
                let premise: Vec<i32> = (0..half - 1).map(|_| self.content(rng)).collect();
                for (k, v) in premise.iter().enumerate() {
                    toks[1 + k] = *v;
                }
                toks[half] = SEP;
                let hyp_len = self.seq - half - 1;
                for k in 0..hyp_len {
                    let v = match label {
                        0 => premise[rng.below(premise.len())], // entail: subset
                        1 => {
                            // contradict / not-entail: fresh tokens only
                            let mut v = self.content(rng);
                            while premise.contains(&v) {
                                v = self.content(rng);
                            }
                            v
                        }
                        _ => {
                            // neutral: half overlap
                            if rng.flip(0.5) {
                                premise[rng.below(premise.len())]
                            } else {
                                self.content(rng)
                            }
                        }
                    };
                    toks[half + 1 + k] = v;
                }
                (toks, label)
            }
            Task::Stsb => {
                // similarity bucket = fraction of shared tokens, 4 levels.
                let label = rng.below(4) as i32;
                let first: Vec<i32> = (0..half - 1).map(|_| self.content(rng)).collect();
                let overlap = (first.len() * label as usize) / 3;
                let mut second = Vec::with_capacity(first.len());
                for k in 0..first.len() {
                    if k < overlap {
                        second.push(first[k]);
                    } else {
                        let mut v = self.content(rng);
                        while first.contains(&v) {
                            v = self.content(rng);
                        }
                        second.push(v);
                    }
                }
                rng.shuffle(&mut second);
                for (k, v) in first.iter().enumerate() {
                    toks[1 + k] = *v;
                }
                toks[half] = SEP;
                for (k, v) in second.iter().enumerate() {
                    toks[half + 1 + k] = *v;
                }
                (toks, label)
            }
        }
    }

    /// A batch of examples, flattened for the artifact inputs.
    pub fn batch(&self, n: usize, rng: &mut Rng) -> (Vec<i32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(n * self.seq);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let (t, l) = self.sample(rng);
            xs.extend_from_slice(&t);
            ys.push(l);
        }
        (xs, ys)
    }
}

/// Pretraining batch: a uniform mixture over all tasks (each sequence
/// keeps its task prefix token, so the base model learns every format).
pub fn pretrain_batch(vocab: usize, seq: usize, n: usize, rng: &mut Rng) -> (Vec<i32>, Vec<i32>) {
    let mut xs = Vec::with_capacity(n * seq);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let task = *rng.choice(&ALL_TASKS);
        let g = TaskGen::new(task, vocab, seq);
        let (t, l) = g.sample(rng);
        xs.extend_from_slice(&t);
        ys.push(l);
    }
    (xs, ys)
}

// ---- metrics ---------------------------------------------------------------

/// Matthews correlation coefficient for binary predictions.
pub fn matthews(preds: &[i32], labels: &[i32]) -> f64 {
    let (mut tp, mut tn, mut fp, mut fnn) = (0f64, 0f64, 0f64, 0f64);
    for (&p, &l) in preds.iter().zip(labels) {
        match (p, l) {
            (1, 1) => tp += 1.0,
            (0, 0) => tn += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fnn += 1.0,
            _ => {}
        }
    }
    let denom = ((tp + fp) * (tp + fnn) * (tn + fp) * (tn + fnn)).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        (tp * tn - fp * fnn) / denom
    }
}

/// Pearson correlation between two integer series.
pub fn pearson(xs: &[i32], ys: &[i32]) -> f64 {
    let n = xs.len() as f64;
    if n == 0.0 {
        return 0.0;
    }
    let mx = xs.iter().map(|&x| x as f64).sum::<f64>() / n;
    let my = ys.iter().map(|&y| y as f64).sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        let dx = x as f64 - mx;
        let dy = y as f64 - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(task: Task) -> TaskGen {
        TaskGen::new(task, 512, 32)
    }

    #[test]
    fn labels_in_range_and_tokens_in_vocab() {
        let mut rng = Rng::new(1);
        for task in ALL_TASKS {
            let g = gen(task);
            for _ in 0..50 {
                let (toks, label) = g.sample(&mut rng);
                assert_eq!(toks.len(), 32);
                assert!((0..task.num_classes() as i32).contains(&label), "{task:?}");
                assert!(toks.iter().all(|&t| (0..512).contains(&t)), "{task:?}");
                assert_eq!(toks[0], task.prefix_token());
            }
        }
    }

    #[test]
    fn labels_are_roughly_balanced() {
        let mut rng = Rng::new(2);
        for task in ALL_TASKS {
            let g = gen(task);
            let n = 600;
            let mut counts = vec![0usize; task.num_classes()];
            for _ in 0..n {
                let (_, l) = g.sample(&mut rng);
                counts[l as usize] += 1;
            }
            let expect = n / task.num_classes();
            for (c, &k) in counts.iter().enumerate() {
                assert!(
                    k > expect / 2 && k < expect * 2,
                    "{task:?} class {c}: {k}/{n}"
                );
            }
        }
    }

    #[test]
    fn tasks_are_learnable_by_construction() {
        // A hand-written oracle must beat chance on each task — guards
        // against generating label-free noise.
        let mut rng = Rng::new(3);
        for task in [Task::Qnli, Task::Rte] {
            let g = gen(task);
            let mut correct = 0;
            let n = 400;
            for _ in 0..n {
                let (toks, label) = g.sample(&mut rng);
                let guess = match task {
                    Task::Qnli => {
                        let q = toks[1];
                        toks[3..].contains(&q) as i32
                    }
                    Task::Rte => {
                        let half = 31 / 2;
                        let premise = &toks[1..half];
                        let hyp = &toks[half + 1..];
                        let overlap =
                            hyp.iter().filter(|t| premise.contains(t)).count();
                        (overlap < hyp.len() / 2) as i32
                    }
                    _ => unreachable!(),
                };
                if guess == label {
                    correct += 1;
                }
            }
            let acc = correct as f64 / n as f64;
            assert!(acc > 0.9, "{task:?} oracle acc {acc}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let g = gen(Task::Mnli);
        let (a1, b1) = g.batch(8, &mut Rng::new(7));
        let (a2, b2) = g.batch(8, &mut Rng::new(7));
        assert_eq!(a1, a2);
        assert_eq!(b1, b2);
    }

    #[test]
    fn metric_helpers() {
        assert_eq!(matthews(&[1, 0, 1, 0], &[1, 0, 1, 0]), 1.0);
        assert_eq!(matthews(&[0, 1, 0, 1], &[1, 0, 1, 0]), -1.0);
        assert_eq!(matthews(&[1, 1, 1, 1], &[1, 0, 1, 0]), 0.0);
        assert!((pearson(&[0, 1, 2, 3], &[0, 1, 2, 3]) - 1.0).abs() < 1e-12);
        assert!((pearson(&[0, 1, 2, 3], &[3, 2, 1, 0]) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&[1, 1], &[0, 1]), 0.0);
    }

    #[test]
    fn pretrain_mixture_covers_all_tasks() {
        let mut rng = Rng::new(9);
        let (xs, _) = pretrain_batch(512, 32, 256, &mut rng);
        let mut seen = std::collections::HashSet::new();
        for i in 0..256 {
            seen.insert(xs[i * 32]);
        }
        assert_eq!(seen.len(), 8, "all task prefixes present");
    }
}
