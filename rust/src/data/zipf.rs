//! Zipf-distributed popularity sampling — the canonical model for
//! multi-tenant request traffic (a few hot tenants, a long cold tail).
//! Drives the serving-engine benchmarks ([`crate::serve`]): tenant `k`
//! (0-indexed rank) is drawn with probability proportional to
//! `1 / (k+1)^s`.

use crate::util::rng::Rng;

/// Zipf(n, s) sampler over ranks `0..n` via a precomputed CDF and binary
/// search — O(n) setup, O(log n) per sample, fully deterministic from the
/// caller's [`Rng`].
#[derive(Clone, Debug)]
pub struct Zipf {
    /// Cumulative probabilities; `cdf[k]` = P(rank <= k).
    cdf: Vec<f64>,
}

impl Zipf {
    /// `n` ranks with exponent `s` (s = 0 is uniform; s ≈ 1 is classic
    /// web-traffic skew; larger s concentrates harder on the head).
    pub fn new(n: usize, s: f64) -> Zipf {
        assert!(n > 0, "Zipf needs at least one rank");
        assert!(s >= 0.0 && s.is_finite(), "exponent must be finite and >= 0");
        let weights: Vec<f64> = (0..n).map(|k| 1.0 / ((k + 1) as f64).powf(s)).collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        let cdf = weights
            .iter()
            .map(|w| {
                acc += w / total;
                acc
            })
            .collect();
        Zipf { cdf }
    }

    /// Number of ranks.
    pub fn n(&self) -> usize {
        self.cdf.len()
    }

    /// Probability of rank `k`.
    pub fn pmf(&self, k: usize) -> f64 {
        let lo = if k == 0 { 0.0 } else { self.cdf[k - 1] };
        self.cdf[k] - lo
    }

    /// Draw one rank in `0..n`.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        // First index with cdf[k] > u.
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => (i + 1).min(self.cdf.len() - 1),
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Draw a whole request trace of `len` ranks.
    pub fn trace(&self, len: usize, rng: &mut Rng) -> Vec<usize> {
        (0..len).map(|_| self.sample(rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pmf_sums_to_one_and_is_monotone() {
        let z = Zipf::new(64, 1.1);
        let total: f64 = (0..64).map(|k| z.pmf(k)).sum();
        assert!((total - 1.0).abs() < 1e-12);
        for k in 1..64 {
            assert!(z.pmf(k) <= z.pmf(k - 1) + 1e-15, "pmf must be non-increasing");
        }
    }

    #[test]
    fn samples_in_range_and_deterministic() {
        let z = Zipf::new(10, 1.0);
        let a = z.trace(500, &mut Rng::new(7));
        let b = z.trace(500, &mut Rng::new(7));
        assert_eq!(a, b, "same seed, same trace");
        assert!(a.iter().all(|&k| k < 10));
    }

    #[test]
    fn skew_concentrates_on_the_head() {
        let z = Zipf::new(100, 1.2);
        let trace = z.trace(20_000, &mut Rng::new(42));
        let head = trace.iter().filter(|&&k| k < 10).count() as f64 / trace.len() as f64;
        assert!(head > 0.6, "head mass {head} too small for s=1.2");
        // Uniform (s=0) spreads evenly.
        let u = Zipf::new(100, 0.0);
        let trace = u.trace(20_000, &mut Rng::new(42));
        let head = trace.iter().filter(|&&k| k < 10).count() as f64 / trace.len() as f64;
        assert!((head - 0.1).abs() < 0.02, "uniform head mass {head}");
    }

    #[test]
    fn single_rank_degenerate() {
        let z = Zipf::new(1, 2.0);
        assert_eq!(z.sample(&mut Rng::new(1)), 0);
        assert!((z.pmf(0) - 1.0).abs() < 1e-15);
    }
}
