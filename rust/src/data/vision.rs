//! Synthetic image-classification dataset — the CIFAR-100 stand-in for
//! the LipConvnet experiments (Tables 3–4).
//!
//! 16×16×4 images in 8 classes built from oriented gratings × radial
//! envelopes with per-channel phase offsets and additive noise: hard
//! enough that a 1-Lipschitz network shows a real accuracy/robustness
//! tradeoff, easy enough to train in a few hundred CPU steps. Pixel range
//! matches CIFAR's [0,1]-normalized scale so the certified radius
//! ε = 36/255 carries over meaningfully.

use crate::util::rng::Rng;

pub const IMG: usize = 16;
pub const CH: usize = 4;
pub const CLASSES: usize = 8;
pub const PIX: usize = IMG * IMG * CH;

/// Render one image of `class` (NHWC layout).
pub fn image(class: usize, rng: &mut Rng) -> Vec<f32> {
    assert!(class < CLASSES);
    let mut img = vec![0.0f32; PIX];
    // Class determines orientation (4 angles) and frequency (2 bands).
    let angle = (class % 4) as f32 * std::f32::consts::PI / 4.0;
    let freq = if class < 4 { 0.7 } else { 1.3 };
    let (ca, sa) = (angle.cos(), angle.sin());
    let phase = rng.uniform_in(0.0, std::f32::consts::PI);
    let cx = 7.5 + rng.uniform_in(-1.5, 1.5);
    let cy = 7.5 + rng.uniform_in(-1.5, 1.5);
    for y in 0..IMG {
        for x in 0..IMG {
            let fx = x as f32 - cx;
            let fy = y as f32 - cy;
            let u = fx * ca + fy * sa;
            let r2 = fx * fx + fy * fy;
            let envelope = (-r2 / 60.0).exp();
            let grating = (u * freq + phase).sin();
            for c in 0..CH {
                let chphase = c as f32 * 0.6;
                let v = 0.5 + 0.45 * grating * envelope * (chphase.cos())
                    + 0.1 * ((u * freq * 0.5 + chphase).sin());
                img[(y * IMG + x) * CH + c] =
                    (v + rng.normal_f32(0.04)).clamp(0.0, 1.0);
            }
        }
    }
    img
}

/// Batch of (images NHWC-flattened, labels).
pub fn batch(n: usize, rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
    let mut xs = Vec::with_capacity(n * PIX);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let class = rng.below(CLASSES);
        xs.extend_from_slice(&image(class, rng));
        ys.push(class as i32);
    }
    (xs, ys)
}

/// Deterministic held-out test set (fixed seed disjoint from training).
pub fn test_set(n: usize) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Rng::new(0x7E57);
    batch(n, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_range_and_shape() {
        let mut rng = Rng::new(1);
        for class in 0..CLASSES {
            let img = image(class, &mut rng);
            assert_eq!(img.len(), PIX);
            assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn classes_are_separable_by_template_matching() {
        // Nearest-class-mean on raw pixels must beat chance by a wide
        // margin — guards against an unlearnable generator.
        let mut rng = Rng::new(2);
        let mut means = vec![vec![0.0f64; PIX]; CLASSES];
        let per = 24;
        for (class, mean) in means.iter_mut().enumerate() {
            for _ in 0..per {
                let img = image(class, &mut rng);
                for (m, v) in mean.iter_mut().zip(img.iter()) {
                    *m += *v as f64 / per as f64;
                }
            }
        }
        let mut correct = 0;
        let trials = 160;
        for _ in 0..trials {
            let class = rng.below(CLASSES);
            let img = image(class, &mut rng);
            let best = (0..CLASSES)
                .min_by(|&a, &b| {
                    let da: f64 = means[a]
                        .iter()
                        .zip(img.iter())
                        .map(|(m, v)| (m - *v as f64).powi(2))
                        .sum();
                    let db: f64 = means[b]
                        .iter()
                        .zip(img.iter())
                        .map(|(m, v)| (m - *v as f64).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == class {
                correct += 1;
            }
        }
        let acc = correct as f64 / trials as f64;
        assert!(acc > 0.5, "template acc {acc} (chance = 0.125)");
    }

    #[test]
    fn test_set_is_deterministic_and_balancedish() {
        let (x1, y1) = test_set(64);
        let (x2, y2) = test_set(64);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        let distinct: std::collections::HashSet<i32> = y1.iter().copied().collect();
        assert!(distinct.len() >= 6);
    }
}
