//! Dynamic micro-batching: concurrent requests for the *same* tenant are
//! grouped so the engine pays one cache lookup / one (possibly cold) merge
//! / one batched GEMM per flush instead of per request. A batch flushes
//! when it reaches `max_batch` items or when its oldest request has waited
//! `max_wait` (the deadline bound on added latency).
//!
//! Time is passed in explicitly (`Instant` arguments) so the flush logic
//! is deterministic under test.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::obs::{Counter, Gauge, Histo};
use crate::serve::registry::TenantId;

/// Pre-resolved batcher metrics (`serve_queue_*`, `serve_batch_size`,
/// `serve_deadline_miss_total`). Installed by the engine via
/// [`MicroBatcher::set_obs`]; a bare batcher records nothing.
pub struct BatcherObs {
    /// Items waiting across all tenants (gauge, updated on every
    /// push/flush).
    pub queue_depth: Arc<Gauge>,
    /// Items per flushed batch.
    pub batch_size: Arc<Histo>,
    /// Age of a batch's oldest item at flush time, ns.
    pub queue_wait_ns: Arc<Histo>,
    /// Batches that waited > 2× `max_wait` — the ticker fell behind.
    pub deadline_miss: Arc<Counter>,
}

/// A flushed group of same-tenant items.
pub struct Batch<T> {
    pub tenant: TenantId,
    pub items: Vec<T>,
    /// When the oldest item in the batch was enqueued.
    pub opened_at: Instant,
}

struct Pending<T> {
    items: Vec<T>,
    opened_at: Instant,
}

/// Size/deadline micro-batcher. Not thread-safe by itself — the engine
/// wraps it in a mutex and drives flushes from submitters and a ticker.
pub struct MicroBatcher<T> {
    max_batch: usize,
    max_wait: Duration,
    pending: HashMap<TenantId, Pending<T>>,
    obs: Option<BatcherObs>,
}

impl<T> MicroBatcher<T> {
    pub fn new(max_batch: usize, max_wait: Duration) -> MicroBatcher<T> {
        assert!(max_batch >= 1);
        MicroBatcher {
            max_batch,
            max_wait,
            pending: HashMap::new(),
            obs: None,
        }
    }

    /// Install metric handles; every subsequent push/flush records into
    /// them.
    pub fn set_obs(&mut self, obs: BatcherObs) {
        self.obs = Some(obs);
    }

    /// Record a flushed batch and refresh the depth gauge. `now = None`
    /// on the shutdown path, where wait times are not meaningful.
    fn observe(&self, batch: &Batch<T>, now: Option<Instant>) {
        let Some(obs) = &self.obs else { return };
        obs.batch_size.record(batch.items.len() as u64);
        if let Some(now) = now {
            let wait = now.duration_since(batch.opened_at);
            obs.queue_wait_ns.record_duration(wait);
            if wait > self.max_wait * 2 {
                obs.deadline_miss.inc();
            }
        }
    }

    fn set_depth_gauge(&self) {
        if let Some(obs) = &self.obs {
            obs.queue_depth.set(self.pending_items() as u64);
        }
    }

    /// Add one item. Returns a full batch if this item completed one.
    pub fn push(&mut self, tenant: TenantId, item: T, now: Instant) -> Option<Batch<T>> {
        let p = self.pending.entry(tenant).or_insert_with(|| Pending {
            items: Vec::new(),
            opened_at: now,
        });
        p.items.push(item);
        let out = if p.items.len() >= self.max_batch {
            let p = self.pending.remove(&tenant).unwrap();
            let batch = Batch {
                tenant,
                items: p.items,
                opened_at: p.opened_at,
            };
            self.observe(&batch, Some(now));
            Some(batch)
        } else {
            None
        };
        self.set_depth_gauge();
        out
    }

    /// Flush every batch whose oldest item has waited at least `max_wait`.
    pub fn flush_expired(&mut self, now: Instant) -> Vec<Batch<T>> {
        let expired: Vec<TenantId> = self
            .pending
            .iter()
            .filter(|(_, p)| now.duration_since(p.opened_at) >= self.max_wait)
            .map(|(&t, _)| t)
            .collect();
        let out = self.drain(expired);
        for batch in &out {
            self.observe(batch, Some(now));
        }
        self.set_depth_gauge();
        out
    }

    /// Flush everything (shutdown path).
    pub fn flush_all(&mut self) -> Vec<Batch<T>> {
        let all: Vec<TenantId> = self.pending.keys().copied().collect();
        let out = self.drain(all);
        for batch in &out {
            self.observe(batch, None);
        }
        self.set_depth_gauge();
        out
    }

    fn drain(&mut self, tenants: Vec<TenantId>) -> Vec<Batch<T>> {
        let mut out: Vec<Batch<T>> = tenants
            .into_iter()
            .filter_map(|t| {
                self.pending.remove(&t).map(|p| Batch {
                    tenant: t,
                    items: p.items,
                    opened_at: p.opened_at,
                })
            })
            .collect();
        // Oldest first, then tenant id: deterministic flush order.
        out.sort_by_key(|b| (b.opened_at, b.tenant));
        out
    }

    /// Total items waiting across tenants.
    pub fn pending_items(&self) -> usize {
        self.pending.values().map(|p| p.items.len()).sum()
    }

    pub fn max_wait(&self) -> Duration {
        self.max_wait
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    /// A replayable op sequence: pushes (tenant, at offset ms) and
    /// deadline flushes, at non-decreasing times.
    #[derive(Debug, Clone)]
    struct BatcherCase {
        max_batch: usize,
        /// `(tenant, at_ms, is_flush)` — a flush op calls `flush_expired`.
        ops: Vec<(TenantId, u64, bool)>,
    }

    fn shrink_batcher(c: &BatcherCase) -> Vec<BatcherCase> {
        let mut out = Vec::new();
        for max_batch in prop::shrink_usize(c.max_batch, 1) {
            out.push(BatcherCase {
                max_batch,
                ops: c.ops.clone(),
            });
        }
        if !c.ops.is_empty() {
            let half = c.ops.len() / 2;
            out.push(BatcherCase {
                max_batch: c.max_batch,
                ops: c.ops[..half].to_vec(),
            });
            out.push(BatcherCase {
                max_batch: c.max_batch,
                ops: c.ops[half..].to_vec(),
            });
            let mut tail = c.ops.clone();
            tail.remove(0);
            out.push(BatcherCase {
                max_batch: c.max_batch,
                ops: tail,
            });
        }
        out
    }

    #[test]
    fn random_traffic_never_drops_duplicates_or_misflushes() {
        // Conservation + flush invariants under arbitrary interleavings of
        // pushes and deadline flushes:
        //   * size flushes return exactly max_batch same-tenant items, in
        //     FIFO order;
        //   * deadline flushes only return batches aged ≥ max_wait, and
        //     drain *every* expired batch;
        //   * across the whole run + shutdown, every pushed item comes
        //     back exactly once.
        prop::check_shrunk(
            "micro-batcher conservation",
            601,
            48,
            |rng| {
                let n = prop::size_in(rng, 1, 30);
                let mut at = 0u64;
                let ops = (0..n)
                    .map(|_| {
                        at += rng.below(4) as u64;
                        (rng.below(3) as TenantId, at, rng.flip(0.25))
                    })
                    .collect();
                BatcherCase {
                    max_batch: prop::size_in(rng, 1, 4),
                    ops,
                }
            },
            shrink_batcher,
            |c| {
                let max_wait = Duration::from_millis(5);
                let mut b: MicroBatcher<usize> = MicroBatcher::new(c.max_batch, max_wait);
                let t0 = Instant::now();
                let mut emitted: Vec<usize> = Vec::new();
                let mut tenant_of: Vec<TenantId> = Vec::new();
                let check = |batch: &Batch<usize>, size_flush: bool,
                             tenant_of: &[TenantId]| {
                    assert!(!batch.items.is_empty(), "empty batch flushed");
                    assert!(batch.items.len() <= c.max_batch, "oversized batch");
                    if size_flush {
                        assert_eq!(
                            batch.items.len(),
                            c.max_batch,
                            "size flush must return a full batch"
                        );
                    }
                    for pair in batch.items.windows(2) {
                        assert!(pair[0] < pair[1], "batch not FIFO: {:?}", batch.items);
                    }
                    for &id in &batch.items {
                        assert_eq!(tenant_of[id], batch.tenant, "foreign item in batch");
                    }
                };
                for &(tenant, at_ms, is_flush) in &c.ops {
                    let now = t0 + Duration::from_millis(at_ms);
                    if is_flush {
                        for batch in b.flush_expired(now) {
                            assert!(
                                now.duration_since(batch.opened_at) >= max_wait,
                                "flushed a batch younger than max_wait"
                            );
                            check(&batch, false, &tenant_of);
                            emitted.extend(batch.items.iter().copied());
                        }
                        assert!(
                            b.flush_expired(now).is_empty(),
                            "flush_expired left an expired batch behind"
                        );
                    } else {
                        let id = tenant_of.len();
                        tenant_of.push(tenant);
                        if let Some(batch) = b.push(tenant, id, now) {
                            assert_eq!(batch.tenant, tenant);
                            check(&batch, true, &tenant_of);
                            emitted.extend(batch.items.iter().copied());
                        }
                    }
                }
                assert_eq!(
                    emitted.len() + b.pending_items(),
                    tenant_of.len(),
                    "items lost before shutdown"
                );
                for batch in b.flush_all() {
                    check(&batch, false, &tenant_of);
                    emitted.extend(batch.items.iter().copied());
                }
                assert_eq!(b.pending_items(), 0, "flush_all left items behind");
                let mut sorted = emitted.clone();
                sorted.sort_unstable();
                assert_eq!(
                    sorted,
                    (0..tenant_of.len()).collect::<Vec<_>>(),
                    "dropped or duplicated item (emitted {emitted:?})"
                );
            },
        );
    }

    #[test]
    fn flushes_on_size() {
        let mut b: MicroBatcher<u32> = MicroBatcher::new(3, Duration::from_secs(1));
        let t0 = Instant::now();
        assert!(b.push(7, 1, t0).is_none());
        assert!(b.push(7, 2, t0).is_none());
        let batch = b.push(7, 3, t0).expect("third item completes the batch");
        assert_eq!(batch.tenant, 7);
        assert_eq!(batch.items, vec![1, 2, 3]);
        assert_eq!(b.pending_items(), 0);
    }

    #[test]
    fn tenants_batch_independently() {
        let mut b: MicroBatcher<u32> = MicroBatcher::new(2, Duration::from_secs(1));
        let t0 = Instant::now();
        assert!(b.push(1, 10, t0).is_none());
        assert!(b.push(2, 20, t0).is_none());
        assert_eq!(b.pending_items(), 2);
        let batch = b.push(1, 11, t0).unwrap();
        assert_eq!(batch.items, vec![10, 11]);
        assert_eq!(b.pending_items(), 1, "tenant 2 still pending");
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b: MicroBatcher<u32> = MicroBatcher::new(100, Duration::from_millis(10));
        let t0 = Instant::now();
        b.push(1, 1, t0);
        b.push(2, 2, t0 + Duration::from_millis(5));
        // At +9ms nothing has aged past 10ms.
        assert!(b.flush_expired(t0 + Duration::from_millis(9)).is_empty());
        // At +10ms tenant 1's batch (opened at t0) expires; tenant 2's not.
        let flushed = b.flush_expired(t0 + Duration::from_millis(10));
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].tenant, 1);
        // At +15ms tenant 2 expires too.
        let flushed = b.flush_expired(t0 + Duration::from_millis(15));
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].tenant, 2);
        assert_eq!(b.pending_items(), 0);
    }

    #[test]
    fn obs_records_depth_sizes_waits_and_misses() {
        let reg = crate::obs::MetricsRegistry::new();
        let mut b: MicroBatcher<u32> = MicroBatcher::new(2, Duration::from_millis(10));
        b.set_obs(BatcherObs {
            queue_depth: reg.gauge("serve_queue_depth"),
            batch_size: reg.histogram("serve_batch_size"),
            queue_wait_ns: reg.histogram("serve_queue_wait_ns"),
            deadline_miss: reg.counter("serve_deadline_miss_total"),
        });
        let t0 = Instant::now();
        b.push(1, 1, t0);
        assert_eq!(reg.snapshot().gauges["serve_queue_depth"], 1);
        // Size flush at +1ms: wait 1ms, no deadline miss.
        assert!(b.push(1, 2, t0 + Duration::from_millis(1)).is_some());
        b.push(2, 3, t0);
        // Deadline flush at +25ms: 25ms > 2×10ms ⇒ a miss.
        assert_eq!(b.flush_expired(t0 + Duration::from_millis(25)).len(), 1);
        let s = reg.snapshot();
        assert_eq!(s.gauges["serve_queue_depth"], 0);
        assert_eq!(s.counters["serve_deadline_miss_total"], 1);
        assert_eq!(s.histograms["serve_batch_size"].count(), 2);
        let waits = &s.histograms["serve_queue_wait_ns"];
        assert_eq!(waits.count(), 2);
        assert_eq!(waits.max, 25_000_000, "explicit Instants make waits exact");
        // Shutdown flush records size but no (meaningless) wait.
        b.push(3, 4, t0);
        b.flush_all();
        let s = reg.snapshot();
        assert_eq!(s.histograms["serve_batch_size"].count(), 3);
        assert_eq!(s.histograms["serve_queue_wait_ns"].count(), 2);
    }

    #[test]
    fn flush_all_is_deterministic_oldest_first() {
        let mut b: MicroBatcher<u32> = MicroBatcher::new(10, Duration::from_secs(1));
        let t0 = Instant::now();
        b.push(5, 50, t0 + Duration::from_millis(2));
        b.push(3, 30, t0);
        b.push(4, 40, t0 + Duration::from_millis(1));
        let flushed = b.flush_all();
        let order: Vec<TenantId> = flushed.iter().map(|f| f.tenant).collect();
        assert_eq!(order, vec![3, 4, 5]);
        assert_eq!(b.pending_items(), 0);
    }
}
