//! The multi-tenant serving engine: worker threads on a
//! [`crate::util::pool::WorkQueue`] drain micro-batches and serve each one
//! over the cheapest available path:
//!
//! - **hot** (`CachedDense`): the tenant's merged weights are in the LRU
//!   cache → one dense GEMM per layer, exactly the frozen-model cost
//!   (the paper's "no inference overhead" claim, §6.1).
//! - **cold merge** (`ColdMerge`): the tenant just crossed the promotion
//!   threshold → pay `merge` once (Cayley solves + structured `Q·W`),
//!   cache the result, serve this batch from it.
//! - **factorized** (`Factorized`): cold-tail tenants skip merging —
//!   serve `W'X = Q(WX)` with the family's prepared
//!   [`crate::adapter::LayerOp`] (structured GS/OFT apply, low-rank
//!   `WX + A(BX)` for LoRA, direct GS-SOC conv, …), paying a small
//!   per-request overhead instead of a merge. Fully family-agnostic:
//!   new [`crate::adapter::AdapterFamily`]s serve here with no engine
//!   edits.
//! - **spill load** (`SpillLoad`): with a spill tier mounted
//!   ([`EngineOpts::spill_dir`]), a promoted tenant whose merged weights
//!   were evicted to disk is rehydrated with one sequential read instead
//!   of a re-merge — taken only when the Theorem-2 cost model says the
//!   load beats the re-merge ([`Policy::spill_pays_off`]).
//!
//! The promotion threshold comes from the Theorem-2 density cost model
//! ([`Policy::from_cost_model`]).

use std::collections::{BTreeMap, HashMap, HashSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::adapter::gsoft::gs_cost_model;
use crate::adapter::{AdapterFamily, CostModel, LayerOp};
use crate::kernel::KernelCtx;
use crate::linalg::Mat;
use crate::obs::http::{HealthCheck, HealthReport, ObsSources};
use crate::obs::slo::{SloReport, SloSet, SloTracker, SERVE_P99_TARGET_NS};
use crate::obs::{
    CaptureReason, CaptureRing, Captured, Counter, Histo, HistoSnapshot, MetricsRegistry,
    RegistrySnapshot, Stage, TenantStats, TenantSummary, Trace, TraceRing, CAPTURE_RING_CAP,
    DEFAULT_TENANT_TOPK,
};
use crate::store::gsad::params_crc;
use crate::store::{spill, MaintStats, Maintainer, SpillStats, SpillTier, DEFAULT_MAINT_INTERVAL_MS};
use crate::util::pool::{default_workers, WorkQueue};

use super::batcher::{Batch, BatcherObs, MicroBatcher};
use super::cache::{CacheObs, CacheStats, CachedModel, MergedCache};
use super::registry::{AdapterEntry, Registry, TenantId};

/// Which path served a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServePath {
    CachedDense,
    ColdMerge,
    Factorized,
    SpillLoad,
}

impl ServePath {
    pub fn name(&self) -> &'static str {
        match self {
            ServePath::CachedDense => "cached_dense",
            ServePath::ColdMerge => "cold_merge",
            ServePath::Factorized => "factorized",
            ServePath::SpillLoad => "spill_load",
        }
    }
}

/// Promotion policy derived from the paper's density/cost model
/// (`gs/density.rs`): merging pays `m·nnz(factor)·d` flops once, while the
/// factorized path pays `m·nnz(factor)` extra flops per request on top of
/// the base GEMM. With micro-batches of expected size `B`, break-even is
/// after `d/B` requests — tenants past that threshold are merged and
/// cached; the cold tail is served factorized and never evicts a hot
/// tenant.
#[derive(Clone, Copy, Debug)]
pub struct Policy {
    /// Requests seen (per tenant) before the engine merges + caches it.
    pub promote_after: u64,
    /// Whether the merged `Q` support is fully dense at the chosen
    /// `(d, block)` — Theorem 2 guarantees this for `m = 1 + ⌈log_b r⌉`,
    /// which is what makes the cached path a plain dense GEMM.
    pub q_dense: bool,
    /// Theorem-2 merge cost for one adapted layer (flops) — what the
    /// spill tier's load-vs-remerge break-even weighs a disk read
    /// against.
    pub merge_flops_per_layer: u64,
}

/// Load-vs-remerge calibration: how many merge-flops one spilled byte is
/// worth. Sequential disk reads run ~1 GB/s while the merge arithmetic
/// sustains a few Gflop/s, so a byte costs a handful of flop-equivalents.
pub const SPILL_FLOPS_PER_BYTE: f64 = 4.0;

impl Policy {
    /// Derive a policy from a family [`CostModel`] at served dimension
    /// `d`: merging pays `q_col_flops · d` once, factorized serving pays
    /// `q_col_flops` per column — break-even after `d/B` requests at
    /// expected batch size `B`, for every structured family.
    pub fn from_family_model(cm: CostModel, d: usize, expected_batch: usize) -> Policy {
        Policy {
            promote_after: (d / expected_batch.max(1)).max(1) as u64,
            q_dense: cm.q_dense,
            merge_flops_per_layer: cm.q_col_flops * d as u64,
        }
    }

    /// The GS/Theorem-2 instance of [`Policy::from_family_model`] — the
    /// generic default when no structured family is registered. The
    /// support-model math itself lives in one place,
    /// [`crate::adapter::gsoft::gs_cost_model`].
    pub fn from_cost_model(d: usize, block: usize, expected_batch: usize) -> Policy {
        Policy::from_family_model(gs_cost_model(d, block), d, expected_batch)
    }

    /// Fixed threshold (tests, or deployments that know their traffic).
    /// The merge is treated as arbitrarily expensive, so a mounted spill
    /// tier is always preferred over re-merging.
    pub fn fixed(promote_after: u64) -> Policy {
        Policy {
            promote_after,
            q_dense: true,
            merge_flops_per_layer: u64::MAX,
        }
    }

    /// Load-vs-remerge break-even (the spill extension of the Theorem-2
    /// model): reading a `model_bytes` merged model back from disk costs
    /// `bytes · SPILL_FLOPS_PER_BYTE` flop-equivalents; re-merging costs
    /// `merge_flops_per_layer · layers`. The spill tier only runs when
    /// the load wins — for GS adapters the merge side is `m·b·d²` flops
    /// per layer while the model is `~12·d²` bytes (f32 flat + f64 mats),
    /// so spilling wins once `m·b` clears a few dozen: true at production
    /// block sizes (the paper's `d=1024, b=32`), false for toy
    /// geometries, where re-merging really is cheaper than the disk.
    pub fn spill_pays_off(&self, layers: usize, model_bytes: usize) -> bool {
        model_bytes as f64 * SPILL_FLOPS_PER_BYTE
            < self.merge_flops_per_layer as f64 * layers.max(1) as f64
    }
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineOpts {
    pub workers: usize,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Ticker poll interval for deadline flushes.
    pub poll_interval: Duration,
    pub cache_budget_bytes: usize,
    /// `None` → derive from [`Policy::from_cost_model`].
    pub promote_after: Option<u64>,
    /// Compute-kernel dispatch context threaded through every serving
    /// path (dense GEMMs and fused factorized applies alike); deployments
    /// that know their dominant shape can pass
    /// [`KernelCtx::autotuned`].
    pub kernel: KernelCtx,
    /// Mount a spill tier here: RAM-cache evictions write merged weights
    /// to this directory and the cold path checks it before re-merging.
    /// Only engaged when the load-vs-remerge break-even
    /// ([`Policy::spill_pays_off`]) favors it at this model geometry.
    pub spill_dir: Option<PathBuf>,
    /// Byte cap on the spill tier's directory.
    pub spill_budget_bytes: u64,
    /// Capacity of the recent-trace ring ([`Engine::traces`], the
    /// `/tracez` endpoint, `gsoft trace`). Deployments chasing tail
    /// latency raise it; memory cost is one fixed-size [`Trace`] per
    /// slot.
    pub trace_ring_cap: usize,
    /// Slow-request capture threshold in nanoseconds: a served request
    /// whose end-to-end latency reaches it is retained in the capture
    /// ring ([`Engine::captured`], `/tracez?captured=1`). `None` derives
    /// the bar from the serve-SLO p99 objective
    /// ([`SERVE_P99_TARGET_NS`]) — anything that would burn the SLO is
    /// kept.
    pub capture_slow_ns: Option<u64>,
    /// K of the per-tenant heavy-hitter sketches ([`Engine::tenant_summary`],
    /// `/tenantz`, `serve_tenant_topk_*`): telemetry cardinality is
    /// capped at K entries per dimension regardless of fleet size.
    pub tenant_topk: usize,
    /// Idle tick interval of the background maintenance thread
    /// ([`crate::store::Maintainer`]): how often it scans for log
    /// compaction work when no spill job wakes it. The thread is spawned
    /// whenever the engine has a store-backed registry or an engaged
    /// spill tier; it owns *all* compaction and spill-file writes, so
    /// neither ever runs on a request.
    pub maint_interval: Duration,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts {
            workers: default_workers().min(8),
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            poll_interval: Duration::from_micros(500),
            cache_budget_bytes: 64 << 20,
            promote_after: None,
            kernel: KernelCtx::default(),
            spill_dir: None,
            spill_budget_bytes: 256 << 20,
            trace_ring_cap: TRACE_RING_CAP,
            capture_slow_ns: None,
            tenant_topk: DEFAULT_TENANT_TOPK,
            maint_interval: Duration::from_millis(DEFAULT_MAINT_INTERVAL_MS),
        }
    }
}

/// One served request's result.
pub struct ServeOutput {
    pub output: Vec<f32>,
    pub path: ServePath,
    pub latency: Duration,
}

struct Slot {
    result: Mutex<Option<Result<ServeOutput, String>>>,
    done: Condvar,
}

/// Handle to an in-flight request; [`Handle::wait`] blocks for the result.
pub struct Handle {
    slot: Arc<Slot>,
}

impl Handle {
    pub fn wait(self) -> Result<ServeOutput> {
        let mut guard = self.slot.result.lock().unwrap();
        while guard.is_none() {
            guard = self.slot.done.wait(guard).unwrap();
        }
        guard.take().unwrap().map_err(|e| anyhow!(e))
    }
}

fn fulfill(slot: &Slot, result: Result<ServeOutput, String>) {
    *slot.result.lock().unwrap() = Some(result);
    slot.done.notify_all();
}

/// Marker embedded in the error string of a request shed for missing its
/// client deadline — the network front matches on it to answer 504
/// instead of 500.
pub const DEADLINE_EXCEEDED: &str = "deadline exceeded before execution";

struct Job {
    input: Vec<f32>,
    submitted_at: Instant,
    /// Client-propagated deadline: past this instant the caller has
    /// given up, so the batch worker sheds the job instead of computing
    /// a result nobody will read.
    deadline: Option<Instant>,
    /// Caller-visible correlation id carried into the request's
    /// [`Trace`]; 0 = unattributed (bare [`Engine::submit`]).
    req_id: u64,
    slot: Arc<Slot>,
}

/// Latency statistics for one path (or overall). Quantiles come from the
/// log-bucketed [`crate::obs::Histo`] (≤12.5 % relative overshoot,
/// clamped to the observed max), not a sorted sample vector.
#[derive(Clone, Copy, Debug, Default)]
pub struct PathStats {
    pub count: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl PathStats {
    fn from_histo(h: &HistoSnapshot) -> PathStats {
        PathStats {
            count: h.count(),
            mean_ns: h.mean(),
            p50_ns: h.quantile(0.50) as f64,
            p99_ns: h.quantile(0.99) as f64,
        }
    }
}

/// Snapshot of the engine's counters and latency distributions.
///
/// `overall`/`cached`/`cold`/`factorized` are *end-to-end per-request
/// latencies* (submit → result, including batching and queueing);
/// `service_*` are *per-batch worker compute times*, which isolate the
/// cached-GEMM vs cold-merge vs factorized cost difference from queue
/// depth under bursty load.
///
/// The snapshot is monotonic-consistent: `requests` and every per-path
/// `count` are derived from the same histogram bucket arrays, so
/// `requests` always equals the sum of the per-path counts (the old
/// ad-hoc counters read each atomic independently and could disagree
/// mid-flight).
#[derive(Clone, Copy, Debug, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub merges: u64,
    /// Merges avoided by loading spilled weights back from disk.
    pub spill_loads: u64,
    pub overall: PathStats,
    pub cached: PathStats,
    pub cold: PathStats,
    pub factorized: PathStats,
    pub spill: PathStats,
    pub service_cached: PathStats,
    pub service_cold: PathStats,
    pub service_factorized: PathStats,
    pub service_spill: PathStats,
}

/// All four serve paths, indexed by [`path_index`].
const PATHS: [ServePath; 4] = [
    ServePath::CachedDense,
    ServePath::ColdMerge,
    ServePath::Factorized,
    ServePath::SpillLoad,
];

fn path_index(p: ServePath) -> usize {
    match p {
        ServePath::CachedDense => 0,
        ServePath::ColdMerge => 1,
        ServePath::Factorized => 2,
        ServePath::SpillLoad => 3,
    }
}

/// Default capacity of the recent-trace ring
/// ([`EngineOpts::trace_ring_cap`]): traces retained for post-hoc tail
/// inspection ([`Engine::traces`], `gsoft metrics`, `/tracez`).
pub const TRACE_RING_CAP: usize = 256;

struct PathObs {
    count: Arc<Counter>,
    latency: Arc<Histo>,
    service: Arc<Histo>,
}

/// Per-engine telemetry: a private [`MetricsRegistry`] (so concurrent
/// engines — and tests — never share counters), pre-resolved handles for
/// every hot-path metric, and the trace ring. Replaces the ad-hoc
/// `Metrics` struct of unbounded latency `Vec`s: recording is now O(1)
/// and allocation-free per request.
struct EngineObs {
    registry: Arc<MetricsRegistry>,
    batches: Arc<Counter>,
    merges: Arc<Counter>,
    spill_loads: Arc<Counter>,
    /// Jobs dropped unserved because their client deadline passed
    /// before a worker reached them.
    deadline_shed: Arc<Counter>,
    /// Merged-cache hits whose merge-time params CRC no longer matched
    /// the registry (tenant re-registered live): the hit is demoted to a
    /// miss and the stale model dropped.
    stale_crc: Arc<Counter>,
    /// Indexed by [`path_index`].
    paths: [PathObs; 4],
    /// Indexed by [`Stage::index`].
    stages: [Arc<Histo>; Stage::COUNT],
    /// Lazily created per-family handles, keyed by the family wire-tag.
    family_requests: Mutex<HashMap<&'static str, Arc<Counter>>>,
    family_service: Mutex<HashMap<&'static str, Arc<Histo>>>,
    /// Which family each tenant serves — recorded on the first cold
    /// serve, read on the cached hot path (where no registry entry is in
    /// hand).
    family_of: Mutex<HashMap<TenantId, &'static str>>,
    traces: TraceRing,
    /// Per-tenant heavy hitters: bounded K-slot sketches per dimension,
    /// never one series per tenant (DESIGN.md §12).
    tenants: TenantStats,
    /// Slow/shed/error traces, retained past the main ring's wrap.
    captures: CaptureRing,
}

impl EngineObs {
    fn new(trace_cap: usize, tenant_topk: usize) -> EngineObs {
        let registry = Arc::new(MetricsRegistry::new());
        let paths = PATHS.map(|p| PathObs {
            count: registry.counter(&format!("serve_requests_total{{path=\"{}\"}}", p.name())),
            latency: registry.histogram(&format!("serve_request_ns{{path=\"{}\"}}", p.name())),
            service: registry.histogram(&format!("serve_service_ns{{path=\"{}\"}}", p.name())),
        });
        let stages = Stage::ALL
            .map(|s| registry.histogram(&format!("serve_stage_ns{{stage=\"{}\"}}", s.name())));
        EngineObs {
            batches: registry.counter("serve_batches_total"),
            merges: registry.counter("serve_merges_total"),
            spill_loads: registry.counter("serve_spill_loads_total"),
            deadline_shed: registry.counter("serve_deadline_shed_total"),
            stale_crc: registry.counter("serve_cache_stale_crc_total"),
            paths,
            stages,
            family_requests: Mutex::new(HashMap::new()),
            family_service: Mutex::new(HashMap::new()),
            family_of: Mutex::new(HashMap::new()),
            traces: TraceRing::new(trace_cap),
            tenants: TenantStats::new(tenant_topk),
            captures: CaptureRing::new(CAPTURE_RING_CAP),
            registry,
        }
    }

    /// Push a trace to the main ring (stamping its seq) and, when
    /// `reason` says it is interesting, retain a copy in the capture
    /// ring under the *same* seq — so a `/tracez?req=` hit resolves to
    /// one request no matter which ring answered.
    fn push_trace(&self, mut trace: Trace, reason: Option<CaptureReason>) {
        let seq = self.traces.push(trace.clone());
        if let Some(reason) = reason {
            trace.seq = seq;
            self.captures.push(reason, trace);
        }
    }

    fn note_family(&self, tenant: TenantId, tag: &'static str) {
        self.family_of.lock().unwrap().entry(tenant).or_insert(tag);
    }

    fn family_of(&self, tenant: TenantId) -> &'static str {
        self.family_of
            .lock()
            .unwrap()
            .get(&tenant)
            .copied()
            .unwrap_or("unknown")
    }

    fn family_requests(&self, tag: &'static str) -> Arc<Counter> {
        let mut m = self.family_requests.lock().unwrap();
        Arc::clone(m.entry(tag).or_insert_with(|| {
            self.registry
                .counter(&format!("serve_requests_total{{family=\"{tag}\"}}"))
        }))
    }

    fn family_service(&self, tag: &'static str) -> Arc<Histo> {
        let mut m = self.family_service.lock().unwrap();
        Arc::clone(m.entry(tag).or_insert_with(|| {
            self.registry
                .histogram(&format!("serve_family_service_ns{{family=\"{tag}\"}}"))
        }))
    }

    /// Export the inferred Theorem-2 thresholds (satellite of ROADMAP
    /// item 4): the blended policy plus each sampled family's share.
    fn set_policy_gauges(&self, policy: &Policy, families: &[(&'static str, u64, u64)]) {
        let g = |name: &str, v: u64| self.registry.gauge(name).set(v);
        g("serve_policy_promote_after", policy.promote_after);
        g("serve_policy_q_dense", policy.q_dense as u64);
        g("serve_policy_merge_flops_per_layer", policy.merge_flops_per_layer);
        for &(tag, sampled, merge_flops) in families {
            g(&format!("serve_policy_family_sampled{{family=\"{tag}\"}}"), sampled);
            g(
                &format!("serve_policy_family_merge_flops{{family=\"{tag}\"}}"),
                merge_flops,
            );
        }
    }

    /// Rebuild the back-compat [`MetricsSnapshot`] from histogram
    /// snapshots — totals derived from components, never skewed reads.
    fn metrics_snapshot(&self) -> MetricsSnapshot {
        let lat: Vec<HistoSnapshot> = self.paths.iter().map(|p| p.latency.snapshot()).collect();
        let svc: Vec<HistoSnapshot> = self.paths.iter().map(|p| p.service.snapshot()).collect();
        let mut overall = lat[0].clone();
        for h in &lat[1..] {
            overall.merge(h);
        }
        MetricsSnapshot {
            requests: overall.count(),
            batches: self.batches.get(),
            merges: self.merges.get(),
            spill_loads: self.spill_loads.get(),
            overall: PathStats::from_histo(&overall),
            cached: PathStats::from_histo(&lat[0]),
            cold: PathStats::from_histo(&lat[1]),
            factorized: PathStats::from_histo(&lat[2]),
            spill: PathStats::from_histo(&lat[3]),
            service_cached: PathStats::from_histo(&svc[0]),
            service_cold: PathStats::from_histo(&svc[1]),
            service_factorized: PathStats::from_histo(&svc[2]),
            service_spill: PathStats::from_histo(&svc[3]),
        }
    }
}

/// Accumulates wall time into per-stage slots while a batch is served.
struct StageTimer {
    ns: [u64; Stage::COUNT],
}

impl StageTimer {
    fn new() -> StageTimer {
        StageTimer {
            ns: [0; Stage::COUNT],
        }
    }

    fn time<T>(&mut self, stage: Stage, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.ns[stage.index()] += t0.elapsed().as_nanos() as u64;
        out
    }
}

/// Final report returned by [`Engine::finish`].
pub struct EngineReport {
    pub metrics: MetricsSnapshot,
    pub cache: CacheStats,
    /// Spill-tier counters, when a tier was mounted and engaged.
    pub spill: Option<SpillStats>,
    /// Background maintenance counters (compactions, spill writes,
    /// off-request-path busy time), when the thread ran.
    pub maint: Option<MaintStats>,
    /// Full metric dump (`serve_*` taxonomy) — the `obs` section of
    /// `BENCH_serve.json` and the engine's share of `gsoft metrics`.
    pub obs: RegistrySnapshot,
    /// Whole-run SLO verdict ([`SloSet::serve_default`] evaluated over
    /// the final metric dump) — the `slo` section of `BENCH_serve.json`.
    pub slo: SloReport,
    /// The newest [`EngineOpts::trace_ring_cap`] request traces, newest
    /// first.
    pub traces: Vec<Trace>,
    /// Per-tenant heavy-hitter summary (≤ K entries per dimension) — the
    /// `tenants` section of `BENCH_serve.json` and the `/tenantz` payload.
    pub tenants: TenantSummary,
    /// Slow/shed/error traces retained in the capture ring, newest first.
    pub captured: Vec<Captured>,
}

struct Shared {
    registry: Registry,
    /// Names + dense matrices of the square served layers, in spec order.
    base_layers: Vec<(String, Mat)>,
    d: usize,
    policy: Policy,
    /// Kernel dispatch context for every worker's linear algebra.
    kernel: KernelCtx,
    /// Disk tier for evicted merged weights — `Some` only when a spill
    /// dir was configured *and* the load-vs-remerge break-even favors it.
    /// Shared with the maintenance thread, which owns the writes.
    spill: Option<Arc<Mutex<SpillTier>>>,
    /// Background maintenance thread (log compaction + spill writes) —
    /// `Some` whenever there is a sharded store log or a spill tier to
    /// maintain. Requests only *enqueue* work on it.
    maint: Option<Arc<Maintainer>>,
    cache: Mutex<MergedCache>,
    seen: Mutex<HashMap<TenantId, u64>>,
    /// Tenants with a merge in flight — prevents two workers that both
    /// miss the cache from paying the same cold merge concurrently.
    merging: Mutex<HashSet<TenantId>>,
    /// Tenants whose merged model exceeds the whole cache budget: they
    /// stay on the factorized path forever instead of re-merging on every
    /// batch.
    uncacheable: Mutex<HashSet<TenantId>>,
    /// Memoized factorized operators (Cayley blocks are built once per
    /// tenant, not per batch); entries are dropped on promotion. Adapters
    /// are immutable once the engine owns the registry, so this cannot go
    /// stale.
    factored: Mutex<HashMap<TenantId, Arc<Vec<Option<Box<dyn LayerOp>>>>>>,
    batcher: Mutex<MicroBatcher<Job>>,
    queue: WorkQueue<Batch<Job>>,
    obs: EngineObs,
    /// Resolved slow-capture bar ([`EngineOpts::capture_slow_ns`] or the
    /// serve-SLO p99 objective).
    capture_slow_ns: u64,
    /// Request-id mint; starts at 1 so id 0 stays "unattributed".
    req_seq: AtomicU64,
    shutting_down: AtomicBool,
    /// Engine birth — the zero point of every trace's `start_ns`
    /// timeline (what the Chrome export plots against).
    epoch: Instant,
    /// Live worker-thread count for the `/healthz` probe; incremented
    /// before each spawn, decremented by [`WorkerAlive`] on any exit.
    workers_alive: AtomicUsize,
    workers_spawned: usize,
}

/// Decrements `workers_alive` when a worker exits for *any* reason —
/// normal queue close or an unwinding panic — so the `/healthz` worker
/// probe can never overcount.
struct WorkerAlive(Arc<Shared>);

impl Drop for WorkerAlive {
    fn drop(&mut self) {
        self.0.workers_alive.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The serving engine. `submit` is thread-safe; drop or [`Engine::finish`]
/// drains pending work and joins the workers.
pub struct Engine {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    ticker: Option<JoinHandle<()>>,
}

impl Engine {
    pub fn new(registry: Registry, opts: EngineOpts) -> Result<Engine> {
        let base = registry.base().clone();
        let mut base_layers = Vec::new();
        let mut d = None;
        for (name, shape) in &base.spec.entries {
            if shape.len() == 2 && shape[0] == shape[1] {
                let dim = shape[0];
                anyhow::ensure!(
                    d.is_none() || d == Some(dim),
                    "square layers must share one dimension"
                );
                d = Some(dim);
                let w = Mat::from_f32(dim, dim, base.spec.view(&base.weights, name)?);
                base_layers.push((name.clone(), w));
            }
        }
        let d = d.ok_or_else(|| anyhow!("base model has no square layers to serve"))?;
        // Per-family Theorem-2 samples: wire-tag → (tenants sampled,
        // Σ q_col_flops, tenants with dense merged support). Kept past
        // policy inference so the per-family shares can be exported as
        // gauges.
        let mut per_family: BTreeMap<&'static str, (u64, u64, u64)> = BTreeMap::new();
        let policy = match opts.promote_after {
            Some(k) => Policy::fixed(k),
            None => {
                // Policy inference needs adapter *descriptors*, not the
                // fleet: sample a bounded prefix through the non-caching
                // read so a store-backed registry keeps its lazy cold
                // boot (O(log replay), never O(fleet) hydration). The
                // break-even is d/B requests for *every* family (merging
                // applies Q to each of W's d columns, the factorized path
                // applies the same Q once per served column — identical
                // per-column cost); only `merge_flops_per_layer` and the
                // Theorem-2 density bit are family-specific, so those are
                // *blended* across every sampled family weighted by how
                // often it appears — not taken winner-takes-all from the
                // first sampled descriptor, which misjudged mixed fleets.
                const POLICY_DESC_SAMPLE: usize = 64;
                let batch = opts.max_batch.div_ceil(2).max(1);
                for t in registry.tenant_ids().into_iter().take(POLICY_DESC_SAMPLE) {
                    let Some(desc) = registry.desc_of(t) else { continue };
                    let Some(cm) = desc.family().cost_model(desc.cfg(), d) else {
                        continue;
                    };
                    let e = per_family.entry(desc.family().tag()).or_insert((0, 0, 0));
                    e.0 += 1;
                    e.1 += cm.q_col_flops;
                    e.2 += u64::from(cm.q_dense);
                }
                let total: u64 = per_family.values().map(|v| v.0).sum();
                if total == 0 {
                    // No structured family sampled (e.g. all-LoRA):
                    // generic Theorem-2 default at block d/4.
                    Policy::from_cost_model(d, (d / 4).max(1), batch)
                } else {
                    let sum_q: u64 = per_family.values().map(|v| v.1).sum();
                    let n_dense: u64 = per_family.values().map(|v| v.2).sum();
                    Policy {
                        promote_after: (d / batch.max(1)).max(1) as u64,
                        // Count-weighted majority; ties go dense (the
                        // cached path is a plain GEMM either way — the
                        // bit only gates reporting and spill sizing).
                        q_dense: 2 * n_dense >= total,
                        // Count-weighted mean merge cost, rounded.
                        merge_flops_per_layer: ((sum_q + total / 2) / total) * d as u64,
                    }
                }
            }
        };

        // Spill break-even: one merged model is the f32 flat buffer plus
        // the f64 per-layer GEMM matrices (see `CachedModel::bytes`).
        let model_bytes = base.weights.len() * 4 + base_layers.len() * d * d * 8;
        let spill = match &opts.spill_dir {
            Some(dir) if policy.spill_pays_off(base_layers.len(), model_bytes) => {
                Some(Arc::new(Mutex::new(SpillTier::open(dir, opts.spill_budget_bytes)?)))
            }
            Some(_) => None, // re-merging is cheaper than the disk here
            None => None,
        };

        // Background maintenance: spawned whenever there is a sharded
        // store log to compact or a spill tier to write. It takes
        // ownership of both duties — the log's inline auto-compaction is
        // disabled for the thread's lifetime, and cache evictions only
        // *enqueue* their spill write — so the request path never pays a
        // compaction or a bulk disk write.
        let maint_log = registry.sharded_log();
        let maint = if maint_log.is_some() || spill.is_some() {
            Some(Arc::new(Maintainer::spawn(
                opts.maint_interval,
                maint_log,
                spill.clone(),
            )))
        } else {
            None
        };

        let obs = EngineObs::new(opts.trace_ring_cap, opts.tenant_topk);
        let families: Vec<(&'static str, u64, u64)> = per_family
            .iter()
            .map(|(&tag, &(n, sum_q, _))| (tag, n, ((sum_q + n / 2) / n.max(1)) * d as u64))
            .collect();
        obs.set_policy_gauges(&policy, &families);

        let mut cache = MergedCache::new(opts.cache_budget_bytes);
        cache.set_obs(CacheObs {
            hits: obs.registry.counter("serve_cache_hits_total"),
            misses: obs.registry.counter("serve_cache_misses_total"),
            inserts: obs.registry.counter("serve_cache_inserts_total"),
            evictions: obs.registry.counter("serve_cache_evictions_total"),
            used_bytes: obs.registry.gauge("serve_cache_used_bytes"),
            budget_bytes: obs.registry.gauge("serve_cache_budget_bytes"),
        });
        let mut batcher = MicroBatcher::new(opts.max_batch, opts.max_wait);
        batcher.set_obs(BatcherObs {
            queue_depth: obs.registry.gauge("serve_queue_depth"),
            batch_size: obs.registry.histogram("serve_batch_size"),
            queue_wait_ns: obs.registry.histogram("serve_queue_wait_ns"),
            deadline_miss: obs.registry.counter("serve_deadline_miss_total"),
        });

        let shared = Arc::new(Shared {
            registry,
            base_layers,
            d,
            policy,
            kernel: opts.kernel,
            spill,
            maint,
            cache: Mutex::new(cache),
            seen: Mutex::new(HashMap::new()),
            merging: Mutex::new(HashSet::new()),
            uncacheable: Mutex::new(HashSet::new()),
            factored: Mutex::new(HashMap::new()),
            batcher: Mutex::new(batcher),
            queue: WorkQueue::new(),
            obs,
            capture_slow_ns: opts.capture_slow_ns.unwrap_or(SERVE_P99_TARGET_NS),
            req_seq: AtomicU64::new(1),
            shutting_down: AtomicBool::new(false),
            epoch: Instant::now(),
            workers_alive: AtomicUsize::new(0),
            workers_spawned: opts.workers.max(1),
        });

        // Live re-registration: when the registry overwrites a live
        // tenant it calls back here (post-durability), and the engine
        // drops that tenant's memoized factorized operators and its
        // uncacheable pin — both were built from the old adapter. The
        // merged cache is left to the per-hit CRC recheck in
        // `serve_batch`, which also covers windows this hook can't (a
        // merge that was already in flight when the hook fired). Weak:
        // the registry must not keep the engine alive.
        let weak: Weak<Shared> = Arc::downgrade(&shared);
        shared.registry.set_update_hook(Box::new(move |tenant| {
            if let Some(sh) = weak.upgrade() {
                sh.factored.lock().unwrap().remove(&tenant);
                sh.uncacheable.lock().unwrap().remove(&tenant);
            }
        }));

        let workers = (0..opts.workers.max(1))
            .map(|w| {
                shared.workers_alive.fetch_add(1, Ordering::SeqCst);
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || {
                    let _alive = WorkerAlive(Arc::clone(&sh));
                    while let Some(batch) = sh.queue.pop() {
                        process_batch(&sh, batch, w as u32);
                    }
                })
            })
            .collect();

        let ticker = {
            let sh = Arc::clone(&shared);
            let poll = opts.poll_interval;
            std::thread::spawn(move || {
                while !sh.shutting_down.load(Ordering::SeqCst) {
                    std::thread::sleep(poll);
                    let expired = sh.batcher.lock().unwrap().flush_expired(Instant::now());
                    for b in expired {
                        sh.queue.push(b);
                    }
                }
            })
        };

        Ok(Engine {
            shared,
            workers,
            ticker: Some(ticker),
        })
    }

    /// Input/output dimension of the served model.
    pub fn input_dim(&self) -> usize {
        self.shared.d
    }

    /// The registry this engine serves from. Registration is
    /// concurrent-safe, so *new* tenants can join while traffic flows
    /// (`serve-bench --store` drives exactly that contention), and
    /// replacing a live tenant's adapter under traffic is safe end to
    /// end: the registry's update hook drops the tenant's factorized
    /// operators, and every merged-cache hit rechecks the params CRC
    /// captured at merge time against the registry
    /// (`serve_cache_stale_crc_total` counts the invalidations), so a
    /// stale model can be served at most until the registration is
    /// acknowledged — never after.
    pub fn registry(&self) -> &Registry {
        &self.shared.registry
    }

    pub fn policy(&self) -> Policy {
        self.shared.policy
    }

    /// Enqueue one request. The returned handle resolves once a worker has
    /// served the micro-batch the request lands in.
    pub fn submit(&self, tenant: TenantId, input: Vec<f32>) -> Result<Handle> {
        self.submit_traced(tenant, input, None, 0)
    }

    /// [`Engine::submit`] with a client deadline attached. A job whose
    /// deadline has passed by the time a worker picks up its batch is
    /// shed before compute: its handle fails with a message containing
    /// [`DEADLINE_EXCEEDED`] and `serve_deadline_shed_total` increments.
    pub fn submit_with_deadline(
        &self,
        tenant: TenantId,
        input: Vec<f32>,
        deadline: Option<Instant>,
    ) -> Result<Handle> {
        self.submit_traced(tenant, input, deadline, 0)
    }

    /// Mint a fresh request id from this engine's sequence — unique for
    /// the engine's lifetime, never 0 (0 marks unattributed traces). The
    /// front mints *before* submitting so even a rejected request's
    /// error body can echo its id.
    pub fn next_req_id(&self) -> u64 {
        self.shared.req_seq.fetch_add(1, Ordering::Relaxed)
    }

    /// [`Engine::submit_with_deadline`] carrying a caller-visible
    /// request id ([`Engine::next_req_id`] or client-supplied): the id
    /// rides the job into its [`Trace`], making the request findable via
    /// `/tracez?req=`.
    pub fn submit_traced(
        &self,
        tenant: TenantId,
        input: Vec<f32>,
        deadline: Option<Instant>,
        req_id: u64,
    ) -> Result<Handle> {
        anyhow::ensure!(
            !self.shared.shutting_down.load(Ordering::SeqCst),
            "engine is shutting down"
        );
        anyhow::ensure!(
            input.len() == self.shared.d,
            "input has {} floats, model dimension is {}",
            input.len(),
            self.shared.d
        );
        anyhow::ensure!(
            self.shared.registry.contains(tenant),
            "unknown tenant {tenant}"
        );
        let slot = Arc::new(Slot {
            result: Mutex::new(None),
            done: Condvar::new(),
        });
        let job = Job {
            input,
            submitted_at: Instant::now(),
            deadline,
            req_id,
            slot: Arc::clone(&slot),
        };
        let full = self
            .shared
            .batcher
            .lock()
            .unwrap()
            .push(tenant, job, Instant::now());
        if let Some(batch) = full {
            self.shared.queue.push(batch);
        }
        Ok(Handle { slot })
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.obs.metrics_snapshot()
    }

    /// Full dump of this engine's metric registry (`serve_*` taxonomy).
    pub fn obs_snapshot(&self) -> RegistrySnapshot {
        self.shared.obs.registry.snapshot()
    }

    /// The newest retained request traces, newest first.
    pub fn traces(&self) -> Vec<Trace> {
        self.shared.obs.traces.snapshot()
    }

    /// Slow/shed/error traces retained in the capture ring, newest first.
    pub fn captured(&self) -> Vec<Captured> {
        self.shared.obs.captures.snapshot()
    }

    /// Per-tenant heavy-hitter summary: at most
    /// [`EngineOpts::tenant_topk`] entries per dimension, whatever the
    /// fleet size.
    pub fn tenant_summary(&self) -> TenantSummary {
        self.shared.obs.tenants.summary()
    }

    /// Record an admission-plane rejection (429/503) against the
    /// tenant's heavy-hitter sketch. Lives here because the engine owns
    /// the sketches; the network front calls it when it bounces a
    /// request before submit.
    pub fn note_rejection(&self, tenant: TenantId) {
        self.shared.obs.tenants.record_rejection(tenant);
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.shared.cache.lock().unwrap().stats()
    }

    /// Whether the spill tier is mounted and engaged (a configured dir
    /// can still be declined by the load-vs-remerge break-even).
    pub fn spill_enabled(&self) -> bool {
        self.shared.spill.is_some()
    }

    pub fn spill_stats(&self) -> Option<SpillStats> {
        self.shared.spill.as_ref().map(|s| s.lock().unwrap().stats())
    }

    /// Background-maintenance counters so far (`None` when no thread
    /// was spawned — in-memory registry and no spill tier).
    pub fn maint_stats(&self) -> Option<MaintStats> {
        self.shared.maint.as_ref().map(|m| m.stats())
    }

    /// Block until the maintenance thread has drained every queued spill
    /// write and run one full compaction scan. Benches call this between
    /// phases so spilled models are on disk before a reload is measured;
    /// it is never needed for correctness (the factor tier is always
    /// durable before an ack).
    pub fn drain_maintenance(&self) {
        if let Some(m) = &self.shared.maint {
            m.drain();
        }
    }

    /// Point-in-time health probes — the `/healthz` payload: still
    /// accepting, worker pool alive, spill dir writable, store log tail
    /// acked.
    pub fn health(&self) -> HealthReport {
        health_of(&self.shared)
    }

    /// Scrape sources for the HTTP exporter
    /// ([`crate::obs::http::ObsServer::bind`]). Each closure captures the
    /// shared engine state, so the exporter thread is independent of
    /// `&self` lifetimes and can be shut down separately from the engine.
    /// The metrics source merges the process-wide registry when `--obs`
    /// is on, so one scrape sees the `serve_*`, `kernel_*` and `store_*`
    /// taxonomies together.
    pub fn obs_sources(&self) -> ObsSources {
        let m = Arc::clone(&self.shared);
        let t = Arc::clone(&self.shared);
        let c = Arc::clone(&self.shared);
        let ten = Arc::clone(&self.shared);
        let h = Arc::clone(&self.shared);
        ObsSources {
            metrics: Box::new(move || {
                let mut snap = m.obs.registry.snapshot();
                // Tenant gauges are synthesized per scrape from the
                // K-slot sketches — the live registry never grows a
                // per-tenant series, so cardinality stays ≤ K even for
                // a 10k-tenant fleet.
                snap.merge(&m.obs.tenants.summary().metrics());
                if crate::obs::enabled() {
                    snap.merge(&crate::obs::global().snapshot());
                }
                snap
            }),
            traces: Box::new(move || t.obs.traces.snapshot()),
            captured: Box::new(move || c.obs.captures.snapshot()),
            tenants: Box::new(move || ten.obs.tenants.summary()),
            health: Box::new(move || health_of(&h)),
            slo: SloTracker::new(SloSet::serve_default(), Vec::new()),
        }
    }

    fn shutdown(&mut self) {
        if self.shared.shutting_down.swap(true, Ordering::SeqCst) {
            return;
        }
        if let Some(t) = self.ticker.take() {
            let _ = t.join();
        }
        let flushed = self.shared.batcher.lock().unwrap().flush_all();
        for b in flushed {
            self.shared.queue.push(b);
        }
        self.shared.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Workers are quiet: drain queued spill writes, run a final
        // compaction scan, and hand inline auto-compaction back to the
        // log before the engine reports.
        if let Some(m) = &self.shared.maint {
            m.shutdown();
        }
    }

    /// Drain pending work, join workers, and return the final report.
    pub fn finish(mut self) -> EngineReport {
        self.shutdown();
        // Evaluate the whole-run SLO verdict over the final metric dump,
        // export it as gauges, then take the report's dump — so `obs`
        // carries the `slo_*` gauges a scraper would have seen.
        let wall = self.shared.epoch.elapsed();
        let slo = SloSet::serve_default().eval_total(&self.obs_snapshot(), wall);
        slo.export_gauges(&self.shared.obs.registry);
        let tenants = self.tenant_summary();
        // The report's metric dump carries the same synthesized tenant
        // gauges a live scrape would have seen.
        let mut obs = self.obs_snapshot();
        obs.merge(&tenants.metrics());
        EngineReport {
            metrics: self.metrics(),
            cache: self.cache_stats(),
            spill: self.spill_stats(),
            maint: self.maint_stats(),
            obs,
            slo,
            traces: self.traces(),
            tenants,
            captured: self.captured(),
        }
    }
}

/// `/healthz` probes, shared by [`Engine::health`] and the exporter's
/// health source (which outlives the `Engine` handle).
fn health_of(sh: &Shared) -> HealthReport {
    let mut checks = Vec::new();
    let accepting = !sh.shutting_down.load(Ordering::SeqCst);
    checks.push(HealthCheck {
        name: "accepting".to_string(),
        ok: accepting,
        detail: if accepting { "accepting submissions" } else { "shutting down" }.to_string(),
    });
    let alive = sh.workers_alive.load(Ordering::SeqCst);
    checks.push(HealthCheck {
        name: "workers".to_string(),
        ok: alive > 0,
        detail: format!("{alive}/{} alive", sh.workers_spawned),
    });
    let (ok, detail) = match &sh.spill {
        Some(tier) => {
            let ok = tier.lock().unwrap().probe_writable();
            (ok, if ok { "spill dir writable" } else { "spill dir NOT writable" }.to_string())
        }
        None => (true, "no spill tier mounted".to_string()),
    };
    checks.push(HealthCheck {
        name: "spill_dir".to_string(),
        ok,
        detail,
    });
    let (ok, detail) = match sh.registry.store_health() {
        Some(h) => (
            h.ok(),
            format!(
                "{} tenants, {:.0}% garbage, torn tail {} B, dir {}",
                h.tenants,
                h.garbage_ratio * 100.0,
                h.truncated_tail_bytes,
                if h.dir_writable { "writable" } else { "NOT writable" },
            ),
        ),
        None => (true, "in-memory registry (no store)".to_string()),
    };
    checks.push(HealthCheck {
        name: "store_log".to_string(),
        ok,
        detail,
    });
    HealthReport { checks }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---- batch serving ---------------------------------------------------------

fn activate(m: &mut Mat) {
    for v in m.data.iter_mut() {
        *v = v.tanh();
    }
}

fn forward_dense(ctx: &KernelCtx, layers: &[Mat], mut x: Mat) -> Mat {
    for w in layers {
        x = ctx.gemm(w, &x);
        activate(&mut x);
    }
    x
}

/// `W' X = Q (W X)` per layer without ever forming `W' = Q W` — the base
/// GEMM plus the family's prepared [`LayerOp`], both through the engine's
/// [`KernelCtx`]. Fully family-agnostic: the operator was planned by
/// [`crate::adapter::AdapterFamily::plan_layer`].
fn forward_factorized(sh: &Shared, ops: &[Option<Box<dyn LayerOp>>], mut x: Mat) -> Mat {
    let ctx = &sh.kernel;
    for ((_, w), q) in sh.base_layers.iter().zip(ops) {
        let base_y = ctx.gemm(w, &x);
        let y = match q {
            Some(op) => op.apply(base_y, &x, ctx),
            None => base_y,
        };
        x = y;
        activate(&mut x);
    }
    x
}

/// Per-tenant factorized operators, built once (the Cayley solves and
/// relayout planning are the expensive part) and reused across batches
/// until the tenant is promoted.
fn factored_ops(
    sh: &Shared,
    tenant: TenantId,
    entry: &AdapterEntry,
) -> Result<Arc<Vec<Option<Box<dyn LayerOp>>>>> {
    if let Some(ops) = sh.factored.lock().unwrap().get(&tenant) {
        return Ok(Arc::clone(ops));
    }
    let family = entry.desc.family();
    let ops: Vec<Option<Box<dyn LayerOp>>> = sh
        .base_layers
        .iter()
        .map(|(name, _)| family.plan_layer(entry.desc.cfg(), &entry.params, &entry.spec, name, sh.d))
        .collect::<Result<_>>()?;
    let ops = Arc::new(ops);
    // Racing builders both produce identical operators; keep whichever
    // landed first.
    Ok(Arc::clone(
        sh.factored
            .lock()
            .unwrap()
            .entry(tenant)
            .or_insert_with(|| Arc::clone(&ops)),
    ))
}

/// Cache a merged model; displaced models are handed to the maintenance
/// thread, which encodes and writes them to the spill tier off the
/// request path (the worker only pushes `(tenant, crc, Arc<flat>)` onto a
/// queue). A model too big for the whole budget pins its tenant to the
/// factorized path.
fn insert_cached(sh: &Shared, tenant: TenantId, model: CachedModel) {
    let outcome = sh.cache.lock().unwrap().insert(tenant, model);
    if outcome.inserted {
        // The factorized operators are dead weight once cached.
        sh.factored.lock().unwrap().remove(&tenant);
    } else {
        // Model alone exceeds the whole budget: never merge again,
        // keep serving this tenant factorized.
        sh.uncacheable.lock().unwrap().insert(tenant);
    }
    if sh.spill.is_none() {
        return;
    }
    let Some(maint) = &sh.maint else { return };
    for (t, m) in outcome.evicted {
        // The freshness tag is the CRC captured when the model was
        // merged — never a re-read of the registry, which could have a
        // newer adapter by now.
        maint.enqueue_spill(t, m.params_crc, Arc::clone(&m.flat));
    }
}

/// Load a spilled model with the read + CRC/staleness check *outside*
/// the tier mutex. The generation from `begin_get`
/// makes the invalidation safe against racing re-puts: a failed read of
/// an already-replaced entry must not drop the replacement.
fn spill_get(spill: &Mutex<SpillTier>, tenant: TenantId, expected_crc: u32) -> Option<Vec<f32>> {
    let (path, gen) = spill.lock().unwrap().begin_get(tenant)?;
    match spill::read_merged(&path, tenant, expected_crc) {
        Some(flat) => {
            spill.lock().unwrap().record_hit();
            Some(flat)
        }
        None => {
            // Corrupt, stale, or vanished — drop it (same-generation
            // entries only).
            spill.lock().unwrap().invalidate(tenant, gen);
            None
        }
    }
}

fn layer_mats(sh: &Shared, flat: &[f32]) -> Result<Vec<Mat>> {
    let spec = &sh.registry.base().spec;
    sh.base_layers
        .iter()
        .map(|(name, _)| Ok(Mat::from_f32(sh.d, sh.d, spec.view(flat, name)?)))
        .collect()
}

/// Serve one micro-batch. Returns the outputs, the path taken, and the
/// per-stage wall-time attribution ([`Stage::index`]-indexed; `Queue` and
/// `Reply` are filled in per request by [`process_batch`]).
fn serve_batch(
    sh: &Shared,
    tenant: TenantId,
    jobs: &[Job],
) -> Result<(Mat, ServePath, [u64; Stage::COUNT])> {
    let d = sh.d;
    let mut timer = StageTimer::new();
    let mut x = Mat::zeros(d, jobs.len());
    for (j, job) in jobs.iter().enumerate() {
        for i in 0..d {
            x[(i, j)] = job.input[i] as f64;
        }
    }

    // Hot path: merged weights already cached — but a hit is only
    // servable if the params CRC captured at merge time still matches
    // the registry's current adapter. A mismatch means the tenant was
    // re-registered live: the stale model is dropped (treated as a
    // miss, counted under `serve_cache_stale_crc_total`) and this batch
    // falls through to the cold path, which merges the new params.
    let cached = timer.time(Stage::Plan, || sh.cache.lock().unwrap().get(tenant));
    if let Some(model) = cached {
        if sh.registry.params_crc_of(tenant) == Some(model.params_crc) {
            let y = timer.time(Stage::Kernel, || forward_dense(&sh.kernel, &model.layers, x));
            return Ok((y, ServePath::CachedDense, timer.ns));
        }
        sh.obs.stale_crc.inc();
        sh.cache.lock().unwrap().remove(tenant);
    }

    let entry = sh
        .registry
        .get(tenant)
        .ok_or_else(|| anyhow!("tenant {tenant} disappeared from the registry"))?;
    sh.obs.note_family(tenant, entry.desc.family().tag());

    // Promotion: merge once the tenant has proven hot enough to amortize.
    let total_seen = {
        let mut seen = sh.seen.lock().unwrap();
        let e = seen.entry(tenant).or_insert(0);
        *e += jobs.len() as u64;
        *e
    };
    // A tenant past the threshold is merged by exactly one worker: claim
    // it in the `merging` set; concurrent batches that lose the claim are
    // served factorized while the merge is in flight. Tenants whose
    // merged model cannot fit the cache at all stay factorized.
    let promotable = total_seen >= sh.policy.promote_after
        && !sh.uncacheable.lock().unwrap().contains(&tenant);
    if promotable && sh.merging.lock().unwrap().insert(tenant) {
        // Double-check: a peer may have finished merging between our
        // cache miss and the claim. Bind the lookup so the cache mutex
        // is released before the forward pass. Same staleness guard as
        // the hit path — the peer may have merged a since-replaced
        // adapter.
        let recheck = timer.time(Stage::Plan, || sh.cache.lock().unwrap().get(tenant));
        if let Some(model) = recheck {
            if sh.registry.params_crc_of(tenant) == Some(model.params_crc) {
                sh.merging.lock().unwrap().remove(&tenant);
                let y = timer.time(Stage::Kernel, || forward_dense(&sh.kernel, &model.layers, x));
                return Ok((y, ServePath::CachedDense, timer.ns));
            }
            sh.obs.stale_crc.inc();
            sh.cache.lock().unwrap().remove(tenant);
        }
        // Spill tier first: an earlier eviction may have left this
        // tenant's merged weights one sequential read away (the tier is
        // only mounted when the cost model says the load beats the
        // re-merge). The params-CRC tag guarantees freshness.
        if let Some(spill) = &sh.spill {
            let crc = params_crc(&entry);
            let flat = timer.time(Stage::Spill, || spill_get(spill, tenant, crc));
            if let Some(flat) = flat {
                let loaded = timer.time(Stage::Spill, || {
                    layer_mats(sh, &flat).map(|layers| CachedModel {
                        flat: Arc::new(flat),
                        layers,
                        params_crc: crc,
                    })
                });
                sh.merging.lock().unwrap().remove(&tenant);
                let model = loaded?;
                let y = timer.time(Stage::Kernel, || forward_dense(&sh.kernel, &model.layers, x));
                sh.obs.spill_loads.inc();
                insert_cached(sh, tenant, model);
                return Ok((y, ServePath::SpillLoad, timer.ns));
            }
        }
        let merged = timer.time(Stage::Merge, || -> Result<CachedModel> {
            let flat = sh.registry.merge(tenant)?;
            let layers = layer_mats(sh, &flat)?;
            Ok(CachedModel {
                flat: Arc::new(flat),
                layers,
                // Tag with the params this very merge consumed.
                params_crc: params_crc(&entry),
            })
        });
        sh.merging.lock().unwrap().remove(&tenant);
        let model = merged?;
        let y = timer.time(Stage::Kernel, || forward_dense(&sh.kernel, &model.layers, x));
        sh.obs.merges.inc();
        insert_cached(sh, tenant, model);
        return Ok((y, ServePath::ColdMerge, timer.ns));
    }

    // Cold tail: factorized apply, no merge.
    let ops = timer.time(Stage::Plan, || factored_ops(sh, tenant, &entry))?;
    let y = timer.time(Stage::Kernel, || forward_factorized(sh, &ops, x));
    Ok((y, ServePath::Factorized, timer.ns))
}

/// Trace for a request that never produced an output (shed or errored):
/// all elapsed time is attributed to `Queue`, and the synthetic `path`
/// names the outcome so `/tracez` readers can tell it from a serve.
fn terminal_trace(
    sh: &Shared,
    job: &Job,
    tenant: TenantId,
    path: &'static str,
    worker: u32,
) -> Trace {
    let total_ns = job.submitted_at.elapsed().as_nanos() as u64;
    let mut stage_ns = [0u64; Stage::COUNT];
    stage_ns[Stage::Queue.index()] = total_ns;
    Trace {
        seq: 0, // stamped by the ring
        req_id: job.req_id,
        tenant,
        path,
        start_ns: job.submitted_at.saturating_duration_since(sh.epoch).as_nanos() as u64,
        worker,
        total_ns,
        stage_ns,
    }
}

fn process_batch(sh: &Shared, mut batch: Batch<Job>, worker: u32) {
    // Shed jobs whose client deadline has already passed: the caller is
    // gone, so computing their share of the batch is pure waste. They
    // fail fast with the DEADLINE_EXCEEDED marker (→ 504 at the front).
    let now = Instant::now();
    if batch.items.iter().any(|j| j.deadline.is_some_and(|d| d <= now)) {
        let (expired, live): (Vec<Job>, Vec<Job>) = batch
            .items
            .into_iter()
            .partition(|j| j.deadline.is_some_and(|d| d <= now));
        for job in expired {
            sh.obs.deadline_shed.inc();
            sh.obs.tenants.record_shed(batch.tenant);
            sh.obs.push_trace(
                terminal_trace(sh, &job, batch.tenant, "shed", worker),
                Some(CaptureReason::DeadlineShed),
            );
            fulfill(&job.slot, Err(DEADLINE_EXCEEDED.to_string()));
        }
        if live.is_empty() {
            return;
        }
        batch.items = live;
    }
    sh.obs.batches.inc();
    let service_start = Instant::now();
    // Contain panics from the linear algebra: a poisoned batch must fail
    // its handles (and leave the worker alive), never hang `wait()`.
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        serve_batch(sh, batch.tenant, &batch.items)
    }));
    match outcome {
        Ok(Ok((y, path, stage_ns))) => {
            let service = service_start.elapsed();
            let service_ns = service.as_nanos() as u64;
            let path_obs = &sh.obs.paths[path_index(path)];
            path_obs.service.record(service_ns);
            let family = sh.obs.family_of(batch.tenant);
            sh.obs.family_service(family).record(service_ns);
            let family_requests = sh.obs.family_requests(family);
            // Per-batch stages; zero means the stage was not entered.
            for (i, &ns) in stage_ns.iter().enumerate() {
                if ns > 0 {
                    sh.obs.stages[i].record(ns);
                }
            }
            for (j, job) in batch.items.into_iter().enumerate() {
                let output: Vec<f32> = (0..sh.d).map(|i| y[(i, j)] as f32).collect();
                let latency = job.submitted_at.elapsed();
                let total_ns = latency.as_nanos() as u64;
                // Per-request stages: queue is submit → service start,
                // reply is whatever the service window doesn't cover.
                let queue_ns = service_start.duration_since(job.submitted_at).as_nanos() as u64;
                let reply_ns = total_ns.saturating_sub(queue_ns).saturating_sub(service_ns);
                path_obs.count.inc();
                path_obs.latency.record(total_ns);
                family_requests.inc();
                sh.obs.stages[Stage::Queue.index()].record(queue_ns);
                sh.obs.stages[Stage::Reply.index()].record(reply_ns);
                let mut trace_ns = stage_ns;
                trace_ns[Stage::Queue.index()] = queue_ns;
                trace_ns[Stage::Reply.index()] = reply_ns;
                sh.obs.tenants.record_request(batch.tenant, total_ns);
                // A request at or past the slow bar is retained in the
                // capture ring, where the main ring's wrap can't evict it.
                let reason = (total_ns >= sh.capture_slow_ns).then_some(CaptureReason::Slow);
                sh.obs.push_trace(
                    Trace {
                        seq: 0, // stamped by the ring
                        req_id: job.req_id,
                        tenant: batch.tenant,
                        path: path.name(),
                        start_ns: job.submitted_at.saturating_duration_since(sh.epoch).as_nanos()
                            as u64,
                        worker,
                        total_ns,
                        stage_ns: trace_ns,
                    },
                    reason,
                );
                fulfill(
                    &job.slot,
                    Ok(ServeOutput {
                        output,
                        path,
                        latency,
                    }),
                );
            }
        }
        Ok(Err(e)) => {
            let msg = format!("serve failed for tenant {}: {e:#}", batch.tenant);
            for job in batch.items {
                sh.obs.push_trace(
                    terminal_trace(sh, &job, batch.tenant, "error", worker),
                    Some(CaptureReason::Error),
                );
                fulfill(&job.slot, Err(msg.clone()));
            }
        }
        Err(panic) => {
            let detail = crate::util::prop::panic_message(panic.as_ref());
            let msg = format!("serve panicked for tenant {}: {detail}", batch.tenant);
            for job in batch.items {
                sh.obs.push_trace(
                    terminal_trace(sh, &job, batch.tenant, "error", worker),
                    Some(CaptureReason::Error),
                );
                fulfill(&job.slot, Err(msg.clone()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::registry::synthetic;

    fn quick_opts() -> EngineOpts {
        EngineOpts {
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_micros(200),
            poll_interval: Duration::from_micros(200),
            cache_budget_bytes: 16 << 20,
            promote_after: Some(3),
            kernel: KernelCtx::default(),
            spill_dir: None,
            spill_budget_bytes: 16 << 20,
            trace_ring_cap: TRACE_RING_CAP,
            capture_slow_ns: None,
            tenant_topk: DEFAULT_TENANT_TOPK,
            maint_interval: Duration::from_millis(25),
        }
    }

    #[test]
    fn expired_deadline_jobs_are_shed_before_compute() {
        let reg = synthetic(2, 2, 8, 2, 11).unwrap();
        let engine = Engine::new(reg, quick_opts()).unwrap();
        let d = engine.input_dim();
        let input: Vec<f32> = vec![0.1; d];

        // A deadline of "now" is already expired by the time any worker
        // reaches the batch: the handle must fail with the marker, not
        // hang or return a result.
        let h = engine
            .submit_with_deadline(0, input.clone(), Some(Instant::now()))
            .unwrap();
        let err = h.wait().unwrap_err();
        assert!(err.to_string().contains(DEADLINE_EXCEEDED), "{err}");

        // A generous deadline serves normally.
        let far = Instant::now() + Duration::from_secs(60);
        let h = engine.submit_with_deadline(0, input.clone(), Some(far)).unwrap();
        assert_eq!(h.wait().unwrap().output.len(), d);

        let report = engine.finish();
        assert!(
            report.obs.counters["serve_deadline_shed_total"] >= 1,
            "shed counter must record the expired job"
        );
        assert_eq!(report.metrics.requests, 1, "shed jobs never count as served");
    }

    #[test]
    fn paths_progress_from_factorized_to_cached() {
        let reg = synthetic(4, 2, 8, 2, 7).unwrap();
        let engine = Engine::new(reg, quick_opts()).unwrap();
        let d = engine.input_dim();
        let input: Vec<f32> = (0..d).map(|i| (i as f32 / d as f32) - 0.4).collect();

        let mut paths = Vec::new();
        let mut outputs: Vec<Vec<f32>> = Vec::new();
        for _ in 0..6 {
            let h = engine.submit(0, input.clone()).unwrap();
            let out = h.wait().unwrap();
            assert_eq!(out.output.len(), d);
            assert!(out.output.iter().all(|x| x.is_finite()));
            paths.push(out.path);
            outputs.push(out.output);
        }
        // promote_after=3: requests 1-2 factorized, the batch containing
        // request 3 pays the merge, everything after hits the cache.
        assert_eq!(paths[0], ServePath::Factorized);
        assert_eq!(paths[1], ServePath::Factorized);
        assert_eq!(paths[2], ServePath::ColdMerge);
        assert_eq!(*paths.last().unwrap(), ServePath::CachedDense);
        // All paths compute the same function (merge rounds through f32).
        for out in &outputs[1..] {
            for (a, b) in out.iter().zip(outputs[0].iter()) {
                assert!((a - b).abs() < 1e-3, "path mismatch: {a} vs {b}");
            }
        }
        let report = engine.finish();
        assert_eq!(report.metrics.requests, 6);
        assert_eq!(report.metrics.merges, 1);
        assert!(report.cache.hits >= 1);
        assert!(report.metrics.cached.count >= 1);
        assert!(report.metrics.factorized.count == 2);
        assert_eq!(report.metrics.service_cold.count, 1, "one cold-merge batch");
        assert!(report.metrics.service_cached.count >= 1);
    }

    #[test]
    fn full_batches_flush_without_waiting_for_the_ticker() {
        let reg = synthetic(2, 1, 8, 2, 8).unwrap();
        let mut opts = quick_opts();
        opts.max_batch = 2;
        // Ticker effectively disabled: only size-triggered flushes.
        opts.max_wait = Duration::from_secs(60);
        opts.poll_interval = Duration::from_millis(1);
        let engine = Engine::new(reg, opts).unwrap();
        let d = engine.input_dim();
        let h1 = engine.submit(1, vec![0.1; d]).unwrap();
        let h2 = engine.submit(1, vec![0.2; d]).unwrap();
        assert!(h1.wait().is_ok());
        assert!(h2.wait().is_ok());
    }

    #[test]
    fn shutdown_flushes_partial_batches() {
        let reg = synthetic(2, 1, 8, 2, 9).unwrap();
        let mut opts = quick_opts();
        opts.max_wait = Duration::from_secs(60); // only finish() can flush
        let engine = Engine::new(reg, opts).unwrap();
        let d = engine.input_dim();
        let h = engine.submit(0, vec![0.3; d]).unwrap();
        let report = engine.finish();
        let out = h.wait().unwrap();
        assert_eq!(out.output.len(), d);
        assert_eq!(report.metrics.requests, 1);
    }

    #[test]
    fn submit_validates_tenant_and_dimension() {
        let reg = synthetic(2, 1, 8, 2, 10).unwrap();
        let engine = Engine::new(reg, quick_opts()).unwrap();
        assert!(engine.submit(99, vec![0.0; 8]).is_err(), "unknown tenant");
        assert!(engine.submit(0, vec![0.0; 5]).is_err(), "wrong dimension");
    }

    #[test]
    fn trace_ring_cap_is_configurable_and_traces_carry_worker_and_start() {
        let reg = synthetic(2, 1, 8, 2, 21).unwrap();
        let mut opts = quick_opts();
        opts.trace_ring_cap = 4;
        let engine = Engine::new(reg, opts).unwrap();
        let d = engine.input_dim();
        for _ in 0..12 {
            engine.submit(0, vec![0.2; d]).unwrap().wait().unwrap();
        }
        let report = engine.finish();
        assert_eq!(report.traces.len(), 4, "ring holds exactly the configured cap");
        let newest = &report.traces[0];
        assert!(newest.seq >= 8, "newest-first snapshot");
        assert!((newest.worker as usize) < 2, "worker index within the pool");
        // Sequential submissions: later seq ⇒ later start on the epoch
        // timeline (what the Chrome export plots).
        for w in report.traces.windows(2) {
            assert!(w[0].seq > w[1].seq);
            assert!(w[0].start_ns >= w[1].start_ns);
        }
    }

    #[test]
    fn slow_bar_at_zero_captures_every_request_under_its_ring_seq() {
        let reg = synthetic(2, 1, 8, 2, 41).unwrap();
        let mut opts = quick_opts();
        opts.capture_slow_ns = Some(0); // every serve is "slow"
        let engine = Engine::new(reg, opts).unwrap();
        let d = engine.input_dim();
        let req_id = engine.next_req_id();
        assert!(req_id >= 1, "id 0 is reserved for unattributed submits");
        engine.submit_traced(0, vec![0.1; d], None, req_id).unwrap().wait().unwrap();
        engine.submit(0, vec![0.2; d]).unwrap().wait().unwrap();

        let report = engine.finish();
        assert_eq!(report.captured.len(), 2);
        assert!(report.captured.iter().all(|c| c.reason == crate::obs::CaptureReason::Slow));
        // The captured copy carries the main-ring seq, so both rings
        // resolve a req= lookup to the same request.
        let cap = report.captured.iter().find(|c| c.trace.req_id == req_id).unwrap();
        let main = report.traces.iter().find(|t| t.req_id == req_id).unwrap();
        assert_eq!(cap.trace.seq, main.seq);
        assert_eq!(report.traces.iter().filter(|t| t.req_id == 0).count(), 1, "bare submit");
    }

    #[test]
    fn shed_requests_are_captured_with_their_reason() {
        let reg = synthetic(2, 2, 8, 2, 42).unwrap();
        let engine = Engine::new(reg, quick_opts()).unwrap();
        let d = engine.input_dim();
        let req_id = engine.next_req_id();
        let h = engine
            .submit_traced(0, vec![0.1; d], Some(Instant::now()), req_id)
            .unwrap();
        assert!(h.wait().unwrap_err().to_string().contains(DEADLINE_EXCEEDED));
        let report = engine.finish();
        let cap = report
            .captured
            .iter()
            .find(|c| c.trace.req_id == req_id)
            .expect("shed request must be captured");
        assert_eq!(cap.reason, crate::obs::CaptureReason::DeadlineShed);
        assert_eq!(cap.trace.path, "shed");
        let sheds = report.tenants.dims.iter().find(|d| d.name == "deadline_sheds").unwrap();
        assert_eq!(sheds.total, 1);
        assert_eq!(sheds.entries[0].tenant, 0);
    }

    #[test]
    fn report_tenant_summary_and_gauges_stay_within_k() {
        let reg = synthetic(4, 1, 8, 2, 43).unwrap();
        let mut opts = quick_opts();
        opts.tenant_topk = 2; // fewer slots than tenants
        let engine = Engine::new(reg, opts).unwrap();
        let d = engine.input_dim();
        for r in 0..12u64 {
            engine.submit(r % 4, vec![0.1; d]).unwrap().wait().unwrap();
        }
        engine.note_rejection(3);
        let report = engine.finish();
        let reqs = report.tenants.dims.iter().find(|d| d.name == "requests").unwrap();
        assert_eq!(reqs.total, 12, "sketch total counts every request exactly");
        assert!(reqs.entries.len() <= 2);
        let rej = report.tenants.dims.iter().find(|d| d.name == "admission_rejected").unwrap();
        assert_eq!((rej.total, rej.entries[0].tenant), (1, 3));
        // Synthesized gauges ride in the report's metric dump, ≤ K per dim.
        assert_eq!(report.obs.gauges["serve_tenant_topk_k"], 2);
        let topk_series = report
            .obs
            .gauges
            .keys()
            .filter(|k| k.starts_with("serve_tenant_topk_requests{"))
            .count();
        assert!(topk_series <= 2, "{topk_series} series for K=2");
    }

    #[test]
    fn health_is_ok_on_a_live_engine() {
        let reg = synthetic(2, 1, 8, 2, 22).unwrap();
        let engine = Engine::new(reg, quick_opts()).unwrap();
        let d = engine.input_dim();
        engine.submit(0, vec![0.1; d]).unwrap().wait().unwrap();
        let health = engine.health();
        assert!(health.ok(), "{:?}", health.checks);
        let names: Vec<&str> = health.checks.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(names, vec!["accepting", "workers", "spill_dir", "store_log"]);
        assert!(health.checks.iter().all(|c| !c.detail.is_empty()));
    }

    #[test]
    fn finish_report_carries_a_slo_verdict_and_gauges() {
        use crate::obs::slo::SloStatus;
        let reg = synthetic(2, 1, 8, 2, 23).unwrap();
        let engine = Engine::new(reg, quick_opts()).unwrap();
        let d = engine.input_dim();
        for _ in 0..4 {
            engine.submit(0, vec![0.1; d]).unwrap().wait().unwrap();
        }
        let report = engine.finish();
        assert_eq!(report.slo.objectives.len(), 3);
        let p99 =
            report.slo.objectives.iter().find(|o| o.name == "serve_p99_latency").unwrap();
        assert_ne!(p99.status, SloStatus::NoData, "requests flowed");
        // The verdict is exported into the final metric dump as gauges.
        assert!(report.obs.gauges.contains_key("slo_ok"));
        assert!(report.obs.gauges.contains_key("slo_status{slo=\"serve_deadline_miss\"}"));
    }

    #[test]
    fn every_adapter_kind_serves_and_matches_its_merged_model() {
        // Tenants 0,1 gsoft; 2 lora; 3 oft (synthetic kind mix).
        let reg = synthetic(4, 2, 8, 2, 11).unwrap();
        let mut opts = quick_opts();
        opts.promote_after = Some(2);
        let engine = Engine::new(reg, opts).unwrap();
        let d = engine.input_dim();
        let input: Vec<f32> = (0..d).map(|i| ((i * 7 % 5) as f32) * 0.1 - 0.2).collect();
        for tenant in 0..4u64 {
            let cold = engine.submit(tenant, input.clone()).unwrap().wait().unwrap();
            assert_eq!(cold.path, ServePath::Factorized);
            let merged = engine.submit(tenant, input.clone()).unwrap().wait().unwrap();
            assert_eq!(merged.path, ServePath::ColdMerge);
            for (a, b) in cold.output.iter().zip(merged.output.iter()) {
                assert!(
                    (a - b).abs() < 1e-3,
                    "tenant {tenant}: factorized {a} vs merged {b}"
                );
            }
        }
        let report = engine.finish();
        assert_eq!(report.metrics.merges, 4);
    }

    #[test]
    fn conv_gssoc_tenant_agrees_across_serving_paths() {
        use crate::serve::registry::synthetic_conv;
        let reg = synthetic_conv(2, 2, 4, 3, 2, 2, 3, 13).unwrap();
        let mut opts = quick_opts();
        opts.promote_after = Some(2);
        let engine = Engine::new(reg, opts).unwrap();
        let d = engine.input_dim();
        assert_eq!(d, 4 * 2 * 3);
        let input: Vec<f32> = (0..d).map(|i| ((i * 3 % 7) as f32) * 0.1 - 0.3).collect();
        let cold = engine.submit(0, input.clone()).unwrap().wait().unwrap();
        assert_eq!(cold.path, ServePath::Factorized);
        let merged = engine.submit(0, input.clone()).unwrap().wait().unwrap();
        assert_eq!(merged.path, ServePath::ColdMerge);
        for (a, b) in cold.output.iter().zip(merged.output.iter()) {
            assert!(
                (a - b).abs() < 1e-3,
                "conv factorized {a} vs merged {b} must agree"
            );
        }
        let hot = engine.submit(0, input).unwrap().wait().unwrap();
        assert_eq!(hot.path, ServePath::CachedDense);
        let report = engine.finish();
        assert_eq!(report.metrics.merges, 1);
    }

    #[test]
    fn conv_only_registry_derives_the_d_over_b_policy() {
        use crate::serve::registry::synthetic_conv;
        let reg = synthetic_conv(2, 1, 4, 3, 2, 2, 3, 14).unwrap(); // d = 24
        let mut opts = quick_opts();
        opts.promote_after = None;
        opts.max_batch = 8; // expected batch 4 → break-even after 24/4 = 6
        let engine = Engine::new(reg, opts).unwrap();
        assert_eq!(engine.policy().promote_after, 6);
        assert!(!engine.policy().q_dense, "conv merged support is banded, not dense");
        engine.finish();
    }

    #[test]
    fn mixed_fleet_policy_blends_per_family_thresholds() {
        use crate::coordinator::merge::AdapterKind;
        use crate::serve::registry::synthetic_layer_names;
        use crate::util::rng::Rng;
        // Tenant 0: GSOFT at block 2. Tenant 1: OFT at block 4 — a
        // different Theorem-2 model, so winner-takes-all from the first
        // sampled desc would ignore it.
        let d = 8usize;
        let reg = synthetic(1, 1, d, 2, 21).unwrap();
        let names = synthetic_layer_names(1);
        let desc = AdapterKind::Oft { block: 4 }.desc();
        let spec = Arc::new(
            desc.family()
                .synthetic_spec(desc.cfg(), &names, d, 4)
                .unwrap(),
        );
        let std = desc.family().synthetic_std(desc.cfg());
        let params = Rng::new(99).normal_vec(spec.size(), std);
        reg.register(
            1,
            AdapterEntry {
                desc,
                params: Arc::new(params),
                spec,
            },
        )
        .unwrap();

        let mut opts = quick_opts();
        opts.promote_after = None; // max_batch 4 → expected batch 2
        let engine = Engine::new(reg, opts).unwrap();
        let p = engine.policy();
        assert_eq!(p.promote_after, (d / 2) as u64);

        let g = gs_cost_model(d, 2);
        let o = gs_cost_model(d, 4);
        assert_ne!(g.q_col_flops, o.q_col_flops, "families must differ for this test");
        // Count-weighted blend (rounded mean × d), not either family alone.
        let want = (g.q_col_flops + o.q_col_flops).div_ceil(2) * d as u64;
        assert_eq!(p.merge_flops_per_layer, want);
        let n_dense = u64::from(g.q_dense) + u64::from(o.q_dense);
        assert_eq!(p.q_dense, 2 * n_dense >= 2);

        // The chosen thresholds and per-family shares are exported as
        // gauges through the engine registry.
        let snap = engine.obs_snapshot();
        assert_eq!(snap.gauges["serve_policy_promote_after"], p.promote_after);
        assert_eq!(snap.gauges["serve_policy_merge_flops_per_layer"], want);
        assert_eq!(snap.gauges["serve_policy_family_sampled{family=\"gsoft\"}"], 1);
        assert_eq!(snap.gauges["serve_policy_family_sampled{family=\"oft\"}"], 1);
        assert_eq!(
            snap.gauges["serve_policy_family_merge_flops{family=\"oft\"}"],
            o.q_col_flops * d as u64
        );
        engine.finish();
    }

    #[test]
    fn obs_counts_sum_to_requests_and_quantiles_are_monotone() {
        // Tenants 0,1 gsoft; 2 lora; 3 oft — three families, four serve
        // paths exercised across promotion.
        let reg = synthetic(4, 2, 8, 2, 31).unwrap();
        let engine = Engine::new(reg, quick_opts()).unwrap();
        let d = engine.input_dim();
        let input: Vec<f32> = (0..d).map(|i| (i as f32 * 0.7).sin() * 0.2).collect();
        let requests = 12u64;
        for r in 0..requests {
            let t = r % 4;
            engine.submit(t, input.clone()).unwrap().wait().unwrap();
        }
        let report = engine.finish();
        assert_eq!(report.metrics.requests, requests);
        let snap = &report.obs;

        // Per-path and per-family request counts both partition the total.
        let count = |name: &str| snap.counters.get(name).copied().unwrap_or(0);
        let by_path: u64 = PATHS
            .iter()
            .map(|p| count(&format!("serve_requests_total{{path=\"{}\"}}", p.name())))
            .sum();
        assert_eq!(by_path, requests, "per-path counts must sum to total");
        let by_family: u64 = snap
            .counters
            .iter()
            .filter(|(k, _)| k.starts_with("serve_requests_total{family="))
            .map(|(_, &v)| v)
            .sum();
        assert_eq!(by_family, requests, "per-family counts must sum to total");
        assert!(
            !snap.counters.contains_key("serve_requests_total{family=\"unknown\"}"),
            "every tenant's family is known after its cold serve"
        );

        // Every exported latency histogram has monotone quantiles.
        for (name, h) in &snap.histograms {
            let (p50, p95, p99) = (h.quantile(0.50), h.quantile(0.95), h.quantile(0.99));
            assert!(
                p50 <= p95 && p95 <= p99 && p99 <= h.max.max(p99),
                "{name}: p50={p50} p95={p95} p99={p99}"
            );
        }

        // Stage histograms: queue is per request, kernel per batch.
        assert_eq!(
            snap.histograms["serve_stage_ns{stage=\"queue\"}"].count(),
            requests
        );
        let kernel = &snap.histograms["serve_stage_ns{stage=\"kernel\"}"];
        assert!(kernel.count() >= 1 && kernel.count() <= report.metrics.batches);
        assert_eq!(
            snap.histograms["serve_stage_ns{stage=\"merge\"}"].count(),
            report.metrics.merges
        );

        // The trace ring retained every request (12 < TRACE_RING_CAP),
        // newest first.
        assert_eq!(report.traces.len() as u64, requests);
        assert!(report.traces.windows(2).all(|w| w[0].seq > w[1].seq));
        assert!(report.traces.iter().all(|t| t.total_ns > 0));
    }

    #[test]
    fn uncacheable_tenant_merges_once_then_stays_factorized() {
        let reg = synthetic(2, 2, 8, 2, 12).unwrap();
        let mut opts = quick_opts();
        opts.cache_budget_bytes = 64; // smaller than any merged model
        opts.promote_after = Some(2);
        let engine = Engine::new(reg, opts).unwrap();
        let d = engine.input_dim();
        let mut paths = Vec::new();
        for _ in 0..5 {
            let out = engine.submit(0, vec![0.1; d]).unwrap().wait().unwrap();
            paths.push(out.path);
        }
        assert_eq!(paths[1], ServePath::ColdMerge, "one merge attempt");
        assert!(
            paths[2..].iter().all(|p| *p == ServePath::Factorized),
            "oversized model must not re-merge every batch: {paths:?}"
        );
        let report = engine.finish();
        assert_eq!(report.metrics.merges, 1);
    }

    #[test]
    fn policy_cost_model_is_sane() {
        // Paper's worked example: d=1024, b=32 → Q dense at m=2; with
        // expected batches of 8 the break-even is d/8 = 128 requests.
        let p = Policy::from_cost_model(1024, 32, 8);
        assert!(p.q_dense);
        assert_eq!(p.promote_after, 128);
        // m=2 factors of nnz d·b each, applied to d columns.
        assert_eq!(p.merge_flops_per_layer, (2 * 1024 * 32 * 1024) as u64);
        // Tiny geometry still yields a positive threshold.
        let p = Policy::from_cost_model(8, 2, 16);
        assert!(p.promote_after >= 1);
    }

    #[test]
    fn spill_break_even_follows_the_cost_model() {
        // Paper geometry: per-layer merge is 2·32·1024² ≈ 67M flops; one
        // layer's share of the model is ~12·1024² ≈ 12.6MB ≈ 50M
        // flop-equivalents at 4 flops/byte — loading wins.
        let p = Policy::from_cost_model(1024, 32, 8);
        let model_bytes = 4 * (1024 * 1024 * 4) + 4 * (1024 * 1024 * 8); // 4 layers
        assert!(p.spill_pays_off(4, model_bytes));
        // Toy geometry (d=8, b=2): merging is a few hundred flops, far
        // cheaper than any disk read — the tier must decline.
        let p = Policy::from_cost_model(8, 2, 4);
        assert!(!p.spill_pays_off(2, 1600));
        // Fixed policies treat merges as arbitrarily expensive.
        assert!(Policy::fixed(1).spill_pays_off(1, usize::MAX / 8));
    }

    #[test]
    fn evicted_tenant_reloads_from_spill_instead_of_remerging() {
        use crate::util::tmp::unique_temp_dir;
        let spill_dir = unique_temp_dir("engine_spill");
        let reg = synthetic(2, 2, 8, 2, 15).unwrap();
        // Budget sized to hold exactly one merged model (f32 flat + two
        // 8×8 f64 mats), so the second tenant's promotion evicts the first.
        let one_model = reg.base().weights.len() * 4 + 2 * 8 * 8 * 8;
        let mut opts = quick_opts();
        opts.workers = 1; // deterministic path sequence
        opts.promote_after = Some(1);
        opts.cache_budget_bytes = one_model + one_model / 2;
        opts.spill_dir = Some(spill_dir.clone());
        let engine = Engine::new(reg, opts).unwrap();
        assert!(engine.spill_enabled(), "fixed policy always engages the tier");
        let d = engine.input_dim();
        let input: Vec<f32> = (0..d).map(|i| (i as f32).sin() * 0.3).collect();
        let serve = |t: u64| engine.submit(t, input.clone()).unwrap().wait().unwrap();

        let t0_merge = serve(0);
        assert_eq!(t0_merge.path, ServePath::ColdMerge);
        let t1_merge = serve(1); // evicts tenant 0 → enqueued for spilling
        assert_eq!(t1_merge.path, ServePath::ColdMerge);
        // The spill write happens on the maintenance thread, not the
        // request path — wait for it to land before asking for a reload.
        engine.drain_maintenance();
        let t0_back = serve(0); // must come back from disk, not a re-merge
        assert_eq!(t0_back.path, ServePath::SpillLoad);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(
            bits(&t0_back.output),
            bits(&t0_merge.output),
            "spill-loaded weights must serve bit-identically to the merge"
        );
        let t0_hot = serve(0); // the spill load re-cached it
        assert_eq!(t0_hot.path, ServePath::CachedDense);

        let report = engine.finish();
        assert_eq!(report.metrics.merges, 2, "exactly one merge per tenant");
        assert_eq!(report.metrics.spill_loads, 1);
        assert_eq!(report.metrics.spill.count, 1);
        let spill = report.spill.expect("tier engaged");
        assert_eq!(spill.hits, 1);
        assert!(spill.puts >= 1);
        // Every spill write was the maintenance thread's, not a worker's.
        let maint = report.maint.expect("maintainer ran");
        assert_eq!(maint.spill_writes, spill.puts, "all spill puts off-path");
        assert!(maint.off_path_ns > 0);
        let _ = std::fs::remove_dir_all(&spill_dir);
    }

    /// Clone `tenant`'s adapter from `donor` (same-family different
    /// params — `seed` varies the donor registry) for re-registration.
    fn entry_from(seed: u64, tenant: TenantId) -> AdapterEntry {
        let donor = synthetic(2, 2, 8, 2, seed).unwrap();
        donor.get(tenant).unwrap()
    }

    #[test]
    fn live_re_registration_invalidates_the_cached_model() {
        let reg = synthetic(2, 2, 8, 2, 16).unwrap();
        let mut opts = quick_opts();
        opts.workers = 1; // deterministic path sequence
        opts.promote_after = Some(1);
        let engine = Engine::new(reg, opts).unwrap();
        let d = engine.input_dim();
        let input: Vec<f32> = (0..d).map(|i| (i as f32).cos() * 0.2).collect();
        let serve = || engine.submit(0, input.clone()).unwrap().wait().unwrap();

        assert_eq!(serve().path, ServePath::ColdMerge);
        let old_hot = serve();
        assert_eq!(old_hot.path, ServePath::CachedDense);

        // Replace tenant 0's adapter while the engine is live. The next
        // request *hits* the cache, detects the stale CRC, and re-merges
        // the new params instead of serving the old model.
        let new_entry = entry_from(61, 0);
        engine.registry().register(0, new_entry).unwrap();
        let post = serve();
        assert_eq!(post.path, ServePath::ColdMerge, "stale hit must demote to a merge");
        assert_ne!(
            post.output, old_hot.output,
            "post-update outputs must reflect the new adapter"
        );
        // And the re-merged model serves hot and bit-identically after.
        let post_hot = serve();
        assert_eq!(post_hot.path, ServePath::CachedDense);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&post_hot.output), bits(&post.output));

        let report = engine.finish();
        assert_eq!(report.obs.counters["serve_cache_stale_crc_total"], 1);
        assert_eq!(report.metrics.merges, 2);
    }

    #[test]
    fn re_registration_rebuilds_factorized_operators() {
        // A cold (never-promoted) tenant's memoized LayerOps were built
        // from the old adapter — the update hook must drop them so the
        // very next factorized serve uses the new params.
        let reg = synthetic(2, 2, 8, 2, 17).unwrap();
        let mut opts = quick_opts();
        opts.workers = 1;
        opts.promote_after = Some(100); // stay factorized throughout
        let engine = Engine::new(reg, opts).unwrap();
        let d = engine.input_dim();
        let input: Vec<f32> = (0..d).map(|i| ((i % 3) as f32) * 0.1 - 0.1).collect();
        let serve = || engine.submit(0, input.clone()).unwrap().wait().unwrap();

        let before = serve();
        assert_eq!(before.path, ServePath::Factorized);
        engine.registry().register(0, entry_from(62, 0)).unwrap();
        let after = serve();
        assert_eq!(after.path, ServePath::Factorized);
        assert_ne!(
            after.output, before.output,
            "factorized serve must use the re-registered adapter"
        );
        engine.finish();
    }
}
