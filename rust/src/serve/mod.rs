//! Multi-tenant GSOFT adapter serving (DESIGN.md §6) — the paper's
//! headline use-case at system scale: thousands of cheap Group-and-Shuffle
//! orthogonal adapters sharing one frozen base model, served under heavy
//! mixed-tenant traffic.
//!
//! - [`registry`] — adapters keyed by tenant id over a shared base
//!   [`crate::coordinator::FlatSpec`] buffer
//! - [`cache`] — byte-budgeted LRU of merged (`W' = Q W`) weights
//! - [`batcher`] — size/deadline micro-batching of same-tenant requests
//! - [`engine`] — worker engine on [`crate::util::pool`]:
//!   `submit(tenant, input) -> Handle`, three serving paths
//!   (cached dense / cold merge / factorized GS apply), and
//!   latency/throughput/hit-rate metrics
//!
//! Benchmarked by `gsoft serve-bench` and `rust/benches/serve.rs` with a
//! Zipf tenant-popularity trace from [`crate::data::zipf`].

pub mod batcher;
pub mod cache;
pub mod engine;
pub mod registry;

pub use batcher::{Batch, MicroBatcher};
pub use cache::{CacheStats, CachedModel, MergedCache};
pub use engine::{
    Engine, EngineOpts, EngineReport, Handle, MetricsSnapshot, PathStats, Policy, ServeOutput,
    ServePath,
};
pub use registry::{synthetic, synthetic_conv, AdapterEntry, BaseModel, Registry, TenantId};
