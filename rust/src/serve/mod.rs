//! Multi-tenant GSOFT adapter serving (DESIGN.md §6) — the paper's
//! headline use-case at system scale: thousands of cheap Group-and-Shuffle
//! orthogonal adapters sharing one frozen base model, served under heavy
//! mixed-tenant traffic.
//!
//! - [`registry`] — adapters keyed by tenant id over a shared base
//!   [`crate::coordinator::FlatSpec`] buffer; in-memory or backed by the
//!   durable [`crate::store::AdapterStore`] with lazy hydration and
//!   whole-fleet snapshot/restore
//! - [`cache`] — byte-budgeted LRU of merged (`W' = Q W`) weights,
//!   handing evicted models back for the disk spill tier
//! - [`batcher`] — size/deadline micro-batching of same-tenant requests
//! - [`engine`] — worker engine on [`crate::util::pool`]:
//!   `submit(tenant, input) -> Handle`, four serving paths
//!   (cached dense / cold merge / factorized GS apply / spill load), fully
//!   instrumented through [`crate::obs`]: per-path/per-family request
//!   counters, stage-latency histograms, a ring of recent request traces
//!   ([`engine::TRACE_RING_CAP`]), per-tenant heavy-hitter sketches
//!   (bounded at [`crate::obs::DEFAULT_TENANT_TOPK`] entries per
//!   dimension), and a capture ring of slow/shed/errored requests with
//!   request-id correlation (`submit_traced`, DESIGN.md §12)
//! - [`admission`] — request gating for the network front: per-tenant
//!   token buckets, a global in-flight cap, deadline accounting
//! - [`front`] — `gsoft serve --listen`: HTTP/1.1 request front over the
//!   engine ([`crate::util::net`] listener), JSON in/out, obs endpoints
//!   on the same socket (DESIGN.md §11)
//!
//! Benchmarked by `gsoft serve-bench` and `rust/benches/serve.rs` with a
//! Zipf tenant-popularity trace from [`crate::data::zipf`]; the
//! store-backed tiers by `gsoft store-bench` and `rust/benches/store.rs`.

pub mod admission;
pub mod batcher;
pub mod cache;
pub mod engine;
pub mod front;
pub mod registry;

pub use admission::{Admission, AdmissionCfg, InflightGuard, Rejection};
pub use batcher::{Batch, BatcherObs, MicroBatcher};
pub use cache::{CacheObs, CacheStats, CachedModel, Inserted, MergedCache};
pub use engine::{
    Engine, EngineOpts, EngineReport, Handle, MetricsSnapshot, PathStats, Policy, ServeOutput,
    ServePath, DEADLINE_EXCEEDED, SPILL_FLOPS_PER_BYTE, TRACE_RING_CAP,
};
pub use front::{FrontOpts, ServeFront};
pub use registry::{
    synthetic, synthetic_conv, synthetic_of, AdapterEntry, BaseModel, Registry, TenantId,
};
