//! Admission control for the network serving front (DESIGN.md §11).
//!
//! Three gates run before a request touches the engine, each with its
//! own rejection label on `serve_admission_rejected_total{reason}`:
//!
//! - **Per-tenant token bucket** (`reason="rate"` → 429): each tenant
//!   accrues [`AdmissionCfg::rate_per_sec`] tokens per second up to
//!   [`AdmissionCfg::burst`]; one query spends one token. A new tenant
//!   starts with a full bucket, so burst-then-sustain traffic is
//!   admitted up to the configured shape and an aggressive tenant
//!   cannot starve the others.
//! - **Global in-flight cap** (`reason="inflight"` → 503): at most
//!   [`AdmissionCfg::max_inflight`] admitted queries may be between
//!   admission and response at once — a memory bound independent of any
//!   single tenant's rate. Admission returns an RAII
//!   [`InflightGuard`]; dropping it (response written, or the
//!   connection handler unwinding) releases the slot.
//! - **Deadline** (`reason="deadline"` → 504): requests carrying a
//!   `deadline_ms` that expires before execution are counted here by
//!   the front, whether they expire at admission or are shed later in
//!   the batch pipeline.
//!
//! Time is passed in explicitly (`now: Instant`) so the bucket
//! arithmetic is deterministic under test.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::obs::{Counter, Gauge, MetricsRegistry};
use crate::serve::TenantId;

/// Token-bucket and in-flight parameters for [`Admission`].
#[derive(Clone, Copy, Debug)]
pub struct AdmissionCfg {
    /// Steady-state queries per second each tenant may issue.
    pub rate_per_sec: f64,
    /// Bucket capacity: how far above the steady rate a tenant may
    /// burst after idling.
    pub burst: f64,
    /// Global cap on admitted-but-unanswered queries.
    pub max_inflight: usize,
}

impl Default for AdmissionCfg {
    fn default() -> AdmissionCfg {
        AdmissionCfg {
            rate_per_sec: 50.0,
            burst: 100.0,
            max_inflight: 256,
        }
    }
}

/// Why a request was refused; maps to a status code and a metric label.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rejection {
    /// Tenant token bucket empty → 429.
    Rate,
    /// Global in-flight cap reached → 503.
    Inflight,
    /// Client deadline expired before execution → 504.
    Deadline,
}

impl Rejection {
    pub fn reason(self) -> &'static str {
        match self {
            Rejection::Rate => "rate",
            Rejection::Inflight => "inflight",
            Rejection::Deadline => "deadline",
        }
    }

    pub fn status(self) -> u16 {
        match self {
            Rejection::Rate => 429,
            Rejection::Inflight => 503,
            Rejection::Deadline => 504,
        }
    }
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

/// The admission gate. Shared by every front worker (`Arc`).
pub struct Admission {
    cfg: AdmissionCfg,
    buckets: Mutex<HashMap<TenantId, Bucket>>,
    inflight: Arc<AtomicUsize>,
    inflight_gauge: Arc<Gauge>,
    rejected: [Arc<Counter>; 3],
}

impl Admission {
    /// Build a gate whose rejection counters and in-flight gauge live in
    /// `registry` (the front's own registry, merged into `/metrics`).
    pub fn new(cfg: AdmissionCfg, registry: &MetricsRegistry) -> Admission {
        let rejected = [Rejection::Rate, Rejection::Inflight, Rejection::Deadline].map(|r| {
            registry.counter(&format!(
                "serve_admission_rejected_total{{reason=\"{}\"}}",
                r.reason()
            ))
        });
        Admission {
            cfg,
            buckets: Mutex::new(HashMap::new()),
            inflight: Arc::new(AtomicUsize::new(0)),
            inflight_gauge: registry.gauge("serve_front_inflight"),
            rejected,
        }
    }

    pub fn cfg(&self) -> AdmissionCfg {
        self.cfg
    }

    /// Try to admit one query for `tenant` at time `now`. On success the
    /// returned guard holds an in-flight slot until dropped; on
    /// rejection the matching counter has been incremented.
    pub fn admit(&self, tenant: TenantId, now: Instant) -> Result<InflightGuard, Rejection> {
        if !self.take_token(tenant, now) {
            return Err(self.reject(Rejection::Rate));
        }
        // Reserve optimistically; back out if the cap was hit. The
        // token already spent stays spent — a rejected-at-capacity
        // request still consumed front work.
        let prev = self.inflight.fetch_add(1, Ordering::SeqCst);
        if prev >= self.cfg.max_inflight {
            self.inflight.fetch_sub(1, Ordering::SeqCst);
            return Err(self.reject(Rejection::Inflight));
        }
        self.inflight_gauge.set((prev + 1) as u64);
        Ok(InflightGuard {
            inflight: Arc::clone(&self.inflight),
            gauge: Arc::clone(&self.inflight_gauge),
        })
    }

    /// Count a deadline rejection (expired at admission or shed in the
    /// batcher) and hand the caller its status code.
    pub fn reject(&self, r: Rejection) -> Rejection {
        let idx = match r {
            Rejection::Rate => 0,
            Rejection::Inflight => 1,
            Rejection::Deadline => 2,
        };
        self.rejected[idx].inc();
        r
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    fn take_token(&self, tenant: TenantId, now: Instant) -> bool {
        let mut buckets = self.buckets.lock().unwrap();
        let b = buckets.entry(tenant).or_insert(Bucket {
            tokens: self.cfg.burst,
            last: now,
        });
        let dt = now.saturating_duration_since(b.last).as_secs_f64();
        b.last = now;
        b.tokens = (b.tokens + dt * self.cfg.rate_per_sec).min(self.cfg.burst);
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// RAII in-flight slot: dropping it releases the global cap.
pub struct InflightGuard {
    inflight: Arc<AtomicUsize>,
    gauge: Arc<Gauge>,
}

impl Drop for InflightGuard {
    fn drop(&mut self) {
        let prev = self.inflight.fetch_sub(1, Ordering::SeqCst);
        self.gauge.set(prev.saturating_sub(1) as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn gate(rate: f64, burst: f64, max_inflight: usize) -> (Admission, MetricsRegistry) {
        let reg = MetricsRegistry::new();
        let adm = Admission::new(
            AdmissionCfg {
                rate_per_sec: rate,
                burst,
                max_inflight,
            },
            &reg,
        );
        (adm, reg)
    }

    fn rejected(reg: &MetricsRegistry, reason: &str) -> u64 {
        reg.counter(&format!("serve_admission_rejected_total{{reason=\"{reason}\"}}")).get()
    }

    #[test]
    fn bucket_admits_burst_then_refills_at_the_configured_rate() {
        let (adm, reg) = gate(10.0, 3.0, 100);
        let t0 = Instant::now();
        // Full bucket: exactly `burst` admissions at one instant.
        for _ in 0..3 {
            assert!(adm.admit(7, t0).is_ok());
        }
        assert_eq!(adm.admit(7, t0).unwrap_err(), Rejection::Rate);
        assert_eq!(rejected(&reg, "rate"), 1);

        // 100 ms at 10 tokens/s = exactly one fresh token.
        let t1 = t0 + Duration::from_millis(100);
        assert!(adm.admit(7, t1).is_ok());
        assert_eq!(adm.admit(7, t1).unwrap_err(), Rejection::Rate);

        // A long idle refills to burst, not beyond.
        let t2 = t1 + Duration::from_secs(60);
        for _ in 0..3 {
            assert!(adm.admit(7, t2).is_ok());
        }
        assert_eq!(adm.admit(7, t2).unwrap_err(), Rejection::Rate);
        assert_eq!(rejected(&reg, "rate"), 3);
    }

    #[test]
    fn buckets_are_per_tenant() {
        let (adm, _reg) = gate(1.0, 1.0, 100);
        let t0 = Instant::now();
        assert!(adm.admit(1, t0).is_ok());
        assert!(adm.admit(1, t0).is_err(), "tenant 1 spent its bucket");
        assert!(adm.admit(2, t0).is_ok(), "tenant 2 has its own bucket");
    }

    #[test]
    fn inflight_cap_is_global_and_released_by_guard_drop() {
        let (adm, reg) = gate(1000.0, 1000.0, 2);
        let t0 = Instant::now();
        let g1 = adm.admit(1, t0).unwrap();
        let _g2 = adm.admit(2, t0).unwrap();
        assert_eq!(adm.inflight(), 2);
        assert_eq!(adm.admit(3, t0).unwrap_err(), Rejection::Inflight);
        assert_eq!(rejected(&reg, "inflight"), 1);
        assert_eq!(adm.inflight(), 2, "rejected request does not leak a slot");
        drop(g1);
        assert_eq!(adm.inflight(), 1);
        assert!(adm.admit(3, t0).is_ok(), "slot freed by the guard drop");
        assert_eq!(reg.gauge("serve_front_inflight").get(), 2);
    }

    #[test]
    fn deadline_rejections_are_counted() {
        let (adm, reg) = gate(1.0, 1.0, 1);
        assert_eq!(adm.reject(Rejection::Deadline), Rejection::Deadline);
        adm.reject(Rejection::Deadline);
        assert_eq!(rejected(&reg, "deadline"), 2);
        assert_eq!(Rejection::Deadline.status(), 504);
        assert_eq!(Rejection::Rate.status(), 429);
        assert_eq!(Rejection::Inflight.status(), 503);
    }
}
