//! LRU cache of merged per-tenant weights with byte-budget eviction.
//!
//! Merging `Q` into `W` (§6.1) makes a tenant's forward pass exactly as
//! cheap as the frozen base model — but costs a full merge (Cayley solves
//! + structured `Q·W` products) and a dense copy of the base buffer. Hot
//! tenants should pay that once; cold tenants should not evict them. This
//! cache gives the serving engine that policy knob: a strict LRU over
//! merged models, bounded by bytes instead of entry count (all tenants
//! share one base, so every entry costs the same, but the byte budget is
//! the operational unit a deployment reasons in).

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use crate::linalg::Mat;
use crate::obs::{Counter, Gauge};
use crate::serve::registry::TenantId;

/// Pre-resolved cache metrics (`serve_cache_*`). Installed by the engine
/// via [`MergedCache::set_obs`]; mirrors [`CacheStats`] exactly (the
/// stats struct stays the source of truth the model-based property test
/// pins down).
pub struct CacheObs {
    pub hits: Arc<Counter>,
    pub misses: Arc<Counter>,
    pub inserts: Arc<Counter>,
    pub evictions: Arc<Counter>,
    pub used_bytes: Arc<Gauge>,
    pub budget_bytes: Arc<Gauge>,
}

/// A merged tenant model, ready for the dense hot path: the flat merged
/// buffer (bit-identical to what a cold `merge` returns — tested) plus the
/// per-layer dense matrices the GEMM path multiplies by.
pub struct CachedModel {
    pub flat: Arc<Vec<f32>>,
    pub layers: Vec<Mat>,
    /// CRC32 of the adapter params this model was merged from, captured
    /// at merge time — the spill tier's freshness tag. Re-reading the
    /// registry at eviction time instead would tag old merged bytes with
    /// a *newer* adapter's CRC and defeat the staleness guard.
    pub params_crc: u32,
}

impl CachedModel {
    /// Resident bytes: the f32 flat buffer + f64 layer matrices.
    pub fn bytes(&self) -> usize {
        self.flat.len() * 4
            + self
                .layers
                .iter()
                .map(|m| m.data.len() * 8)
                .sum::<usize>()
    }
}

/// Outcome of [`MergedCache::insert`]: whether the model was cached, and
/// which tenants were displaced (oldest first) to make room.
pub struct Inserted {
    pub inserted: bool,
    pub evicted: Vec<(TenantId, Arc<CachedModel>)>,
}

/// Cache counters (monotonic; snapshot with [`MergedCache::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Slot {
    model: Arc<CachedModel>,
    bytes: usize,
    /// Tick of the most recent touch; stale queue entries are skipped.
    tick: u64,
}

/// Strict-LRU, byte-budgeted cache. Recency is tracked with a lazily
/// compacted queue of `(tick, tenant)` touches — O(1) amortized per
/// operation, no linked-list unsafe code.
pub struct MergedCache {
    budget_bytes: usize,
    used_bytes: usize,
    slots: HashMap<TenantId, Slot>,
    recency: VecDeque<(u64, TenantId)>,
    clock: u64,
    stats: CacheStats,
    obs: Option<CacheObs>,
}

impl MergedCache {
    pub fn new(budget_bytes: usize) -> MergedCache {
        MergedCache {
            budget_bytes,
            used_bytes: 0,
            slots: HashMap::new(),
            recency: VecDeque::new(),
            clock: 0,
            stats: CacheStats::default(),
            obs: None,
        }
    }

    /// Install metric handles mirroring the [`CacheStats`] counters plus
    /// byte gauges. The budget gauge is set once here (it never changes).
    pub fn set_obs(&mut self, obs: CacheObs) {
        obs.budget_bytes.set(self.budget_bytes as u64);
        obs.used_bytes.set(self.used_bytes as u64);
        self.obs = Some(obs);
    }

    fn touch(&mut self, tenant: TenantId) {
        self.clock += 1;
        let tick = self.clock;
        if let Some(slot) = self.slots.get_mut(&tenant) {
            slot.tick = tick;
        }
        self.recency.push_back((tick, tenant));
        // Bound the queue: compact once stale entries dominate.
        if self.recency.len() > 4 * self.slots.len().max(8) {
            let slots = &self.slots;
            self.recency
                .retain(|&(t, id)| slots.get(&id).is_some_and(|s| s.tick == t));
        }
    }

    /// Look up a tenant's merged model, counting a hit or miss and
    /// refreshing recency on hit.
    pub fn get(&mut self, tenant: TenantId) -> Option<Arc<CachedModel>> {
        if let Some(model) = self.slots.get(&tenant).map(|s| Arc::clone(&s.model)) {
            self.stats.hits += 1;
            if let Some(obs) = &self.obs {
                obs.hits.inc();
            }
            self.touch(tenant);
            Some(model)
        } else {
            self.stats.misses += 1;
            if let Some(obs) = &self.obs {
                obs.misses.inc();
            }
            None
        }
    }

    /// Peek without touching recency or counters (for tests/metrics).
    pub fn peek(&self, tenant: TenantId) -> Option<Arc<CachedModel>> {
        self.slots.get(&tenant).map(|s| Arc::clone(&s.model))
    }

    /// Insert a merged model, evicting least-recently-used tenants until
    /// it fits. `inserted` is `false` (and nothing is cached) when the
    /// model alone exceeds the whole budget; `evicted` hands the displaced
    /// models back to the caller in LRU order, so a spill tier
    /// ([`crate::store::SpillTier`]) can absorb them instead of the floor
    /// — the cache itself stays pure bookkeeping, no I/O under its lock.
    pub fn insert(&mut self, tenant: TenantId, model: CachedModel) -> Inserted {
        let bytes = model.bytes();
        if bytes > self.budget_bytes {
            return Inserted {
                inserted: false,
                evicted: Vec::new(),
            };
        }
        if let Some(old) = self.slots.remove(&tenant) {
            // Replacement, not eviction: the caller's new version
            // supersedes the old model, which must not be spilled.
            self.used_bytes -= old.bytes;
        }
        let mut evicted = Vec::new();
        while self.used_bytes + bytes > self.budget_bytes {
            match self.evict_lru() {
                Some(pair) => evicted.push(pair),
                None => break,
            }
        }
        self.used_bytes += bytes;
        self.slots.insert(
            tenant,
            Slot {
                model: Arc::new(model),
                bytes,
                tick: self.clock,
            },
        );
        self.touch(tenant);
        self.stats.inserts += 1;
        if let Some(obs) = &self.obs {
            obs.inserts.inc();
            obs.used_bytes.set(self.used_bytes as u64);
        }
        Inserted {
            inserted: true,
            evicted,
        }
    }

    /// Drop a tenant's model outright (live re-registration made it
    /// stale), returning it. Not an LRU *eviction*: the model is invalid,
    /// so it must not be spilled and is not counted in
    /// [`CacheStats::evictions`] — the caller accounts for invalidations.
    pub fn remove(&mut self, tenant: TenantId) -> Option<Arc<CachedModel>> {
        let slot = self.slots.remove(&tenant)?;
        self.used_bytes -= slot.bytes;
        // Stale recency-queue entries for this tenant are skipped by
        // `evict_lru`'s liveness check; no need to scrub them here.
        if let Some(obs) = &self.obs {
            obs.used_bytes.set(self.used_bytes as u64);
        }
        Some(slot.model)
    }

    /// Evict the least-recently-used entry, returning it (`None` if empty).
    fn evict_lru(&mut self) -> Option<(TenantId, Arc<CachedModel>)> {
        while let Some((tick, tenant)) = self.recency.pop_front() {
            let live = self
                .slots
                .get(&tenant)
                .is_some_and(|s| s.tick == tick);
            if live {
                let slot = self.slots.remove(&tenant).unwrap();
                self.used_bytes -= slot.bytes;
                self.stats.evictions += 1;
                if let Some(obs) = &self.obs {
                    obs.evictions.inc();
                }
                return Some((tenant, slot.model));
            }
        }
        None
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    /// One cache op: which tenant, what to do, and (for inserts) a size
    /// class — 0: small (a quarter of the budget fits four), 1: half the
    /// budget, 2: oversized (must be refused).
    #[derive(Debug, Clone)]
    struct CacheCase {
        ops: Vec<(TenantId, u8, u8)>,
    }

    fn shrink_cache(c: &CacheCase) -> Vec<CacheCase> {
        let mut out = Vec::new();
        if !c.ops.is_empty() {
            let half = c.ops.len() / 2;
            out.push(CacheCase {
                ops: c.ops[..half].to_vec(),
            });
            out.push(CacheCase {
                ops: c.ops[half..].to_vec(),
            });
            let mut tail = c.ops.clone();
            tail.remove(0);
            out.push(CacheCase { ops: tail });
        }
        out
    }

    #[test]
    fn random_ops_agree_with_a_reference_lru_model() {
        // Model-based property: a straight-line Vec LRU (front = oldest)
        // replayed alongside the real cache. After every op the live key
        // set, byte accounting, and hit/miss/insert/eviction counters must
        // match; the byte budget must never be exceeded.
        const BUDGET: usize = 1 << 10; // 1 KiB = 256 f32s
        let floats_of = |size_class: u8| match size_class {
            0 => BUDGET / 4 / 4,     // 4 of these fit
            1 => BUDGET / 2 / 4,     // 2 of these fit
            _ => BUDGET / 4 + 1,     // bytes > budget: refused
        };
        prop::check_shrunk(
            "MergedCache == reference LRU model",
            701,
            48,
            |rng| CacheCase {
                ops: (0..prop::size_in(rng, 1, 40))
                    .map(|_| {
                        (
                            rng.below(5) as TenantId,
                            rng.below(3) as u8, // 0: get, 1: insert, 2: peek
                            rng.below(3) as u8, // size class
                        )
                    })
                    .collect(),
            },
            shrink_cache,
            |c| {
                let mut cache = MergedCache::new(BUDGET);
                // (tenant, bytes), most-recently-used last.
                let mut lru: Vec<(TenantId, usize)> = Vec::new();
                let mut want = CacheStats::default();
                for &(tenant, op, size_class) in &c.ops {
                    match op {
                        0 => {
                            let hit = cache.get(tenant).is_some();
                            let pos = lru.iter().position(|&(t, _)| t == tenant);
                            assert_eq!(hit, pos.is_some(), "get({tenant}) hit/miss");
                            if let Some(p) = pos {
                                let e = lru.remove(p);
                                lru.push(e); // refresh recency
                                want.hits += 1;
                            } else {
                                want.misses += 1;
                            }
                        }
                        1 => {
                            let floats = floats_of(size_class);
                            let bytes = floats * 4;
                            let outcome = cache.insert(tenant, model(floats));
                            if bytes > BUDGET {
                                assert!(!outcome.inserted, "oversized model must be refused");
                                assert!(outcome.evicted.is_empty(), "refusal must not evict");
                                continue;
                            }
                            assert!(outcome.inserted);
                            want.inserts += 1;
                            if let Some(p) = lru.iter().position(|&(t, _)| t == tenant) {
                                lru.remove(p); // replace: old bytes released first
                            }
                            let mut used: usize = lru.iter().map(|&(_, b)| b).sum();
                            let mut want_evicted = Vec::new();
                            while used + bytes > BUDGET {
                                let (t, evicted) = lru.remove(0); // strict LRU order
                                used -= evicted;
                                want.evictions += 1;
                                want_evicted.push(t);
                            }
                            lru.push((tenant, bytes));
                            // The displaced models come back in LRU order.
                            let got: Vec<TenantId> =
                                outcome.evicted.iter().map(|&(t, _)| t).collect();
                            assert_eq!(got, want_evicted, "evicted sequence diverged");
                        }
                        _ => {
                            // peek must not touch recency or counters.
                            let hit = cache.peek(tenant).is_some();
                            assert_eq!(hit, lru.iter().any(|&(t, _)| t == tenant));
                        }
                    }
                    // Invariants after every op.
                    let used: usize = lru.iter().map(|&(_, b)| b).sum();
                    assert!(
                        cache.used_bytes() <= cache.budget_bytes(),
                        "byte budget exceeded: {} > {}",
                        cache.used_bytes(),
                        cache.budget_bytes()
                    );
                    assert_eq!(cache.used_bytes(), used, "byte accounting drifted");
                    assert_eq!(cache.len(), lru.len(), "live set size");
                    for &(t, _) in &lru {
                        assert!(cache.peek(t).is_some(), "model key {t} missing");
                    }
                    assert_eq!(cache.stats(), want, "counter drift");
                }
            },
        );
    }

    fn model(floats: usize) -> CachedModel {
        CachedModel {
            flat: Arc::new(vec![0.5; floats]),
            layers: Vec::new(),
            params_crc: 0,
        }
    }

    #[test]
    fn hit_miss_and_hit_rate() {
        let mut c = MergedCache::new(1 << 20);
        assert!(c.get(1).is_none());
        assert!(c.insert(1, model(10)).inserted);
        assert!(c.get(1).is_some());
        assert!(c.get(2).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.inserts), (1, 2, 1));
        assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn byte_budget_evicts_lru_order() {
        // Budget fits exactly two 100-float models (400 bytes each).
        let mut c = MergedCache::new(800);
        assert!(c.insert(1, model(100)).inserted);
        assert!(c.insert(2, model(100)).inserted);
        assert_eq!(c.used_bytes(), 800);
        // Touch 1 so 2 becomes LRU.
        assert!(c.get(1).is_some());
        let outcome = c.insert(3, model(100));
        assert!(outcome.inserted);
        let evicted: Vec<TenantId> = outcome.evicted.iter().map(|&(t, _)| t).collect();
        assert_eq!(evicted, vec![2], "displaced model handed back for spilling");
        assert_eq!(c.len(), 2);
        assert!(c.peek(1).is_some(), "recently used survives");
        assert!(c.peek(2).is_none(), "LRU evicted");
        assert!(c.peek(3).is_some());
        assert_eq!(c.stats().evictions, 1);
        assert!(c.used_bytes() <= c.budget_bytes());
    }

    #[test]
    fn obs_mirrors_stats_and_byte_gauges() {
        let reg = crate::obs::MetricsRegistry::new();
        let mut c = MergedCache::new(800);
        c.set_obs(CacheObs {
            hits: reg.counter("serve_cache_hits_total"),
            misses: reg.counter("serve_cache_misses_total"),
            inserts: reg.counter("serve_cache_inserts_total"),
            evictions: reg.counter("serve_cache_evictions_total"),
            used_bytes: reg.gauge("serve_cache_used_bytes"),
            budget_bytes: reg.gauge("serve_cache_budget_bytes"),
        });
        assert!(c.get(1).is_none());
        assert!(c.insert(1, model(100)).inserted);
        assert!(c.insert(2, model(100)).inserted);
        assert!(c.get(1).is_some());
        assert!(c.insert(3, model(100)).inserted); // evicts tenant 2
        let s = c.stats();
        let snap = reg.snapshot();
        assert_eq!(snap.counters["serve_cache_hits_total"], s.hits);
        assert_eq!(snap.counters["serve_cache_misses_total"], s.misses);
        assert_eq!(snap.counters["serve_cache_inserts_total"], s.inserts);
        assert_eq!(snap.counters["serve_cache_evictions_total"], s.evictions);
        assert_eq!(snap.gauges["serve_cache_used_bytes"], c.used_bytes() as u64);
        assert_eq!(snap.gauges["serve_cache_budget_bytes"], 800);
        assert_eq!(s.evictions, 1);
    }

    #[test]
    fn remove_releases_bytes_without_counting_an_eviction() {
        let mut c = MergedCache::new(800);
        assert!(c.insert(1, model(100)).inserted);
        assert!(c.insert(2, model(100)).inserted);
        let gone = c.remove(1).expect("tenant 1 was cached");
        assert_eq!(gone.flat.len(), 100);
        assert!(c.remove(1).is_none(), "second remove is a no-op");
        assert_eq!(c.len(), 1);
        assert_eq!(c.used_bytes(), 400);
        assert_eq!(c.stats().evictions, 0, "invalidation is not an eviction");
        // The freed budget is usable again and the stale recency entry
        // for tenant 1 does not confuse later evictions.
        assert!(c.insert(3, model(100)).inserted);
        let outcome = c.insert(4, model(100));
        assert!(outcome.inserted);
        let evicted: Vec<TenantId> = outcome.evicted.iter().map(|&(t, _)| t).collect();
        assert_eq!(evicted, vec![2], "LRU order unaffected by the removal");
    }

    #[test]
    fn oversized_model_is_refused() {
        let mut c = MergedCache::new(100);
        assert!(!c.insert(1, model(1000)).inserted);
        assert!(c.is_empty());
        assert_eq!(c.used_bytes(), 0);
    }

    #[test]
    fn reinsert_replaces_without_leaking_bytes() {
        let mut c = MergedCache::new(10_000);
        assert!(c.insert(1, model(100)).inserted);
        let outcome = c.insert(1, model(200));
        assert!(outcome.inserted);
        assert!(
            outcome.evicted.is_empty(),
            "replacing a tenant's own model is not an eviction"
        );
        assert_eq!(c.len(), 1);
        assert_eq!(c.used_bytes(), 800);
    }

    #[test]
    fn recency_queue_compacts_under_churn() {
        let mut c = MergedCache::new(4 * 4 * 10); // fits 4 ten-float models
        for round in 0..50u64 {
            for t in 0..4 {
                let tenant = t + (round % 2) * 2; // overlapping working sets
                if c.peek(tenant).is_none() {
                    c.insert(tenant, model(10));
                } else {
                    c.get(tenant);
                }
            }
        }
        assert!(
            c.recency.len() <= 4 * c.slots.len().max(8) + 1,
            "recency queue must stay bounded, got {}",
            c.recency.len()
        );
        assert!(c.used_bytes() <= c.budget_bytes());
    }
}
