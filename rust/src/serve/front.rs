//! Network request front for the serving engine (DESIGN.md §11):
//! `gsoft serve --listen` — the adapt-then-deploy story on a socket.
//!
//! A pure-std HTTP/1.1 surface over [`Engine`] on the shared hardened
//! listener ([`crate::util::net::HttpServer`]), speaking
//! [`crate::util::json`] both ways:
//!
//! | endpoint            | payload                                      |
//! |---------------------|----------------------------------------------|
//! | `POST /v1/register` | `{tenant, desc, spec, params}` → register    |
//! | `POST /v1/query`    | `{tenant, input, deadline_ms?, req_id?}` → output |
//! | `POST /v1/evict`    | `{tenant}` → unregister                      |
//! | `GET /v1/tenants`   | live tenant ids                              |
//! | obs endpoints       | `/metrics(.json) /healthz /tracez /tenantz /slo` |
//!
//! Request correlation (DESIGN.md §12): every `/v1/query` resolves to a
//! `req_id` — the client's own (any nonzero unsigned integer) or one
//! minted from the engine's sequence — echoed in the success payload
//! *and* every admission/serve error body, and stamped into the
//! request's [`crate::obs::Trace`] so `/tracez?req=<id>` finds it later.
//!
//! `desc` is the GSAD wire object ([`crate::adapter::desc_from_json`]),
//! `spec` the [`FlatSpec`] schema, `params` a flat JSON float array —
//! the same codec the durable store speaks, so anything persistable is
//! registrable over the wire and validation is the registry's
//! ([`crate::serve::Registry::register`] rejects malformed entries
//! before they can reach a worker).
//!
//! Every request passes the admission gate
//! ([`crate::serve::admission::Admission`]) before touching the engine:
//! per-tenant token buckets (429), a global in-flight cap (503), and
//! client deadlines (`deadline_ms`, measured from arrival) propagated
//! into the micro-batcher so expired work is shed before compute (504,
//! [`DEADLINE_EXCEEDED`]). Rejections land on
//! `serve_admission_rejected_total{reason}` in the front's registry,
//! which `/metrics` merges with the engine's own.
//!
//! Outputs cross the wire bit-identically: `f32 → f64` widening is
//! exact, and the JSON number writer emits shortest-round-trip floats.

use std::net::SocketAddr;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::adapter::desc_from_json;
use crate::coordinator::FlatSpec;
use crate::obs::http::ObsRoutes;
use crate::obs::{MetricsRegistry, ObsSources};
use crate::serve::admission::{Admission, AdmissionCfg, Rejection};
use crate::serve::engine::DEADLINE_EXCEEDED;
use crate::serve::{AdapterEntry, Engine, TenantId};
use crate::util::json::Json;
use crate::util::net::{Handler, HttpServer, Request, Response, ServerOpts};

/// Front configuration: admission shape + listener hardening bounds.
#[derive(Clone, Copy, Default)]
pub struct FrontOpts {
    pub admission: AdmissionCfg,
    pub net: ServerOpts,
}

/// Request endpoints, used as metric labels so attacker-chosen paths
/// never become metric names.
const ENDPOINTS: [&str; 5] = ["/", "/v1/register", "/v1/query", "/v1/evict", "/v1/tenants"];

struct FrontState {
    engine: Arc<Engine>,
    admission: Admission,
    obs: ObsRoutes,
    /// Front-local registry (admission + request metrics), merged into
    /// the `/metrics` scrape alongside the engine's registry.
    registry: Arc<MetricsRegistry>,
}

/// Handle to the running front. Dropping it (or calling
/// [`ServeFront::shutdown`]) stops the listener and joins its threads;
/// the engine behind it is left running.
pub struct ServeFront {
    inner: HttpServer,
}

impl ServeFront {
    /// Bind `addr` (port 0 for ephemeral) and serve `engine` behind the
    /// admission gate. The engine's obs sources are mounted on the same
    /// listener, with the front's own registry merged into `/metrics`.
    pub fn bind(addr: &str, engine: Arc<Engine>, opts: FrontOpts) -> Result<ServeFront> {
        let registry = Arc::new(MetricsRegistry::new());
        let admission = Admission::new(opts.admission, &registry);
        let ObsSources {
            metrics,
            traces,
            captured,
            tenants: tenant_stats,
            health,
            slo,
        } = engine.obs_sources();
        let front_reg = Arc::clone(&registry);
        let sources = ObsSources {
            metrics: Box::new(move || {
                let mut snap = metrics();
                snap.merge(&front_reg.snapshot());
                snap
            }),
            traces,
            captured,
            tenants: tenant_stats,
            health,
            slo,
        };
        let state = Arc::new(FrontState {
            engine,
            admission,
            obs: ObsRoutes::new(sources),
            registry,
        });
        let handler: Handler = Arc::new(move |req: &Request| front_handler(&state, req));
        let inner = HttpServer::bind(addr, "serve front", opts.net, handler)?;
        Ok(ServeFront { inner })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.inner.addr()
    }

    pub fn url(&self) -> String {
        self.inner.url()
    }

    /// Stop accepting and join the listener threads.
    pub fn shutdown(self) {
        self.inner.shutdown();
    }
}

fn front_handler(state: &FrontState, req: &Request) -> Response {
    let t0 = Instant::now();
    let label = if ENDPOINTS.contains(&req.path.as_str()) {
        req.path.as_str()
    } else {
        "other"
    };
    let resp = route(state, req);
    state
        .registry
        .counter(&format!(
            "serve_front_requests_total{{path=\"{label}\",status=\"{}\"}}",
            resp.status
        ))
        .inc();
    state
        .registry
        .histogram(&format!("serve_front_request_ns{{path=\"{label}\"}}"))
        .record(t0.elapsed().as_nanos() as u64);
    resp
}

fn route(state: &FrontState, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/") => Response::text(
            200,
            "gsoft serve front\n\nPOST /v1/register\nPOST /v1/query\nPOST /v1/evict\n\
             GET /v1/tenants\n\n/metrics\n/metrics.json\n/healthz\n/tracez\n/tenantz\n/slo\n",
        ),
        ("POST", "/v1/register") => register(state, req),
        ("POST", "/v1/query") => query(state, req),
        ("POST", "/v1/evict") => evict(state, req),
        ("GET", "/v1/tenants") => tenants(state),
        _ => {
            if let Some(resp) = state.obs.handle(req) {
                return resp;
            }
            if ENDPOINTS.contains(&req.path.as_str()) {
                return Response::text(405, "wrong method for this endpoint\n");
            }
            Response::text(404, "not found\n")
        }
    }
}

fn bad_request(msg: &str) -> Response {
    Response::text(400, &format!("bad request: {msg}\n"))
}

/// JSON error body carrying the request's correlation id — a rejected or
/// failed request is still findable in `/tracez?req=` (when it reached
/// the engine) and attributable in a client's logs.
fn error_response(status: u16, msg: &str, req_id: u64) -> Response {
    Response::json(
        status,
        &Json::obj(vec![
            ("error", Json::Str(msg.to_string())),
            ("req_id", Json::u64(req_id)),
        ]),
    )
}

fn rejection(r: Rejection, req_id: u64) -> Response {
    let msg = match r {
        Rejection::Rate => "rate limit exceeded for tenant",
        Rejection::Inflight => "too many requests in flight",
        Rejection::Deadline => "deadline exceeded",
    };
    error_response(r.status(), msg, req_id)
}

/// `{tenant, desc, spec, params}` → validated [`AdapterEntry`] →
/// registry. All decode and validation errors are client errors (400).
fn register(state: &FrontState, req: &Request) -> Response {
    let body = match req.body_json() {
        Ok(b) => b,
        Err(e) => return bad_request(&e),
    };
    match try_register(state, &body) {
        Ok(tenant) => Response::json(
            200,
            &Json::obj(vec![
                ("registered", Json::Bool(true)),
                ("tenant", Json::Num(tenant as f64)),
            ]),
        ),
        Err(e) => bad_request(&format!("{e:#}")),
    }
}

fn try_register(state: &FrontState, body: &Json) -> Result<TenantId> {
    let tenant = tenant_of(body)?;
    let desc = desc_from_json(body.req("desc").map_err(|e| anyhow!("{e}"))?)
        .context("decoding 'desc'")?;
    let spec = FlatSpec::from_json(body.req("spec").map_err(|e| anyhow!("{e}"))?)
        .context("decoding 'spec'")?;
    let params = float_vec(body.req("params").map_err(|e| anyhow!("{e}"))?)
        .context("decoding 'params'")?;
    state
        .engine
        .registry()
        .register(
            tenant,
            AdapterEntry {
                desc,
                params: Arc::new(params),
                spec: Arc::new(spec),
            },
        )
        .context("registering adapter")?;
    Ok(tenant)
}

/// `{tenant, input, deadline_ms?, req_id?}` → admission → engine →
/// output JSON carrying the request's correlation id.
fn query(state: &FrontState, req: &Request) -> Response {
    let body = match req.body_json() {
        Ok(b) => b,
        Err(e) => return bad_request(&e),
    };
    let (tenant, input, deadline_ms, client_req) = match decode_query(&body) {
        Ok(q) => q,
        Err(e) => return bad_request(&format!("{e:#}")),
    };
    // Resolve the correlation id before admission: even a 429/503/504
    // error body names the request. Client 0 (= unattributed) is
    // replaced by a minted id so the echo is always meaningful.
    let req_id = client_req.filter(|&id| id != 0).unwrap_or_else(|| state.engine.next_req_id());
    let now = Instant::now();
    let _guard = match state.admission.admit(tenant, now) {
        Ok(g) => g,
        Err(r) => {
            state.engine.note_rejection(tenant);
            return rejection(r, req_id);
        }
    };
    let deadline = deadline_ms.map(|ms| now + Duration::from_millis(ms));
    if deadline.is_some_and(|d| d <= Instant::now()) {
        state.engine.note_rejection(tenant);
        return rejection(state.admission.reject(Rejection::Deadline), req_id);
    }
    let handle = match state.engine.submit_traced(tenant, input, deadline, req_id) {
        Ok(h) => h,
        Err(e) => return error_response(400, &format!("bad request: {e:#}"), req_id),
    };
    match handle.wait() {
        Ok(out) => {
            let output: Vec<f64> = out.output.iter().map(|&x| x as f64).collect();
            Response::json(
                200,
                &Json::obj(vec![
                    ("tenant", Json::Num(tenant as f64)),
                    ("req_id", Json::u64(req_id)),
                    ("path", Json::Str(out.path.name().to_string())),
                    ("latency_ns", Json::Num(out.latency.as_nanos() as f64)),
                    ("output", Json::arr_f64(&output)),
                ]),
            )
        }
        Err(e) if e.to_string().contains(DEADLINE_EXCEEDED) => {
            rejection(state.admission.reject(Rejection::Deadline), req_id)
        }
        Err(e) => error_response(500, &format!("serve failed: {e:#}"), req_id),
    }
}

fn decode_query(body: &Json) -> Result<(TenantId, Vec<f32>, Option<u64>, Option<u64>)> {
    let tenant = tenant_of(body)?;
    let input = float_vec(body.req("input").map_err(|e| anyhow!("{e}"))?)
        .context("decoding 'input'")?;
    let deadline_ms = match body.get("deadline_ms") {
        None | Some(Json::Null) => None,
        Some(v) => Some(
            v.as_i64()
                .filter(|&ms| ms >= 0)
                .ok_or_else(|| anyhow!("'deadline_ms' is not a non-negative integer"))?
                as u64,
        ),
    };
    let req_id = match body.get("req_id") {
        None | Some(Json::Null) => None,
        Some(v) => Some(
            v.as_u64()
                .ok_or_else(|| anyhow!("'req_id' is not an unsigned integer"))?,
        ),
    };
    Ok((tenant, input, deadline_ms, req_id))
}

/// `{tenant}` → unregister. Cached merged weights for the tenant may
/// linger until LRU eviction, but the tenant is unservable immediately
/// (submit checks the registry).
fn evict(state: &FrontState, req: &Request) -> Response {
    let body = match req.body_json() {
        Ok(b) => b,
        Err(e) => return bad_request(&e),
    };
    let tenant = match tenant_of(&body) {
        Ok(t) => t,
        Err(e) => return bad_request(&format!("{e:#}")),
    };
    match state.engine.registry().unregister(tenant) {
        Ok(true) => Response::json(
            200,
            &Json::obj(vec![
                ("evicted", Json::Bool(true)),
                ("tenant", Json::Num(tenant as f64)),
            ]),
        ),
        Ok(false) => Response::text(404, "unknown tenant\n"),
        Err(e) => Response::text(500, &format!("evict failed: {e:#}\n")),
    }
}

fn tenants(state: &FrontState) -> Response {
    let ids = state.engine.registry().tenant_ids();
    Response::json(
        200,
        &Json::obj(vec![
            ("count", Json::Num(ids.len() as f64)),
            (
                "tenants",
                Json::Arr(ids.iter().map(|&t| Json::Num(t as f64)).collect()),
            ),
        ]),
    )
}

fn tenant_of(body: &Json) -> Result<TenantId> {
    body.req("tenant")
        .map_err(|e| anyhow!("{e}"))?
        .as_i64()
        .filter(|&t| t >= 0)
        .map(|t| t as TenantId)
        .ok_or_else(|| anyhow!("'tenant' is not a non-negative integer"))
}

/// Decode a JSON array of numbers into f32s. Non-finite entries are
/// rejected: they cannot round-trip JSON and would poison the kernels.
fn float_vec(v: &Json) -> Result<Vec<f32>> {
    let arr = v.as_arr().ok_or_else(|| anyhow!("expected a number array"))?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, x) in arr.iter().enumerate() {
        let x = x
            .as_f64()
            .filter(|x| x.is_finite())
            .ok_or_else(|| anyhow!("entry {i} is not a finite number"))?;
        out.push(x as f32);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::desc_to_json;
    use crate::serve::{synthetic, EngineOpts};
    use crate::util::net::http_request;

    fn quick_opts() -> EngineOpts {
        EngineOpts {
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_micros(200),
            promote_after: Some(3),
            ..EngineOpts::default()
        }
    }

    fn front_with(admission: AdmissionCfg) -> (Arc<Engine>, ServeFront) {
        let reg = synthetic(4, 2, 8, 2, 21).unwrap();
        let engine = Arc::new(Engine::new(reg, quick_opts()).unwrap());
        let opts = FrontOpts {
            admission,
            ..FrontOpts::default()
        };
        let front = ServeFront::bind("127.0.0.1:0", Arc::clone(&engine), opts).unwrap();
        (engine, front)
    }

    fn open_admission() -> AdmissionCfg {
        AdmissionCfg {
            rate_per_sec: 1e6,
            burst: 1e6,
            max_inflight: 64,
        }
    }

    fn post(addr: SocketAddr, target: &str, body: &Json) -> (u16, String) {
        http_request(addr, "POST", target, Some(&body.to_string())).unwrap()
    }

    fn get(addr: SocketAddr, target: &str) -> (u16, String) {
        http_request(addr, "GET", target, None).unwrap()
    }

    fn output_bits(body: &str) -> Vec<u32> {
        Json::parse(body)
            .unwrap()
            .get("output")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| (v.as_f64().unwrap() as f32).to_bits())
            .collect()
    }

    #[test]
    fn register_query_evict_round_trip_is_bit_identical_to_in_process() {
        let (engine, front) = front_with(open_admission());
        let addr = front.addr();
        let d = engine.input_dim();
        let input: Vec<f32> = (0..d).map(|i| (i as f32 / d as f32) - 0.4).collect();

        // Clone tenant 0's adapter and register it over the wire as a
        // fresh tenant: identical desc/spec/params, untouched caches on
        // both sides, so the first query takes the same path.
        let entry = engine.registry().get(0).unwrap();
        let body = Json::obj(vec![
            ("tenant", Json::Num(1000.0)),
            ("desc", desc_to_json(&entry.desc)),
            ("spec", entry.spec.to_json()),
            (
                "params",
                Json::arr_f64(&entry.params.iter().map(|&x| x as f64).collect::<Vec<f64>>()),
            ),
        ]);
        let (status, resp) = post(addr, "/v1/register", &body);
        assert_eq!(status, 200, "{resp}");
        let ack = Json::parse(&resp).unwrap();
        assert_eq!(ack.get("registered").and_then(|v| v.as_bool()), Some(true));

        let (status, resp) = get(addr, "/v1/tenants");
        assert_eq!(status, 200);
        let listed = Json::parse(&resp).unwrap();
        let ids = listed.get("tenants").unwrap().as_arr().unwrap();
        assert!(ids.contains(&Json::Num(1000.0)), "{resp}");

        // Wire query of the clone vs in-process query of the original.
        let q = Json::obj(vec![
            ("tenant", Json::Num(1000.0)),
            (
                "input",
                Json::arr_f64(&input.iter().map(|&x| x as f64).collect::<Vec<f64>>()),
            ),
        ]);
        let (status, resp) = post(addr, "/v1/query", &q);
        assert_eq!(status, 200, "{resp}");
        let wire_bits = output_bits(&resp);
        assert_eq!(wire_bits.len(), d);

        let local = engine.submit(0, input.clone()).unwrap().wait().unwrap();
        let local_bits: Vec<u32> = local.output.iter().map(|x| x.to_bits()).collect();
        assert_eq!(wire_bits, local_bits, "wire and in-process outputs must be bit-identical");

        // Evict, then the tenant is gone from list and query.
        let ev = Json::obj(vec![("tenant", Json::Num(1000.0))]);
        let (status, resp) = post(addr, "/v1/evict", &ev);
        assert_eq!(status, 200, "{resp}");
        let (status, _) = post(addr, "/v1/evict", &ev);
        assert_eq!(status, 404, "double evict");
        let (status, resp) = post(addr, "/v1/query", &q);
        assert_eq!(status, 400, "evicted tenant is unservable: {resp}");

        front.shutdown();
    }

    #[test]
    fn malformed_bodies_and_wrong_methods_are_client_errors() {
        let (_engine, front) = front_with(open_admission());
        let addr = front.addr();

        let (status, _) = http_request(addr, "POST", "/v1/query", Some("{not json")).unwrap();
        assert_eq!(status, 400);
        let (status, _) = post(addr, "/v1/query", &Json::obj(vec![("tenant", Json::Num(0.0))]));
        assert_eq!(status, 400, "missing input field");
        let (status, _) = post(
            addr,
            "/v1/query",
            &Json::obj(vec![
                ("tenant", Json::Str("zero".into())),
                ("input", Json::arr_f64(&[0.0])),
            ]),
        );
        assert_eq!(status, 400, "non-numeric tenant");
        let (status, _) = post(addr, "/v1/register", &Json::obj(vec![("tenant", Json::Num(1.0))]));
        assert_eq!(status, 400, "register without desc/spec/params");
        let (status, _) = get(addr, "/v1/query");
        assert_eq!(status, 405, "query is POST-only");
        let (status, _) = http_request(addr, "POST", "/v1/tenants", Some("{}")).unwrap();
        assert_eq!(status, 405, "tenants is GET-only");
        let (status, _) = get(addr, "/v1/nope");
        assert_eq!(status, 404);

        // A deeply nested body must error cleanly, not overflow the
        // parser stack inside a worker.
        let hostile = "[".repeat(50_000);
        let (status, _) = http_request(addr, "POST", "/v1/query", Some(&hostile)).unwrap();
        assert_eq!(status, 400);

        front.shutdown();
    }

    #[test]
    fn over_rate_tenant_gets_429_and_the_rejection_counter_increments() {
        let (engine, front) = front_with(AdmissionCfg {
            rate_per_sec: 0.001, // no refill at test timescale
            burst: 2.0,
            max_inflight: 64,
        });
        let addr = front.addr();
        let d = engine.input_dim();
        let q = Json::obj(vec![
            ("tenant", Json::Num(0.0)),
            ("input", Json::arr_f64(&vec![0.25; d])),
        ]);

        let mut statuses = Vec::new();
        for _ in 0..4 {
            statuses.push(post(addr, "/v1/query", &q).0);
        }
        assert_eq!(&statuses[..2], &[200, 200], "burst admitted: {statuses:?}");
        assert_eq!(&statuses[2..], &[429, 429], "past burst rejected: {statuses:?}");

        // Another tenant still gets through (per-tenant buckets)...
        let q2 = Json::obj(vec![
            ("tenant", Json::Num(1.0)),
            ("input", Json::arr_f64(&vec![0.25; d])),
        ]);
        assert_eq!(post(addr, "/v1/query", &q2).0, 200);

        // ...and the scrape shows the rejections on the same listener.
        let (status, body) = get(addr, "/metrics");
        assert_eq!(status, 200);
        assert!(
            body.contains("serve_admission_rejected_total{reason=\"rate\"} 2"),
            "{body}"
        );

        // The heavy-hitter plane attributes both rejections to tenant 0.
        let (status, body) = get(addr, "/tenantz");
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        let rej = j.get("dims").unwrap().get("admission_rejected").unwrap();
        assert_eq!(rej.get("total").unwrap().as_u64(), Some(2), "{body}");
        let top = &rej.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(top.get("tenant").unwrap().as_u64(), Some(0));

        front.shutdown();
    }

    #[test]
    fn expired_deadline_gets_504_and_counts_as_deadline_rejection() {
        let (engine, front) = front_with(open_admission());
        let addr = front.addr();
        let d = engine.input_dim();
        let q = Json::obj(vec![
            ("tenant", Json::Num(0.0)),
            ("input", Json::arr_f64(&vec![0.5; d])),
            ("deadline_ms", Json::Num(0.0)),
        ]);
        let (status, _) = post(addr, "/v1/query", &q);
        assert_eq!(status, 504);

        // A generous deadline is served.
        let q = Json::obj(vec![
            ("tenant", Json::Num(0.0)),
            ("input", Json::arr_f64(&vec![0.5; d])),
            ("deadline_ms", Json::Num(60_000.0)),
        ]);
        let (status, resp) = post(addr, "/v1/query", &q);
        assert_eq!(status, 200, "{resp}");

        let (_, body) = get(addr, "/metrics");
        assert!(
            body.contains("serve_admission_rejected_total{reason=\"deadline\"} 1"),
            "{body}"
        );
        front.shutdown();
    }

    #[test]
    fn obs_endpoints_ride_the_same_listener() {
        let (engine, front) = front_with(open_admission());
        let addr = front.addr();
        let d = engine.input_dim();
        let q = Json::obj(vec![
            ("tenant", Json::Num(0.0)),
            ("input", Json::arr_f64(&vec![0.1; d])),
        ]);
        assert_eq!(post(addr, "/v1/query", &q).0, 200);

        let (status, body) = get(addr, "/healthz");
        assert_eq!(status, 200);
        assert_eq!(Json::parse(&body).unwrap().get("ok").and_then(|v| v.as_bool()), Some(true));

        let (status, body) = get(addr, "/metrics.json");
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        let counters = j.get("counters").unwrap().as_obj().unwrap();
        // Engine metrics and front metrics in one scrape.
        assert!(
            counters.keys().any(|k| k.starts_with("serve_requests_total")),
            "{body}"
        );
        assert!(
            counters.keys().any(|k| k.starts_with("serve_front_requests_total")),
            "{body}"
        );

        let (status, _) = get(addr, "/slo");
        assert_eq!(status, 200);
        let (status, body) = get(addr, "/");
        assert_eq!(status, 200);
        assert!(body.contains("/v1/register"), "{body}");
        front.shutdown();
    }

    #[test]
    fn known_req_id_is_retrievable_after_the_main_ring_wraps() {
        // The acceptance path for request correlation: a query with a
        // client-chosen req_id stays findable via /tracez?req= even
        // after enough traffic has flooded the main ring to evict it —
        // the capture ring (slow bar at 0 here) holds it.
        let reg = synthetic(4, 2, 8, 2, 21).unwrap();
        let mut eopts = quick_opts();
        eopts.trace_ring_cap = 2;
        eopts.capture_slow_ns = Some(0);
        let engine = Arc::new(Engine::new(reg, eopts).unwrap());
        let opts = FrontOpts {
            admission: open_admission(),
            ..FrontOpts::default()
        };
        let front = ServeFront::bind("127.0.0.1:0", Arc::clone(&engine), opts).unwrap();
        let addr = front.addr();
        let d = engine.input_dim();

        let q = Json::obj(vec![
            ("tenant", Json::Num(0.0)),
            ("input", Json::arr_f64(&vec![0.1; d])),
            ("req_id", Json::Num(424242.0)),
        ]);
        let (status, resp) = post(addr, "/v1/query", &q);
        assert_eq!(status, 200, "{resp}");
        let echoed = Json::parse(&resp).unwrap();
        assert_eq!(echoed.get("req_id").unwrap().as_u64(), Some(424242), "client id echoed");

        // Flood the 2-slot main ring well past capacity.
        for t in 1..4u64 {
            for _ in 0..3 {
                let flood = Json::obj(vec![
                    ("tenant", Json::Num(t as f64)),
                    ("input", Json::arr_f64(&vec![0.2; d])),
                ]);
                assert_eq!(post(addr, "/v1/query", &flood).0, 200);
            }
        }
        assert!(
            engine.traces().iter().all(|t| t.req_id != 424242),
            "flood must have evicted the target from the main ring"
        );

        let (status, body) = get(addr, "/tracez?req=424242");
        assert_eq!(status, 200);
        let hits = Json::parse(&body).unwrap().as_arr().unwrap().to_vec();
        assert_eq!(hits.len(), 1, "capture ring must still hold the request: {body}");
        assert_eq!(hits[0].get("req_id").unwrap().as_u64(), Some(424242));
        assert_eq!(hits[0].get("tenant").unwrap().as_f64(), Some(0.0));
        assert_eq!(hits[0].get("reason").unwrap().as_str(), Some("slow"));
        let stages = hits[0].get("stage_ns").unwrap().as_obj().unwrap();
        assert!(stages.contains_key("queue"), "stage trace rides along: {body}");

        // A query without req_id gets a minted, nonzero id echoed.
        let bare = Json::obj(vec![
            ("tenant", Json::Num(0.0)),
            ("input", Json::arr_f64(&vec![0.3; d])),
        ]);
        let (status, resp) = post(addr, "/v1/query", &bare);
        assert_eq!(status, 200, "{resp}");
        let minted = Json::parse(&resp).unwrap().get("req_id").unwrap().as_u64().unwrap();
        assert!(minted >= 1, "minted ids are never 0");
        front.shutdown();
    }

    #[test]
    fn tracez_filters_and_rejection_bodies_work_over_the_live_listener() {
        let (engine, front) = front_with(open_admission());
        let addr = front.addr();
        let d = engine.input_dim();
        let q = Json::obj(vec![
            ("tenant", Json::Num(2.0)),
            ("input", Json::arr_f64(&vec![0.1; d])),
        ]);
        assert_eq!(post(addr, "/v1/query", &q).0, 200);

        // Match: tenant 2 served at least once, every hit is tenant 2.
        let (status, body) = get(addr, "/tracez?tenant=2");
        assert_eq!(status, 200);
        let hits = Json::parse(&body).unwrap().as_arr().unwrap().to_vec();
        assert!(!hits.is_empty(), "{body}");
        assert!(hits.iter().all(|t| t.get("tenant").unwrap().as_f64() == Some(2.0)));

        // No match: tenant 3 never queried; latency bar nothing clears.
        let (status, body) = get(addr, "/tracez?tenant=3");
        assert_eq!(status, 200);
        assert!(Json::parse(&body).unwrap().as_arr().unwrap().is_empty());
        let (status, body) = get(addr, "/tracez?tenant=2&min_total_ns=999999999999");
        assert_eq!(status, 200);
        assert!(Json::parse(&body).unwrap().as_arr().unwrap().is_empty());

        // Malformed: unknown key and non-numeric value are 400s.
        for bad in ["/tracez?owner=2", "/tracez?tenant=zebra", "/tracez?tenant"] {
            let (status, _) = get(addr, bad);
            assert_eq!(status, 400, "{bad}");
        }

        // A malformed req_id is a 400 before any submit.
        let bad_q = Json::obj(vec![
            ("tenant", Json::Num(0.0)),
            ("input", Json::arr_f64(&vec![0.1; d])),
            ("req_id", Json::Str("abc".into())),
        ]);
        assert_eq!(post(addr, "/v1/query", &bad_q).0, 400);

        // Deadline-expired queries answer 504 with the id in the body.
        let q = Json::obj(vec![
            ("tenant", Json::Num(0.0)),
            ("input", Json::arr_f64(&vec![0.5; d])),
            ("deadline_ms", Json::Num(0.0)),
            ("req_id", Json::Num(777.0)),
        ]);
        let (status, resp) = post(addr, "/v1/query", &q);
        assert_eq!(status, 504);
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.get("req_id").unwrap().as_u64(), Some(777), "{resp}");
        assert!(j.get("error").unwrap().as_str().unwrap().contains("deadline"));
        front.shutdown();
    }
}
