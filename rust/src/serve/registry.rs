//! Multi-tenant adapter registry: one frozen base model (flat f32 buffer +
//! [`FlatSpec`]) shared by every tenant, plus per-tenant adapter parameters
//! (any registered [`crate::adapter::AdapterFamily`] — the §6.1 use-case
//! of thousands of cheap orthogonal adapters over one pretrained model).
//!
//! Two modes share one API:
//! - **in-memory** ([`Registry::new`]) — tenants live in a `HashMap`;
//! - **store-backed** ([`Registry::with_store`]) — the durable
//!   [`crate::store::AdapterStore`] is the source of truth;
//!   registrations write through to the segment log before they are
//!   acknowledged, lookups hydrate lazily from disk into the in-RAM map
//!   (droppable again with [`Registry::drop_hydrated`]), and the whole
//!   fleet can be [`Registry::snapshot`]ed to / [`Registry::restore`]d
//!   from a single `GSAD` fleet file.
//!
//! This module contains no per-family code: validation, synthetic
//! generation, and merging all dispatch through
//! [`crate::adapter::AdapterDesc`], so new families (e.g.
//! [`crate::adapter::monarch`]) serve here without edits.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{anyhow, Context, Result};

use crate::adapter::{merge_entry, AdapterDesc, AdapterFamily, SlabCx};
use crate::coordinator::merge::AdapterKind;
use crate::coordinator::FlatSpec;
use crate::store::{gsad, AdapterStore};
use crate::util::rng::Rng;

/// Tenant identifier (subject / task / user id).
pub type TenantId = u64;

/// One tenant's adapter: family descriptor + flat parameters + their
/// layout.
#[derive(Clone)]
pub struct AdapterEntry {
    pub desc: AdapterDesc,
    pub params: Arc<Vec<f32>>,
    pub spec: Arc<FlatSpec>,
}

/// The shared base model every tenant adapts.
#[derive(Clone)]
pub struct BaseModel {
    pub weights: Arc<Vec<f32>>,
    pub spec: Arc<FlatSpec>,
}

/// Registry of adapters keyed by tenant id over one shared base.
/// Registration is concurrent-safe; lookups clone `Arc`s only (in
/// store-backed mode a cold lookup additionally pays one disk read).
///
/// Mutations for one tenant serialize on a *stripe* lock chosen by the
/// same hash that places the tenant in the store's sharded log
/// ([`crate::store::shard_of`]), so registrations landing in different
/// shards proceed fully in parallel — neither the tenant map nor the
/// store is locked across another shard's durable append.
pub struct Registry {
    base: BaseModel,
    /// In-memory mode: the tenant set. Store-backed mode: the hydration
    /// cache — always a subset of the store's live set.
    tenants: RwLock<HashMap<TenantId, AdapterEntry>>,
    /// The durable store ([`AdapterStore`] is internally synchronized
    /// per shard — no outer lock, so appends to different shards run in
    /// parallel).
    store: Option<AdapterStore>,
    /// Striped mutation locks (stripe = store shard of the tenant).
    /// Holding a tenant's stripe makes register / unregister / hydrate
    /// atomic with respect to each other *for that tenant* — the
    /// RAM-map-vs-log agreement the old whole-map lock provided, at
    /// per-shard granularity.
    stripes: Vec<Mutex<()>>,
    /// CRC32 of every known tenant's current flat params
    /// ([`gsad::params_crc`]), maintained on register / hydrate /
    /// unregister. The serving engine compares a merged-cache hit's
    /// captured CRC against this map to detect live re-registrations.
    crcs: Mutex<HashMap<TenantId, u32>>,
    /// Fired (outside all registry locks) after a registration
    /// *overwrites* a live tenant — the engine hooks this to evict that
    /// tenant's factored operators and cached merged weights.
    update_hook: RwLock<Option<Box<dyn Fn(TenantId) + Send + Sync>>>,
}

impl Registry {
    pub fn new(base_weights: Vec<f32>, base_spec: FlatSpec) -> Result<Registry> {
        Registry::build(base_weights, base_spec, None)
    }

    /// Store-backed mode: mount a durable [`AdapterStore`] under the same
    /// API. Tenants already in the store are served via lazy hydration
    /// (nothing is loaded here — cold boot is O(log replay), not
    /// O(fleet)); new registrations are durably appended before they are
    /// acknowledged.
    pub fn with_store(
        base_weights: Vec<f32>,
        base_spec: FlatSpec,
        store: AdapterStore,
    ) -> Result<Registry> {
        Registry::build(base_weights, base_spec, Some(store))
    }

    fn build(
        base_weights: Vec<f32>,
        base_spec: FlatSpec,
        store: Option<AdapterStore>,
    ) -> Result<Registry> {
        anyhow::ensure!(
            base_weights.len() == base_spec.size(),
            "base buffer has {} floats but spec expects {}",
            base_weights.len(),
            base_spec.size()
        );
        let n_stripes = store
            .as_ref()
            .map(|s| s.num_shards())
            .unwrap_or(crate::store::DEFAULT_SHARDS)
            .max(1);
        Ok(Registry {
            base: BaseModel {
                weights: Arc::new(base_weights),
                spec: Arc::new(base_spec),
            },
            tenants: RwLock::new(HashMap::new()),
            store,
            stripes: (0..n_stripes).map(|_| Mutex::new(())).collect(),
            crcs: Mutex::new(HashMap::new()),
            update_hook: RwLock::new(None),
        })
    }

    /// The stripe serializing mutations of `tenant`. Same hash as the
    /// store's shard placement, so one stripe maps onto one shard's
    /// append lock and two stripes never contend on the same shard file.
    fn stripe(&self, tenant: TenantId) -> &Mutex<()> {
        &self.stripes[crate::store::shard_of(tenant, self.stripes.len())]
    }

    pub fn base(&self) -> &BaseModel {
        &self.base
    }

    /// Whether this registry is backed by a durable store.
    pub fn is_store_backed(&self) -> bool {
        self.store.is_some()
    }

    /// Health probe of the backing store, if any (`/healthz`). `None`
    /// for in-memory registries — which are vacuously healthy.
    pub fn store_health(&self) -> Option<crate::store::StoreHealth> {
        self.store.as_ref().map(|s| s.health())
    }

    /// The backing store's sharded log, if any — for wiring the
    /// background [`crate::store::Maintainer`].
    pub fn sharded_log(&self) -> Option<Arc<crate::store::ShardedLog>> {
        self.store.as_ref().map(|s| s.sharded_log())
    }

    /// Install the live re-registration hook: called with the tenant id
    /// after a registration overwrites a live tenant, once the new
    /// record is durable and visible. Runs outside every registry lock
    /// (it may take its own), but must not call back into registration.
    pub fn set_update_hook(&self, hook: Box<dyn Fn(TenantId) + Send + Sync>) {
        *self.update_hook.write().unwrap() = Some(hook);
    }

    /// Register (or replace) a tenant's adapter. Validates
    /// ([`Registry::validate`]), then — in store-backed mode — durably
    /// appends to the segment log *before* the in-RAM insert, so an
    /// acknowledged registration survives a crash.
    ///
    /// Lock order everywhere in this type: stripe → store shard →
    /// `tenants` (brief) → `crcs`. Holding the tenant's *stripe* across
    /// the durable append keeps RAM and log in agreement under
    /// concurrent register / unregister / hydrate (two racing
    /// re-registrations must not leave the map on v1 while the log's
    /// live record is v2) — without serializing registrations that land
    /// in different shards.
    pub fn register(&self, tenant: TenantId, entry: AdapterEntry) -> Result<()> {
        self.validate(tenant, &entry)?;
        let crc = gsad::params_crc(&entry);
        let replaced = {
            let _stripe = self.stripe(tenant).lock().unwrap();
            let live = self.tenants.read().unwrap().contains_key(&tenant)
                || self.store.as_ref().is_some_and(|s| s.contains(tenant));
            if let Some(store) = &self.store {
                store.put(tenant, &entry)?;
            }
            self.tenants.write().unwrap().insert(tenant, entry);
            self.crcs.lock().unwrap().insert(tenant, crc);
            live
        };
        if replaced {
            // Outside the stripe: the hook takes engine locks, and the
            // engine's miss path takes them before hydrating (stripe).
            // Correctness does not depend on this ordering — the CRC
            // recheck on cache hits is the backstop for any window
            // between the insert above and the eviction here.
            if let Some(hook) = self.update_hook.read().unwrap().as_ref() {
                hook(tenant);
            }
        }
        Ok(())
    }

    /// CRC32 of the tenant's current flat params, or `None` for an
    /// unknown tenant. Served from the maintained map; a store-backed
    /// tenant that was never hydrated pays one uncached disk read, after
    /// which the value is remembered. This is the engine's staleness
    /// oracle for merged-cache hits.
    pub fn params_crc_of(&self, tenant: TenantId) -> Option<u32> {
        if let Some(c) = self.crcs.lock().unwrap().get(&tenant) {
            return Some(*c);
        }
        // Serialize with register/unregister so we never cache a CRC
        // computed from a record that a racing overwrite already
        // superseded.
        let _stripe = self.stripe(tenant).lock().unwrap();
        if let Some(c) = self.crcs.lock().unwrap().get(&tenant) {
            return Some(*c);
        }
        let entry = self.read_uncached(tenant).ok().flatten()?;
        let crc = gsad::params_crc(&entry);
        self.crcs.lock().unwrap().insert(tenant, crc);
        Some(crc)
    }

    /// Validate an adapter entry: the parameter buffer against its spec,
    /// that every adapted layer exists in the base spec, that every slab
    /// suffix belongs to the entry's family, and — via
    /// [`crate::adapter::AdapterFamily::validate_slab`] — that each
    /// slab's shape is consistent with the family config and the adapted
    /// layer's dimensions. A malformed entry must be rejected here (and
    /// at hydration time), not panic later inside a serving worker.
    fn validate(&self, tenant: TenantId, entry: &AdapterEntry) -> Result<()> {
        anyhow::ensure!(
            entry.params.len() == entry.spec.size(),
            "tenant {tenant}: adapter buffer has {} floats but spec expects {}",
            entry.params.len(),
            entry.spec.size()
        );
        let family = entry.desc.family();
        family.validate_config(entry.desc.cfg())?;
        for (name, shape) in &entry.spec.entries {
            let (layer, suffix) = name
                .rsplit_once('.')
                .ok_or_else(|| anyhow!("tenant {tenant}: bad adapter entry name '{name}'"))?;
            let (_, wshape) = self
                .base
                .spec
                .locate(layer)
                .map_err(|_| anyhow!("tenant {tenant}: adapts unknown base layer '{layer}'"))?;
            anyhow::ensure!(
                wshape.len() == 2,
                "tenant {tenant}: adapted base entry '{layer}' is not a matrix"
            );
            anyhow::ensure!(
                family.suffixes().contains(&suffix),
                "tenant {tenant}: entry '{name}' does not belong to a {} adapter",
                entry.desc.tag()
            );
            family.validate_slab(
                entry.desc.cfg(),
                &SlabCx {
                    tenant,
                    name,
                    layer,
                    suffix,
                    shape,
                    din: wshape[0],
                    dout: wshape[1],
                    spec: entry.spec.as_ref(),
                },
            )?;
        }
        Ok(())
    }

    /// Cheap lookup (Arc clones); in store-backed mode a RAM miss
    /// hydrates from disk (validated, then cached for later lookups). A
    /// hydration I/O or validation failure is reported and served as
    /// `None` — a corrupt store entry must degrade, not panic a worker.
    pub fn get(&self, tenant: TenantId) -> Option<AdapterEntry> {
        if let Some(e) = self.tenants.read().unwrap().get(&tenant).cloned() {
            return Some(e);
        }
        match self.hydrate(tenant) {
            Ok(e) => e,
            Err(err) => {
                eprintln!("[registry] hydrating tenant {tenant} failed: {err:#}");
                None
            }
        }
    }

    fn hydrate(&self, tenant: TenantId) -> Result<Option<AdapterEntry>> {
        let Some(store) = &self.store else {
            return Ok(None);
        };
        // Stripe first (see `register` for the order), held across the
        // disk read: a hydration must not resurrect a tenant that a
        // concurrent `unregister` tombstones between our read and
        // insert. Hydrations of tenants in other shards proceed freely.
        let _stripe = self.stripe(tenant).lock().unwrap();
        if let Some(e) = self.tenants.read().unwrap().get(&tenant) {
            return Ok(Some(e.clone())); // raced hydrator landed first
        }
        let Some(entry) = store.get(tenant)? else {
            return Ok(None);
        };
        self.validate(tenant, &entry)?;
        self.crcs.lock().unwrap().insert(tenant, gsad::params_crc(&entry));
        self.tenants.write().unwrap().insert(tenant, entry.clone());
        Ok(Some(entry))
    }

    /// Read a tenant's entry *without* populating the hydration cache —
    /// for maintenance reads (snapshots, policy inference) that must not
    /// silently pin the whole fleet in RAM.
    fn read_uncached(&self, tenant: TenantId) -> Result<Option<AdapterEntry>> {
        if let Some(e) = self.tenants.read().unwrap().get(&tenant).cloned() {
            return Ok(Some(e));
        }
        let Some(store) = &self.store else {
            return Ok(None);
        };
        store.get(tenant)
    }

    /// A tenant's family descriptor without hydrating it (store-backed
    /// lookups decode the record and drop it) — the engine's policy
    /// inference must not defeat lazy cold boot.
    pub fn desc_of(&self, tenant: TenantId) -> Option<AdapterDesc> {
        self.read_uncached(tenant).ok().flatten().map(|e| e.desc)
    }

    /// Drop a tenant's in-RAM hydration, keeping the durable record
    /// (store-backed mode only — without a backing store this would lose
    /// the adapter, so it is a no-op there).
    pub fn drop_hydrated(&self, tenant: TenantId) {
        if self.store.is_some() {
            self.tenants.write().unwrap().remove(&tenant);
        }
    }

    /// Number of tenants currently hydrated in RAM (== [`Registry::len`]
    /// for in-memory registries).
    pub fn hydrated_len(&self) -> usize {
        self.tenants.read().unwrap().len()
    }

    /// Remove a tenant entirely (tombstoned in the store when backed).
    /// Returns `false` if the tenant was unknown.
    pub fn unregister(&self, tenant: TenantId) -> Result<bool> {
        let _stripe = self.stripe(tenant).lock().unwrap();
        let in_ram = self.tenants.write().unwrap().remove(&tenant).is_some();
        self.crcs.lock().unwrap().remove(&tenant);
        if let Some(store) = &self.store {
            let in_store = store.delete(tenant)?;
            return Ok(in_ram || in_store);
        }
        Ok(in_ram)
    }

    pub fn contains(&self, tenant: TenantId) -> bool {
        if self.tenants.read().unwrap().contains_key(&tenant) {
            return true;
        }
        self.store.as_ref().is_some_and(|s| s.contains(tenant))
    }

    pub fn len(&self) -> usize {
        match &self.store {
            // Write-through keeps RAM ⊆ store, so the store is authoritative.
            Some(s) => s.len(),
            None => self.tenants.read().unwrap().len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn tenant_ids(&self) -> Vec<TenantId> {
        match &self.store {
            Some(s) => s.tenant_ids(),
            None => {
                let mut ids: Vec<TenantId> =
                    self.tenants.read().unwrap().keys().copied().collect();
                ids.sort_unstable();
                ids
            }
        }
    }

    /// Eagerly hydrate every stored tenant (cold-boot warmup). Returns
    /// the number of tenants hydrated from disk.
    pub fn hydrate_all(&self) -> Result<usize> {
        let mut n = 0;
        for t in self.tenant_ids() {
            if !self.tenants.read().unwrap().contains_key(&t) {
                anyhow::ensure!(
                    self.hydrate(t)?.is_some(),
                    "tenant {t} listed by the store but not hydratable"
                );
                n += 1;
            }
        }
        Ok(n)
    }

    /// Snapshot the whole fleet — base model plus every tenant's adapter —
    /// into one `GSAD` fleet file. Store-backed tenants are read without
    /// entering the hydration cache, so a backup does not permanently
    /// inflate RAM from O(hot set) to O(fleet).
    pub fn snapshot(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut tenants = Vec::new();
        for t in self.tenant_ids() {
            let e = self
                .read_uncached(t)?
                .ok_or_else(|| anyhow!("tenant {t} vanished during snapshot"))?;
            tenants.push((t, e));
        }
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, gsad::encode_fleet(&self.base, &tenants))
            .with_context(|| format!("writing fleet snapshot {}", path.display()))?;
        Ok(())
    }

    /// Rebuild a registry (in-memory mode) from a fleet snapshot; every
    /// adapter is re-validated on the way in.
    pub fn restore(path: impl AsRef<Path>) -> Result<Registry> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading fleet snapshot {}", path.display()))?;
        let (base, base_spec, tenants) = gsad::decode_fleet(&bytes)?;
        let reg = Registry::new(base, base_spec)?;
        for (t, e) in tenants {
            reg.register(t, e)?;
        }
        Ok(reg)
    }

    /// Cold merge: produce the tenant's dense merged base buffer
    /// (`W' = Q W` per adapted layer). This is the expensive path the
    /// serving cache exists to amortize.
    pub fn merge(&self, tenant: TenantId) -> Result<Vec<f32>> {
        let entry = self
            .get(tenant)
            .ok_or_else(|| anyhow!("unknown tenant {tenant}"))?;
        merge_entry(
            &entry.desc,
            &self.base.weights,
            &entry.params,
            &self.base.spec,
            &entry.spec,
        )
    }
}

/// Names of the square adapted layers in a [`synthetic`] registry.
pub fn synthetic_layer_names(layers: usize) -> Vec<String> {
    (0..layers).map(|i| format!("layer{i}.w")).collect()
}

/// Square `d×d` base (plus an unadapted head) shared by the synthetic
/// registry builders.
fn synthetic_base(layers: usize, d: usize, rng: &mut Rng) -> Result<Registry> {
    let mut base_entries: Vec<(String, Vec<usize>)> = synthetic_layer_names(layers)
        .into_iter()
        .map(|n| (n, vec![d, d]))
        .collect();
    base_entries.push(("head".to_string(), vec![d, 2]));
    let base_spec = FlatSpec {
        entries: base_entries,
    };
    let base: Vec<f32> = rng.normal_vec(base_spec.size(), (1.0 / d as f32).sqrt());
    Registry::new(base, base_spec)
}

/// Build a synthetic many-tenant registry for benchmarks and tests:
/// `layers` square `d×d` base matrices (plus an unadapted head), and one
/// adapter per tenant — GSOFT for most tenants, OFT and LoRA sprinkled in
/// (tenant id mod 4) to exercise every merge path. Specs and init scales
/// come from the families themselves.
pub fn synthetic(
    tenants: usize,
    layers: usize,
    d: usize,
    block: usize,
    seed: u64,
) -> Result<Registry> {
    anyhow::ensure!(d % block == 0, "block must divide d");
    let mut rng = Rng::new(seed);
    let registry = synthetic_base(layers, d, &mut rng)?;
    let names = synthetic_layer_names(layers);

    // Per-kind descriptors + shared specs, generated by the families.
    let mk = |kind: AdapterKind| -> Result<(AdapterDesc, Arc<FlatSpec>)> {
        let desc = kind.desc();
        let spec = desc.family().synthetic_spec(desc.cfg(), &names, d, block)?;
        Ok((desc, Arc::new(spec)))
    };
    let gsoft = mk(AdapterKind::Gsoft { block })?;
    let lora = mk(AdapterKind::Lora)?;
    let oft = mk(AdapterKind::Oft { block })?;
    let mix = [&gsoft, &gsoft, &lora, &oft];

    for t in 0..tenants as TenantId {
        let mut trng = rng.fork(t);
        let (desc, spec) = mix[(t % 4) as usize];
        let std = desc.family().synthetic_std(desc.cfg());
        let params = trng.normal_vec(spec.size(), std);
        registry.register(
            t,
            AdapterEntry {
                desc: desc.clone(),
                params: Arc::new(params),
                spec: Arc::clone(spec),
            },
        )?;
    }
    Ok(registry)
}

/// Build a synthetic registry where every tenant runs one family — fully
/// generic over the open family set, so external families (e.g.
/// [`crate::adapter::monarch`]) get bench/test coverage with zero edits
/// here. `hint` is forwarded to
/// [`crate::adapter::AdapterFamily::synthetic_spec`].
pub fn synthetic_of(
    desc: &AdapterDesc,
    tenants: usize,
    layers: usize,
    d: usize,
    hint: usize,
    seed: u64,
) -> Result<Registry> {
    let mut rng = Rng::new(seed);
    let registry = synthetic_base(layers, d, &mut rng)?;
    let names = synthetic_layer_names(layers);
    let spec = Arc::new(desc.family().synthetic_spec(desc.cfg(), &names, d, hint)?);
    let std = desc.family().synthetic_std(desc.cfg());
    for t in 0..tenants as TenantId {
        let mut trng = rng.fork(t);
        let params = trng.normal_vec(spec.size(), std);
        registry.register(
            t,
            AdapterEntry {
                desc: desc.clone(),
                params: Arc::new(params),
                spec: Arc::clone(&spec),
            },
        )?;
    }
    Ok(registry)
}

/// Taylor terms used for synthetic GS-SOC conv tenants (matches the SOC
/// practice of a short series; the small synthetic kernel magnitudes keep
/// it converged).
pub const SYNTHETIC_CONV_TERMS: usize = 8;

/// Build a synthetic registry of GS-SOC orthogonal-convolution tenants
/// (§6.3 served as adapters): `layers` square `d×d` base matrices with
/// `d = c·h·w`, and one `ConvGsSoc` adapter per tenant holding a raw
/// grouped kernel slab per layer.
#[allow(clippy::too_many_arguments)]
pub fn synthetic_conv(
    tenants: usize,
    layers: usize,
    c: usize,
    k: usize,
    groups: usize,
    h: usize,
    w: usize,
    seed: u64,
) -> Result<Registry> {
    anyhow::ensure!(groups > 0 && c % groups == 0, "groups must divide c");
    anyhow::ensure!(k % 2 == 1, "same-padded conv needs odd k");
    let desc = AdapterKind::ConvGsSoc {
        c,
        k,
        groups,
        h,
        w,
        terms: SYNTHETIC_CONV_TERMS,
    }
    .desc();
    synthetic_of(&desc, tenants, layers, c * h * w, 0, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn synthetic_registry_builds_and_merges_every_kind() {
        let reg = synthetic(8, 2, 8, 2, 1).unwrap();
        assert_eq!(reg.len(), 8);
        for t in reg.tenant_ids() {
            let merged = reg.merge(t).unwrap();
            assert_eq!(merged.len(), reg.base().weights.len());
            assert!(merged.iter().all(|x| x.is_finite()));
            // Orthogonal kinds preserve the base layer's singular values.
            let entry = reg.get(t).unwrap();
            if entry.desc.is_orthogonal() {
                let spec = &reg.base().spec;
                let w0 = Mat::from_f32(8, 8, spec.view(&reg.base().weights, "layer0.w").unwrap());
                let w1 = Mat::from_f32(8, 8, spec.view(&merged, "layer0.w").unwrap());
                let s0 = crate::linalg::singular_values(&w0);
                let s1 = crate::linalg::singular_values(&w1);
                for (a, b) in s0.iter().zip(s1.iter()) {
                    assert!((a - b).abs() < 1e-4, "tenant {t}: {a} vs {b}");
                }
            }
            // Head is never adapted.
            let spec = &reg.base().spec;
            assert_eq!(
                spec.view(&merged, "head").unwrap(),
                spec.view(&reg.base().weights, "head").unwrap()
            );
        }
    }

    #[test]
    fn register_validates_sizes_and_layers() {
        let reg = synthetic(1, 1, 8, 2, 2).unwrap();
        let good = reg.get(0).unwrap();
        // Wrong buffer length.
        let bad = AdapterEntry {
            desc: good.desc.clone(),
            params: Arc::new(vec![0.0; 3]),
            spec: Arc::clone(&good.spec),
        };
        assert!(reg.register(9, bad).is_err());
        // Unknown base layer.
        let bad_spec = Arc::new(FlatSpec {
            entries: vec![("nope.gs_l".to_string(), vec![4, 2, 2])],
        });
        let bad = AdapterEntry {
            desc: good.desc.clone(),
            params: Arc::new(vec![0.0; 16]),
            spec: bad_spec,
        };
        assert!(reg.register(9, bad).is_err());
        assert!(!reg.contains(9));
        assert!(reg.merge(77).is_err(), "unknown tenant");
    }

    #[test]
    fn register_rejects_kind_and_shape_mismatches() {
        use crate::coordinator::merge::AdapterKind;
        let reg = synthetic(1, 1, 8, 2, 3).unwrap();

        // Slab block size disagrees with the kind's block size.
        let spec = Arc::new(FlatSpec {
            entries: vec![("layer0.w.oft_k".to_string(), vec![2, 4, 4])],
        });
        let bad = AdapterEntry {
            desc: AdapterKind::Oft { block: 3 }.desc(),
            params: Arc::new(vec![0.0; 32]),
            spec: Arc::clone(&spec),
        };
        assert!(reg.register(9, bad).is_err(), "block 3 does not divide 8");
        let bad = AdapterEntry {
            desc: AdapterKind::Oft { block: 2 }.desc(),
            params: Arc::new(vec![0.0; 32]),
            spec,
        };
        assert!(reg.register(9, bad).is_err(), "slab shaped for block 4, kind says 2");

        // Entry suffix from a different adapter family.
        let spec = Arc::new(FlatSpec {
            entries: vec![("layer0.w.gs_l".to_string(), vec![4, 2, 2])],
        });
        let bad = AdapterEntry {
            desc: AdapterKind::Oft { block: 2 }.desc(),
            params: Arc::new(vec![0.0; 16]),
            spec,
        };
        assert!(reg.register(9, bad).is_err(), "gs_l slab under an OFT kind");

        // LoRA with mismatched a/b ranks.
        let spec = Arc::new(FlatSpec {
            entries: vec![
                ("layer0.w.lora_a".to_string(), vec![8, 2]),
                ("layer0.w.lora_b".to_string(), vec![3, 8]),
            ],
        });
        let bad = AdapterEntry {
            desc: AdapterKind::Lora.desc(),
            params: Arc::new(vec![0.0; 16 + 24]),
            spec,
        };
        assert!(reg.register(9, bad).is_err(), "rank 2 a vs rank 3 b");

        // Unpaired factors: lone gs_r would be silently ignored, lone
        // lora_b likewise; both must be rejected.
        let spec = Arc::new(FlatSpec {
            entries: vec![("layer0.w.gs_r".to_string(), vec![4, 2, 2])],
        });
        let bad = AdapterEntry {
            desc: AdapterKind::Gsoft { block: 2 }.desc(),
            params: Arc::new(vec![0.0; 16]),
            spec,
        };
        assert!(reg.register(9, bad).is_err(), "gs_r without gs_l");
        let spec = Arc::new(FlatSpec {
            entries: vec![("layer0.w.lora_b".to_string(), vec![2, 8])],
        });
        let bad = AdapterEntry {
            desc: AdapterKind::Lora.desc(),
            params: Arc::new(vec![0.0; 16]),
            spec,
        };
        assert!(reg.register(9, bad).is_err(), "lora_b without lora_a");
        assert!(!reg.contains(9));
    }

    #[test]
    fn synthetic_conv_registry_builds_and_merges() {
        let reg = synthetic_conv(3, 2, 4, 3, 2, 2, 3, 21).unwrap();
        assert_eq!(reg.len(), 3);
        let d = 4 * 2 * 3;
        for t in reg.tenant_ids() {
            let merged = reg.merge(t).unwrap();
            assert_eq!(merged.len(), reg.base().weights.len());
            assert!(merged.iter().all(|x| x.is_finite()));
            // Orthogonal conv Q preserves each layer's singular values.
            let spec = &reg.base().spec;
            let w0 = Mat::from_f32(d, d, spec.view(&reg.base().weights, "layer0.w").unwrap());
            let w1 = Mat::from_f32(d, d, spec.view(&merged, "layer0.w").unwrap());
            let s0 = crate::linalg::singular_values(&w0);
            let s1 = crate::linalg::singular_values(&w1);
            for (a, b) in s0.iter().zip(s1.iter()) {
                assert!((a - b).abs() < 1e-3, "tenant {t}: {a} vs {b}");
            }
            // Head never adapted.
            assert_eq!(
                spec.view(&merged, "head").unwrap(),
                spec.view(&reg.base().weights, "head").unwrap()
            );
        }
    }

    #[test]
    fn register_rejects_malformed_conv_gssoc_entries() {
        use crate::coordinator::merge::AdapterKind;
        let reg = synthetic_conv(1, 1, 4, 3, 2, 2, 3, 22).unwrap();
        let good_desc = AdapterKind::ConvGsSoc {
            c: 4,
            k: 3,
            groups: 2,
            h: 2,
            w: 3,
            terms: 8,
        }
        .desc();
        let slab = 4 * 2 * 3 * 3;

        // Geometry c·h·w ≠ layer dim.
        let spec = Arc::new(FlatSpec {
            entries: vec![("layer0.w.soc_k".to_string(), vec![4, 2, 3, 3])],
        });
        let bad = AdapterEntry {
            desc: AdapterKind::ConvGsSoc {
                c: 4,
                k: 3,
                groups: 2,
                h: 3,
                w: 3,
                terms: 8,
            }
            .desc(),
            params: Arc::new(vec![0.0; slab]),
            spec: Arc::clone(&spec),
        };
        assert!(reg.register(9, bad).is_err(), "c·h·w = 36 vs layer dim 24");

        // Slab shaped for the wrong group count.
        let wrong = Arc::new(FlatSpec {
            entries: vec![("layer0.w.soc_k".to_string(), vec![4, 4, 3, 3])],
        });
        let bad = AdapterEntry {
            desc: good_desc.clone(),
            params: Arc::new(vec![0.0; 4 * 4 * 3 * 3]),
            spec: wrong,
        };
        assert!(reg.register(9, bad).is_err(), "slab for groups=1, kind says 2");

        // Foreign suffix under a conv kind.
        let foreign = Arc::new(FlatSpec {
            entries: vec![("layer0.w.gs_l".to_string(), vec![4, 2, 3, 3])],
        });
        let bad = AdapterEntry {
            desc: good_desc,
            params: Arc::new(vec![0.0; slab]),
            spec: foreign,
        };
        assert!(reg.register(9, bad).is_err(), "gs_l slab under a conv kind");

        // Even kernel size.
        let spec = Arc::new(FlatSpec {
            entries: vec![("layer0.w.soc_k".to_string(), vec![4, 2, 2, 2])],
        });
        let bad = AdapterEntry {
            desc: AdapterKind::ConvGsSoc {
                c: 4,
                k: 2,
                groups: 2,
                h: 2,
                w: 3,
                terms: 8,
            }
            .desc(),
            params: Arc::new(vec![0.0; 4 * 2 * 2 * 2]),
            spec,
        };
        assert!(reg.register(9, bad).is_err(), "even kernel size");
        assert!(!reg.contains(9));
    }

    #[test]
    fn external_family_registers_and_merges_through_the_open_api() {
        // Monarch exists only as a family module + one registration line:
        // the registry must validate, persist, and merge it with zero
        // family-specific code here.
        let desc = crate::adapter::monarch::desc(4);
        let reg = synthetic_of(&desc, 3, 2, 16, 4, 77).unwrap();
        assert_eq!(reg.len(), 3);
        for t in reg.tenant_ids() {
            let entry = reg.get(t).unwrap();
            assert_eq!(entry.desc.tag(), "monarch");
            assert!(entry.desc.is_orthogonal());
            let merged = reg.merge(t).unwrap();
            let spec = &reg.base().spec;
            let w0 = Mat::from_f32(16, 16, spec.view(&reg.base().weights, "layer0.w").unwrap());
            let w1 = Mat::from_f32(16, 16, spec.view(&merged, "layer0.w").unwrap());
            let s0 = crate::linalg::singular_values(&w0);
            let s1 = crate::linalg::singular_values(&w1);
            for (a, b) in s0.iter().zip(s1.iter()) {
                assert!((a - b).abs() < 1e-4, "tenant {t}: {a} vs {b}");
            }
        }
        // The Monarch coupling (d = block²) is enforced at registration.
        assert!(
            synthetic_of(&desc, 1, 1, 8, 4, 78).is_err(),
            "d=8 with block=4 violates d = block²"
        );
    }

    use crate::store::gsad::tests::entries_equal;
    use crate::store::AdapterStore;
    use crate::util::prop;
    use crate::util::tmp::unique_temp_dir;

    /// Harvest a pool of valid adapter entries (mixed kinds) plus the
    /// base they are valid for.
    fn entry_pool(seed: u64) -> (Vec<f32>, FlatSpec, Vec<AdapterEntry>) {
        let donor = synthetic(6, 2, 8, 2, seed).unwrap();
        let pool: Vec<AdapterEntry> =
            donor.tenant_ids().into_iter().map(|t| donor.get(t).unwrap()).collect();
        (
            donor.base().weights.as_ref().clone(),
            donor.base().spec.as_ref().clone(),
            pool,
        )
    }

    #[derive(Debug, Clone)]
    struct RegCase {
        /// (tenant, op, pool index); op: 0 register, 1 get, 2 unregister,
        /// 3 drop_hydrated, 4 register an invalid entry.
        ops: Vec<(TenantId, u8, usize)>,
    }

    fn shrink_reg(c: &RegCase) -> Vec<RegCase> {
        let mut out = Vec::new();
        if !c.ops.is_empty() {
            out.push(RegCase {
                ops: c.ops[..c.ops.len() / 2].to_vec(),
            });
            let mut tail = c.ops.clone();
            tail.remove(0);
            out.push(RegCase { ops: tail });
        }
        out
    }

    #[test]
    fn store_backed_registry_behaves_identically_to_in_memory() {
        // Property (shrinking): under a random register / get /
        // unregister / drop-hydration sequence, a store-backed registry
        // is observationally identical to the plain in-memory one —
        // same membership, same sizes, and bit-identical adapters.
        let (base, spec, pool) = entry_pool(51);
        prop::check_shrunk(
            "store-backed registry == in-memory registry",
            903,
            16,
            |rng| RegCase {
                ops: (0..prop::size_in(rng, 1, 20))
                    .map(|_| {
                        (
                            rng.below(4) as TenantId,
                            rng.below(5) as u8,
                            rng.below(6),
                        )
                    })
                    .collect(),
            },
            shrink_reg,
            |case| {
                let dir = unique_temp_dir("reg_equiv");
                let mem = Registry::new(base.clone(), spec.clone()).unwrap();
                let sb = Registry::with_store(
                    base.clone(),
                    spec.clone(),
                    AdapterStore::open(&dir).unwrap(),
                )
                .unwrap();
                for &(tenant, op, pick) in &case.ops {
                    match op {
                        0 => {
                            let e = pool[pick].clone();
                            mem.register(tenant, e.clone()).unwrap();
                            sb.register(tenant, e).unwrap();
                        }
                        1 => {
                            let a = mem.get(tenant);
                            let b = sb.get(tenant);
                            match (&a, &b) {
                                (None, None) => {}
                                (Some(x), Some(y)) => {
                                    assert!(entries_equal(x, y), "get({tenant}) diverged")
                                }
                                _ => panic!(
                                    "get({tenant}): in-memory {:?} vs store-backed {:?}",
                                    a.is_some(),
                                    b.is_some()
                                ),
                            }
                        }
                        2 => {
                            let a = mem.unregister(tenant).unwrap();
                            let b = sb.unregister(tenant).unwrap();
                            assert_eq!(a, b, "unregister({tenant}) diverged");
                        }
                        3 => {
                            // Dehydration is a cache action: it must not
                            // change observable state on either side.
                            mem.drop_hydrated(tenant);
                            sb.drop_hydrated(tenant);
                        }
                        _ => {
                            let good = &pool[pick];
                            let bad = AdapterEntry {
                                desc: good.desc.clone(),
                                params: Arc::new(vec![0.0; 3]),
                                spec: Arc::clone(&good.spec),
                            };
                            assert!(mem.register(tenant, bad.clone()).is_err());
                            assert!(sb.register(tenant, bad).is_err());
                        }
                    }
                    assert_eq!(mem.len(), sb.len(), "len diverged");
                    assert_eq!(mem.tenant_ids(), sb.tenant_ids(), "tenant set diverged");
                    for t in 0..4u64 {
                        assert_eq!(mem.contains(t), sb.contains(t), "contains({t}) diverged");
                    }
                }
                let _ = std::fs::remove_dir_all(&dir);
            },
        );
    }

    #[test]
    fn store_backed_registry_hydrates_lazily_across_reopen() {
        let (base, spec, pool) = entry_pool(52);
        let dir = unique_temp_dir("reg_reopen");
        {
            let reg = Registry::with_store(
                base.clone(),
                spec.clone(),
                AdapterStore::open(&dir).unwrap(),
            )
            .unwrap();
            for (t, e) in pool.iter().enumerate() {
                reg.register(t as TenantId, e.clone()).unwrap();
            }
            assert!(reg.is_store_backed());
        } // drop all in-memory state
        let reg =
            Registry::with_store(base, spec, AdapterStore::open(&dir).unwrap()).unwrap();
        assert_eq!(reg.len(), pool.len(), "membership survives reopen");
        assert_eq!(reg.hydrated_len(), 0, "reopen must not eagerly load");
        // Maintenance reads must not populate the hydration cache:
        // family inspection (engine policy inference) and fleet
        // snapshots.
        assert_eq!(reg.desc_of(0), Some(pool[0].desc.clone()));
        reg.snapshot(dir.join("fleet.gsad")).unwrap();
        assert_eq!(
            reg.hydrated_len(),
            0,
            "desc_of/snapshot must read uncached, not hydrate the fleet"
        );
        let e0 = reg.get(0).expect("tenant 0 hydrates");
        assert!(entries_equal(&e0, &pool[0]));
        assert_eq!(reg.hydrated_len(), 1, "get() hydrated exactly one tenant");
        reg.drop_hydrated(0);
        assert_eq!(reg.hydrated_len(), 0);
        assert!(reg.contains(0), "dehydration keeps the durable record");
        // Merging a lazily hydrated tenant works end to end.
        let merged = reg.merge(1).unwrap();
        assert_eq!(merged.len(), reg.base().weights.len());
        assert_eq!(reg.hydrate_all().unwrap(), pool.len() - 1);
        assert_eq!(reg.hydrated_len(), pool.len());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn re_registration_fires_the_update_hook_and_refreshes_the_crc() {
        let (base, spec, pool) = entry_pool(54);
        let dir = unique_temp_dir("reg_rereg");
        let reg = Registry::with_store(
            base,
            spec,
            AdapterStore::open(&dir).unwrap(),
        )
        .unwrap();
        let fired: Arc<Mutex<Vec<TenantId>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&fired);
        reg.set_update_hook(Box::new(move |t| sink.lock().unwrap().push(t)));

        assert_eq!(reg.params_crc_of(7), None, "unknown tenant has no CRC");
        reg.register(7, pool[0].clone()).unwrap();
        assert!(fired.lock().unwrap().is_empty(), "first registration is not an update");
        let crc1 = reg.params_crc_of(7).expect("registered tenant has a CRC");
        assert_eq!(crc1, crate::store::gsad::params_crc(&pool[0]));

        // Overwrite with different params: hook fires, CRC moves.
        reg.register(7, pool[1].clone()).unwrap();
        assert_eq!(*fired.lock().unwrap(), vec![7]);
        let crc2 = reg.params_crc_of(7).unwrap();
        assert_eq!(crc2, crate::store::gsad::params_crc(&pool[1]));
        assert_ne!(crc1, crc2, "pool entries must differ for this test");

        // A dehydrated tenant still answers the CRC oracle (one uncached
        // read), and unregister forgets it.
        reg.drop_hydrated(7);
        assert_eq!(reg.params_crc_of(7), Some(crc2));
        assert!(reg.unregister(7).unwrap());
        assert_eq!(reg.params_crc_of(7), None);
        assert_eq!(*fired.lock().unwrap(), vec![7], "unregister is not an update");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_registrations_across_shards_all_land() {
        // The lock-narrowing contract: stripes only serialize same-shard
        // tenants, so a storm of distinct-tenant registrations from many
        // threads must all be acknowledged, durable, and readable.
        let (base, spec, pool) = entry_pool(55);
        let dir = unique_temp_dir("reg_storm");
        let reg = Registry::with_store(
            base,
            spec,
            AdapterStore::open_sharded(&dir, 8).unwrap(),
        )
        .unwrap();
        crate::util::pool::parallel_map(48, 8, |i| {
            let t = i as TenantId;
            reg.register(t, pool[i % pool.len()].clone()).unwrap();
        });
        assert_eq!(reg.len(), 48);
        for i in 0..48usize {
            let t = i as TenantId;
            let back = reg.get(t).expect("registered tenant");
            assert!(entries_equal(&back, &pool[i % pool.len()]), "tenant {t} drifted");
            assert_eq!(
                reg.params_crc_of(t),
                Some(crate::store::gsad::params_crc(&pool[i % pool.len()]))
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fleet_snapshot_restores_bit_identically() {
        let reg = synthetic(5, 2, 8, 2, 53).unwrap();
        let dir = unique_temp_dir("reg_fleet");
        let path = dir.join("fleet.gsad");
        reg.snapshot(&path).unwrap();
        let back = Registry::restore(&path).unwrap();
        assert_eq!(back.tenant_ids(), reg.tenant_ids());
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(
            bits(&back.base().weights),
            bits(&reg.base().weights),
            "base weights must survive bit-exactly"
        );
        for t in reg.tenant_ids() {
            assert!(entries_equal(&back.get(t).unwrap(), &reg.get(t).unwrap()));
            // Merges (pure functions of base+adapter) are bit-identical.
            assert_eq!(bits(&back.merge(t).unwrap()), bits(&reg.merge(t).unwrap()));
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
