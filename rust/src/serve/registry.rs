//! Multi-tenant adapter registry: one frozen base model (flat f32 buffer +
//! [`FlatSpec`]) shared by every tenant, plus per-tenant adapter parameters
//! (GSOFT / OFT / LoRA — the §6.1 use-case of thousands of cheap
//! orthogonal adapters over one pretrained model).

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use anyhow::{anyhow, Result};

use crate::coordinator::merge::{merge_adapter, AdapterKind};
use crate::coordinator::FlatSpec;
use crate::util::rng::Rng;

/// Tenant identifier (subject / task / user id).
pub type TenantId = u64;

/// One tenant's adapter: kind + flat parameters + their layout.
#[derive(Clone)]
pub struct AdapterEntry {
    pub kind: AdapterKind,
    pub params: Arc<Vec<f32>>,
    pub spec: Arc<FlatSpec>,
}

/// The shared base model every tenant adapts.
#[derive(Clone)]
pub struct BaseModel {
    pub weights: Arc<Vec<f32>>,
    pub spec: Arc<FlatSpec>,
}

/// Registry of adapters keyed by tenant id over one shared base.
/// Registration is concurrent-safe (`RwLock`); lookups clone `Arc`s only.
pub struct Registry {
    base: BaseModel,
    tenants: RwLock<HashMap<TenantId, AdapterEntry>>,
}

impl Registry {
    pub fn new(base_weights: Vec<f32>, base_spec: FlatSpec) -> Result<Registry> {
        anyhow::ensure!(
            base_weights.len() == base_spec.size(),
            "base buffer has {} floats but spec expects {}",
            base_weights.len(),
            base_spec.size()
        );
        Ok(Registry {
            base: BaseModel {
                weights: Arc::new(base_weights),
                spec: Arc::new(base_spec),
            },
            tenants: RwLock::new(HashMap::new()),
        })
    }

    pub fn base(&self) -> &BaseModel {
        &self.base
    }

    /// Register (or replace) a tenant's adapter. Validates the parameter
    /// buffer against its spec, that every adapted layer exists in the
    /// base spec, and that every slab's shape is consistent with the
    /// adapter kind and the adapted layer's dimensions — a malformed
    /// entry must be rejected here, not panic later inside a serving
    /// worker.
    pub fn register(&self, tenant: TenantId, entry: AdapterEntry) -> Result<()> {
        anyhow::ensure!(
            entry.params.len() == entry.spec.size(),
            "tenant {tenant}: adapter buffer has {} floats but spec expects {}",
            entry.params.len(),
            entry.spec.size()
        );
        for (name, shape) in &entry.spec.entries {
            let (layer, suffix) = name
                .rsplit_once('.')
                .ok_or_else(|| anyhow!("tenant {tenant}: bad adapter entry name '{name}'"))?;
            let (_, wshape) = self
                .base
                .spec
                .locate(layer)
                .map_err(|_| anyhow!("tenant {tenant}: adapts unknown base layer '{layer}'"))?;
            anyhow::ensure!(
                wshape.len() == 2,
                "tenant {tenant}: adapted base entry '{layer}' is not a matrix"
            );
            let (din, dout) = (wshape[0], wshape[1]);
            match entry.kind {
                AdapterKind::Gsoft { block } | AdapterKind::Oft { block } => {
                    let suffix_ok = match entry.kind {
                        AdapterKind::Gsoft { .. } => suffix == "gs_l" || suffix == "gs_r",
                        _ => suffix == "oft_k",
                    };
                    anyhow::ensure!(
                        suffix_ok,
                        "tenant {tenant}: entry '{name}' does not belong to a {} adapter",
                        entry.kind.name()
                    );
                    anyhow::ensure!(
                        block > 0 && din % block == 0,
                        "tenant {tenant}: block {block} does not divide layer dim {din}"
                    );
                    anyhow::ensure!(
                        *shape == [din / block, block, block],
                        "tenant {tenant}: '{name}' has shape {shape:?}, expected {:?}",
                        [din / block, block, block]
                    );
                    // GSOFT factors come in pairs: a lone gs_l errors at
                    // serve time, a lone gs_r is silently ignored — both
                    // must be rejected here.
                    if suffix == "gs_l" || suffix == "gs_r" {
                        let other = if suffix == "gs_l" { "gs_r" } else { "gs_l" };
                        let paired = entry
                            .spec
                            .locate(&format!("{layer}.{other}"))
                            .map(|(_, s)| s == &shape[..])
                            .unwrap_or(false);
                        anyhow::ensure!(
                            paired,
                            "tenant {tenant}: '{name}' has no matching '{layer}.{other}'"
                        );
                    }
                }
                AdapterKind::Lora => match suffix {
                    "lora_a" => {
                        anyhow::ensure!(
                            shape.len() == 2 && shape[0] == din,
                            "tenant {tenant}: '{name}' has shape {shape:?}, expected [{din}, rank]"
                        );
                        let (_, bshape) = entry
                            .spec
                            .locate(&format!("{layer}.lora_b"))
                            .map_err(|_| anyhow!("tenant {tenant}: '{name}' has no paired lora_b"))?;
                        anyhow::ensure!(
                            bshape.len() == 2 && bshape[0] == shape[1] && bshape[1] == dout,
                            "tenant {tenant}: '{layer}.lora_b' has shape {bshape:?}, \
                             expected [{}, {dout}]",
                            shape[1]
                        );
                    }
                    "lora_b" => {
                        // Shape details are checked from the lora_a side;
                        // here just reject an unpaired lora_b (it would be
                        // silently ignored by merge and serve).
                        anyhow::ensure!(
                            entry.spec.locate(&format!("{layer}.lora_a")).is_ok(),
                            "tenant {tenant}: '{name}' has no matching '{layer}.lora_a'"
                        );
                    }
                    _ => anyhow::bail!(
                        "tenant {tenant}: entry '{name}' does not belong to a LoRA adapter"
                    ),
                },
                AdapterKind::ConvGsSoc {
                    c,
                    k,
                    groups,
                    h,
                    w,
                    terms,
                } => {
                    anyhow::ensure!(
                        suffix == "soc_k",
                        "tenant {tenant}: entry '{name}' does not belong to a conv_gssoc adapter"
                    );
                    anyhow::ensure!(
                        k % 2 == 1,
                        "tenant {tenant}: same-padded conv needs an odd kernel (got k={k})"
                    );
                    anyhow::ensure!(
                        terms >= 1,
                        "tenant {tenant}: conv exponential needs at least one Taylor term"
                    );
                    anyhow::ensure!(
                        groups > 0 && c % groups == 0,
                        "tenant {tenant}: groups {groups} must divide channels {c}"
                    );
                    anyhow::ensure!(
                        c * h * w == din,
                        "tenant {tenant}: adapted layer '{layer}' has input dim {din}, \
                         but the conv geometry gives c·h·w = {}·{}·{} = {}",
                        c,
                        h,
                        w,
                        c * h * w
                    );
                    anyhow::ensure!(
                        *shape == [c, c / groups, k, k],
                        "tenant {tenant}: '{name}' has shape {shape:?}, expected {:?}",
                        [c, c / groups, k, k]
                    );
                }
            }
        }
        self.tenants.write().unwrap().insert(tenant, entry);
        Ok(())
    }

    /// Cheap lookup (Arc clones).
    pub fn get(&self, tenant: TenantId) -> Option<AdapterEntry> {
        self.tenants.read().unwrap().get(&tenant).cloned()
    }

    pub fn contains(&self, tenant: TenantId) -> bool {
        self.tenants.read().unwrap().contains_key(&tenant)
    }

    pub fn len(&self) -> usize {
        self.tenants.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn tenant_ids(&self) -> Vec<TenantId> {
        let mut ids: Vec<TenantId> = self.tenants.read().unwrap().keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// Cold merge: produce the tenant's dense merged base buffer
    /// (`W' = Q W` per adapted layer). This is the expensive path the
    /// serving cache exists to amortize.
    pub fn merge(&self, tenant: TenantId) -> Result<Vec<f32>> {
        let entry = self
            .get(tenant)
            .ok_or_else(|| anyhow!("unknown tenant {tenant}"))?;
        merge_adapter(
            entry.kind,
            &self.base.weights,
            &entry.params,
            &self.base.spec,
            &entry.spec,
        )
    }
}

/// Names of the square adapted layers in a [`synthetic`] registry.
pub fn synthetic_layer_names(layers: usize) -> Vec<String> {
    (0..layers).map(|i| format!("layer{i}.w")).collect()
}

/// Build a synthetic many-tenant registry for benchmarks and tests:
/// `layers` square `d×d` base matrices (plus an unadapted head), and one
/// adapter per tenant — GSOFT for most tenants, OFT and LoRA sprinkled in
/// (tenant id mod 4) to exercise every merge path.
pub fn synthetic(
    tenants: usize,
    layers: usize,
    d: usize,
    block: usize,
    seed: u64,
) -> Result<Registry> {
    anyhow::ensure!(d % block == 0, "block must divide d");
    let r = d / block;
    let mut rng = Rng::new(seed);

    // Base spec: layer{i}.w [d,d] + head [d,2].
    let mut base_entries: Vec<(String, Vec<usize>)> = synthetic_layer_names(layers)
        .into_iter()
        .map(|n| (n, vec![d, d]))
        .collect();
    base_entries.push(("head".to_string(), vec![d, 2]));
    let base_spec = FlatSpec {
        entries: base_entries,
    };
    let base: Vec<f32> = rng.normal_vec(base_spec.size(), (1.0 / d as f32).sqrt());
    let registry = Registry::new(base, base_spec)?;

    // Per-kind adapter specs are shared across tenants.
    let gsoft_spec = Arc::new(FlatSpec {
        entries: synthetic_layer_names(layers)
            .into_iter()
            .flat_map(|n| {
                [
                    (format!("{n}.gs_l"), vec![r, block, block]),
                    (format!("{n}.gs_r"), vec![r, block, block]),
                ]
            })
            .collect(),
    });
    let oft_spec = Arc::new(FlatSpec {
        entries: synthetic_layer_names(layers)
            .into_iter()
            .map(|n| (format!("{n}.oft_k"), vec![r, block, block]))
            .collect(),
    });
    let lora_rank = block.min(d / 2).max(1);
    let lora_spec = Arc::new(FlatSpec {
        entries: synthetic_layer_names(layers)
            .into_iter()
            .flat_map(|n| {
                [
                    (format!("{n}.lora_a"), vec![d, lora_rank]),
                    (format!("{n}.lora_b"), vec![lora_rank, d]),
                ]
            })
            .collect(),
    });

    for t in 0..tenants as TenantId {
        let mut trng = rng.fork(t);
        let (kind, spec) = match t % 4 {
            3 => (AdapterKind::Oft { block }, Arc::clone(&oft_spec)),
            2 => (AdapterKind::Lora, Arc::clone(&lora_spec)),
            _ => (AdapterKind::Gsoft { block }, Arc::clone(&gsoft_spec)),
        };
        let std = if kind == AdapterKind::Lora { 0.05 } else { 0.3 };
        let params = trng.normal_vec(spec.size(), std);
        registry.register(
            t,
            AdapterEntry {
                kind,
                params: Arc::new(params),
                spec,
            },
        )?;
    }
    Ok(registry)
}

/// Taylor terms used for synthetic GS-SOC conv tenants (matches the SOC
/// practice of a short series; the small synthetic kernel magnitudes keep
/// it converged).
pub const SYNTHETIC_CONV_TERMS: usize = 8;

/// Build a synthetic registry of GS-SOC orthogonal-convolution tenants
/// (§6.3 served as adapters): `layers` square `d×d` base matrices with
/// `d = c·h·w`, and one `ConvGsSoc` adapter per tenant holding a raw
/// grouped kernel slab per layer.
#[allow(clippy::too_many_arguments)]
pub fn synthetic_conv(
    tenants: usize,
    layers: usize,
    c: usize,
    k: usize,
    groups: usize,
    h: usize,
    w: usize,
    seed: u64,
) -> Result<Registry> {
    anyhow::ensure!(groups > 0 && c % groups == 0, "groups must divide c");
    anyhow::ensure!(k % 2 == 1, "same-padded conv needs odd k");
    let d = c * h * w;
    let mut rng = Rng::new(seed);

    let mut base_entries: Vec<(String, Vec<usize>)> = synthetic_layer_names(layers)
        .into_iter()
        .map(|n| (n, vec![d, d]))
        .collect();
    base_entries.push(("head".to_string(), vec![d, 2]));
    let base_spec = FlatSpec {
        entries: base_entries,
    };
    let base: Vec<f32> = rng.normal_vec(base_spec.size(), (1.0 / d as f32).sqrt());
    let registry = Registry::new(base, base_spec)?;

    let spec = Arc::new(FlatSpec {
        entries: synthetic_layer_names(layers)
            .into_iter()
            .map(|n| (format!("{n}.soc_k"), vec![c, c / groups, k, k]))
            .collect(),
    });
    let kind = AdapterKind::ConvGsSoc {
        c,
        k,
        groups,
        h,
        w,
        terms: SYNTHETIC_CONV_TERMS,
    };
    for t in 0..tenants as TenantId {
        let mut trng = rng.fork(t);
        // Small kernel magnitude: keeps the truncated exponential
        // converged so factorized and merged serving agree tightly.
        let params = trng.normal_vec(spec.size(), 0.05);
        registry.register(
            t,
            AdapterEntry {
                kind,
                params: Arc::new(params),
                spec: Arc::clone(&spec),
            },
        )?;
    }
    Ok(registry)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn synthetic_registry_builds_and_merges_every_kind() {
        let reg = synthetic(8, 2, 8, 2, 1).unwrap();
        assert_eq!(reg.len(), 8);
        for t in reg.tenant_ids() {
            let merged = reg.merge(t).unwrap();
            assert_eq!(merged.len(), reg.base().weights.len());
            assert!(merged.iter().all(|x| x.is_finite()));
            // Orthogonal kinds preserve the base layer's singular values.
            let entry = reg.get(t).unwrap();
            if entry.kind.is_orthogonal() {
                let spec = &reg.base().spec;
                let w0 = Mat::from_f32(8, 8, spec.view(&reg.base().weights, "layer0.w").unwrap());
                let w1 = Mat::from_f32(8, 8, spec.view(&merged, "layer0.w").unwrap());
                let s0 = crate::linalg::singular_values(&w0);
                let s1 = crate::linalg::singular_values(&w1);
                for (a, b) in s0.iter().zip(s1.iter()) {
                    assert!((a - b).abs() < 1e-4, "tenant {t}: {a} vs {b}");
                }
            }
            // Head is never adapted.
            let spec = &reg.base().spec;
            assert_eq!(
                spec.view(&merged, "head").unwrap(),
                spec.view(&reg.base().weights, "head").unwrap()
            );
        }
    }

    #[test]
    fn register_validates_sizes_and_layers() {
        let reg = synthetic(1, 1, 8, 2, 2).unwrap();
        let good = reg.get(0).unwrap();
        // Wrong buffer length.
        let bad = AdapterEntry {
            kind: good.kind,
            params: Arc::new(vec![0.0; 3]),
            spec: Arc::clone(&good.spec),
        };
        assert!(reg.register(9, bad).is_err());
        // Unknown base layer.
        let bad_spec = Arc::new(FlatSpec {
            entries: vec![("nope.gs_l".to_string(), vec![4, 2, 2])],
        });
        let bad = AdapterEntry {
            kind: good.kind,
            params: Arc::new(vec![0.0; 16]),
            spec: bad_spec,
        };
        assert!(reg.register(9, bad).is_err());
        assert!(!reg.contains(9));
        assert!(reg.merge(77).is_err(), "unknown tenant");
    }

    #[test]
    fn register_rejects_kind_and_shape_mismatches() {
        use crate::coordinator::merge::AdapterKind;
        let reg = synthetic(1, 1, 8, 2, 3).unwrap();

        // Slab block size disagrees with the kind's block size.
        let spec = Arc::new(FlatSpec {
            entries: vec![("layer0.w.oft_k".to_string(), vec![2, 4, 4])],
        });
        let bad = AdapterEntry {
            kind: AdapterKind::Oft { block: 3 },
            params: Arc::new(vec![0.0; 32]),
            spec: Arc::clone(&spec),
        };
        assert!(reg.register(9, bad).is_err(), "block 3 does not divide 8");
        let bad = AdapterEntry {
            kind: AdapterKind::Oft { block: 2 },
            params: Arc::new(vec![0.0; 32]),
            spec,
        };
        assert!(reg.register(9, bad).is_err(), "slab shaped for block 4, kind says 2");

        // Entry suffix from a different adapter family.
        let spec = Arc::new(FlatSpec {
            entries: vec![("layer0.w.gs_l".to_string(), vec![4, 2, 2])],
        });
        let bad = AdapterEntry {
            kind: AdapterKind::Oft { block: 2 },
            params: Arc::new(vec![0.0; 16]),
            spec,
        };
        assert!(reg.register(9, bad).is_err(), "gs_l slab under an OFT kind");

        // LoRA with mismatched a/b ranks.
        let spec = Arc::new(FlatSpec {
            entries: vec![
                ("layer0.w.lora_a".to_string(), vec![8, 2]),
                ("layer0.w.lora_b".to_string(), vec![3, 8]),
            ],
        });
        let bad = AdapterEntry {
            kind: AdapterKind::Lora,
            params: Arc::new(vec![0.0; 16 + 24]),
            spec,
        };
        assert!(reg.register(9, bad).is_err(), "rank 2 a vs rank 3 b");

        // Unpaired factors: lone gs_r would be silently ignored, lone
        // lora_b likewise; both must be rejected.
        let spec = Arc::new(FlatSpec {
            entries: vec![("layer0.w.gs_r".to_string(), vec![4, 2, 2])],
        });
        let bad = AdapterEntry {
            kind: AdapterKind::Gsoft { block: 2 },
            params: Arc::new(vec![0.0; 16]),
            spec,
        };
        assert!(reg.register(9, bad).is_err(), "gs_r without gs_l");
        let spec = Arc::new(FlatSpec {
            entries: vec![("layer0.w.lora_b".to_string(), vec![2, 8])],
        });
        let bad = AdapterEntry {
            kind: AdapterKind::Lora,
            params: Arc::new(vec![0.0; 16]),
            spec,
        };
        assert!(reg.register(9, bad).is_err(), "lora_b without lora_a");
        assert!(!reg.contains(9));
    }

    #[test]
    fn synthetic_conv_registry_builds_and_merges() {
        let reg = synthetic_conv(3, 2, 4, 3, 2, 2, 3, 21).unwrap();
        assert_eq!(reg.len(), 3);
        let d = 4 * 2 * 3;
        for t in reg.tenant_ids() {
            let merged = reg.merge(t).unwrap();
            assert_eq!(merged.len(), reg.base().weights.len());
            assert!(merged.iter().all(|x| x.is_finite()));
            // Orthogonal conv Q preserves each layer's singular values.
            let spec = &reg.base().spec;
            let w0 = Mat::from_f32(d, d, spec.view(&reg.base().weights, "layer0.w").unwrap());
            let w1 = Mat::from_f32(d, d, spec.view(&merged, "layer0.w").unwrap());
            let s0 = crate::linalg::singular_values(&w0);
            let s1 = crate::linalg::singular_values(&w1);
            for (a, b) in s0.iter().zip(s1.iter()) {
                assert!((a - b).abs() < 1e-3, "tenant {t}: {a} vs {b}");
            }
            // Head never adapted.
            assert_eq!(
                spec.view(&merged, "head").unwrap(),
                spec.view(&reg.base().weights, "head").unwrap()
            );
        }
    }

    #[test]
    fn register_rejects_malformed_conv_gssoc_entries() {
        use crate::coordinator::merge::AdapterKind;
        let reg = synthetic_conv(1, 1, 4, 3, 2, 2, 3, 22).unwrap();
        let good_kind = AdapterKind::ConvGsSoc {
            c: 4,
            k: 3,
            groups: 2,
            h: 2,
            w: 3,
            terms: 8,
        };
        let slab = 4 * 2 * 3 * 3;

        // Geometry c·h·w ≠ layer dim.
        let spec = Arc::new(FlatSpec {
            entries: vec![("layer0.w.soc_k".to_string(), vec![4, 2, 3, 3])],
        });
        let bad = AdapterEntry {
            kind: AdapterKind::ConvGsSoc {
                c: 4,
                k: 3,
                groups: 2,
                h: 3,
                w: 3,
                terms: 8,
            },
            params: Arc::new(vec![0.0; slab]),
            spec: Arc::clone(&spec),
        };
        assert!(reg.register(9, bad).is_err(), "c·h·w = 36 vs layer dim 24");

        // Slab shaped for the wrong group count.
        let wrong = Arc::new(FlatSpec {
            entries: vec![("layer0.w.soc_k".to_string(), vec![4, 4, 3, 3])],
        });
        let bad = AdapterEntry {
            kind: good_kind,
            params: Arc::new(vec![0.0; 4 * 4 * 3 * 3]),
            spec: wrong,
        };
        assert!(reg.register(9, bad).is_err(), "slab for groups=1, kind says 2");

        // Foreign suffix under a conv kind.
        let foreign = Arc::new(FlatSpec {
            entries: vec![("layer0.w.gs_l".to_string(), vec![4, 2, 3, 3])],
        });
        let bad = AdapterEntry {
            kind: good_kind,
            params: Arc::new(vec![0.0; slab]),
            spec: foreign,
        };
        assert!(reg.register(9, bad).is_err(), "gs_l slab under a conv kind");

        // Even kernel size.
        let spec = Arc::new(FlatSpec {
            entries: vec![("layer0.w.soc_k".to_string(), vec![4, 2, 2, 2])],
        });
        let bad = AdapterEntry {
            kind: AdapterKind::ConvGsSoc {
                c: 4,
                k: 2,
                groups: 2,
                h: 2,
                w: 3,
                terms: 8,
            },
            params: Arc::new(vec![0.0; 4 * 2 * 2 * 2]),
            spec,
        };
        assert!(reg.register(9, bad).is_err(), "even kernel size");
        assert!(!reg.contains(9));
    }
}
