//! `gsoft` — launcher CLI for the Group-and-Shuffle reproduction.
//!
//! Subcommands regenerate every table/figure of the paper (see
//! DESIGN.md §3) plus utilities:
//!
//! ```text
//! gsoft table1   [--steps N --pretrain-steps N --lr X --workers N]
//! gsoft table2   | gsoft fig6
//! gsoft table3   | gsoft table4
//! gsoft density  [--d 1024 --b 32]
//! gsoft params-table
//! gsoft perms
//! gsoft merge-demo
//! gsoft list     # artifacts in the registry
//! gsoft all      # every experiment, in order
//! ```

use anyhow::Result;

use gsoft::coordinator::config::RunOpts;
use gsoft::coordinator::experiments::{statics, table1, table2, table3};
use gsoft::util::cli::Args;

const FLAGS: &[&str] = &["no-cache", "help"];

fn main() {
    let args = Args::from_env(FLAGS);
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    let sub = args.subcommand.as_deref().unwrap_or("help");
    match sub {
        "table1" => {
            let opts = RunOpts::load("table1", args)?;
            table1::run(&opts)?.emit("table1")?;
        }
        "table2" => {
            let opts = RunOpts::load("table2", args)?;
            table2::run(&opts)?.emit("table2")?;
        }
        "fig6" => {
            let opts = RunOpts::load("table2", args)?;
            table2::fig6(&opts)?.emit("fig6")?;
        }
        "table3" => {
            let opts = RunOpts::load("table3", args)?;
            match args.opt("variants") {
                Some(csv) => {
                    let vs: Vec<String> = csv.split(',').map(String::from).collect();
                    let cells = table3::run_variants(&vs, &opts)?;
                    table3::render_partial("Table 3 (subset)", &cells, false).emit("table3")?;
                }
                None => table3::run_table3(&opts)?.emit("table3")?,
            }
        }
        "table4" => {
            let opts = RunOpts::load("table3", args)?;
            match args.opt("variants") {
                Some(csv) => {
                    let vs: Vec<String> = csv.split(',').map(String::from).collect();
                    let cells = table3::run_variants(&vs, &opts)?;
                    table3::render_partial("Table 4 (subset)", &cells, true).emit("table4")?;
                }
                None => table3::run_table4(&opts)?.emit("table4")?,
            }
        }
        "density" => {
            let d = args.opt_usize("d", 1024)?;
            let b = args.opt_usize("b", 32)?;
            statics::density_table(d, b)?.emit("density")?;
        }
        "params-table" => {
            statics::params_table().emit("params_table")?;
            statics::budget_table(args.opt_usize("d", 128)?).emit("budgets")?;
        }
        "perms" => {
            let s = statics::perms_figure();
            println!("{s}");
            std::fs::create_dir_all("results")?;
            std::fs::write("results/fig3_perms.txt", s)?;
        }
        "merge-demo" => merge_demo(args)?,
        "compress-demo" => compress_demo(args)?,
        "list" => {
            let opts = RunOpts::load("table1", args)?;
            let rt = gsoft::runtime::Runtime::new(&opts.artifacts)?;
            println!("platform: {}", rt.platform());
            for name in rt.manifest()? {
                println!("  {name}");
            }
        }
        "all" => {
            let t1 = RunOpts::load("table1", args)?;
            table1::run(&t1)?.emit("table1")?;
            let t2 = RunOpts::load("table2", args)?;
            table2::run(&t2)?.emit("table2")?;
            table2::fig6(&t2)?.emit("fig6")?;
            let t3 = RunOpts::load("table3", args)?;
            table3::run_table4(&t3)?.emit("table4")?;
            table3::run_table3(&t3)?.emit("table3")?;
            statics::params_table().emit("params_table")?;
            statics::density_table(1024, 32)?.emit("density")?;
        }
        _ => {
            println!("{HELP}");
        }
    }
    Ok(())
}

/// End-to-end "no inference overhead" demonstration: fine-tune GSOFT on
/// one task, merge Q into the base weights in Rust (exact GS algebra),
/// and verify the plain (ft) forward pass reproduces the adapted model's
/// predictions at the eval batches.
fn merge_demo(args: &Args) -> Result<()> {
    use gsoft::coordinator::experiments::pretrained_cls_base;
    use gsoft::coordinator::flatspec::FlatSpec;
    use gsoft::coordinator::merge::merge_gsoft;
    use gsoft::data::synglue::{Task, TaskGen};
    use gsoft::runtime::{Runtime, Tensor};

    let mut opts = RunOpts::load("table1", args)?;
    opts.steps = args.opt_usize("steps", 60)?;
    let rt = Runtime::new(&opts.artifacts)?;
    let base = pretrained_cls_base(&rt, "cls", &opts)?;
    println!(
        "[merge-demo] fine-tuning GSOFT on RTE* for {} steps…",
        opts.steps
    );
    let (_log, acc, state, _) = table1::finetune_once(
        &rt,
        "cls",
        "gsoft",
        Task::Rte,
        &base,
        &opts,
    )?;
    println!("[merge-demo] adapted accuracy: {acc:.2}%");

    let train = rt.load("cls_gsoft_train")?;
    let block = train.meta.extra_usize("block")?;
    let base_spec = FlatSpec::from_json(
        train
            .meta
            .extra
            .get("base_spec")
            .ok_or_else(|| anyhow::anyhow!("no base_spec"))?,
    )?;
    let adapter_spec = FlatSpec::from_json(
        train
            .meta
            .extra
            .get("adapter_spec")
            .ok_or_else(|| anyhow::anyhow!("no adapter_spec"))?,
    )?;
    let merged = merge_gsoft(&base, &state.trainable, &base_spec, &adapter_spec, block)?;

    // Compare: gsoft eval(adapter, base) vs ft eval(merged).
    let eval_gs = rt.load("cls_gsoft_eval")?;
    let eval_ft = rt.load("cls_ft_eval")?;
    let gen = TaskGen::new(Task::Rte, 512, 32);
    let mut rng = gsoft::util::rng::Rng::new(123);
    let mut mismatches = 0usize;
    for _ in 0..5 {
        let (xs, ys) = gen.batch(16, &mut rng);
        let out_gs = eval_gs.run(&[
            Tensor::f32(vec![state.trainable.len()], state.trainable.clone()),
            Tensor::f32(vec![base.len()], base.clone()),
            Tensor::i32(vec![16, 32], xs.clone()),
            Tensor::i32(vec![16], ys.clone()),
        ])?;
        let out_ft = eval_ft.run(&[
            Tensor::f32(vec![merged.len()], merged.clone()),
            Tensor::f32(vec![1], vec![0.0]),
            Tensor::i32(vec![16, 32], xs),
            Tensor::i32(vec![16], ys),
        ])?;
        let p1 = out_gs[2].as_i32()?;
        let p2 = out_ft[2].as_i32()?;
        mismatches += p1.iter().zip(p2).filter(|(a, b)| a != b).count();
    }
    println!("[merge-demo] merged-vs-adapted prediction mismatches over 80 examples: {mismatches}");
    anyhow::ensure!(
        mismatches == 0,
        "merged weights must reproduce adapted predictions"
    );
    println!("[merge-demo] OK — zero inference overhead after merging.");
    Ok(())
}

/// Non-orthogonal GS compression (the concluding remarks' direction):
/// project a pretrained attention weight onto the GS class at several
/// block sizes and compare against budget-matched truncated SVD.
fn compress_demo(args: &Args) -> Result<()> {
    use gsoft::coordinator::experiments::pretrained_cls_base;
    use gsoft::coordinator::flatspec::FlatSpec;
    use gsoft::gs::compress::frontier;
    use gsoft::linalg::Mat;
    use gsoft::report::{fmt, fmt_params, Table};
    use gsoft::runtime::Runtime;

    let opts = RunOpts::load("table1", args)?;
    let rt = Runtime::new(&opts.artifacts)?;
    let base = pretrained_cls_base(&rt, "cls", &opts)?;
    let train = rt.load("cls_ft_train")?;
    let base_spec = FlatSpec::from_json(
        train
            .meta
            .extra
            .get("base_spec")
            .ok_or_else(|| anyhow::anyhow!("no base_spec"))?,
    )?;
    let (_, shape) = base_spec.locate("layer0.wq")?;
    let w = Mat::from_f32(shape[0], shape[1], base_spec.view(&base, "layer0.wq")?);
    let mut table = Table::new(
        "Non-orthogonal GS compression of the pretrained layer0.wq (Algorithm 1) vs budget-matched SVD",
        &["Approximation", "Params", "Compression", "Rel. Frobenius error"],
    );
    for p in frontier(&w, &[4, 8, 16, 32]) {
        table.row(vec![
            p.label.clone(),
            fmt_params(p.params),
            format!("{}x", fmt(p.ratio, 1)),
            fmt(p.rel_error, 4),
        ]);
    }
    table.emit("compress_demo")?;
    Ok(())
}

const HELP: &str = r#"gsoft — Group-and-Shuffle structured orthogonal parametrization

Usage: gsoft <subcommand> [--key value] [--no-cache]

Experiments (regenerate the paper's tables/figures into results/):
  table1        SynGLUE fine-tuning (FT/LoRA/OFT/BOFT/GSOFT/DoubleGSOFT)
  table2        subject-driven adaptation (denoiser stand-in)
  fig6          fidelity/editability series at two checkpoints
  table3        LipConvnet: SOC vs GS-SOC
  table4        activation x permutation ablation
  density       Theorem-2 support-density sweep   [--d 1024 --b 32]
  params-table  §5.2 parameter accounting
  perms         Figure-3 permutation matrices
  all           everything above

Utilities:
  merge-demo    fine-tune, merge Q into W in Rust, verify zero overhead
  compress-demo non-orthogonal GS layer compression vs truncated SVD
  list          list compiled artifacts

Common options: --steps N --pretrain-steps N --eval-batches N --lr X
                --workers N --seed N --artifacts DIR --no-cache
"#;
