//! `gsoft` — launcher CLI for the Group-and-Shuffle reproduction.
//!
//! Subcommands regenerate every table/figure of the paper (see
//! DESIGN.md §3) plus utilities:
//!
//! ```text
//! gsoft table1   [--steps N --pretrain-steps N --lr X --workers N]
//! gsoft table2   | gsoft fig6
//! gsoft table3   | gsoft table4
//! gsoft density  [--d 1024 --b 32]
//! gsoft params-table
//! gsoft perms
//! gsoft serve    [--listen 127.0.0.1:9200 --tenants 8 --d 16
//!                 --rate 50 --burst 100 --max-inflight 256 --hold-ms N
//!                 --capture-slow-ms N --topk K]
//! gsoft serve-bench [--tenants 256 --requests 4096 --d 64 --block 8
//!                    --store DIR --shards 4 --maint-interval-ms 200
//!                    --reg-every 16 --smoke --obs
//!                    --listen ADDR --hold-ms N --trace-cap N
//!                    --capture-slow-ms N --topk K]
//! gsoft kernel-bench [--smoke --seed 7 --out BENCH_kernels.json --obs --listen ADDR]
//! gsoft conv-bench [--smoke --seed 7 --out BENCH_conv.json --obs --listen ADDR]
//! gsoft store-bench [--smoke --seed 7 --out BENCH_store.json --obs --listen ADDR
//!                    --shards N --maint-interval-ms 200]
//! gsoft obs-serve [--listen 127.0.0.1:9100 --hold-ms N]
//! gsoft trace    [--out results/trace.json --requests 128]
//! gsoft metrics  [--requests 128 --format text|json]
//! gsoft merge-demo
//! gsoft list     # artifacts in the registry
//! gsoft all      # every experiment, in order
//! ```

use anyhow::Result;

use gsoft::coordinator::config::RunOpts;
use gsoft::coordinator::experiments::{statics, table1, table2, table3};
use gsoft::util::cli::Args;

const FLAGS: &[&str] = &["no-cache", "help", "smoke", "obs"];

fn main() {
    let args = Args::from_env(FLAGS);
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    // `--obs` turns on the process-wide kernel/store instrumentation for
    // any subcommand; benches then append an `obs` section to their JSON
    // records (see DESIGN.md §9).
    if args.flag("obs") {
        gsoft::obs::set_enabled(true);
    }
    let sub = args.subcommand.as_deref().unwrap_or("help");
    match sub {
        "table1" => {
            let opts = RunOpts::load("table1", args)?;
            table1::run(&opts)?.emit("table1")?;
        }
        "table2" => {
            let opts = RunOpts::load("table2", args)?;
            table2::run(&opts)?.emit("table2")?;
        }
        "fig6" => {
            let opts = RunOpts::load("table2", args)?;
            table2::fig6(&opts)?.emit("fig6")?;
        }
        "table3" => {
            let opts = RunOpts::load("table3", args)?;
            match args.opt("variants") {
                Some(csv) => {
                    let vs: Vec<String> = csv.split(',').map(String::from).collect();
                    let cells = table3::run_variants(&vs, &opts)?;
                    table3::render_partial("Table 3 (subset)", &cells, false).emit("table3")?;
                }
                None => table3::run_table3(&opts)?.emit("table3")?,
            }
        }
        "table4" => {
            let opts = RunOpts::load("table3", args)?;
            match args.opt("variants") {
                Some(csv) => {
                    let vs: Vec<String> = csv.split(',').map(String::from).collect();
                    let cells = table3::run_variants(&vs, &opts)?;
                    table3::render_partial("Table 4 (subset)", &cells, true).emit("table4")?;
                }
                None => table3::run_table4(&opts)?.emit("table4")?,
            }
        }
        "density" => {
            let d = args.opt_usize("d", 1024)?;
            let b = args.opt_usize("b", 32)?;
            statics::density_table(d, b)?.emit("density")?;
        }
        "params-table" => {
            statics::params_table().emit("params_table")?;
            statics::budget_table(args.opt_usize("d", 128)?).emit("budgets")?;
        }
        "perms" => {
            gsoft::report::emit_text("fig3_perms", &statics::perms_figure())?;
        }
        "serve" => serve_cmd(args)?,
        "serve-bench" => serve_bench(args)?,
        "kernel-bench" => kernel_bench(args)?,
        "conv-bench" => conv_bench(args)?,
        "store-bench" => store_bench(args)?,
        "obs-serve" => obs_serve(args)?,
        "trace" => trace_cmd(args)?,
        "metrics" => metrics_cmd(args)?,
        "merge-demo" => merge_demo(args)?,
        "compress-demo" => compress_demo(args)?,
        "list" => {
            let opts = RunOpts::load("table1", args)?;
            let rt = gsoft::runtime::Runtime::new(&opts.artifacts)?;
            println!("platform: {}", rt.platform());
            for name in rt.manifest()? {
                println!("  {name}");
            }
        }
        "all" => {
            let t1 = RunOpts::load("table1", args)?;
            table1::run(&t1)?.emit("table1")?;
            let t2 = RunOpts::load("table2", args)?;
            table2::run(&t2)?.emit("table2")?;
            table2::fig6(&t2)?.emit("fig6")?;
            let t3 = RunOpts::load("table3", args)?;
            table3::run_table4(&t3)?.emit("table4")?;
            table3::run_table3(&t3)?.emit("table3")?;
            statics::params_table().emit("params_table")?;
            statics::density_table(1024, 32)?.emit("density")?;
        }
        _ => {
            println!("{HELP}");
        }
    }
    Ok(())
}

/// Append the process-wide (kernel + store) telemetry snapshot as an
/// `obs` section — plus the [`gsoft::obs::SloSet::global_default`]
/// verdict as a `slo` section — when `--obs` is on. Histograms land
/// under a `timings` key, so `strip_timing` keeps the record comparable
/// across runs.
fn attach_global_obs(mut record: gsoft::util::json::Json) -> gsoft::util::json::Json {
    use gsoft::util::json::Json;
    if gsoft::obs::enabled() {
        if let Json::Obj(m) = &mut record {
            let snap = gsoft::obs::global().snapshot();
            let slo =
                gsoft::obs::SloSet::global_default().eval_total(&snap, std::time::Duration::ZERO);
            m.insert("obs".into(), snap.to_json());
            m.insert("slo".into(), slo.to_json());
        }
    }
    record
}

/// `--listen ADDR` support for benches with no serving engine: scrape
/// the process-wide registry live while the sweep runs. Listening
/// implies `--obs` (a live scrape of a dark registry is useless).
fn bind_global_listener(args: &Args) -> Result<Option<gsoft::obs::ObsServer>> {
    let Some(addr) = args.opt("listen") else {
        return Ok(None);
    };
    gsoft::obs::set_enabled(true);
    let server = gsoft::obs::ObsServer::bind(addr, gsoft::obs::ObsSources::global_only())?;
    println!(
        "[obs] scrape endpoints live at {} (process-wide kernel_*/store_* registry)",
        server.url()
    );
    Ok(Some(server))
}

/// Optionally hold the exporter open past the end of the run
/// (`--hold-ms N`), then shut it down.
fn release_listener(args: &Args, server: Option<gsoft::obs::ObsServer>) -> Result<()> {
    if let Some(server) = server {
        let hold_ms = args.opt_u64("hold-ms", 0)?;
        if hold_ms > 0 {
            println!("[obs] holding {hold_ms} ms for live scrapes at {}", server.url());
            std::thread::sleep(std::time::Duration::from_millis(hold_ms));
        }
        server.shutdown();
    }
    Ok(())
}

/// Serve the live scrape endpoints over a small synthetic engine — the
/// standing exporter (`/metrics`, `/metrics.json`, `/healthz`,
/// `/tracez`, `/tenantz`, `/slo`; DESIGN.md §10, §12). Primes the fleet with demo
/// traffic so every endpoint has data, then stays up for `--hold-ms`
/// milliseconds (0 = until the process is killed).
fn obs_serve(args: &Args) -> Result<()> {
    use gsoft::obs::ObsServer;
    use gsoft::serve::{synthetic, Engine, EngineOpts, TenantId};
    use gsoft::util::rng::Rng;

    gsoft::obs::set_enabled(true);
    let listen = args.opt_or("listen", "127.0.0.1:9100").to_string();
    let tenants = args.opt_usize("tenants", 8)?;
    let requests = args.opt_usize("requests", 128)?;
    let d = args.opt_usize("d", 16)?;
    let seed = args.opt_u64("seed", 42)?;
    let hold_ms = args.opt_u64("hold-ms", 0)?;

    let registry = synthetic(tenants, 2, d, 4, seed)?;
    let engine = Engine::new(
        registry,
        EngineOpts {
            workers: 2,
            max_batch: 8,
            ..EngineOpts::default()
        },
    )?;
    let server = ObsServer::bind(&listen, engine.obs_sources())?;
    println!(
        "[obs-serve] live at {} — /metrics /metrics.json /healthz /tracez /tenantz /slo",
        server.url()
    );
    let mut rng = Rng::new(seed ^ 0xb5);
    for i in 0..requests {
        let input = rng.normal_vec(d, 0.5);
        engine.submit((i % tenants) as TenantId, input)?.wait()?;
    }
    println!("[obs-serve] primed with {requests} demo requests; registry is hot");
    if hold_ms > 0 {
        std::thread::sleep(std::time::Duration::from_millis(hold_ms));
    } else {
        println!("[obs-serve] serving until killed (Ctrl-C)…");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(1));
        }
    }
    server.shutdown();
    engine.finish();
    Ok(())
}

/// `gsoft serve --listen ADDR` — the network request front (DESIGN.md
/// §11): an HTTP/1.1 JSON API over a serving engine, behind admission
/// control. Starts from a synthetic fleet; new adapters arrive over the
/// wire (`POST /v1/register`), queries hit `POST /v1/query` (with
/// optional `deadline_ms`), and the obs scrape endpoints share the
/// listener. Stays up for `--hold-ms` milliseconds (0 = until killed).
fn serve_cmd(args: &Args) -> Result<()> {
    use gsoft::serve::{synthetic, AdmissionCfg, Engine, EngineOpts, FrontOpts, ServeFront};
    use std::sync::Arc;

    let listen = args.opt_or("listen", "127.0.0.1:9200").to_string();
    let tenants = args.opt_usize("tenants", 8)?;
    let layers = args.opt_usize("layers", 2)?;
    let d = args.opt_usize("d", 16)?;
    let block = args.opt_usize("block", 4)?;
    let seed = args.opt_u64("seed", 42)?;
    let workers = args.opt_usize("workers", 2)?;
    let rate = args.opt_f64("rate", AdmissionCfg::default().rate_per_sec)?;
    let burst = args.opt_f64("burst", AdmissionCfg::default().burst)?;
    let max_inflight = args.opt_usize("max-inflight", AdmissionCfg::default().max_inflight)?;
    let hold_ms = args.opt_u64("hold-ms", 0)?;
    // Per-tenant observability plane (DESIGN.md §12): requests slower
    // than --capture-slow-ms land in the capture ring (default: the SLO
    // p99 target); --topk bounds the heavy-hitter sketches.
    let capture_slow_ms = args.opt_u64_opt("capture-slow-ms")?;
    let topk = args.opt_usize("topk", gsoft::obs::DEFAULT_TENANT_TOPK)?;

    let registry = synthetic(tenants, layers, d, block, seed)?;
    let engine = Arc::new(Engine::new(
        registry,
        EngineOpts {
            workers,
            capture_slow_ns: capture_slow_ms.map(|ms| ms.saturating_mul(1_000_000)),
            tenant_topk: topk,
            ..EngineOpts::default()
        },
    )?);
    let opts = FrontOpts {
        admission: AdmissionCfg {
            rate_per_sec: rate,
            burst,
            max_inflight,
        },
        ..FrontOpts::default()
    };
    let front = ServeFront::bind(&listen, Arc::clone(&engine), opts)?;
    println!(
        "[serve] request front live at {} — POST /v1/register /v1/query /v1/evict, \
         GET /v1/tenants (+ /metrics /metrics.json /healthz /tracez /tenantz /slo)",
        front.url()
    );
    println!(
        "[serve] fleet: {tenants} synthetic tenants over {layers} layers of {d}x{d} \
         (input dim {d}); admission: {rate}/s per tenant, burst {burst}, \
         {max_inflight} in flight"
    );
    if hold_ms > 0 {
        std::thread::sleep(std::time::Duration::from_millis(hold_ms));
    } else {
        println!("[serve] serving until killed (Ctrl-C)…");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(1));
        }
    }
    front.shutdown();
    if let Ok(engine) = Arc::try_unwrap(engine) {
        engine.finish();
    }
    Ok(())
}

/// Drive a small synthetic fleet and export its request traces as
/// Chrome trace-event JSON — one pid for the engine, one tid per
/// worker, stage spans nested in request spans. Load the output in
/// chrome://tracing or Perfetto.
fn trace_cmd(args: &Args) -> Result<()> {
    use gsoft::report::emit_json_record;
    use gsoft::serve::{synthetic, Engine, EngineOpts, TenantId};
    use gsoft::util::rng::Rng;

    let tenants = args.opt_usize("tenants", 8)?;
    let requests = args.opt_usize("requests", 128)?;
    let d = args.opt_usize("d", 16)?;
    let seed = args.opt_u64("seed", 42)?;
    let trace_cap = args.opt_usize("trace-cap", gsoft::serve::TRACE_RING_CAP)?;
    let out_path = args.opt_or("out", "results/trace.json").to_string();

    let registry = synthetic(tenants, 2, d, 4, seed)?;
    let engine = Engine::new(
        registry,
        EngineOpts {
            workers: 2,
            max_batch: 8,
            trace_ring_cap: trace_cap,
            ..EngineOpts::default()
        },
    )?;
    let mut rng = Rng::new(seed ^ 0xb5);
    for i in 0..requests {
        let input = rng.normal_vec(d, 0.5);
        engine.submit((i % tenants) as TenantId, input)?.wait()?;
    }
    let report = engine.finish();
    let doc = gsoft::obs::chrome_trace(&report.traces, 1);
    emit_json_record(std::path::Path::new(&out_path), &doc)?;
    println!(
        "[trace] {} traces exported to {out_path} — load in chrome://tracing or Perfetto",
        report.traces.len()
    );
    Ok(())
}

/// Exercise the serving engine on a tiny synthetic fleet with full
/// telemetry on, then dump the merged metrics registry — per-engine
/// serve_* metrics plus the process-wide kernel_*/store_* metrics — as
/// Prometheus text (default) or JSON (`--format json`). This is the same
/// exporter a future `/metrics` scrape endpoint would serve (DESIGN.md
/// §9).
fn metrics_cmd(args: &Args) -> Result<()> {
    use gsoft::report::{emit_json_record, emit_text};
    use gsoft::serve::{synthetic, Engine, EngineOpts, TenantId};
    use gsoft::util::rng::Rng;

    gsoft::obs::set_enabled(true);
    let tenants = args.opt_usize("tenants", 8)?;
    let requests = args.opt_usize("requests", 128)?;
    let d = args.opt_usize("d", 16)?;
    let seed = args.opt_u64("seed", 42)?;
    let registry = synthetic(tenants, 2, d, 4, seed)?;
    let engine = Engine::new(
        registry,
        EngineOpts {
            workers: 2,
            max_batch: 8,
            ..EngineOpts::default()
        },
    )?;
    let mut rng = Rng::new(seed ^ 0xb5);
    for i in 0..requests {
        let input = rng.normal_vec(d, 0.5);
        engine.submit((i % tenants) as TenantId, input)?.wait()?;
    }
    let report = engine.finish();
    let mut snap = report.obs;
    snap.merge(&gsoft::obs::global().snapshot());
    match args.opt_or("format", "text") {
        "json" => {
            emit_json_record(std::path::Path::new("results/metrics.json"), &snap.to_json())?
        }
        _ => emit_text("metrics", &snap.prometheus())?,
    }
    Ok(())
}

/// End-to-end "no inference overhead" demonstration: fine-tune GSOFT on
/// one task, merge Q into the base weights in Rust (exact GS algebra),
/// and verify the plain (ft) forward pass reproduces the adapted model's
/// predictions at the eval batches.
fn merge_demo(args: &Args) -> Result<()> {
    use gsoft::coordinator::experiments::pretrained_cls_base;
    use gsoft::coordinator::flatspec::FlatSpec;
    use gsoft::coordinator::merge::merge_gsoft;
    use gsoft::data::synglue::{Task, TaskGen};
    use gsoft::runtime::{Runtime, Tensor};

    let mut opts = RunOpts::load("table1", args)?;
    opts.steps = args.opt_usize("steps", 60)?;
    let rt = Runtime::new(&opts.artifacts)?;
    let base = pretrained_cls_base(&rt, "cls", &opts)?;
    println!(
        "[merge-demo] fine-tuning GSOFT on RTE* for {} steps…",
        opts.steps
    );
    let (_log, acc, state, _) = table1::finetune_once(
        &rt,
        "cls",
        "gsoft",
        Task::Rte,
        &base,
        &opts,
    )?;
    println!("[merge-demo] adapted accuracy: {acc:.2}%");

    let train = rt.load("cls_gsoft_train")?;
    let block = train.meta.extra_usize("block")?;
    let base_spec = FlatSpec::from_json(
        train
            .meta
            .extra
            .get("base_spec")
            .ok_or_else(|| anyhow::anyhow!("no base_spec"))?,
    )?;
    let adapter_spec = FlatSpec::from_json(
        train
            .meta
            .extra
            .get("adapter_spec")
            .ok_or_else(|| anyhow::anyhow!("no adapter_spec"))?,
    )?;
    let merged = merge_gsoft(&base, &state.trainable, &base_spec, &adapter_spec, block)?;

    // Compare: gsoft eval(adapter, base) vs ft eval(merged).
    let eval_gs = rt.load("cls_gsoft_eval")?;
    let eval_ft = rt.load("cls_ft_eval")?;
    let gen = TaskGen::new(Task::Rte, 512, 32);
    let mut rng = gsoft::util::rng::Rng::new(123);
    let mut mismatches = 0usize;
    for _ in 0..5 {
        let (xs, ys) = gen.batch(16, &mut rng);
        let out_gs = eval_gs.run(&[
            Tensor::f32(vec![state.trainable.len()], state.trainable.clone()),
            Tensor::f32(vec![base.len()], base.clone()),
            Tensor::i32(vec![16, 32], xs.clone()),
            Tensor::i32(vec![16], ys.clone()),
        ])?;
        let out_ft = eval_ft.run(&[
            Tensor::f32(vec![merged.len()], merged.clone()),
            Tensor::f32(vec![1], vec![0.0]),
            Tensor::i32(vec![16, 32], xs),
            Tensor::i32(vec![16], ys),
        ])?;
        let p1 = out_gs[2].as_i32()?;
        let p2 = out_ft[2].as_i32()?;
        mismatches += p1.iter().zip(p2).filter(|(a, b)| a != b).count();
    }
    println!("[merge-demo] merged-vs-adapted prediction mismatches over 80 examples: {mismatches}");
    anyhow::ensure!(
        mismatches == 0,
        "merged weights must reproduce adapted predictions"
    );
    println!("[merge-demo] OK — zero inference overhead after merging.");
    Ok(())
}

/// Multi-tenant serving benchmark: a synthetic registry of adapters over
/// one frozen base, driven by a Zipf-popularity request trace through the
/// `serve::Engine`. With `--store DIR` the registry is durably
/// store-backed and the query trace is *mixed with registration traffic*
/// (every `--reg-every`-th request durably registers a brand-new tenant
/// and immediately queries it cold), measuring write/read contention on
/// the store. Reports end-to-end p50/p99 latency, throughput, cache
/// hit-rate, and per-path worker service times, and writes a
/// machine-readable `BENCH_serve.json` perf record.
fn serve_bench(args: &Args) -> Result<()> {
    use gsoft::adapter::AdapterFamily;
    use gsoft::data::zipf::Zipf;
    use gsoft::report::{emit_json_record, fmt, Table};
    use gsoft::serve::{synthetic, AdapterEntry, Engine, EngineOpts, Registry, TenantId};
    use gsoft::store::AdapterStore;
    use gsoft::util::json::Json;
    use gsoft::util::rng::Rng;
    use std::sync::Arc;
    use std::time::Instant;

    let smoke = args.flag("smoke");
    let tenants = args.opt_usize("tenants", if smoke { 24 } else { 256 })?;
    let requests = args.opt_usize("requests", if smoke { 192 } else { 4096 })?;
    let layers = args.opt_usize("layers", if smoke { 2 } else { 4 })?;
    let d = args.opt_usize("d", if smoke { 16 } else { 64 })?;
    let block = args.opt_usize("block", if smoke { 4 } else { 8 })?;
    let zipf_s = args.opt_f64("zipf-s", 1.1)?;
    let workers = args.opt_usize("workers", gsoft::util::pool::default_workers().min(8))?;
    let max_batch = args.opt_usize("max-batch", 16)?;
    let cache_mb = args.opt_usize("cache-mb", 64)?;
    let seed = args.opt_u64("seed", 42)?;
    let reg_every = args.opt_usize("reg-every", 16)?.max(1);
    let store_dir = args.opt("store").map(std::path::PathBuf::from);
    let shards = args.opt_usize("shards", gsoft::store::DEFAULT_SHARDS)?;
    let maint_ms = args.opt_u64("maint-interval-ms", gsoft::store::DEFAULT_MAINT_INTERVAL_MS)?;
    let trace_cap = args.opt_usize("trace-cap", gsoft::serve::TRACE_RING_CAP)?;
    let capture_slow_ms = args.opt_u64_opt("capture-slow-ms")?;
    let topk = args.opt_usize("topk", gsoft::obs::DEFAULT_TENANT_TOPK)?;
    let listen = args.opt("listen").map(String::from);

    println!(
        "[serve-bench] registry: {tenants} tenants over {layers} layers of {d}x{d} (block {block})"
    );
    let donor = synthetic(tenants, layers, d, block, seed)?;
    // Store mode: persist the fleet through a durable store-backed
    // registry (write-through segment log) and keep an entry pool to
    // clone fresh registrations from during the trace.
    let (registry, reg_pool) = match &store_dir {
        Some(dir) => {
            let pool: Vec<AdapterEntry> = donor
                .tenant_ids()
                .into_iter()
                .map(|t| donor.get(t).expect("donor tenant"))
                .collect();
            let reg = Registry::with_store(
                donor.base().weights.as_ref().clone(),
                donor.base().spec.as_ref().clone(),
                AdapterStore::open_sharded(dir.join("factors"), shards)?,
            )?;
            let t0 = Instant::now();
            for (t, e) in pool.iter().enumerate() {
                reg.register(t as TenantId, e.clone())?;
            }
            println!(
                "[serve-bench] store mode: fleet durably persisted to {} in {:.1} ms",
                dir.display(),
                t0.elapsed().as_secs_f64() * 1e3
            );
            (reg, Some(pool))
        }
        None => (donor, None),
    };
    let engine = Engine::new(
        registry,
        EngineOpts {
            workers,
            max_batch,
            cache_budget_bytes: cache_mb << 20,
            spill_dir: store_dir.as_ref().map(|dir| dir.join("spill")),
            maint_interval: std::time::Duration::from_millis(maint_ms),
            trace_ring_cap: trace_cap,
            capture_slow_ns: capture_slow_ms.map(|ms| ms.saturating_mul(1_000_000)),
            tenant_topk: topk,
            ..EngineOpts::default()
        },
    )?;
    // Live scrape endpoints over this engine's registry/traces/health
    // for the duration of the sweep (`--listen ADDR`; DESIGN.md §10).
    let server = match &listen {
        Some(addr) => {
            let s = gsoft::obs::ObsServer::bind(addr, engine.obs_sources())?;
            println!("[serve-bench] scrape endpoints live at {}", s.url());
            Some(s)
        }
        None => None,
    };
    let policy = engine.policy();
    println!(
        "[serve-bench] policy: promote after {} requests/tenant (Theorem-2 density model; Q dense: {})",
        policy.promote_after, policy.q_dense
    );

    // Zipf-popular request trace with per-request random inputs.
    let zipf = Zipf::new(tenants, zipf_s);
    let mut rng = Rng::new(seed ^ 0x5eed);
    let trace = zipf.trace(requests, &mut rng);
    let inputs: Vec<Vec<f32>> = (0..requests).map(|_| rng.normal_vec(d, 0.5)).collect();

    println!("[serve-bench] submitting {requests} requests (zipf s={zipf_s}, {workers} workers)…");
    let mut reg_ns: Vec<u64> = Vec::new();
    let mut next_new = tenants as TenantId;
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(requests);
    for (i, (tenant, input)) in trace.iter().zip(inputs).enumerate() {
        let mut target = *tenant as TenantId;
        if let Some(pool) = &reg_pool {
            if (i + 1) % reg_every == 0 {
                // Registration traffic interleaved with queries: a durable
                // append on the same store the workers hydrate from, then
                // an immediate cold query of the fresh tenant.
                let template = &pool[(next_new as usize) % pool.len()];
                let std = template.desc.family().synthetic_std(template.desc.cfg());
                let entry = AdapterEntry {
                    desc: template.desc.clone(),
                    params: Arc::new(rng.normal_vec(template.spec.size(), std)),
                    spec: Arc::clone(&template.spec),
                };
                let r0 = Instant::now();
                engine.registry().register(next_new, entry)?;
                reg_ns.push(r0.elapsed().as_nanos() as u64);
                target = next_new;
                next_new += 1;
            }
        }
        handles.push(engine.submit(target, input)?);
    }
    for h in handles {
        h.wait()?;
    }
    let wall = t0.elapsed();
    // Let the maintenance thread finish queued spill writes before the
    // front probe reads the spill tier, so its tallies are settled.
    engine.drain_maintenance();
    // Front-end request latency (DESIGN.md §11): stand the network front
    // up on a loopback ephemeral port over the still-hot engine and time
    // end-to-end HTTP queries — parse, admission, batcher, JSON response.
    let front_requests = args.opt_usize("front-requests", if smoke { 32 } else { 256 })?;
    let (front_json, engine) = front_probe(engine, tenants, d, front_requests, seed)?;
    // Hold the exporter open while the engine is still live (workers
    // parked, health green) so CI can scrape mid-flight state, then shut
    // it down before finish() tears the fleet down.
    release_listener(args, server)?;
    let report = engine.finish();
    let m = &report.metrics;
    let throughput = m.requests as f64 / wall.as_secs_f64();
    let hit_rate = report.cache.hit_rate();

    let ns_ms = 1e-6;
    let mut table = Table::new(
        "serve-bench — multi-tenant adapter serving",
        &["Metric", "Value"],
    );
    table.row(vec!["requests".into(), m.requests.to_string()]);
    table.row(vec!["batches".into(), m.batches.to_string()]);
    table.row(vec!["merges".into(), m.merges.to_string()]);
    table.row(vec!["wall time (s)".into(), fmt(wall.as_secs_f64(), 3)]);
    table.row(vec!["throughput (req/s)".into(), fmt(throughput, 0)]);
    table.row(vec!["p50 latency (ms)".into(), fmt(m.overall.p50_ns * ns_ms, 3)]);
    table.row(vec!["p99 latency (ms)".into(), fmt(m.overall.p99_ns * ns_ms, 3)]);
    table.row(vec!["cache hit-rate".into(), fmt(hit_rate, 3)]);
    table.row(vec![
        "cached batches / p50 service (ms)".into(),
        format!(
            "{} / {}",
            m.service_cached.count,
            fmt(m.service_cached.p50_ns * ns_ms, 4)
        ),
    ]);
    table.row(vec![
        "cold-merge batches / p50 service (ms)".into(),
        format!(
            "{} / {}",
            m.service_cold.count,
            fmt(m.service_cold.p50_ns * ns_ms, 4)
        ),
    ]);
    table.row(vec![
        "factorized batches / p50 service (ms)".into(),
        format!(
            "{} / {}",
            m.service_factorized.count,
            fmt(m.service_factorized.p50_ns * ns_ms, 4)
        ),
    ]);
    // Store-mode extras: registration traffic + spill activity.
    let pct = |ns: &[u64], q: f64| -> f64 {
        if ns.is_empty() {
            return 0.0;
        }
        let mut v = ns.to_vec();
        v.sort_unstable();
        v[((v.len() as f64 - 1.0) * q).round() as usize] as f64
    };
    if reg_pool.is_some() {
        table.row(vec![
            "registrations / p50 / p99 (ms)".into(),
            format!(
                "{} / {} / {}",
                reg_ns.len(),
                fmt(pct(&reg_ns, 0.50) * ns_ms, 3),
                fmt(pct(&reg_ns, 0.99) * ns_ms, 3)
            ),
        ]);
        table.row(vec![
            "spill loads".into(),
            report.metrics.spill_loads.to_string(),
        ]);
    }
    table.emit("serve_bench")?;

    if m.service_cached.count > 0 && m.service_cold.count > 0 {
        let speedup = m.service_cold.p50_ns / m.service_cached.p50_ns.max(1.0);
        println!(
            "[serve-bench] cold-merge p50 service / cached p50 service = {:.1}x",
            speedup
        );
        if speedup <= 1.0 {
            println!("[serve-bench] WARNING: cached path was not faster than cold merges");
        }
    }

    let path_stats_json = |s: &gsoft::serve::engine::PathStats| {
        Json::obj(vec![
            ("count", Json::Num(s.count as f64)),
            ("mean_ns", Json::Num(s.mean_ns)),
            ("p50_ns", Json::Num(s.p50_ns)),
            ("p99_ns", Json::Num(s.p99_ns)),
        ])
    };
    let mut fields = vec![
        (
            "config",
            Json::obj(vec![
                ("tenants", Json::Num(tenants as f64)),
                ("requests", Json::Num(requests as f64)),
                ("layers", Json::Num(layers as f64)),
                ("d", Json::Num(d as f64)),
                ("block", Json::Num(block as f64)),
                ("zipf_s", Json::Num(zipf_s)),
                ("workers", Json::Num(workers as f64)),
                ("max_batch", Json::Num(max_batch as f64)),
                ("cache_mb", Json::Num(cache_mb as f64)),
                ("seed", Json::Num(seed as f64)),
                ("promote_after", Json::Num(policy.promote_after as f64)),
                ("smoke", Json::Bool(smoke)),
            ]),
        ),
        ("wall_s", Json::Num(wall.as_secs_f64())),
        ("throughput_rps", Json::Num(throughput)),
        ("p50_latency_ns", Json::Num(m.overall.p50_ns)),
        ("p99_latency_ns", Json::Num(m.overall.p99_ns)),
        ("cache_hit_rate", Json::Num(hit_rate)),
        ("cache_evictions", Json::Num(report.cache.evictions as f64)),
        ("batches", Json::Num(m.batches as f64)),
        ("merges", Json::Num(m.merges as f64)),
        ("latency_cached", path_stats_json(&m.cached)),
        ("latency_cold_merge", path_stats_json(&m.cold)),
        ("latency_factorized", path_stats_json(&m.factorized)),
        ("service_cached", path_stats_json(&m.service_cached)),
        ("service_cold_merge", path_stats_json(&m.service_cold)),
        ("service_factorized", path_stats_json(&m.service_factorized)),
        ("front", front_json),
    ];
    // Fleet telemetry: per-path/per-family request counters, policy
    // gauges, batcher/cache metrics and stage-latency histograms from the
    // engine's registry; with --obs the process-wide kernel_*/store_*
    // metrics are merged in. Histograms live under "timings" so
    // strip_timing keeps the record comparable.
    let mut obs_snap = report.obs.clone();
    if gsoft::obs::enabled() {
        obs_snap.merge(&gsoft::obs::global().snapshot());
    }
    fields.push(("obs", obs_snap.to_json()));
    // Pass/fail SLO verdict over the whole run (serve_default objectives
    // evaluated on the final snapshot; burn rates also land in the obs
    // gauges as slo_*).
    fields.push(("slo", report.slo.to_json()));
    // Per-tenant heavy hitters (DESIGN.md §12): bounded top-K sketches
    // per dimension. Latency sums are run-dependent, so bench_diff
    // ignores the whole "tenants." subtree like "obs."/"slo.".
    fields.push(("tenants", report.tenants.to_json()));
    fields.push(("traces_recorded", Json::Num(report.traces.len() as f64)));
    if reg_pool.is_some() {
        fields.push((
            "store",
            Json::obj(vec![
                ("shards", Json::Num(shards as f64)),
                ("reg_every", Json::Num(reg_every as f64)),
                ("registrations", Json::Num(reg_ns.len() as f64)),
                ("reg_p50_ns", Json::Num(pct(&reg_ns, 0.50))),
                ("reg_p99_ns", Json::Num(pct(&reg_ns, 0.99))),
                ("spill_loads", Json::Num(m.spill_loads as f64)),
                (
                    "latency_spill_load",
                    path_stats_json(&m.spill),
                ),
            ]),
        ));
    }
    emit_json_record(std::path::Path::new("BENCH_serve.json"), &Json::obj(fields))?;
    Ok(())
}

/// Measure the network front's end-to-end request latency over a hot
/// engine: bind [`gsoft::serve::ServeFront`] on a loopback ephemeral
/// port, issue `requests` sequential `POST /v1/query` calls, and return
/// a `front` section for the bench record. Admission is opened wide —
/// the probe measures the wire path, not the gate. Hands the engine
/// back once the front's threads are joined.
fn front_probe(
    engine: gsoft::serve::Engine,
    tenants: usize,
    d: usize,
    requests: usize,
    seed: u64,
) -> Result<(gsoft::util::json::Json, gsoft::serve::Engine)> {
    use gsoft::serve::{AdmissionCfg, FrontOpts, ServeFront, TenantId};
    use gsoft::util::json::Json;
    use gsoft::util::net::http_request;
    use gsoft::util::rng::Rng;
    use std::sync::Arc;
    use std::time::Instant;

    let requests = requests.max(1);
    let engine = Arc::new(engine);
    let opts = FrontOpts {
        admission: AdmissionCfg {
            rate_per_sec: 1e9,
            burst: 1e9,
            max_inflight: 1024,
        },
        ..FrontOpts::default()
    };
    let front = ServeFront::bind("127.0.0.1:0", Arc::clone(&engine), opts)?;
    let addr = front.addr();
    let mut rng = Rng::new(seed ^ 0xf207);
    let mut ns: Vec<u64> = Vec::with_capacity(requests);
    for i in 0..requests {
        let tenant = (i % tenants) as TenantId;
        let input: Vec<f64> = rng.normal_vec(d, 0.5).iter().map(|&x| x as f64).collect();
        let body = Json::obj(vec![
            ("tenant", Json::Num(tenant as f64)),
            ("input", Json::arr_f64(&input)),
        ])
        .to_string();
        let t0 = Instant::now();
        let (status, resp) = http_request(addr, "POST", "/v1/query", Some(&body))?;
        anyhow::ensure!(status == 200, "front query failed ({status}): {resp}");
        ns.push(t0.elapsed().as_nanos() as u64);
    }
    front.shutdown();
    let engine = Arc::try_unwrap(engine)
        .map_err(|_| anyhow::anyhow!("front still holds the engine after shutdown"))?;

    ns.sort_unstable();
    let q = |f: f64| ns[((ns.len() as f64 - 1.0) * f).round() as usize] as f64;
    let mean = ns.iter().sum::<u64>() as f64 / ns.len() as f64;
    println!(
        "[serve-bench] front: {requests} loopback queries, p50 {:.3} ms, p99 {:.3} ms",
        q(0.50) * 1e-6,
        q(0.99) * 1e-6
    );
    let json = Json::obj(vec![
        ("requests", Json::Num(requests as f64)),
        ("mean_ns", Json::Num(mean)),
        ("p50_ns", Json::Num(q(0.50))),
        ("p99_ns", Json::Num(q(0.99))),
    ]);
    Ok((json, engine))
}

/// CPU kernel sweep: for each (d, b, m, batch) config, time the dense
/// merged GEMM (naive reference + blocked/parallel dispatch) against the
/// fused factorized group-and-shuffle apply and its batched multi-RHS
/// variant, then write a machine-readable `BENCH_kernels.json` perf
/// record. `--smoke` runs one small config with short measurement windows
/// (the CI gate exercising the dispatch/autotune path on every push).
fn kernel_bench(args: &Args) -> Result<()> {
    use gsoft::gs::GsChain;
    use gsoft::kernel::{self, KernelCtx};
    use gsoft::linalg::Mat;
    use gsoft::report::{emit_json_record, fmt, Table};
    use gsoft::util::bench::{black_box, Bench};
    use gsoft::util::json::Json;
    use gsoft::util::rng::Rng;

    let smoke = args.flag("smoke");
    if smoke {
        // Short warmup/measurement windows (same env var CI benches use);
        // must be set before Bench::new reads it.
        std::env::set_var("GSOFT_BENCH_QUICK", "1");
    }
    let seed = args.opt_u64("seed", 7)?;
    let out_path = args.opt_or("out", "BENCH_kernels.json").to_string();
    let server = bind_global_listener(args)?;

    // Autotune the tile on a representative shape — the same dispatch
    // layer Mat::matmul and the serving engine front.
    let ctx = if smoke {
        KernelCtx::autotuned(64, 16)
    } else {
        KernelCtx::autotuned(256, 32)
    };
    println!(
        "[kernel-bench] autotuned tile {:?}, {} workers, naive below {} flops, parallel above {}",
        ctx.tile, ctx.workers, ctx.naive_below_flops, ctx.parallel_above_flops
    );

    let grid: Vec<(usize, usize, usize, usize)> = if smoke {
        vec![(64, 8, 2, 8)]
    } else {
        let mut g = Vec::new();
        for d in [128usize, 256] {
            for b in [8usize, 16, 32] {
                if d % b != 0 {
                    continue;
                }
                for m in [1usize, 2] {
                    for batch in [8usize, 32] {
                        g.push((d, b, m, batch));
                    }
                }
            }
        }
        g
    };

    let mut bench = Bench::new("kernel_bench");
    if smoke {
        bench.measure_time(std::time::Duration::from_millis(60));
    }
    let mut rng = Rng::new(seed);
    let mut table = Table::new(
        "kernel-bench — fused group-and-shuffle apply vs dense merged GEMM",
        &[
            "config",
            "naive p50 (µs)",
            "dispatch p50 (µs)",
            "fused p50 (µs)",
            "batched×4 p50 (µs)",
            "fused speedup vs dense",
        ],
    );
    let mut configs = Vec::new();
    let mut best_speedup = 0.0f64;
    for &(d, b, m, batch) in &grid {
        let chain = GsChain::gs_kn(d, b, m, &mut rng, true);
        let q = chain.to_dense();
        let x = Mat::randn(d, batch, 1.0, &mut rng);
        let tag = format!("d{d}_b{b}_m{m}_t{batch}");
        let naive = bench
            .bench(&format!("dense_naive/{tag}"), || {
                black_box(kernel::gemm_naive(&q, &x))
            })
            .clone();
        let blocked = bench
            .bench(&format!("dense_dispatch/{tag}"), || black_box(ctx.gemm(&q, &x)))
            .clone();
        let fused = bench
            .bench(&format!("fused_apply/{tag}"), || {
                black_box(kernel::chain_apply(&chain, &x, &ctx))
            })
            .clone();
        let xs: Vec<Mat> = (0..4).map(|_| Mat::randn(d, batch, 1.0, &mut rng)).collect();
        let batched = bench
            .bench(&format!("fused_batched_x4/{tag}"), || {
                black_box(kernel::chain_apply_batch(&chain, &xs, &ctx))
            })
            .clone();
        // The dense path a serving deployment would actually run is the
        // dispatched one; credit dense with its best showing.
        let dense_best = blocked.p50_ns.min(naive.p50_ns);
        let speedup = dense_best / fused.p50_ns.max(1.0);
        best_speedup = best_speedup.max(speedup);
        table.row(vec![
            tag,
            fmt(naive.p50_ns / 1e3, 1),
            fmt(blocked.p50_ns / 1e3, 1),
            fmt(fused.p50_ns / 1e3, 1),
            fmt(batched.p50_ns / 1e3, 1),
            format!("{}x", fmt(speedup, 2)),
        ]);
        configs.push(Json::obj(vec![
            ("d", Json::Num(d as f64)),
            ("b", Json::Num(b as f64)),
            ("m", Json::Num(m as f64)),
            ("batch", Json::Num(batch as f64)),
            ("dense_naive", naive.to_json()),
            ("dense_dispatch", blocked.to_json()),
            ("fused", fused.to_json()),
            ("fused_batched_x4", batched.to_json()),
            ("fused_speedup_vs_dense", Json::Num(speedup)),
        ]));
    }
    table.emit("kernel_bench")?;
    let record = Json::obj(vec![
        ("smoke", Json::Bool(smoke)),
        ("seed", Json::Num(seed as f64)),
        (
            "tile",
            Json::obj(vec![
                ("mc", Json::Num(ctx.tile.mc as f64)),
                ("kc", Json::Num(ctx.tile.kc as f64)),
                ("nc", Json::Num(ctx.tile.nc as f64)),
            ]),
        ),
        ("workers", Json::Num(ctx.workers as f64)),
        ("configs", Json::Arr(configs)),
        ("best_fused_speedup_vs_dense", Json::Num(best_speedup)),
    ]);
    emit_json_record(std::path::Path::new(&out_path), &attach_global_obs(record))?;
    if best_speedup > 1.0 {
        println!(
            "[kernel-bench] fused factorized apply beats the dense merged GEMM: best {}x",
            fmt(best_speedup, 2)
        );
    } else {
        println!("[kernel-bench] WARNING: fused apply did not beat the dense GEMM on this sweep");
    }
    bench.finish();
    release_listener(args, server)?;
    Ok(())
}

/// Direct GS-SOC convolution runtime sweep: for each (c, k, H·W, groups,
/// batch) config, time the direct AXPY kernel, the im2col-into-blocked-
/// GEMM kernel, the KernelCtx-dispatched conv, the streaming convolution
/// exponential and the full GS-SOC layer apply against the materialized
/// dense `(c·H·W)²` operator (where small enough to build), then write a
/// machine-readable `BENCH_conv.json` perf record. `--smoke` runs one
/// small config with short measurement windows (the CI gate).
fn conv_bench(args: &Args) -> Result<()> {
    use gsoft::kernel::convbench::{record, ConvBenchOpts};
    use gsoft::kernel::KernelCtx;
    use gsoft::report::emit_json_record;

    let smoke = args.flag("smoke");
    if smoke {
        // Short warmup/measurement windows; must be set before Bench::new
        // reads it (same convention as kernel-bench).
        std::env::set_var("GSOFT_BENCH_QUICK", "1");
    }
    let seed = args.opt_u64("seed", 7)?;
    let out_path = args.opt_or("out", "BENCH_conv.json").to_string();
    let server = bind_global_listener(args)?;
    let ctx = if smoke {
        KernelCtx::autotuned(64, 16)
    } else {
        KernelCtx::autotuned(256, 32)
    };
    println!(
        "[conv-bench] autotuned tile {:?}, {} workers; sweeping the direct GS-SOC conv runtime",
        ctx.tile, ctx.workers
    );
    let opts = ConvBenchOpts {
        smoke,
        seed,
        measure: smoke.then_some(std::time::Duration::from_millis(60)),
    };
    let (table, rec) = record(&opts, &ctx);
    table.emit("conv_bench")?;
    emit_json_record(std::path::Path::new(&out_path), &attach_global_obs(rec))?;
    println!("[conv-bench] record is deterministic modulo 'timings' fields (same seed ⇒ same checksums)");
    release_listener(args, server)?;
    Ok(())
}

/// Persistent tiered adapter store benchmark: for each (tenant count ×
/// adapter kind × hit ratio) config, measure durable-persist throughput,
/// cold-boot open (log replay) latency, per-tenant lazy hydration
/// latency, and — driving the store-backed engine with a hot/cold trace —
/// the spill-hit vs re-merge service times the load-vs-remerge break-even
/// trades between. Writes a machine-readable `BENCH_store.json`.
/// `--smoke` runs one small config (the CI gate exercising persist →
/// replay → hydrate → spill on every push).
fn store_bench(args: &Args) -> Result<()> {
    use gsoft::report::{emit_json_record, fmt, Table};
    use gsoft::serve::{synthetic, synthetic_conv, Engine, EngineOpts, Registry, TenantId};
    use gsoft::store::{AdapterStore, DEFAULT_MAINT_INTERVAL_MS, DEFAULT_SHARDS};
    use gsoft::util::json::Json;
    use gsoft::util::rng::Rng;
    use gsoft::util::tmp::unique_temp_dir;
    use std::time::{Duration, Instant};

    let smoke = args.flag("smoke");
    let seed = args.opt_u64("seed", 7)?;
    let out_path = args.opt_or("out", "BENCH_store.json").to_string();
    let server = bind_global_listener(args)?;
    let requests = args.opt_usize("requests", if smoke { 64 } else { 1024 })?;
    // `--shards N` pins every config to N segment-log shards; without it
    // the full sweep adds a shard-scaling axis ({1, 4, 16}) on the mixed
    // fleet so registration throughput vs shard count lands in the record.
    let shards_opt = match args.opt("shards") {
        Some(_) => Some(args.opt_usize("shards", DEFAULT_SHARDS)?),
        None => None,
    };
    let maint_ms = args.opt_u64("maint-interval-ms", DEFAULT_MAINT_INTERVAL_MS)?;

    // (adapter kind, tenant count, hot-set hit ratio, shards)
    let grid: Vec<(&str, usize, f64, usize)> = if smoke {
        vec![("mixed", 12, 0.7, shards_opt.unwrap_or(DEFAULT_SHARDS))]
    } else {
        let mut g = Vec::new();
        if shards_opt.is_none() {
            for &s in &[1usize, 4, 16] {
                g.push(("mixed", 256, 0.7, s));
            }
        }
        for &tenants in &[64usize, 256] {
            for kind in ["mixed", "conv_gssoc"] {
                for &hit in &[0.5f64, 0.9] {
                    g.push((kind, tenants, hit, shards_opt.unwrap_or(DEFAULT_SHARDS)));
                }
            }
        }
        g
    };

    let layers = 2usize;
    let mut table = Table::new(
        "store-bench — persistent tiered adapter store",
        &[
            "config",
            "persist (ms)",
            "reg storm (reg/s)",
            "cold open (ms)",
            "hydrate (µs/tenant)",
            "re-merge p50 (ms)",
            "spill-hit p50 (ms)",
            "spill hits",
        ],
    );
    let mut configs = Vec::new();
    for &(kind, tenants, hit_ratio, shards) in &grid {
        let (donor, d) = match kind {
            "mixed" => {
                let d = if smoke { 16 } else { 32 };
                (synthetic(tenants, layers, d, d / 4, seed)?, d)
            }
            _ => (synthetic_conv(tenants, layers, 4, 3, 2, 2, 3, seed)?, 4 * 2 * 3),
        };
        let base_w = donor.base().weights.as_ref().clone();
        let base_spec = donor.base().spec.as_ref().clone();
        let entries: Vec<_> = donor
            .tenant_ids()
            .into_iter()
            .map(|t| (t, donor.get(t).unwrap()))
            .collect();

        let dir = unique_temp_dir("store_bench");
        // Phase 1: durable persist (synced appends, one writer).
        let t0 = Instant::now();
        {
            let store = AdapterStore::open_sharded(dir.join("factors"), shards)?;
            for (t, e) in &entries {
                store.put(*t, e)?;
            }
        }
        let persist = t0.elapsed();

        // Phase 1b: parallel registration storm — concurrent registers
        // through a store-backed registry land on independent shard
        // locks, so durable registration throughput scales with the
        // shard count (the tentpole's headline number).
        let storm_workers = gsoft::util::pool::default_workers().min(8);
        let t0 = Instant::now();
        {
            let reg = Registry::with_store(
                base_w.clone(),
                base_spec.clone(),
                AdapterStore::open_sharded(dir.join("storm"), shards)?,
            )?;
            gsoft::util::pool::parallel_map(entries.len(), storm_workers, |i| {
                let (t, e) = &entries[i];
                reg.register(*t, e.clone()).expect("storm register");
            });
            anyhow::ensure!(reg.len() == tenants, "storm lost registrations");
        }
        let storm = t0.elapsed();
        let storm_rps = tenants as f64 / storm.as_secs_f64().max(1e-9);

        // Phase 2: cold boot — parallel shard replay, then lazy
        // hydration of the fleet.
        let t0 = Instant::now();
        let store = AdapterStore::open(dir.join("factors"))?;
        let open = t0.elapsed();
        anyhow::ensure!(
            store.num_shards() == shards,
            "reopen changed the shard count ({} != {shards})",
            store.num_shards()
        );
        let registry = Registry::with_store(base_w, base_spec, store)?;
        let t0 = Instant::now();
        let hydrated = registry.hydrate_all()?;
        let hydrate = t0.elapsed();
        anyhow::ensure!(hydrated == tenants, "hydrated {hydrated}/{tenants} tenants");

        // Phase 3: spill-hit vs re-merge under a hot/cold trace. The RAM
        // cache holds only the hot set; cold tenants merge once, spill on
        // eviction, and later hits come back from disk.
        let hot = (tenants / 8).max(1);
        let model_bytes =
            registry.base().weights.len() * 4 + layers * d * d * 8;
        // Keep a handle on the sharded log: after finish() the engine is
        // gone, but the log's counters prove where compactions ran.
        let slog = registry.sharded_log().expect("store-backed registry");
        let engine = Engine::new(
            registry,
            EngineOpts {
                workers: 2,
                max_batch: 8,
                cache_budget_bytes: model_bytes * hot + model_bytes / 2,
                promote_after: Some(1),
                spill_dir: Some(dir.join("spill")),
                maint_interval: Duration::from_millis(maint_ms),
                ..EngineOpts::default()
            },
        )?;
        let mut rng = Rng::new(seed ^ 0x570e);
        let inputs: Vec<Vec<f32>> = (0..requests).map(|_| rng.normal_vec(d, 0.3)).collect();
        let trace: Vec<TenantId> = (0..requests)
            .map(|_| {
                if rng.uniform() < hit_ratio {
                    rng.below(hot) as TenantId
                } else {
                    (hot + rng.below(tenants - hot)) as TenantId
                }
            })
            .collect();
        let mut handles = Vec::with_capacity(requests);
        for (tenant, input) in trace.iter().zip(inputs) {
            handles.push(engine.submit(*tenant, input)?);
        }
        for h in handles {
            h.wait()?;
        }
        // Flush queued maintenance work (spill writes for evicted
        // models, one compaction scan) so the maint tallies below are
        // complete before the report is cut.
        engine.drain_maintenance();
        let report = engine.finish();
        let m = &report.metrics;
        let spill = report.spill.unwrap_or_default();
        let maint = report.maint.unwrap_or_default();
        let lstats = slog.stats();
        // The tentpole's off-path contract: every compaction and every
        // spill write this run was the maintenance thread's, never a
        // request's. (The log instance was opened fresh in phase 2, so
        // its compaction counter covers exactly the engine's lifetime.)
        anyhow::ensure!(
            lstats.compactions == maint.compactions,
            "{} compaction(s) ran on the request path",
            lstats.compactions - maint.compactions
        );
        anyhow::ensure!(
            spill.puts == maint.spill_writes,
            "{} spill write(s) ran on the request path",
            spill.puts - maint.spill_writes
        );

        let ns_ms = 1e-6;
        let tag = format!("{kind}_{tenants}t_hit{hit_ratio}_s{shards}");
        let hydrate_us = hydrate.as_secs_f64() * 1e6 / tenants as f64;
        table.row(vec![
            tag,
            fmt(persist.as_secs_f64() * 1e3, 2),
            fmt(storm_rps, 0),
            fmt(open.as_secs_f64() * 1e3, 2),
            fmt(hydrate_us, 1),
            fmt(m.service_cold.p50_ns * ns_ms, 4),
            fmt(m.service_spill.p50_ns * ns_ms, 4),
            spill.hits.to_string(),
        ]);
        configs.push(Json::obj(vec![
            ("kind", Json::Str(kind.to_string())),
            ("tenants", Json::Num(tenants as f64)),
            ("layers", Json::Num(layers as f64)),
            ("d", Json::Num(d as f64)),
            ("hit_ratio", Json::Num(hit_ratio)),
            ("shards", Json::Num(shards as f64)),
            ("requests", Json::Num(requests as f64)),
            ("persist_s", Json::Num(persist.as_secs_f64())),
            ("reg_storm_s", Json::Num(storm.as_secs_f64())),
            ("reg_storm_rps", Json::Num(storm_rps)),
            ("cold_open_s", Json::Num(open.as_secs_f64())),
            ("hydrate_us_per_tenant", Json::Num(hydrate_us)),
            ("merges", Json::Num(m.merges as f64)),
            ("spill_loads", Json::Num(m.spill_loads as f64)),
            ("remerge_service_p50_ns", Json::Num(m.service_cold.p50_ns)),
            ("spill_service_p50_ns", Json::Num(m.service_spill.p50_ns)),
            ("spill_hits", Json::Num(spill.hits as f64)),
            ("spill_evictions", Json::Num(spill.evictions as f64)),
            ("cache_hit_rate", Json::Num(report.cache.hit_rate())),
            // Background maintenance attribution (DESIGN.md §13): the
            // request path never compacts or writes spills; the two
            // request_path_* leaves are invariants pinned at 0.
            (
                "maint",
                Json::obj(vec![
                    ("ticks", Json::Num(maint.ticks as f64)),
                    ("compactions", Json::Num(maint.compactions as f64)),
                    ("spill_writes", Json::Num(maint.spill_writes as f64)),
                    (
                        "spill_write_failures",
                        Json::Num(maint.spill_write_failures as f64),
                    ),
                    ("queue_depth_peak", Json::Num(maint.max_queue_depth as f64)),
                    ("off_path_ns", Json::Num(maint.off_path_ns as f64)),
                    (
                        "request_path_compactions",
                        Json::Num((lstats.compactions - maint.compactions) as f64),
                    ),
                    (
                        "request_path_spill_writes",
                        Json::Num((spill.puts - maint.spill_writes) as f64),
                    ),
                ]),
            ),
        ]));
        let _ = std::fs::remove_dir_all(&dir);
    }
    table.emit("store_bench")?;
    let record = Json::obj(vec![
        ("smoke", Json::Bool(smoke)),
        ("seed", Json::Num(seed as f64)),
        ("configs", Json::Arr(configs)),
    ]);
    emit_json_record(std::path::Path::new(&out_path), &attach_global_obs(record))?;
    println!(
        "[store-bench] durable persist → replay → lazy hydrate → spill round-trip complete"
    );
    release_listener(args, server)?;
    Ok(())
}

/// Non-orthogonal GS compression (the concluding remarks' direction):
/// project a pretrained attention weight onto the GS class at several
/// block sizes and compare against budget-matched truncated SVD.
fn compress_demo(args: &Args) -> Result<()> {
    use gsoft::coordinator::experiments::pretrained_cls_base;
    use gsoft::coordinator::flatspec::FlatSpec;
    use gsoft::gs::compress::frontier;
    use gsoft::linalg::Mat;
    use gsoft::report::{fmt, fmt_params, Table};
    use gsoft::runtime::Runtime;

    let opts = RunOpts::load("table1", args)?;
    let rt = Runtime::new(&opts.artifacts)?;
    let base = pretrained_cls_base(&rt, "cls", &opts)?;
    let train = rt.load("cls_ft_train")?;
    let base_spec = FlatSpec::from_json(
        train
            .meta
            .extra
            .get("base_spec")
            .ok_or_else(|| anyhow::anyhow!("no base_spec"))?,
    )?;
    let (_, shape) = base_spec.locate("layer0.wq")?;
    let w = Mat::from_f32(shape[0], shape[1], base_spec.view(&base, "layer0.wq")?);
    let mut table = Table::new(
        "Non-orthogonal GS compression of the pretrained layer0.wq (Algorithm 1) vs budget-matched SVD",
        &["Approximation", "Params", "Compression", "Rel. Frobenius error"],
    );
    for p in frontier(&w, &[4, 8, 16, 32]) {
        table.row(vec![
            p.label.clone(),
            fmt_params(p.params),
            format!("{}x", fmt(p.ratio, 1)),
            fmt(p.rel_error, 4),
        ]);
    }
    table.emit("compress_demo")?;
    Ok(())
}

const HELP: &str = r#"gsoft — Group-and-Shuffle structured orthogonal parametrization

Usage: gsoft <subcommand> [--key value] [--no-cache]

Experiments (regenerate the paper's tables/figures into results/):
  table1        SynGLUE fine-tuning (FT/LoRA/OFT/BOFT/GSOFT/DoubleGSOFT)
  table2        subject-driven adaptation (denoiser stand-in)
  fig6          fidelity/editability series at two checkpoints
  table3        LipConvnet: SOC vs GS-SOC
  table4        activation x permutation ablation
  density       Theorem-2 support-density sweep   [--d 1024 --b 32]
  params-table  §5.2 parameter accounting
  perms         Figure-3 permutation matrices
  all           everything above

Utilities:
  merge-demo    fine-tune, merge Q into W in Rust, verify zero overhead
  compress-demo non-orthogonal GS layer compression vs truncated SVD
  serve         network request front over a serving engine
                (DESIGN.md §11): POST /v1/register /v1/query /v1/evict
                and GET /v1/tenants as JSON over HTTP/1.1, plus the obs
                scrape endpoints on the same listener. Every request
                passes admission control: per-tenant token buckets
                (429 past --rate/--burst), a global --max-inflight cap
                (503), and client deadlines (`deadline_ms` in the query
                body; expired work is shed before compute, 504). Every
                response carries a `req_id` (client-supplied or minted)
                that `/tracez?req=ID` resolves to its stage trace even
                after the main ring wraps; `/tenantz` serves the
                per-tenant heavy hitters (DESIGN.md §12)
                [--listen 127.0.0.1:9200 --tenants 8 --layers 2 --d 16
                 --block 4 --workers 2 --rate 50 --burst 100
                 --max-inflight 256 --hold-ms N (0 = forever)
                 --capture-slow-ms N --topk K]
  serve-bench   multi-tenant adapter serving engine benchmark
                [--tenants 256 --requests 4096 --layers 4 --d 64
                 --block 8 --zipf-s 1.1 --max-batch 16 --cache-mb 64]
                with --store DIR: durable store-backed registry over
                --shards N hash-sharded segment logs (default 4), and
                the Zipf query trace is mixed with registration traffic
                (every --reg-every-th request durably registers a new
                tenant, then queries it cold — write/read contention);
                compaction and spill writes run on the background
                maintenance thread (--maint-interval-ms N, default 200),
                never on a request; --smoke shrinks the run for CI
                Adapter families are an open set (gsoft, oft, lora,
                conv_gssoc, monarch, ... — see gsoft::adapter): new
                families serve, persist, and merge with zero engine or
                store edits.
  kernel-bench  CPU kernel sweep over (d, b, m, batch): fused
                group-and-shuffle apply vs dense merged GEMM; writes
                BENCH_kernels.json   [--smoke --seed 7 --out PATH]
  conv-bench    direct GS-SOC conv runtime sweep over (c, k, HxW,
                groups, batch): direct/im2col/conv_exp/GS-SOC layer vs
                materialized dense operator; writes BENCH_conv.json
                [--smoke --seed 7 --out PATH]
  store-bench   persistent tiered adapter store sweep over (tenants x
                adapter kind x hit ratio x shards): durable persist, a
                parallel registration storm across the hash-sharded
                segment logs, cold-boot parallel shard replay, lazy
                hydration, spill-hit vs re-merge, and a background-
                maintenance attribution section (maint) proving zero
                request-path compactions/spill writes; without --shards
                the full sweep adds a {1,4,16} shard-scaling axis
                [--smoke --seed 7 --out PATH --shards N
                 --maint-interval-ms 200]
  metrics       drive a tiny synthetic fleet with full telemetry on and
                dump the unified metrics registry (serve_* + kernel_* +
                store_* counters/gauges/latency histograms) as
                Prometheus text, or results/metrics.json with
                --format json   [--tenants 8 --requests 128 --d 16]
  obs-serve     stand up the live scrape endpoints over a small
                synthetic engine: /metrics (Prometheus text),
                /metrics.json, /healthz, /tracez, /tenantz, /slo
                [--listen 127.0.0.1:9100 --hold-ms N (0 = forever)
                 --tenants 8 --requests 128 --d 16]
  trace         drive a small synthetic fleet and export its request
                traces as Chrome trace-event JSON (open in
                chrome://tracing or Perfetto); one pid per engine, one
                tid per worker, stage spans nested in request spans
                [--out results/trace.json --requests 128 --trace-cap N]
  list          list compiled artifacts

Observability (DESIGN.md §9-§10): every bench JSON record carries an
"obs" section (metrics registry snapshot) and an "slo" section
(multi-window burn-rate verdict over p99 latency, deadline-miss ratio
and cache hit-rate objectives). serve-bench always includes its
engine's registry; the global kernel_*/store_* metrics join in under
--obs (one relaxed atomic load on the hot path when off). Every bench
also takes --listen ADDR to serve the live scrape endpoints during the
run (serve-bench: that engine's metrics/traces/health; other benches:
the process-wide registry) and --hold-ms N to keep them up after the
sweep. serve-bench --trace-cap N resizes the recent-trace ring.

Per-tenant plane (DESIGN.md §12): serve and serve-bench track heavy
hitters per tenant in bounded top-K sketches (--topk K, default 32 —
at most K metric series per dimension no matter how many tenants) and
capture slow/shed/errored request traces in a separate ring
(--capture-slow-ms N; default: the serve p99 SLO target). /tenantz
serves the sketches (?format=text for a table); /tracez grows
?req=ID / ?tenant=T / ?min_total_ns=N / ?captured=1 filters, and
serve-bench records the sketch summary under "tenants" in
BENCH_serve.json.

Common options: --steps N --pretrain-steps N --eval-batches N --lr X
                --workers N --seed N --artifacts DIR --no-cache --obs
                --listen ADDR --hold-ms N
"#;
