//! Declarative SLO objectives evaluated over metric-snapshot *deltas*
//! with multi-window burn rates (DESIGN.md §10).
//!
//! An [`SloObjective`] names a target over the metrics taxonomy — a
//! latency-quantile ceiling, an error-ratio budget, or a hit-rate floor —
//! and is evaluated against a [`RegistrySnapshot`] *window*: the
//! [`RegistrySnapshot::delta`] between two points in time, so a burst an
//! hour ago cannot mask a violation happening now. The [`SloTracker`]
//! retains a short snapshot history and evaluates every objective over
//! several look-back windows at once (the classic multi-window burn-rate
//! alerting shape): a short window catches fast burns, a long window
//! catches slow ones.
//!
//! **Burn rate** is "how fast the error budget is being consumed",
//! normalized so `1.0` = exactly at target:
//! - quantile ceilings: `observed_quantile / max`;
//! - ratio budgets (e.g. deadline misses): `bad_ratio / budget`;
//! - rate floors (e.g. cache hit-rate): `(1 - rate) / (1 - floor)`.
//!
//! A window with no eligible samples is `no_data`, never a failure —
//! an idle engine is not out of SLO. Reports export as JSON (the `slo`
//! section of `BENCH_*.json` and the `/slo` endpoint) and as gauges
//! (`slo_ok`, `slo_status{slo=...}`, `slo_burn_milli{slo=...,window=...}`)
//! so a scraper can alert on them directly.

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::json::Json;

use super::hist::HistoSnapshot;
use super::registry::{MetricsRegistry, RegistrySnapshot};

/// Burn rates are capped here so a JSON export never carries an
/// infinity (e.g. a miss ratio against a zero budget).
pub const BURN_CAP: f64 = 1e6;

/// The `serve_p99_latency` objective ceiling in [`SloSet::serve_default`]
/// (250 ms end-to-end). Also the default slow-request capture threshold:
/// when `EngineOpts::capture_slow_ns` is unset, any request at or past
/// the SLO objective is retained in the capture ring.
pub const SERVE_P99_TARGET_NS: u64 = 250_000_000;

/// What an objective measures over a snapshot window. Metric selectors
/// are *prefixes* into the flat metric namespace, so one objective can
/// aggregate a labeled family (`serve_request_ns{` merges every path's
/// latency histogram).
#[derive(Clone, Debug)]
pub enum SloKind {
    /// `quantile(q)` of the merged histograms matching `histo_prefix`
    /// must stay at or below `max`.
    QuantileMax { histo_prefix: String, q: f64, max: u64 },
    /// `Σ num / Σ den` (counter prefix sums) must stay at or below
    /// `budget`.
    RatioMax { num: Vec<String>, den: Vec<String>, budget: f64 },
    /// `Σ num / Σ den` must stay at or above `floor` (< 1.0).
    RatioMin { num: Vec<String>, den: Vec<String>, floor: f64 },
}

#[derive(Clone, Debug)]
pub struct SloObjective {
    pub name: String,
    pub kind: SloKind,
}

/// A named set of objectives — what a deployment declares once and every
/// exporter (EngineReport, bench records, `/slo`) evaluates.
#[derive(Clone, Debug, Default)]
pub struct SloSet {
    pub objectives: Vec<SloObjective>,
}

impl SloSet {
    /// Default objectives for the serving engine's `serve_*` taxonomy.
    /// Targets are generous enough for shared CI runners; deployments
    /// with real latency contracts should declare their own set.
    pub fn serve_default() -> SloSet {
        let s = |x: &str| x.to_string();
        SloSet {
            objectives: vec![
                SloObjective {
                    name: s("serve_p99_latency"),
                    kind: SloKind::QuantileMax {
                        histo_prefix: s("serve_request_ns{"),
                        q: 0.99,
                        max: SERVE_P99_TARGET_NS, // 250 ms end-to-end
                    },
                },
                SloObjective {
                    name: s("serve_deadline_miss"),
                    kind: SloKind::RatioMax {
                        num: vec![s("serve_deadline_miss_total")],
                        den: vec![s("serve_requests_total{path=")],
                        budget: 0.05,
                    },
                },
                SloObjective {
                    name: s("serve_cache_hit_rate"),
                    kind: SloKind::RatioMin {
                        num: vec![s("serve_cache_hits_total")],
                        den: vec![s("serve_cache_hits_total"), s("serve_cache_misses_total")],
                        floor: 0.25,
                    },
                },
            ],
        }
    }

    /// Default objectives over the process-wide `kernel_*`/`store_*`
    /// taxonomy (the benches that have no serving engine). Objectives
    /// whose metrics were never recorded report `no_data`.
    pub fn global_default() -> SloSet {
        let s = |x: &str| x.to_string();
        SloSet {
            objectives: vec![
                SloObjective {
                    name: s("store_append_p99"),
                    kind: SloKind::QuantileMax {
                        histo_prefix: s("store_append_ns"),
                        q: 0.99,
                        max: 100_000_000, // 100 ms per synced append
                    },
                },
                SloObjective {
                    name: s("store_spill_read_p99"),
                    kind: SloKind::QuantileMax {
                        histo_prefix: s("store_spill_read_ns"),
                        q: 0.99,
                        max: 250_000_000,
                    },
                },
                SloObjective {
                    name: s("kernel_gemm_p99"),
                    kind: SloKind::QuantileMax {
                        histo_prefix: s("kernel_gemm_ns{"),
                        q: 0.99,
                        max: 500_000_000,
                    },
                },
            ],
        }
    }

    /// Evaluate every objective over one whole-run window (the delta
    /// from an empty registry, i.e. the run's full activity). This is
    /// the `slo` section of [`crate::serve::EngineReport`] and the bench
    /// records.
    pub fn eval_total(&self, snap: &RegistrySnapshot, wall: Duration) -> SloReport {
        let windows = vec![("total".to_string(), wall.as_secs_f64(), snap.clone())];
        eval_windows(self, &windows)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SloStatus {
    Pass,
    Fail,
    /// No eligible samples in the window — idle is not a violation.
    NoData,
}

impl SloStatus {
    pub fn name(self) -> &'static str {
        match self {
            SloStatus::Pass => "pass",
            SloStatus::Fail => "fail",
            SloStatus::NoData => "no_data",
        }
    }

    /// Gauge encoding: pass=1, fail=0, no_data=2.
    fn code(self) -> u64 {
        match self {
            SloStatus::Pass => 1,
            SloStatus::Fail => 0,
            SloStatus::NoData => 2,
        }
    }
}

/// One objective × one look-back window.
#[derive(Clone, Debug)]
pub struct WindowEval {
    /// Window label (`"10s"`, `"60s"`, `"total"`).
    pub window: String,
    /// Actual covered span in seconds (a young tracker covers less than
    /// the nominal window).
    pub seconds: f64,
    pub status: SloStatus,
    pub burn_rate: f64,
    pub observed: f64,
    pub target: f64,
}

#[derive(Clone, Debug)]
pub struct ObjectiveReport {
    pub name: String,
    /// Fail if any window fails; no_data only if every window is.
    pub status: SloStatus,
    pub windows: Vec<WindowEval>,
}

/// Pass/fail summary across a whole [`SloSet`].
#[derive(Clone, Debug, Default)]
pub struct SloReport {
    pub objectives: Vec<ObjectiveReport>,
}

impl SloReport {
    /// True when no objective failed (no_data counts as ok).
    pub fn ok(&self) -> bool {
        self.objectives.iter().all(|o| o.status != SloStatus::Fail)
    }

    pub fn to_json(&self) -> Json {
        let objectives = self
            .objectives
            .iter()
            .map(|o| {
                let windows = o
                    .windows
                    .iter()
                    .map(|w| {
                        Json::obj(vec![
                            ("window", Json::Str(w.window.clone())),
                            ("seconds", Json::Num(w.seconds)),
                            ("status", Json::Str(w.status.name().to_string())),
                            ("burn_rate", Json::Num(w.burn_rate)),
                            ("observed", Json::Num(w.observed)),
                            ("target", Json::Num(w.target)),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    ("name", Json::Str(o.name.clone())),
                    ("status", Json::Str(o.status.name().to_string())),
                    ("windows", Json::Arr(windows)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("ok", Json::Bool(self.ok())),
            ("objectives", Json::Arr(objectives)),
        ])
    }

    /// Export the summary as gauges so `/metrics` scrapers can alert on
    /// SLO state without parsing JSON: `slo_ok`, per-objective
    /// `slo_status{slo=...}` (1 pass / 0 fail / 2 no_data) and
    /// per-window `slo_burn_milli{slo=...,window=...}`.
    pub fn export_gauges(&self, reg: &MetricsRegistry) {
        reg.gauge("slo_ok").set(self.ok() as u64);
        for o in &self.objectives {
            reg.gauge(&format!("slo_status{{slo=\"{}\"}}", o.name)).set(o.status.code());
            for w in &o.windows {
                let milli = (w.burn_rate * 1000.0).min(BURN_CAP) as u64;
                reg.gauge(&format!("slo_burn_milli{{slo=\"{}\",window=\"{}\"}}", o.name, w.window))
                    .set(milli);
            }
        }
    }
}

fn sum_matching(snap: &RegistrySnapshot, prefixes: &[String]) -> u64 {
    snap.counters
        .iter()
        .filter(|(k, _)| prefixes.iter().any(|p| k.starts_with(p.as_str())))
        .map(|(_, &v)| v)
        .sum()
}

fn merged_matching(snap: &RegistrySnapshot, prefix: &str) -> HistoSnapshot {
    let mut out = HistoSnapshot::default();
    for (name, h) in &snap.histograms {
        if name.starts_with(prefix) {
            out.merge(h);
        }
    }
    out
}

/// Evaluate one objective over one window delta. Returns
/// `(status, burn_rate, observed, target)`.
fn eval_objective(obj: &SloObjective, window: &RegistrySnapshot) -> (SloStatus, f64, f64, f64) {
    let (burn, observed, target, has_data) = match &obj.kind {
        SloKind::QuantileMax { histo_prefix, q, max } => {
            let h = merged_matching(window, histo_prefix);
            let observed = h.quantile(*q) as f64;
            let target = *max as f64;
            (observed / target.max(1.0), observed, target, !h.is_empty())
        }
        SloKind::RatioMax { num, den, budget } => {
            let n = sum_matching(window, num) as f64;
            let d = sum_matching(window, den) as f64;
            let ratio = if d > 0.0 { n / d } else { 0.0 };
            let burn = if *budget > 0.0 {
                ratio / budget
            } else if ratio > 0.0 {
                BURN_CAP
            } else {
                0.0
            };
            (burn, ratio, *budget, d > 0.0)
        }
        SloKind::RatioMin { num, den, floor } => {
            let n = sum_matching(window, num) as f64;
            let d = sum_matching(window, den) as f64;
            let rate = if d > 0.0 { n / d } else { 0.0 };
            let slack = (1.0 - floor).max(f64::EPSILON);
            ((1.0 - rate) / slack, rate, *floor, d > 0.0)
        }
    };
    let burn = burn.min(BURN_CAP);
    if !has_data {
        (SloStatus::NoData, 0.0, observed, target)
    } else if burn > 1.0 {
        (SloStatus::Fail, burn, observed, target)
    } else {
        (SloStatus::Pass, burn, observed, target)
    }
}

/// Evaluate a set over pre-computed `(label, seconds, delta)` windows.
fn eval_windows(set: &SloSet, windows: &[(String, f64, RegistrySnapshot)]) -> SloReport {
    let objectives = set
        .objectives
        .iter()
        .map(|obj| {
            let evals: Vec<WindowEval> = windows
                .iter()
                .map(|(label, seconds, delta)| {
                    let (status, burn_rate, observed, target) = eval_objective(obj, delta);
                    WindowEval {
                        window: label.clone(),
                        seconds: *seconds,
                        status,
                        burn_rate,
                        observed,
                        target,
                    }
                })
                .collect();
            let status = if evals.iter().any(|w| w.status == SloStatus::Fail) {
                SloStatus::Fail
            } else if evals.iter().all(|w| w.status == SloStatus::NoData) {
                SloStatus::NoData
            } else {
                SloStatus::Pass
            };
            ObjectiveReport {
                name: obj.name.clone(),
                status,
                windows: evals,
            }
        })
        .collect();
    SloReport { objectives }
}

fn window_label(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 && s.fract() == 0.0 {
        format!("{}s", s as u64)
    } else {
        format!("{s:.1}s")
    }
}

/// Multi-window burn-rate tracker: feed it registry snapshots over time
/// ([`SloTracker::observe`]), read back per-window evaluations
/// ([`SloTracker::report`]). The `/slo` endpoint observes lazily on each
/// request — no dedicated ticker thread. History is pruned to the
/// longest window, so memory is bounded by scrape frequency × horizon.
pub struct SloTracker {
    set: SloSet,
    /// Nominal look-back windows, e.g. `[10s, 60s]`.
    windows: Vec<Duration>,
    history: Mutex<VecDeque<(Instant, RegistrySnapshot)>>,
}

impl SloTracker {
    pub fn new(set: SloSet, windows: Vec<Duration>) -> SloTracker {
        SloTracker {
            set,
            windows: if windows.is_empty() {
                vec![Duration::from_secs(10), Duration::from_secs(60)]
            } else {
                windows
            },
            history: Mutex::new(VecDeque::new()),
        }
    }

    pub fn set(&self) -> &SloSet {
        &self.set
    }

    /// Record a snapshot at `now` and prune history past the longest
    /// window (one entry at-or-before the horizon is retained so the
    /// longest window always has a baseline).
    pub fn observe(&self, now: Instant, snap: RegistrySnapshot) {
        let horizon = self.windows.iter().copied().max().unwrap_or(Duration::from_secs(60));
        let mut h = self.history.lock().unwrap();
        h.push_back((now, snap));
        while h.len() >= 2 {
            let second_age = now.saturating_duration_since(h[1].0);
            if second_age >= horizon {
                h.pop_front();
            } else {
                break;
            }
        }
    }

    /// Evaluate every objective over every window ending at the newest
    /// observation. A window whose nominal span predates the oldest
    /// retained snapshot evaluates over what is covered (its `seconds`
    /// says how much); with fewer than two observations everything is
    /// `no_data`.
    pub fn report(&self, now: Instant) -> SloReport {
        let h = self.history.lock().unwrap();
        let Some((newest_t, newest)) = h.back() else {
            return eval_windows(&self.set, &[]);
        };
        let windows: Vec<(String, f64, RegistrySnapshot)> = self
            .windows
            .iter()
            .map(|&w| {
                let start = now.checked_sub(w);
                // Newest observation at-or-before the window start; falls
                // back to the oldest retained one.
                let base = h
                    .iter()
                    .rev()
                    .find(|(t, _)| start.map(|s| *t <= s).unwrap_or(false))
                    .or_else(|| h.front())
                    .expect("history is non-empty");
                let seconds = newest_t.saturating_duration_since(base.0).as_secs_f64();
                (window_label(w), seconds, newest.delta(&base.1))
            })
            .collect();
        eval_windows(&self.set, &windows)
    }

    /// Convenience for endpoint handlers: observe `snap` now, then
    /// report.
    pub fn observe_and_report(&self, snap: RegistrySnapshot) -> SloReport {
        let now = Instant::now();
        self.observe(now, snap);
        self.report(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn serve_like_registry() -> MetricsRegistry {
        let reg = MetricsRegistry::new();
        let lat = reg.histogram("serve_request_ns{path=\"cached_dense\"}");
        for i in 0..100u64 {
            lat.record(1_000_000 + i); // ~1 ms, far under the 250 ms ceiling
        }
        reg.counter("serve_requests_total{path=\"cached_dense\"}").add(100);
        reg.counter("serve_deadline_miss_total").add(1); // 1% < 5% budget
        reg.counter("serve_cache_hits_total").add(80);
        reg.counter("serve_cache_misses_total").add(20);
        reg
    }

    #[test]
    fn total_eval_passes_a_healthy_run_and_fails_a_burned_budget() {
        let set = SloSet::serve_default();
        let reg = serve_like_registry();
        let report = set.eval_total(&reg.snapshot(), Duration::from_secs(1));
        assert!(report.ok(), "healthy run must pass: {:?}", report.objectives);
        for o in &report.objectives {
            assert_eq!(o.status, SloStatus::Pass, "{}", o.name);
            assert_eq!(o.windows.len(), 1);
            assert!(o.windows[0].burn_rate <= 1.0, "{}: {}", o.name, o.windows[0].burn_rate);
        }

        // Burn the deadline budget: 20/120 misses > 5%.
        reg.counter("serve_deadline_miss_total").add(19);
        reg.counter("serve_requests_total{path=\"cached_dense\"}").add(20);
        let report = set.eval_total(&reg.snapshot(), Duration::from_secs(1));
        assert!(!report.ok());
        let miss = report.objectives.iter().find(|o| o.name == "serve_deadline_miss").unwrap();
        assert_eq!(miss.status, SloStatus::Fail);
        assert!(miss.windows[0].burn_rate > 1.0);
    }

    #[test]
    fn empty_window_is_no_data_not_a_failure() {
        let set = SloSet::serve_default();
        let report = set.eval_total(&RegistrySnapshot::default(), Duration::from_secs(1));
        assert!(report.ok(), "idle is never out of SLO");
        for o in &report.objectives {
            assert_eq!(o.status, SloStatus::NoData, "{}", o.name);
        }
    }

    #[test]
    fn tracker_windows_isolate_recent_burns() {
        // Timeline (fabricated instants, no sleeping): a healthy minute,
        // then a 10-second burst of deadline misses. The short window
        // fails; the long window has absorbed enough good traffic that
        // its budget holds.
        let set = SloSet {
            objectives: vec![SloObjective {
                name: "miss".into(),
                kind: SloKind::RatioMax {
                    num: vec!["serve_deadline_miss_total".into()],
                    den: vec!["serve_requests_total{path=".into()],
                    budget: 0.10,
                },
            }],
        };
        let tracker =
            SloTracker::new(set, vec![Duration::from_secs(10), Duration::from_secs(120)]);
        let reg = MetricsRegistry::new();
        let req = reg.counter("serve_requests_total{path=\"cached_dense\"}");
        let miss = reg.counter("serve_deadline_miss_total");

        let t0 = Instant::now();
        tracker.observe(t0, reg.snapshot());
        req.add(1000); // 60 s of clean traffic
        let t1 = t0 + Duration::from_secs(60);
        tracker.observe(t1, reg.snapshot());
        req.add(100);
        miss.add(50); // 50% misses in the last 10 s
        let t2 = t1 + Duration::from_secs(10);
        tracker.observe(t2, reg.snapshot());

        let report = tracker.report(t2);
        let obj = &report.objectives[0];
        let short = obj.windows.iter().find(|w| w.window == "10s").unwrap();
        let long = obj.windows.iter().find(|w| w.window == "120s").unwrap();
        assert_eq!(short.status, SloStatus::Fail, "burst must trip the short window");
        assert!(short.burn_rate > 1.0);
        assert_eq!(long.status, SloStatus::Pass, "long window absorbs the burst");
        assert!(!report.ok(), "any failing window fails the report");
    }

    #[test]
    fn report_json_and_gauge_export_shapes() {
        let set = SloSet::serve_default();
        let reg = serve_like_registry();
        let report = set.eval_total(&reg.snapshot(), Duration::from_secs(2));
        let j = report.to_json();
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true));
        let objs = j.get("objectives").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(objs.len(), 3);
        for o in objs {
            assert!(o.get("name").is_some());
            let status = o.get("status").and_then(|s| s.as_str()).unwrap();
            assert!(["pass", "fail", "no_data"].contains(&status));
            let ws = o.get("windows").and_then(|w| w.as_arr()).unwrap();
            assert!(!ws.is_empty());
            for w in ws {
                assert!(w.get("burn_rate").and_then(|v| v.as_f64()).unwrap() >= 0.0);
                assert!(w.get("target").is_some());
            }
        }
        let out = MetricsRegistry::new();
        report.export_gauges(&out);
        let snap = out.snapshot();
        assert_eq!(snap.gauges["slo_ok"], 1);
        assert_eq!(snap.gauges["slo_status{slo=\"serve_p99_latency\"}"], 1);
        assert!(snap
            .gauges
            .contains_key("slo_burn_milli{slo=\"serve_p99_latency\",window=\"total\"}"));
    }

    #[test]
    fn young_tracker_reports_no_data() {
        let tracker = SloTracker::new(SloSet::serve_default(), vec![Duration::from_secs(10)]);
        let report = tracker.report(Instant::now());
        assert!(report.ok());
        // One observation: every window's delta is empty.
        let reg = serve_like_registry();
        let now = Instant::now();
        tracker.observe(now, reg.snapshot());
        let report = tracker.report(now);
        for o in &report.objectives {
            assert_eq!(o.status, SloStatus::NoData, "{}", o.name);
        }
    }
}
