//! Chrome trace-event export for [`TraceRing`] contents.
//!
//! Converts the engine's per-request stage traces into the Chrome
//! trace-event JSON format — the `{"traceEvents": [...]}` array of
//! complete (`"ph": "X"`) events — loadable directly in
//! `chrome://tracing` or Perfetto (`gsoft trace --out trace.json`).
//!
//! Mapping (DESIGN.md §10):
//! - one **pid** per engine (callers pick; the CLI uses 1);
//! - one **tid** per worker thread (`worker + 1`, so tid 0 never
//!   collides with a real worker's lane);
//! - each request is an enclosing `X` span named by its serve path,
//!   starting at the trace's `start_ns` on the engine epoch timeline and
//!   lasting `total_ns`;
//! - each non-zero stage is a nested `X` span laid out sequentially
//!   inside the request span, in [`Stage::ALL`] pipeline order.
//!
//! Timestamps (`ts`) and durations (`dur`) are microseconds per the
//! format spec; nanosecond figures are divided by 1000 as `f64` so
//! sub-microsecond stages stay visible instead of rounding to zero.

use std::collections::BTreeSet;

use crate::util::json::Json;

use super::trace::{Stage, Trace};

const NS_PER_US: f64 = 1000.0;

fn meta_event(name: &str, pid: u64, tid: u64, value: &str) -> Json {
    Json::obj(vec![
        ("name", Json::Str(name.to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("args", Json::obj(vec![("name", Json::Str(value.to_string()))])),
    ])
}

fn span_event(name: &str, cat: &str, pid: u64, tid: u64, ts_ns: u64, dur_ns: u64, args: Json) -> Json {
    Json::obj(vec![
        ("name", Json::Str(name.to_string())),
        ("cat", Json::Str(cat.to_string())),
        ("ph", Json::Str("X".to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(ts_ns as f64 / NS_PER_US)),
        ("dur", Json::Num(dur_ns as f64 / NS_PER_US)),
        ("args", args),
    ])
}

/// Build a Chrome trace-event document from ring traces. `pid`
/// identifies the engine (a multi-engine process exports one call per
/// engine and concatenates the event arrays).
pub fn chrome_trace(traces: &[Trace], pid: u64) -> Json {
    let mut events = Vec::new();
    events.push(meta_event("process_name", pid, 0, "gsoft-engine"));
    let workers: BTreeSet<u32> = traces.iter().map(|t| t.worker).collect();
    for w in &workers {
        events.push(meta_event("thread_name", pid, *w as u64 + 1, &format!("worker-{w}")));
    }

    // Ring snapshots are newest-first; emit oldest-first so the event
    // array reads in timeline order.
    let mut ordered: Vec<&Trace> = traces.iter().collect();
    ordered.sort_by_key(|t| (t.start_ns, t.seq));
    for t in ordered {
        let tid = t.worker as u64 + 1;
        let args = Json::obj(vec![
            ("tenant", Json::Num(t.tenant as f64)),
            ("seq", Json::u64(t.seq)),
            // Correlation key: the same id the `/v1/query` response and
            // `/tracez?req=` carry.
            ("req", Json::u64(t.req_id)),
        ]);
        events.push(span_event(t.path, "request", pid, tid, t.start_ns, t.total_ns, args));
        // Stages laid out back-to-back from the request start, pipeline
        // order. Stage sums can undershoot total_ns (untimed gaps stay
        // visible as slack inside the request span).
        let mut cursor = t.start_ns;
        for s in Stage::ALL {
            let ns = t.stage_ns[s.index()];
            if ns == 0 {
                continue;
            }
            events.push(span_event(s.name(), "stage", pid, tid, cursor, ns, Json::obj(vec![])));
            cursor = cursor.saturating_add(ns);
        }
    }

    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(seq: u64, worker: u32, start_ns: u64) -> Trace {
        Trace {
            seq,
            req_id: 10 + seq,
            tenant: 7,
            path: "cached_dense",
            start_ns,
            worker,
            total_ns: 5_000,
            stage_ns: [1_000, 500, 0, 0, 3_000, 250],
        }
    }

    #[test]
    fn export_has_metadata_and_one_lane_per_worker() {
        let traces = vec![trace(1, 0, 10_000), trace(2, 2, 20_000)];
        let j = chrome_trace(&traces, 1);
        assert_eq!(j.get("displayTimeUnit").and_then(|v| v.as_str()), Some("ms"));
        let events = j.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        let metas: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("M"))
            .collect();
        // process_name + one thread_name per distinct worker.
        assert_eq!(metas.len(), 3);
        let tids: Vec<f64> = metas
            .iter()
            .filter(|e| e.get("name").and_then(|n| n.as_str()) == Some("thread_name"))
            .map(|e| e.get("tid").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(tids, vec![1.0, 3.0], "tid = worker + 1");
    }

    #[test]
    fn stage_spans_nest_sequentially_inside_the_request_span() {
        let j = chrome_trace(&[trace(4, 1, 100_000)], 1);
        let events = j.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        let spans: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .collect();
        // 1 request span + 4 non-zero stages (merge/spill omitted).
        assert_eq!(spans.len(), 5);
        let req = spans[0];
        assert_eq!(req.get("name").and_then(|n| n.as_str()), Some("cached_dense"));
        assert_eq!(req.get("ts").unwrap().as_f64().unwrap(), 100.0, "ns→µs");
        assert_eq!(req.get("dur").unwrap().as_f64().unwrap(), 5.0);
        let args = req.get("args").unwrap();
        assert_eq!(args.get("req").unwrap().as_u64(), Some(14), "req_id rides in span args");
        let req_end = 100.0 + 5.0;
        let mut cursor = 100.0;
        let names: Vec<&str> =
            spans[1..].iter().map(|s| s.get("name").unwrap().as_str().unwrap()).collect();
        assert_eq!(names, vec!["queue", "plan", "kernel", "reply"]);
        for s in &spans[1..] {
            let ts = s.get("ts").unwrap().as_f64().unwrap();
            let dur = s.get("dur").unwrap().as_f64().unwrap();
            assert_eq!(ts, cursor, "stages are laid out back-to-back");
            assert!(ts + dur <= req_end + 1e-9, "stage stays inside the request span");
            assert_eq!(s.get("tid").unwrap().as_f64().unwrap(), 2.0);
            cursor = ts + dur;
        }
    }

    #[test]
    fn newest_first_input_exports_in_timeline_order() {
        // Ring snapshots arrive newest-first; the event array must come
        // out oldest-first.
        let j = chrome_trace(&[trace(9, 0, 90_000), trace(3, 0, 30_000)], 1);
        let events = j.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        let req_ts: Vec<f64> = events
            .iter()
            .filter(|e| e.get("cat").and_then(|c| c.as_str()) == Some("request"))
            .map(|e| e.get("ts").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(req_ts, vec![30.0, 90.0]);
    }

    #[test]
    fn empty_ring_still_produces_a_loadable_document() {
        let j = chrome_trace(&[], 42);
        let events = j.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(events.len(), 1, "just the process_name metadata");
        assert_eq!(events[0].get("pid").unwrap().as_f64().unwrap(), 42.0);
    }
}
