//! Per-request stage traces and a fixed-size ring of the most recent ones.
//!
//! Each served request is decomposed into the pipeline stages below
//! (enqueue→batch→plan→kernel→merge/spill→reply); the engine records a
//! nanosecond figure per stage and pushes the completed [`Trace`] into a
//! [`TraceRing`]. The ring keeps the newest N traces under concurrent
//! writers — a tail-latency request is still inspectable after the fact
//! (`gsoft metrics` dumps the ring) without logging every request.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;

/// Pipeline stage of a served request. `Queue` and `Reply` are measured
/// per request; the middle stages are measured once per micro-batch and
/// attributed to every request in it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Submit → service start (micro-batcher queue wait).
    Queue,
    /// Cache lookup + per-family cost-model policy decision.
    Plan,
    /// Dense merge of the factor chain (cold/promotion path).
    Merge,
    /// Spill-store read of a previously merged matrix.
    Spill,
    /// The matmul itself (dense or factorized forward).
    Kernel,
    /// Service end → caller handoff (channel send, bookkeeping).
    Reply,
}

impl Stage {
    pub const COUNT: usize = 6;
    pub const ALL: [Stage; Stage::COUNT] =
        [Stage::Queue, Stage::Plan, Stage::Merge, Stage::Spill, Stage::Kernel, Stage::Reply];

    pub fn index(self) -> usize {
        match self {
            Stage::Queue => 0,
            Stage::Plan => 1,
            Stage::Merge => 2,
            Stage::Spill => 3,
            Stage::Kernel => 4,
            Stage::Reply => 5,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Stage::Queue => "queue",
            Stage::Plan => "plan",
            Stage::Merge => "merge",
            Stage::Spill => "spill",
            Stage::Kernel => "kernel",
            Stage::Reply => "reply",
        }
    }
}

/// One completed request trace. Fixed-size (no heap) so pushing into the
/// ring never allocates.
#[derive(Clone, Debug)]
pub struct Trace {
    /// Monotone per-ring sequence number (assigned by `push`).
    pub seq: u64,
    /// Caller-visible request id (client-supplied or minted by the
    /// front/engine), the correlation key for `/tracez?req=` lookups.
    /// 0 = unattributed (in-process submit paths that skip minting).
    pub req_id: u64,
    pub tenant: u64,
    /// `ServePath` wire name the request took.
    pub path: &'static str,
    /// Submit time in nanoseconds since the owning engine's epoch —
    /// what places the request on a common timeline in the Chrome-trace
    /// export ([`crate::obs::chrome`]).
    pub start_ns: u64,
    /// Index of the worker thread that served the batch (one Chrome
    /// `tid` per worker).
    pub worker: u32,
    pub total_ns: u64,
    /// Nanoseconds per stage, indexed by [`Stage::index`]; 0 = stage not
    /// entered.
    pub stage_ns: [u64; Stage::COUNT],
}

impl Trace {
    pub fn to_json(&self) -> Json {
        let stages = Json::Obj(
            Stage::ALL
                .iter()
                .filter(|s| self.stage_ns[s.index()] > 0)
                .map(|s| (s.name().to_string(), Json::Num(self.stage_ns[s.index()] as f64)))
                .collect(),
        );
        // seq / req_id / start_ns are u64 identifiers and epoch
        // nanoseconds — `Json::u64` keeps them exact past 2^53 (start_ns
        // crosses it after ~104 days of engine uptime).
        Json::obj(vec![
            ("seq", Json::u64(self.seq)),
            ("req_id", Json::u64(self.req_id)),
            ("tenant", Json::Num(self.tenant as f64)),
            ("path", Json::Str(self.path.to_string())),
            ("start_ns", Json::u64(self.start_ns)),
            ("worker", Json::Num(self.worker as f64)),
            ("total_ns", Json::Num(self.total_ns as f64)),
            ("stage_ns", stages),
        ])
    }
}

/// Lossy ring of the most recent traces. Writers claim a global sequence
/// number with one `fetch_add`, then write slot `seq % capacity`; a slot
/// only ever moves forward in sequence, so after any quiescent point the
/// ring holds exactly the newest `capacity` traces regardless of write
/// interleaving.
pub struct TraceRing {
    seq: AtomicU64,
    slots: Vec<Mutex<Option<Trace>>>,
}

impl TraceRing {
    pub fn new(capacity: usize) -> TraceRing {
        TraceRing {
            seq: AtomicU64::new(0),
            slots: (0..capacity.max(1)).map(|_| Mutex::new(None)).collect(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total traces ever pushed (not the resident count).
    pub fn pushed(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// Record a trace, stamping its `seq`. Returns the assigned sequence
    /// number.
    pub fn push(&self, mut trace: Trace) -> u64 {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        trace.seq = seq;
        let mut slot = self.slots[(seq % self.slots.len() as u64) as usize].lock().unwrap();
        // Two writers racing on the same slot resolve by sequence: the
        // newer trace wins, so the newest-N invariant survives any
        // interleaving of lock acquisitions.
        let stale = match slot.as_ref() {
            Some(t) => t.seq < seq,
            None => true,
        };
        if stale {
            *slot = Some(trace);
        }
        seq
    }

    /// Resident traces, newest first.
    pub fn snapshot(&self) -> Vec<Trace> {
        let mut out: Vec<Trace> = self
            .slots
            .iter()
            .filter_map(|s| s.lock().unwrap().clone())
            .collect();
        out.sort_by(|a, b| b.seq.cmp(&a.seq));
        out
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(self.snapshot().iter().map(Trace::to_json).collect())
    }
}

impl std::fmt::Debug for TraceRing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TraceRing(cap {}, pushed {})", self.slots.len(), self.pushed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn trace(tenant: u64) -> Trace {
        Trace {
            seq: 0,
            req_id: 1000 + tenant,
            tenant,
            path: "cached_dense",
            start_ns: 100 * tenant,
            worker: (tenant % 3) as u32,
            total_ns: 10 * tenant + 1,
            stage_ns: [tenant, 0, 0, 0, 1, 2],
        }
    }

    #[test]
    fn ring_keeps_newest_n_single_threaded() {
        let ring = TraceRing::new(4);
        for i in 0..10 {
            ring.push(trace(i));
        }
        let snap = ring.snapshot();
        let seqs: Vec<u64> = snap.iter().map(|t| t.seq).collect();
        assert_eq!(seqs, vec![9, 8, 7, 6], "newest first, exactly capacity");
        assert_eq!(ring.pushed(), 10);
    }

    #[test]
    fn ring_keeps_newest_n_under_concurrent_writers() {
        const CAP: usize = 8;
        const THREADS: u64 = 4;
        const PER: u64 = 100;
        let ring = Arc::new(TraceRing::new(CAP));
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let ring = Arc::clone(&ring);
                std::thread::spawn(move || {
                    for i in 0..PER {
                        ring.push(trace(t * PER + i));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let total = THREADS * PER;
        let snap = ring.snapshot();
        let seqs: Vec<u64> = snap.iter().map(|t| t.seq).collect();
        let want: Vec<u64> = (0..CAP as u64).map(|i| total - 1 - i).collect();
        assert_eq!(seqs, want, "ring must retain exactly the newest {CAP} seqs");
    }

    #[test]
    fn trace_json_round_trips_u64_fields_past_2_53() {
        // seq/start_ns/req_id above 2^53 used to go through Json::Num
        // (an f64) and come back corrupted; pin the lossless path.
        let mut t = trace(1);
        t.seq = (1 << 53) + 12345;
        t.req_id = u64::MAX - 7;
        t.start_ns = (1 << 60) + 99; // ~36 years of uptime in ns
        let parsed = crate::util::json::Json::parse(&t.to_json().pretty()).unwrap();
        assert_eq!(parsed.get("seq").unwrap().as_u64(), Some(t.seq));
        assert_eq!(parsed.get("req_id").unwrap().as_u64(), Some(t.req_id));
        assert_eq!(parsed.get("start_ns").unwrap().as_u64(), Some(t.start_ns));
        // Small values still read back through the same accessor.
        let small = crate::util::json::Json::parse(&trace(2).to_json().to_string()).unwrap();
        assert_eq!(small.get("req_id").unwrap().as_u64(), Some(1002));
        assert_eq!(small.get("seq").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn trace_json_skips_unentered_stages() {
        let ring = TraceRing::new(2);
        ring.push(trace(3));
        let j = ring.to_json();
        let t = &j.as_arr().unwrap()[0];
        let stages = t.get("stage_ns").unwrap().as_obj().unwrap();
        assert!(stages.contains_key("queue") && stages.contains_key("reply"));
        assert!(!stages.contains_key("merge"), "zero stages omitted");
    }
}
