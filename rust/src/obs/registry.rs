//! Named metrics registry: counters, gauges, and histograms keyed by a
//! flat name, with mergeable snapshots and two exporters.
//!
//! Names follow the DESIGN.md §9 taxonomy (`serve_*`, `kernel_*`,
//! `store_*`) and may carry an inline Prometheus-style label set, quotes
//! included — e.g. `serve_requests_total{path="cached_dense"}`. The
//! registry map is only locked at registration and snapshot time;
//! recording goes through pre-resolved `Arc` handles (pure relaxed
//! atomics, no lock, no allocation on any hot path).
//!
//! Exporters:
//! - [`RegistrySnapshot::prometheus`] — the text format a future
//!   `serve --listen` `/metrics` endpoint will serve verbatim;
//! - [`RegistrySnapshot::to_json`] — the `obs` section of the
//!   `BENCH_*.json` records, with every latency distribution under a
//!   `timings` sub-object so the records stay deterministic modulo
//!   timings under the `strip_timing` convention
//!   ([`crate::kernel::convbench::strip_timing`]).

use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

use super::hist::{Histo, HistoSnapshot};

/// Monotonic counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time value (queue depth, resident bytes, policy thresholds).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histo(Arc<Histo>),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histo(_) => "histogram",
        }
    }
}

/// A registry of named metrics. `counter`/`gauge`/`histogram` get or
/// create (same name ⇒ same underlying handle, so instrumentation sites
/// can resolve independently); registering one name as two different
/// kinds is a programming error and panics.
#[derive(Default)]
pub struct MetricsRegistry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl fmt::Debug for MetricsRegistry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "MetricsRegistry({} metrics)", self.metrics.lock().unwrap().len())
    }
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().unwrap();
        let metric = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::default())));
        match metric {
            Metric::Counter(c) => Arc::clone(c),
            other => panic!("metric '{name}' already registered as a {}", other.kind()),
        }
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().unwrap();
        let metric = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::default())));
        match metric {
            Metric::Gauge(g) => Arc::clone(g),
            other => panic!("metric '{name}' already registered as a {}", other.kind()),
        }
    }

    pub fn histogram(&self, name: &str) -> Arc<Histo> {
        let mut m = self.metrics.lock().unwrap();
        let metric = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histo(Arc::new(Histo::new())));
        match metric {
            Metric::Histo(h) => Arc::clone(h),
            other => panic!("metric '{name}' already registered as a {}", other.kind()),
        }
    }

    /// All registered names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.metrics.lock().unwrap().keys().cloned().collect()
    }

    /// Consistent point-in-time view: every per-metric total is derived
    /// from its components (histogram counts from bucket arrays), never
    /// from a second independent atomic read.
    pub fn snapshot(&self) -> RegistrySnapshot {
        let m = self.metrics.lock().unwrap();
        let mut snap = RegistrySnapshot::default();
        for (name, metric) in m.iter() {
            match metric {
                Metric::Counter(c) => {
                    snap.counters.insert(name.clone(), c.get());
                }
                Metric::Gauge(g) => {
                    snap.gauges.insert(name.clone(), g.get());
                }
                Metric::Histo(h) => {
                    snap.histograms.insert(name.clone(), h.snapshot());
                }
            }
        }
        snap
    }
}

/// Owned snapshot of a whole registry; merges associatively (counters and
/// histogram buckets add, gauges are point-in-time so the right operand
/// wins on a name collision).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RegistrySnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, u64>,
    pub histograms: BTreeMap<String, HistoSnapshot>,
}

impl RegistrySnapshot {
    /// Fold `other` in (e.g. a per-engine registry plus the process-wide
    /// kernel/store registry into one export).
    pub fn merge(&mut self, other: &RegistrySnapshot) {
        for (name, &v) in &other.counters {
            let e = self.counters.entry(name.clone()).or_insert(0);
            *e = e.wrapping_add(v);
        }
        for (name, &v) in &other.gauges {
            self.gauges.insert(name.clone(), v);
        }
        for (name, h) in &other.histograms {
            match self.histograms.get_mut(name) {
                Some(mine) => mine.merge(h),
                None => {
                    self.histograms.insert(name.clone(), h.clone());
                }
            }
        }
    }

    /// The activity between `earlier` and `self`, where `earlier` is a
    /// previous snapshot of the same registry: counters and histograms
    /// subtract ([`HistoSnapshot::delta`]), gauges are point-in-time so
    /// the later value is kept. Metrics absent from `earlier` (registered
    /// mid-window) delta against zero. The substrate for SLO burn-rate
    /// windows ([`crate::obs::slo`]).
    pub fn delta(&self, earlier: &RegistrySnapshot) -> RegistrySnapshot {
        let mut out = RegistrySnapshot::default();
        for (name, &v) in &self.counters {
            let prev = earlier.counters.get(name).copied().unwrap_or(0);
            out.counters.insert(name.clone(), v.wrapping_sub(prev));
        }
        out.gauges = self.gauges.clone();
        for (name, h) in &self.histograms {
            let d = match earlier.histograms.get(name) {
                Some(prev) => h.delta(prev),
                None => h.clone(),
            };
            out.histograms.insert(name.clone(), d);
        }
        out
    }

    /// The `obs` JSON section: counters and gauges at the top (stable
    /// given a fixed trace), every histogram under `timings` so
    /// `strip_timing` leaves a deterministic record.
    pub fn to_json(&self) -> Json {
        let nums =
            |m: &BTreeMap<String, u64>| Json::Obj(m.iter().map(|(k, &v)| (k.clone(), Json::Num(v as f64))).collect());
        let timings = Json::Obj(
            self.histograms
                .iter()
                .map(|(k, h)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("count", Json::Num(h.count() as f64)),
                            ("mean", Json::Num(h.mean())),
                            ("p50", Json::Num(h.quantile(0.50) as f64)),
                            ("p95", Json::Num(h.quantile(0.95) as f64)),
                            ("p99", Json::Num(h.quantile(0.99) as f64)),
                            ("p999", Json::Num(h.quantile(0.999) as f64)),
                            ("max", Json::Num(h.max as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("counters", nums(&self.counters)),
            ("gauges", nums(&self.gauges)),
            ("timings", timings),
        ])
    }

    /// Prometheus text exposition: counters and gauges verbatim,
    /// histograms as cumulative `_bucket{le="..."}` series (non-empty
    /// buckets only) plus `_sum`/`_count`. This string is what the
    /// planned `serve --listen` `/metrics` endpoint will return.
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_base = String::new();
        let mut typ = |out: &mut String, name: &str, kind: &str, last: &mut String| {
            let base = split_labels(name).0;
            if base != *last {
                out.push_str(&format!("# TYPE {base} {kind}\n"));
                *last = base.to_string();
            }
        };
        for (name, v) in &self.counters {
            typ(&mut out, name, "counter", &mut last_base);
            out.push_str(&format!("{name} {v}\n"));
        }
        for (name, v) in &self.gauges {
            typ(&mut out, name, "gauge", &mut last_base);
            out.push_str(&format!("{name} {v}\n"));
        }
        for (name, h) in &self.histograms {
            let (base, labels) = split_labels(name);
            typ(&mut out, name, "histogram", &mut last_base);
            let lbl = |extra: &str| match labels {
                Some(inner) => format!("{{{inner},{extra}}}"),
                None => format!("{{{extra}}}"),
            };
            let mut cum = 0u64;
            for (i, &c) in h.buckets.iter().enumerate() {
                if c == 0 {
                    continue;
                }
                cum += c;
                let hi = super::hist::bucket_bounds(i).1;
                out.push_str(&format!("{base}_bucket{} {cum}\n", lbl(&format!("le=\"{hi}\""))));
            }
            out.push_str(&format!("{base}_bucket{} {cum}\n", lbl("le=\"+Inf\"")));
            let tail = match labels {
                Some(inner) => format!("{{{inner}}}"),
                None => String::new(),
            };
            out.push_str(&format!("{base}_sum{tail} {}\n", h.sum));
            out.push_str(&format!("{base}_count{tail} {}\n", h.count()));
        }
        out
    }
}

/// Split `base{labels}` into `(base, Some(labels))`; names without an
/// inline label set return `(name, None)`.
fn split_labels(name: &str) -> (&str, Option<&str>) {
    match (name.find('{'), name.ends_with('}')) {
        (Some(i), true) => (&name[..i], Some(&name[i + 1..name.len() - 1])),
        _ => (name, None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn same_name_returns_the_same_handle() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("x_total");
        let b = reg.counter("x_total");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3, "both handles hit one underlying counter");
        assert_eq!(reg.names(), vec!["x_total".to_string()]);
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn kind_clash_panics() {
        let reg = MetricsRegistry::new();
        reg.counter("clash");
        reg.gauge("clash");
    }

    #[test]
    fn snapshot_totals_derive_from_components() {
        let reg = MetricsRegistry::new();
        reg.counter("serve_x_total").add(7);
        reg.gauge("serve_depth").set(3);
        let h = reg.histogram("serve_ns");
        h.record(10);
        h.record(300);
        let s = reg.snapshot();
        assert_eq!(s.counters["serve_x_total"], 7);
        assert_eq!(s.gauges["serve_depth"], 3);
        // Histogram count is the bucket-array sum, not a separate atomic.
        assert_eq!(s.histograms["serve_ns"].count(), 2);
        assert_eq!(
            s.histograms["serve_ns"].buckets.iter().sum::<u64>(),
            s.histograms["serve_ns"].count()
        );
    }

    fn random_snapshot(rng: &mut Rng) -> RegistrySnapshot {
        let mut s = RegistrySnapshot::default();
        let names = ["a_total", "b_total", "c_total"];
        for name in names {
            if rng.flip(0.7) {
                s.counters.insert(name.to_string(), rng.below(1000) as u64);
            }
        }
        for name in ["depth", "bytes"] {
            if rng.flip(0.5) {
                s.gauges.insert(name.to_string(), rng.below(100) as u64);
            }
        }
        for name in ["x_ns", "y_ns"] {
            if rng.flip(0.7) {
                let h = Histo::new();
                for _ in 0..rng.below(50) {
                    h.record(rng.below(1 << 20) as u64);
                }
                s.histograms.insert(name.to_string(), h.snapshot());
            }
        }
        s
    }

    #[test]
    fn registry_snapshot_merge_is_associative() {
        // Counter/histogram merges add; gauge merges are right-biased
        // ("latest wins") — all three are associative, so folding shard
        // snapshots in any grouping yields one fleet view.
        prop::check_named("registry snapshot merge associativity", 903, 64, |rng| {
            let (a, b, c) = (random_snapshot(rng), random_snapshot(rng), random_snapshot(rng));
            let mut left = a.clone();
            left.merge(&b);
            left.merge(&c);
            let mut bc = b.clone();
            bc.merge(&c);
            let mut right = a.clone();
            right.merge(&bc);
            assert_eq!(left, right, "(a+b)+c != a+(b+c)");
        });
    }

    #[test]
    fn snapshot_delta_isolates_window_activity() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("serve_x_total");
        let h = reg.histogram("serve_x_ns");
        c.add(3);
        h.record(10);
        let earlier = reg.snapshot();
        c.add(4);
        h.record(100);
        h.record(200);
        // A histogram registered mid-window deltas against zero.
        reg.histogram("serve_y_ns").record(7);
        let later = reg.snapshot();
        let d = later.delta(&earlier);
        assert_eq!(d.counters["serve_x_total"], 4);
        assert_eq!(d.histograms["serve_x_ns"].count(), 2);
        assert_eq!(d.histograms["serve_x_ns"].sum, 300);
        assert_eq!(d.histograms["serve_y_ns"].count(), 1);
        // delta then merge-back round-trips to the later snapshot.
        let mut rebuilt = d.clone();
        rebuilt.merge(&earlier);
        assert_eq!(rebuilt, later);
    }

    #[test]
    fn prometheus_export_shape() {
        let reg = MetricsRegistry::new();
        reg.counter("serve_requests_total{path=\"cached_dense\"}").add(5);
        reg.gauge("serve_queue_depth").set(2);
        let h = reg.histogram("serve_request_ns{path=\"cached_dense\"}");
        h.record(100);
        h.record(200);
        let text = reg.snapshot().prometheus();
        assert!(text.contains("# TYPE serve_requests_total counter"), "{text}");
        assert!(text.contains("serve_requests_total{path=\"cached_dense\"} 5"), "{text}");
        assert!(text.contains("# TYPE serve_queue_depth gauge"), "{text}");
        assert!(text.contains("# TYPE serve_request_ns histogram"), "{text}");
        assert!(
            text.contains("serve_request_ns_bucket{path=\"cached_dense\",le=\"+Inf\"} 2"),
            "{text}"
        );
        assert!(text.contains("serve_request_ns_sum{path=\"cached_dense\"} 300"), "{text}");
        assert!(text.contains("serve_request_ns_count{path=\"cached_dense\"} 2"), "{text}");
        // Cumulative bucket counts are monotone.
        let mut prev = 0u64;
        for line in text.lines().filter(|l| l.contains("_bucket{")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "bucket series not cumulative: {text}");
            prev = v;
        }
    }

    #[test]
    fn json_export_keeps_timings_separable() {
        let reg = MetricsRegistry::new();
        reg.counter("k_total").add(2);
        let h = reg.histogram("k_ns");
        h.record(50);
        let j = reg.snapshot().to_json();
        let counters = j.get("counters").and_then(|c| c.get("k_total"));
        assert!(counters.is_some(), "counters section");
        let t = j.get("timings").and_then(|t| t.get("k_ns"));
        let p50 = t.and_then(|h| h.get("p50")).and_then(|v| v.as_f64()).unwrap();
        let p99 = t.and_then(|h| h.get("p99")).and_then(|v| v.as_f64()).unwrap();
        assert!(p50 >= 50.0 && p99 >= p50, "quantiles in timings, monotone");
    }
}
