//! Pure-std HTTP scrape exporter (DESIGN.md §10).
//!
//! Serves keep-alive-less HTTP/1.1 GETs over the shared listener in
//! [`crate::util::net`] — no new dependencies, no async runtime.
//! Endpoints:
//!
//! | path            | payload                                           |
//! |-----------------|---------------------------------------------------|
//! | `/metrics`      | Prometheus text ([`RegistrySnapshot::prometheus`])|
//! | `/metrics.json` | the same snapshot as JSON                         |
//! | `/healthz`      | liveness probes, HTTP 200/503                     |
//! | `/tracez`       | newest ring traces, JSON (filterable, see below)  |
//! | `/tenantz`      | per-tenant heavy hitters (JSON or `?format=text`) |
//! | `/slo`          | multi-window SLO burn-rate report                 |
//!
//! `/tracez` accepts query filters — `req=<id>` (request-ID lookup,
//! searched in the main ring *and* the capture ring so an interesting
//! request stays findable after the main ring wraps), `tenant=<id>`,
//! `min_total_ns=<ns>`, and `captured=1` (only retained slow/shed/error
//! traces, each carrying its `reason`). Unknown keys or non-numeric
//! values are a 400, never silently ignored (DESIGN.md §12).
//!
//! The server scrapes through [`ObsSources`] — boxed closures over
//! whatever owns the telemetry (an engine's shared state via
//! [`crate::serve::Engine::obs_sources`], or the process-wide registry
//! via [`ObsSources::global_only`]) — so the exporter thread is
//! `'static` and shuts down independently of the scraped object.
//!
//! Robustness contract: the transport is the shared hardened listener
//! ([`crate::util::net::HttpServer`], DESIGN.md §11) — bounded reads
//! ([`MAX_REQUEST_BYTES`] head cap), a **wall-clock per-request
//! deadline** (a 1-byte-per-second trickler is cut off at the budget,
//! not granted a fresh timeout per read), worker-pool connection
//! handling (a slow client pins one pool worker, not the listener),
//! and panic isolation (a panicking source answers 500 and the
//! exporter lives on). The tests below pin the exporter-level contract;
//! the transport-level cases (trickler 408, split bodies, pool
//! liveness) are tested where they live, in `util::net`.

use std::net::SocketAddr;
use std::sync::Arc;

use anyhow::Result;

use crate::util::json::Json;
use crate::util::net::{Handler, HttpServer, Request, Response, ServerOpts};

use super::capture::Captured;
use super::registry::{MetricsRegistry, RegistrySnapshot};
use super::slo::{SloSet, SloTracker};
use super::tenantstats::{TenantStats, TenantSummary, DEFAULT_TENANT_TOPK};
use super::trace::Trace;

/// Upper bound on the bytes read from one request head (line + headers).
/// A scrape GET is well under 1 KiB; anything larger is a 400.
pub const MAX_REQUEST_BYTES: usize = crate::util::net::DEFAULT_MAX_HEAD_BYTES;

/// One named health probe.
#[derive(Clone, Debug)]
pub struct HealthCheck {
    pub name: String,
    pub ok: bool,
    pub detail: String,
}

/// The `/healthz` payload: overall ok iff every probe passes.
#[derive(Clone, Debug)]
pub struct HealthReport {
    pub checks: Vec<HealthCheck>,
}

impl HealthReport {
    pub fn ok(&self) -> bool {
        self.checks.iter().all(|c| c.ok)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ok", Json::Bool(self.ok())),
            (
                "checks",
                Json::Arr(
                    self.checks
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("name", Json::Str(c.name.clone())),
                                ("ok", Json::Bool(c.ok)),
                                ("detail", Json::Str(c.detail.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// What the exporter scrapes. Closures (not references) so the server
/// thread owns its world: the scraped object can be dropped or finished
/// on its own schedule after [`ObsServer::shutdown`].
pub struct ObsSources {
    pub metrics: Box<dyn Fn() -> RegistrySnapshot + Send + Sync>,
    pub traces: Box<dyn Fn() -> Vec<Trace> + Send + Sync>,
    /// Retained slow/shed/error traces (the capture ring) — backs
    /// `/tracez?captured=1` and `req=` lookups past the main ring.
    pub captured: Box<dyn Fn() -> Vec<Captured> + Send + Sync>,
    /// Per-tenant heavy-hitter summary — the `/tenantz` payload.
    pub tenants: Box<dyn Fn() -> TenantSummary + Send + Sync>,
    pub health: Box<dyn Fn() -> HealthReport + Send + Sync>,
    /// Burn-rate tracker fed lazily by `/slo` requests — scraping IS the
    /// tick, no dedicated timer thread.
    pub slo: SloTracker,
}

impl ObsSources {
    /// Sources for a process with no serving engine (kernel / conv /
    /// store benches): the process-wide registry, no traces, and a
    /// liveness-only health report.
    pub fn global_only() -> ObsSources {
        ObsSources {
            metrics: Box::new(|| super::global().snapshot()),
            traces: Box::new(Vec::new),
            captured: Box::new(Vec::new),
            tenants: Box::new(|| TenantStats::new(DEFAULT_TENANT_TOPK).summary()),
            health: Box::new(|| HealthReport {
                checks: vec![HealthCheck {
                    name: "process".to_string(),
                    ok: true,
                    detail: "alive".to_string(),
                }],
            }),
            slo: SloTracker::new(SloSet::global_default(), Vec::new()),
        }
    }
}

/// Routable paths; anything else is a 404 (and counted under the
/// `other` label so metric names never embed attacker-chosen strings).
const ROUTES: [&str; 7] =
    ["/", "/metrics", "/metrics.json", "/healthz", "/tracez", "/tenantz", "/slo"];

struct ServerState {
    sources: ObsSources,
    /// Server-local `http_requests_total{path=...}` counters, merged
    /// into the `/metrics` output — the exporter observes itself.
    requests: MetricsRegistry,
}

/// The obs endpoint set as a reusable component, for mounting on a
/// listener that also serves other routes — the request front
/// ([`crate::serve::front::ServeFront`]) mounts these next to its
/// `/v1/*` endpoints so one port serves traffic *and* its telemetry.
pub struct ObsRoutes {
    state: Arc<ServerState>,
}

impl ObsRoutes {
    pub fn new(sources: ObsSources) -> ObsRoutes {
        ObsRoutes {
            state: Arc::new(ServerState {
                sources,
                requests: MetricsRegistry::new(),
            }),
        }
    }

    /// Answer `req` if its path is an obs endpoint; `None` hands routing
    /// back to the embedding server.
    pub fn handle(&self, req: &Request) -> Option<Response> {
        if !ROUTES.contains(&req.path.as_str()) {
            return None;
        }
        Some(obs_handler(&self.state, req))
    }
}

/// Handle to the running exporter. Dropping it (or calling
/// [`ObsServer::shutdown`]) stops the listener and joins its threads.
pub struct ObsServer {
    inner: HttpServer,
}

impl ObsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9100`, port 0 for ephemeral) and
    /// start the exporter on the shared hardened listener.
    pub fn bind(addr: &str, sources: ObsSources) -> Result<ObsServer> {
        let state = Arc::new(ServerState {
            sources,
            requests: MetricsRegistry::new(),
        });
        let handler: Handler = Arc::new(move |req: &Request| obs_handler(&state, req));
        // Scrape traffic is a few requests per second: two workers keep
        // one slow scraper from blocking liveness probes, and scrape
        // heads are tiny (no bodies to speak of).
        let opts = ServerOpts {
            workers: 2,
            max_body_bytes: 4096,
            ..ServerOpts::default()
        };
        let inner = HttpServer::bind(addr, "obs exporter", opts, handler)?;
        Ok(ObsServer { inner })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.inner.addr()
    }

    pub fn url(&self) -> String {
        self.inner.url()
    }

    /// Stop accepting, wake the blocked accept loop, and join the
    /// exporter threads.
    pub fn shutdown(self) {
        self.inner.shutdown();
    }
}

/// Per-request exporter logic; transport hardening (bounds, deadline,
/// panic isolation) is `util::net`'s job.
fn obs_handler(state: &ServerState, req: &Request) -> Response {
    if req.method != "GET" {
        return Response::text(405, "GET only\n");
    }
    let label = if ROUTES.contains(&req.path.as_str()) { req.path.as_str() } else { "other" };
    state
        .requests
        .counter(&format!("http_requests_total{{path=\"{label}\"}}"))
        .inc();
    match route(state, req) {
        Some((status, ctype, body)) => Response {
            status,
            content_type: ctype,
            body,
        },
        None => Response::text(404, "not found\n"),
    }
}

/// Parse one numeric query value; the error text names the key so a 400
/// tells the caller exactly which parameter was bad.
fn parse_u64(key: &str, value: &str) -> Result<u64, String> {
    value
        .parse()
        .map_err(|_| format!("parameter '{key}' must be an unsigned integer, got '{value}'\n"))
}

/// `/tracez` with filters. Returns the JSON body, or a 400 message for
/// an unknown key / malformed value.
fn tracez(state: &ServerState, req: &Request) -> Result<String, String> {
    let mut captured_only = false;
    let mut want_req: Option<u64> = None;
    let mut want_tenant: Option<u64> = None;
    let mut min_total_ns: Option<u64> = None;
    for (k, v) in req.query_params()? {
        match k.as_str() {
            "captured" => {
                captured_only = match v.as_str() {
                    "1" => true,
                    "0" => false,
                    _ => return Err(format!("parameter 'captured' must be 0 or 1, got '{v}'\n")),
                }
            }
            "req" => want_req = Some(parse_u64("req", &v)?),
            "tenant" => want_tenant = Some(parse_u64("tenant", &v)?),
            "min_total_ns" => min_total_ns = Some(parse_u64("min_total_ns", &v)?),
            _ => return Err(format!("unknown /tracez parameter '{k}'\n")),
        }
    }
    let keep = |t: &Trace| {
        want_req.is_none_or(|r| t.req_id == r)
            && want_tenant.is_none_or(|x| t.tenant == x)
            && min_total_ns.is_none_or(|m| t.total_ns >= m)
    };
    let mut out = Vec::new();
    let mut seen = std::collections::HashSet::new();
    if !captured_only {
        for t in (state.sources.traces)() {
            if keep(&t) {
                seen.insert(t.seq);
                out.push(t.to_json());
            }
        }
    }
    // The capture ring answers `captured=1` directly and backs every
    // `req=` lookup: an interesting request outlives the main ring here.
    // Capture seqs are main-ring seqs, so resident duplicates dedupe.
    if captured_only || want_req.is_some() {
        for c in (state.sources.captured)() {
            if keep(&c.trace) && seen.insert(c.trace.seq) {
                out.push(c.to_json());
            }
        }
    }
    Ok(Json::Arr(out).pretty())
}

/// `/tenantz`: the heavy-hitter summary, JSON by default or a terminal
/// table with `?format=text`.
fn tenantz(state: &ServerState, req: &Request) -> Result<(&'static str, String), String> {
    let mut text = false;
    for (k, v) in req.query_params()? {
        match (k.as_str(), v.as_str()) {
            ("format", "text") => text = true,
            ("format", "json") => text = false,
            ("format", _) => {
                return Err(format!("parameter 'format' must be json or text, got '{v}'\n"))
            }
            _ => return Err(format!("unknown /tenantz parameter '{k}'\n")),
        }
    }
    let summary = (state.sources.tenants)();
    Ok(if text {
        ("text/plain", summary.text_table())
    } else {
        ("application/json", summary.to_json().pretty())
    })
}

fn route(state: &ServerState, req: &Request) -> Option<(u16, &'static str, String)> {
    match req.path.as_str() {
        "/" => Some((
            200,
            "text/plain",
            "gsoft obs exporter\n\n/metrics\n/metrics.json\n/healthz\n/tracez\n/tenantz\n/slo\n"
                .to_string(),
        )),
        "/metrics" => {
            let mut snap = (state.sources.metrics)();
            snap.merge(&state.requests.snapshot());
            Some((200, "text/plain; version=0.0.4", snap.prometheus()))
        }
        "/metrics.json" => {
            let mut snap = (state.sources.metrics)();
            snap.merge(&state.requests.snapshot());
            Some((200, "application/json", snap.to_json().pretty()))
        }
        "/healthz" => {
            let h = (state.sources.health)();
            let status = if h.ok() { 200 } else { 503 };
            Some((status, "application/json", h.to_json().pretty()))
        }
        "/tracez" => Some(match tracez(state, req) {
            Ok(body) => (200, "application/json", body),
            Err(msg) => (400, "text/plain", msg),
        }),
        "/tenantz" => Some(match tenantz(state, req) {
            Ok((ctype, body)) => (200, ctype, body),
            Err(msg) => (400, "text/plain", msg),
        }),
        "/slo" => {
            let report = state.sources.slo.observe_and_report((state.sources.metrics)());
            Some((200, "application/json", report.to_json().pretty()))
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hist::HistoSnapshot;
    use std::io::{Read, Write};
    use std::net::TcpStream;
    use std::sync::atomic::{AtomicBool, Ordering};

    /// Minimal HTTP client: one GET, read to EOF (the server always
    /// closes), split status and body.
    fn get(addr: SocketAddr, target: &str) -> (u16, String) {
        raw(addr, &format!("GET {target} HTTP/1.1\r\nHost: t\r\n\r\n"))
    }

    fn raw(addr: SocketAddr, request: &str) -> (u16, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(request.as_bytes()).unwrap();
        let mut text = String::new();
        s.read_to_string(&mut text).unwrap();
        let status: u16 = text
            .split_whitespace()
            .nth(1)
            .and_then(|t| t.parse().ok())
            .unwrap_or_else(|| panic!("no status line in {text:?}"));
        let body = text.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
        (status, body)
    }

    fn test_trace(seq: u64) -> Trace {
        Trace {
            seq,
            req_id: 100 + seq,
            tenant: 1,
            path: "cached_dense",
            start_ns: seq * 1000,
            worker: 0,
            total_ns: 500,
            stage_ns: [100, 0, 0, 0, 300, 50],
        }
    }

    /// One retained slow trace, far outside the main ring's seq range.
    fn test_captured() -> Captured {
        let mut t = test_trace(99);
        t.req_id = 777;
        t.tenant = 2;
        t.total_ns = 9_000;
        Captured {
            cap_seq: 0,
            reason: crate::obs::CaptureReason::Slow,
            trace: t,
        }
    }

    fn test_sources(reg: &Arc<MetricsRegistry>, healthy: bool) -> ObsSources {
        let m = Arc::clone(reg);
        ObsSources {
            metrics: Box::new(move || m.snapshot()),
            traces: Box::new(|| vec![test_trace(5), test_trace(4)]),
            captured: Box::new(|| vec![test_captured()]),
            tenants: Box::new(|| {
                let stats = TenantStats::new(4);
                stats.record_request(7, 1_000);
                stats.record_request(7, 2_000);
                stats.record_request(9, 500);
                stats.summary()
            }),
            health: Box::new(move || HealthReport {
                checks: vec![HealthCheck {
                    name: "probe".to_string(),
                    ok: healthy,
                    detail: "test".to_string(),
                }],
            }),
            slo: SloTracker::new(SloSet::serve_default(), Vec::new()),
        }
    }

    #[test]
    fn endpoints_serve_metrics_health_traces_and_slo() {
        let reg = Arc::new(MetricsRegistry::new());
        reg.counter("serve_requests_total{path=\"cached_dense\"}").add(7);
        reg.histogram("serve_request_ns{path=\"cached_dense\"}").record(1_000_000);
        let server = ObsServer::bind("127.0.0.1:0", test_sources(&reg, true)).unwrap();
        let addr = server.addr();

        let (status, body) = get(addr, "/metrics");
        assert_eq!(status, 200);
        assert!(body.contains("serve_requests_total{path=\"cached_dense\"} 7"), "{body}");
        assert!(
            body.contains("http_requests_total{path=\"/metrics\"}"),
            "exporter observes itself: {body}"
        );

        let (status, body) = get(addr, "/metrics.json");
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        assert_eq!(
            j.get("counters")
                .and_then(|c| c.get("serve_requests_total{path=\"cached_dense\"}"))
                .and_then(|v| v.as_f64()),
            Some(7.0)
        );

        let (status, body) = get(addr, "/healthz");
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true));

        let (status, body) = get(addr, "/tracez");
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        let traces = j.as_arr().unwrap();
        assert_eq!(traces.len(), 2);
        assert_eq!(traces[0].get("seq").and_then(|v| v.as_f64()), Some(5.0), "newest first");

        let (status, body) = get(addr, "/slo");
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        assert!(j.get("ok").is_some());
        assert_eq!(j.get("objectives").and_then(|o| o.as_arr()).unwrap().len(), 3);

        let (status, _) = get(addr, "/metrics?debug=1");
        assert_eq!(status, 200, "non-filtering routes ignore query strings");
        let (status, _) = get(addr, "/nope");
        assert_eq!(status, 404);
        let (status, _) = raw(addr, "POST /metrics HTTP/1.1\r\nHost: t\r\n\r\n");
        assert_eq!(status, 405);
        server.shutdown();
    }

    #[test]
    fn tracez_filters_by_req_tenant_total_and_captured() {
        let reg = Arc::new(MetricsRegistry::new());
        let server = ObsServer::bind("127.0.0.1:0", test_sources(&reg, true)).unwrap();
        let addr = server.addr();
        let entries = |target: &str| -> Vec<Json> {
            let (status, body) = get(addr, target);
            assert_eq!(status, 200, "{target}: {body}");
            Json::parse(&body).unwrap().as_arr().unwrap().to_vec()
        };

        // Request-ID lookup in the main ring.
        let hit = entries("/tracez?req=105");
        assert_eq!(hit.len(), 1);
        assert_eq!(hit[0].get("seq").unwrap().as_u64(), Some(5));

        // Request-ID lookup that only the capture ring can answer.
        let hit = entries("/tracez?req=777");
        assert_eq!(hit.len(), 1, "req= must search the capture ring too");
        assert_eq!(hit[0].get("reason").unwrap().as_str(), Some("slow"));

        // captured=1: only retained traces, each with a reason.
        let cap = entries("/tracez?captured=1");
        assert_eq!(cap.len(), 1);
        assert_eq!(cap[0].get("req_id").unwrap().as_u64(), Some(777));

        // Tenant and latency filters over the main ring.
        assert_eq!(entries("/tracez?tenant=1").len(), 2);
        assert_eq!(entries("/tracez?tenant=6").len(), 0);
        assert_eq!(entries("/tracez?min_total_ns=400").len(), 2);
        assert_eq!(entries("/tracez?min_total_ns=501").len(), 0);
        assert_eq!(entries("/tracez?captured=1&tenant=2&min_total_ns=600").len(), 1);

        // Unknown keys and malformed values are 400s, never ignored.
        for bad in [
            "/tracez?bogus=1",
            "/tracez?req=abc",
            "/tracez?tenant=-3",
            "/tracez?min_total_ns=",
            "/tracez?captured=maybe",
            "/tracez?req",
        ] {
            let (status, _) = get(addr, bad);
            assert_eq!(status, 400, "{bad} must be rejected");
        }
        server.shutdown();
    }

    #[test]
    fn tenantz_serves_json_and_text_with_strict_params() {
        let reg = Arc::new(MetricsRegistry::new());
        let server = ObsServer::bind("127.0.0.1:0", test_sources(&reg, true)).unwrap();
        let addr = server.addr();

        let (status, body) = get(addr, "/tenantz");
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("k").unwrap().as_u64(), Some(4));
        let reqs = j.get("dims").unwrap().get("requests").unwrap();
        assert_eq!(reqs.get("total").unwrap().as_u64(), Some(3));
        let top = &reqs.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(top.get("tenant").unwrap().as_u64(), Some(7), "hottest tenant first");

        let (status, body) = get(addr, "/tenantz?format=text");
        assert_eq!(status, 200);
        assert!(body.contains("heavy hitters") && body.contains("latency_ns_sum"), "{body}");
        let (status, _) = get(addr, "/tenantz?format=json");
        assert_eq!(status, 200);
        for bad in ["/tenantz?format=yaml", "/tenantz?k=5"] {
            let (status, _) = get(addr, bad);
            assert_eq!(status, 400, "{bad} must be rejected");
        }
        server.shutdown();
    }

    #[test]
    fn unhealthy_sources_answer_503() {
        let reg = Arc::new(MetricsRegistry::new());
        let server = ObsServer::bind("127.0.0.1:0", test_sources(&reg, false)).unwrap();
        let (status, body) = get(server.addr(), "/healthz");
        assert_eq!(status, 503);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(false));
        server.shutdown();
    }

    #[test]
    fn malformed_and_oversized_requests_get_400_and_the_server_survives() {
        let reg = Arc::new(MetricsRegistry::new());
        let server = ObsServer::bind("127.0.0.1:0", test_sources(&reg, true)).unwrap();
        let addr = server.addr();

        let (status, _) = raw(addr, "GARBAGE\r\n\r\n");
        assert_eq!(status, 400);
        let (status, _) = raw(addr, "GET nopath HTTP/1.1\r\n\r\n");
        assert_eq!(status, 400, "target must start with /");
        let (status, _) = raw(addr, "GET /metrics NOTHTTP\r\n\r\n");
        assert_eq!(status, 400, "version must start with HTTP/");
        let oversized = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(2 * MAX_REQUEST_BYTES));
        let (status, _) = raw(addr, &oversized);
        assert_eq!(status, 400, "request over the byte bound");
        // A silent connect-and-close (what shutdown's wake does) must
        // not produce a response or kill the loop.
        drop(TcpStream::connect(addr).unwrap());

        let (status, _) = get(addr, "/healthz");
        assert_eq!(status, 200, "exporter survived every malformed request");
        server.shutdown();
    }

    #[test]
    fn handler_panic_answers_500_and_the_exporter_lives_on() {
        let reg = Arc::new(MetricsRegistry::new());
        let mut sources = test_sources(&reg, true);
        let flip = Arc::new(AtomicBool::new(true));
        let f = Arc::clone(&flip);
        sources.traces = Box::new(move || {
            if f.load(Ordering::SeqCst) {
                panic!("poisoned trace source");
            }
            Vec::new()
        });
        let server = ObsServer::bind("127.0.0.1:0", sources).unwrap();
        let (status, _) = get(server.addr(), "/tracez");
        assert_eq!(status, 500);
        flip.store(false, Ordering::SeqCst);
        let (status, _) = get(server.addr(), "/tracez");
        assert_eq!(status, 200, "same endpoint recovers once the source stops panicking");
        server.shutdown();
    }

    #[test]
    fn concurrent_scrapes_see_monotone_consistent_snapshots() {
        let reg = Arc::new(MetricsRegistry::new());
        let server = ObsServer::bind("127.0.0.1:0", test_sources(&reg, true)).unwrap();
        let addr = server.addr();
        let writing = Arc::new(AtomicBool::new(true));

        let writer = {
            let reg = Arc::clone(&reg);
            let writing = Arc::clone(&writing);
            std::thread::spawn(move || {
                let c = reg.counter("serve_requests_total{path=\"cached_dense\"}");
                let h = reg.histogram("serve_request_ns{path=\"cached_dense\"}");
                while writing.load(Ordering::SeqCst) {
                    c.inc();
                    h.record(1_000);
                }
            })
        };

        let scrapers: Vec<_> = (0..3)
            .map(|_| {
                std::thread::spawn(move || {
                    let mut last = 0.0;
                    for _ in 0..15 {
                        let (status, body) = get(addr, "/metrics.json");
                        assert_eq!(status, 200);
                        let j = Json::parse(&body).unwrap();
                        let count = j
                            .get("counters")
                            .and_then(|c| c.get("serve_requests_total{path=\"cached_dense\"}"))
                            .and_then(|v| v.as_f64())
                            .unwrap_or(0.0);
                        assert!(count >= last, "counter went backwards: {count} < {last}");
                        last = count;
                        // Read-skew-free invariant: the histogram's count
                        // is derived from its buckets, so mid-record
                        // scrapes still satisfy count == Σ buckets (the
                        // JSON count equals the quantile source's mass).
                        if let Some(t) = j
                            .get("timings")
                            .and_then(|t| t.get("serve_request_ns{path=\"cached_dense\"}"))
                        {
                            assert!(t.get("count").and_then(|v| v.as_f64()).unwrap() >= 0.0);
                        }
                    }
                })
            })
            .collect();
        for s in scrapers {
            s.join().unwrap();
        }
        writing.store(false, Ordering::SeqCst);
        writer.join().unwrap();

        // Direct snapshot-level monotonicity of the same invariant the
        // scrapers observed over HTTP.
        let a = reg.snapshot();
        let b = reg.snapshot();
        let name = "serve_request_ns{path=\"cached_dense\"}";
        let (ha, hb): (&HistoSnapshot, &HistoSnapshot) =
            (&a.histograms[name], &b.histograms[name]);
        assert!(hb.count() >= ha.count());
        server.shutdown();
    }

    #[test]
    fn shutdown_joins_and_releases_the_port() {
        let reg = Arc::new(MetricsRegistry::new());
        let server = ObsServer::bind("127.0.0.1:0", test_sources(&reg, true)).unwrap();
        let addr = server.addr();
        let (status, _) = get(addr, "/");
        assert_eq!(status, 200);
        server.shutdown();
        // The listener is gone: a fresh connect is refused (or, at
        // worst, connects to nothing and reads EOF).
        match TcpStream::connect(addr) {
            Err(_) => {}
            Ok(mut s) => {
                let mut buf = String::new();
                let _ = s.read_to_string(&mut buf);
                assert!(buf.is_empty(), "no server should answer after shutdown");
            }
        }
    }

    #[test]
    fn global_only_sources_serve_the_process_registry() {
        let sources = ObsSources::global_only();
        let server = ObsServer::bind("127.0.0.1:0", sources).unwrap();
        let (status, body) = get(server.addr(), "/metrics");
        assert_eq!(status, 200);
        assert!(body.contains("http_requests_total"), "{body}");
        let (status, body) = get(server.addr(), "/slo");
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true), "idle process passes");
        let (status, _) = get(server.addr(), "/tracez");
        assert_eq!(status, 200);
        server.shutdown();
    }
}
