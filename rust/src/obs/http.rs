//! Pure-std HTTP scrape exporter (DESIGN.md §10).
//!
//! One background thread on a `TcpListener` serves keep-alive-less
//! HTTP/1.1 GETs — no new dependencies, no async runtime. Endpoints:
//!
//! | path            | payload                                           |
//! |-----------------|---------------------------------------------------|
//! | `/metrics`      | Prometheus text ([`RegistrySnapshot::prometheus`])|
//! | `/metrics.json` | the same snapshot as JSON                         |
//! | `/healthz`      | liveness probes, HTTP 200/503                     |
//! | `/tracez`       | newest ring traces, JSON                          |
//! | `/slo`          | multi-window SLO burn-rate report                 |
//!
//! The server scrapes through [`ObsSources`] — boxed closures over
//! whatever owns the telemetry (an engine's shared state via
//! [`crate::serve::Engine::obs_sources`], or the process-wide registry
//! via [`ObsSources::global_only`]) — so the exporter thread is
//! `'static` and shuts down independently of the scraped object.
//!
//! Robustness contract (tested below): requests are read with a bound
//! ([`MAX_REQUEST_BYTES`]) and a timeout; malformed or oversized
//! requests get a 400 and never panic or kill the exporter thread
//! (handler panics are caught and answered with a 500); connections
//! that close without sending anything are dropped silently — that is
//! also how [`ObsServer::shutdown`] wakes the accept loop. Handling is
//! intentionally serial: scrape traffic is a few requests per second,
//! and a serial loop cannot be wedged open by a slow client holding a
//! worker.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::registry::{MetricsRegistry, RegistrySnapshot};
use super::slo::{SloSet, SloTracker};
use super::trace::Trace;

/// Upper bound on the bytes read from one request (line + headers). A
/// scrape GET is well under 1 KiB; anything larger is a 400.
pub const MAX_REQUEST_BYTES: usize = 8192;

/// Per-connection socket timeouts — a stalled client cannot hold the
/// serial accept loop for longer than this.
const IO_TIMEOUT: Duration = Duration::from_secs(2);

/// One named health probe.
#[derive(Clone, Debug)]
pub struct HealthCheck {
    pub name: String,
    pub ok: bool,
    pub detail: String,
}

/// The `/healthz` payload: overall ok iff every probe passes.
#[derive(Clone, Debug)]
pub struct HealthReport {
    pub checks: Vec<HealthCheck>,
}

impl HealthReport {
    pub fn ok(&self) -> bool {
        self.checks.iter().all(|c| c.ok)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ok", Json::Bool(self.ok())),
            (
                "checks",
                Json::Arr(
                    self.checks
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("name", Json::Str(c.name.clone())),
                                ("ok", Json::Bool(c.ok)),
                                ("detail", Json::Str(c.detail.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// What the exporter scrapes. Closures (not references) so the server
/// thread owns its world: the scraped object can be dropped or finished
/// on its own schedule after [`ObsServer::shutdown`].
pub struct ObsSources {
    pub metrics: Box<dyn Fn() -> RegistrySnapshot + Send + Sync>,
    pub traces: Box<dyn Fn() -> Vec<Trace> + Send + Sync>,
    pub health: Box<dyn Fn() -> HealthReport + Send + Sync>,
    /// Burn-rate tracker fed lazily by `/slo` requests — scraping IS the
    /// tick, no dedicated timer thread.
    pub slo: SloTracker,
}

impl ObsSources {
    /// Sources for a process with no serving engine (kernel / conv /
    /// store benches): the process-wide registry, no traces, and a
    /// liveness-only health report.
    pub fn global_only() -> ObsSources {
        ObsSources {
            metrics: Box::new(|| super::global().snapshot()),
            traces: Box::new(Vec::new),
            health: Box::new(|| HealthReport {
                checks: vec![HealthCheck {
                    name: "process".to_string(),
                    ok: true,
                    detail: "alive".to_string(),
                }],
            }),
            slo: SloTracker::new(SloSet::global_default(), Vec::new()),
        }
    }
}

/// Routable paths; anything else is a 404 (and counted under the
/// `other` label so metric names never embed attacker-chosen strings).
const ROUTES: [&str; 6] = ["/", "/metrics", "/metrics.json", "/healthz", "/tracez", "/slo"];

struct ServerState {
    sources: ObsSources,
    /// Server-local `http_requests_total{path=...}` counters, merged
    /// into the `/metrics` output — the exporter observes itself.
    requests: MetricsRegistry,
}

/// Handle to the running exporter thread. Dropping it (or calling
/// [`ObsServer::shutdown`]) stops the listener and joins the thread.
pub struct ObsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl ObsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9100`, port 0 for ephemeral) and
    /// start the exporter thread.
    pub fn bind(addr: &str, sources: ObsSources) -> Result<ObsServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding obs exporter on {addr}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let state = Arc::new(ServerState {
            sources,
            requests: MetricsRegistry::new(),
        });
        let thread = {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                for conn in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    handle_conn(stream, &state);
                }
            })
        };
        Ok(ObsServer {
            addr,
            stop,
            thread: Some(thread),
        })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn url(&self) -> String {
        format!("http://{}", self.addr)
    }

    /// Stop accepting, wake the blocked accept loop with a self-connect,
    /// and join the exporter thread.
    pub fn shutdown(mut self) {
        self.stop_now();
    }

    fn stop_now(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // The accept loop blocks in `incoming()`; an empty connection is
        // read as zero bytes and dropped silently.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ObsServer {
    fn drop(&mut self) {
        self.stop_now();
    }
}

fn handle_conn(mut stream: TcpStream, state: &ServerState) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let line = match read_request_line(&mut stream) {
        Ok(Some(line)) => line,
        // Nothing sent (shutdown wake, port probe): close silently.
        Ok(None) => return,
        Err(status) => {
            write_response(&mut stream, status, "text/plain", "bad request\n");
            return;
        }
    };
    let path = match parse_request_line(&line) {
        Ok(p) => p,
        Err(status) => {
            let body = if status == 405 { "GET only\n" } else { "bad request\n" };
            write_response(&mut stream, status, "text/plain", body);
            return;
        }
    };
    let label = if ROUTES.contains(&path.as_str()) { path.as_str() } else { "other" };
    state
        .requests
        .counter(&format!("http_requests_total{{path=\"{label}\"}}"))
        .inc();
    // A panicking source must answer 500 and leave the exporter alive.
    let outcome =
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| route(state, &path)));
    match outcome {
        Ok(Some((status, ctype, body))) => write_response(&mut stream, status, ctype, &body),
        Ok(None) => write_response(&mut stream, 404, "text/plain", "not found\n"),
        Err(_) => write_response(&mut stream, 500, "text/plain", "internal error\n"),
    }
}

/// Read until the header terminator, EOF, or the size bound; return the
/// request line. `Ok(None)` = the peer sent nothing at all.
fn read_request_line(stream: &mut TcpStream) -> Result<Option<String>, u16> {
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 1024];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if buf.len() > MAX_REQUEST_BYTES {
                    return Err(400);
                }
                if buf.windows(4).any(|w| w == b"\r\n\r\n") {
                    break;
                }
            }
            // Timed out / reset mid-request: answer 400 if anything
            // arrived, otherwise just drop the connection.
            Err(_) => {
                if buf.is_empty() {
                    return Ok(None);
                }
                return Err(400);
            }
        }
    }
    if buf.is_empty() {
        return Ok(None);
    }
    let text = String::from_utf8_lossy(&buf);
    Ok(Some(text.lines().next().unwrap_or("").to_string()))
}

/// `GET /path?query HTTP/1.1` → `/path`. 400 on shape violations, 405
/// on non-GET methods.
fn parse_request_line(line: &str) -> Result<String, u16> {
    let mut parts = line.split_whitespace();
    let (Some(method), Some(target), Some(version), None) =
        (parts.next(), parts.next(), parts.next(), parts.next())
    else {
        return Err(400);
    };
    if !version.starts_with("HTTP/") || !target.starts_with('/') {
        return Err(400);
    }
    if method != "GET" {
        return Err(405);
    }
    let path = target.split('?').next().unwrap_or(target);
    Ok(path.to_string())
}

fn route(state: &ServerState, path: &str) -> Option<(u16, &'static str, String)> {
    match path {
        "/" => Some((
            200,
            "text/plain",
            "gsoft obs exporter\n\n/metrics\n/metrics.json\n/healthz\n/tracez\n/slo\n"
                .to_string(),
        )),
        "/metrics" => {
            let mut snap = (state.sources.metrics)();
            snap.merge(&state.requests.snapshot());
            Some((200, "text/plain; version=0.0.4", snap.prometheus()))
        }
        "/metrics.json" => {
            let mut snap = (state.sources.metrics)();
            snap.merge(&state.requests.snapshot());
            Some((200, "application/json", snap.to_json().pretty()))
        }
        "/healthz" => {
            let h = (state.sources.health)();
            let status = if h.ok() { 200 } else { 503 };
            Some((status, "application/json", h.to_json().pretty()))
        }
        "/tracez" => {
            let traces = (state.sources.traces)();
            let body = Json::Arr(traces.iter().map(Trace::to_json).collect()).pretty();
            Some((200, "application/json", body))
        }
        "/slo" => {
            let report = state.sources.slo.observe_and_report((state.sources.metrics)());
            Some((200, "application/json", report.to_json().pretty()))
        }
        _ => None,
    }
}

fn write_response(stream: &mut TcpStream, status: u16, ctype: &str, body: &str) {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let _ = stream
        .write_all(head.as_bytes())
        .and_then(|_| stream.write_all(body.as_bytes()))
        .and_then(|_| stream.flush());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hist::HistoSnapshot;

    /// Minimal HTTP client: one GET, read to EOF (the server always
    /// closes), split status and body.
    fn get(addr: SocketAddr, target: &str) -> (u16, String) {
        raw(addr, &format!("GET {target} HTTP/1.1\r\nHost: t\r\n\r\n"))
    }

    fn raw(addr: SocketAddr, request: &str) -> (u16, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(request.as_bytes()).unwrap();
        let mut text = String::new();
        s.read_to_string(&mut text).unwrap();
        let status: u16 = text
            .split_whitespace()
            .nth(1)
            .and_then(|t| t.parse().ok())
            .unwrap_or_else(|| panic!("no status line in {text:?}"));
        let body = text.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
        (status, body)
    }

    fn test_trace(seq: u64) -> Trace {
        Trace {
            seq,
            tenant: 1,
            path: "cached_dense",
            start_ns: seq * 1000,
            worker: 0,
            total_ns: 500,
            stage_ns: [100, 0, 0, 0, 300, 50],
        }
    }

    fn test_sources(reg: &Arc<MetricsRegistry>, healthy: bool) -> ObsSources {
        let m = Arc::clone(reg);
        ObsSources {
            metrics: Box::new(move || m.snapshot()),
            traces: Box::new(|| vec![test_trace(5), test_trace(4)]),
            health: Box::new(move || HealthReport {
                checks: vec![HealthCheck {
                    name: "probe".to_string(),
                    ok: healthy,
                    detail: "test".to_string(),
                }],
            }),
            slo: SloTracker::new(SloSet::serve_default(), Vec::new()),
        }
    }

    #[test]
    fn endpoints_serve_metrics_health_traces_and_slo() {
        let reg = Arc::new(MetricsRegistry::new());
        reg.counter("serve_requests_total{path=\"cached_dense\"}").add(7);
        reg.histogram("serve_request_ns{path=\"cached_dense\"}").record(1_000_000);
        let server = ObsServer::bind("127.0.0.1:0", test_sources(&reg, true)).unwrap();
        let addr = server.addr();

        let (status, body) = get(addr, "/metrics");
        assert_eq!(status, 200);
        assert!(body.contains("serve_requests_total{path=\"cached_dense\"} 7"), "{body}");
        assert!(
            body.contains("http_requests_total{path=\"/metrics\"}"),
            "exporter observes itself: {body}"
        );

        let (status, body) = get(addr, "/metrics.json");
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        assert_eq!(
            j.get("counters")
                .and_then(|c| c.get("serve_requests_total{path=\"cached_dense\"}"))
                .and_then(|v| v.as_f64()),
            Some(7.0)
        );

        let (status, body) = get(addr, "/healthz");
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true));

        let (status, body) = get(addr, "/tracez");
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        let traces = j.as_arr().unwrap();
        assert_eq!(traces.len(), 2);
        assert_eq!(traces[0].get("seq").and_then(|v| v.as_f64()), Some(5.0), "newest first");

        let (status, body) = get(addr, "/slo");
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        assert!(j.get("ok").is_some());
        assert_eq!(j.get("objectives").and_then(|o| o.as_arr()).unwrap().len(), 3);

        let (status, _) = get(addr, "/metrics?debug=1");
        assert_eq!(status, 200, "query strings are stripped");
        let (status, _) = get(addr, "/nope");
        assert_eq!(status, 404);
        let (status, _) = raw(addr, "POST /metrics HTTP/1.1\r\nHost: t\r\n\r\n");
        assert_eq!(status, 405);
        server.shutdown();
    }

    #[test]
    fn unhealthy_sources_answer_503() {
        let reg = Arc::new(MetricsRegistry::new());
        let server = ObsServer::bind("127.0.0.1:0", test_sources(&reg, false)).unwrap();
        let (status, body) = get(server.addr(), "/healthz");
        assert_eq!(status, 503);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(false));
        server.shutdown();
    }

    #[test]
    fn malformed_and_oversized_requests_get_400_and_the_server_survives() {
        let reg = Arc::new(MetricsRegistry::new());
        let server = ObsServer::bind("127.0.0.1:0", test_sources(&reg, true)).unwrap();
        let addr = server.addr();

        let (status, _) = raw(addr, "GARBAGE\r\n\r\n");
        assert_eq!(status, 400);
        let (status, _) = raw(addr, "GET nopath HTTP/1.1\r\n\r\n");
        assert_eq!(status, 400, "target must start with /");
        let (status, _) = raw(addr, "GET /metrics NOTHTTP\r\n\r\n");
        assert_eq!(status, 400, "version must start with HTTP/");
        let oversized = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(2 * MAX_REQUEST_BYTES));
        let (status, _) = raw(addr, &oversized);
        assert_eq!(status, 400, "request over the byte bound");
        // A silent connect-and-close (what shutdown's wake does) must
        // not produce a response or kill the loop.
        drop(TcpStream::connect(addr).unwrap());

        let (status, _) = get(addr, "/healthz");
        assert_eq!(status, 200, "exporter survived every malformed request");
        server.shutdown();
    }

    #[test]
    fn handler_panic_answers_500_and_the_exporter_lives_on() {
        let reg = Arc::new(MetricsRegistry::new());
        let mut sources = test_sources(&reg, true);
        let flip = Arc::new(AtomicBool::new(true));
        let f = Arc::clone(&flip);
        sources.traces = Box::new(move || {
            if f.load(Ordering::SeqCst) {
                panic!("poisoned trace source");
            }
            Vec::new()
        });
        let server = ObsServer::bind("127.0.0.1:0", sources).unwrap();
        let (status, _) = get(server.addr(), "/tracez");
        assert_eq!(status, 500);
        flip.store(false, Ordering::SeqCst);
        let (status, _) = get(server.addr(), "/tracez");
        assert_eq!(status, 200, "same endpoint recovers once the source stops panicking");
        server.shutdown();
    }

    #[test]
    fn concurrent_scrapes_see_monotone_consistent_snapshots() {
        let reg = Arc::new(MetricsRegistry::new());
        let server = ObsServer::bind("127.0.0.1:0", test_sources(&reg, true)).unwrap();
        let addr = server.addr();
        let writing = Arc::new(AtomicBool::new(true));

        let writer = {
            let reg = Arc::clone(&reg);
            let writing = Arc::clone(&writing);
            std::thread::spawn(move || {
                let c = reg.counter("serve_requests_total{path=\"cached_dense\"}");
                let h = reg.histogram("serve_request_ns{path=\"cached_dense\"}");
                while writing.load(Ordering::SeqCst) {
                    c.inc();
                    h.record(1_000);
                }
            })
        };

        let scrapers: Vec<_> = (0..3)
            .map(|_| {
                std::thread::spawn(move || {
                    let mut last = 0.0;
                    for _ in 0..15 {
                        let (status, body) = get(addr, "/metrics.json");
                        assert_eq!(status, 200);
                        let j = Json::parse(&body).unwrap();
                        let count = j
                            .get("counters")
                            .and_then(|c| c.get("serve_requests_total{path=\"cached_dense\"}"))
                            .and_then(|v| v.as_f64())
                            .unwrap_or(0.0);
                        assert!(count >= last, "counter went backwards: {count} < {last}");
                        last = count;
                        // Read-skew-free invariant: the histogram's count
                        // is derived from its buckets, so mid-record
                        // scrapes still satisfy count == Σ buckets (the
                        // JSON count equals the quantile source's mass).
                        if let Some(t) = j
                            .get("timings")
                            .and_then(|t| t.get("serve_request_ns{path=\"cached_dense\"}"))
                        {
                            assert!(t.get("count").and_then(|v| v.as_f64()).unwrap() >= 0.0);
                        }
                    }
                })
            })
            .collect();
        for s in scrapers {
            s.join().unwrap();
        }
        writing.store(false, Ordering::SeqCst);
        writer.join().unwrap();

        // Direct snapshot-level monotonicity of the same invariant the
        // scrapers observed over HTTP.
        let a = reg.snapshot();
        let b = reg.snapshot();
        let name = "serve_request_ns{path=\"cached_dense\"}";
        let (ha, hb): (&HistoSnapshot, &HistoSnapshot) =
            (&a.histograms[name], &b.histograms[name]);
        assert!(hb.count() >= ha.count());
        server.shutdown();
    }

    #[test]
    fn shutdown_joins_and_releases_the_port() {
        let reg = Arc::new(MetricsRegistry::new());
        let server = ObsServer::bind("127.0.0.1:0", test_sources(&reg, true)).unwrap();
        let addr = server.addr();
        let (status, _) = get(addr, "/");
        assert_eq!(status, 200);
        server.shutdown();
        // The listener is gone: a fresh connect is refused (or, at
        // worst, connects to nothing and reads EOF).
        match TcpStream::connect(addr) {
            Err(_) => {}
            Ok(mut s) => {
                let mut buf = String::new();
                let _ = s.read_to_string(&mut buf);
                assert!(buf.is_empty(), "no server should answer after shutdown");
            }
        }
    }

    #[test]
    fn global_only_sources_serve_the_process_registry() {
        let sources = ObsSources::global_only();
        let server = ObsServer::bind("127.0.0.1:0", sources).unwrap();
        let (status, body) = get(server.addr(), "/metrics");
        assert_eq!(status, 200);
        assert!(body.contains("http_requests_total"), "{body}");
        let (status, body) = get(server.addr(), "/slo");
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true), "idle process passes");
        let (status, _) = get(server.addr(), "/tracez");
        assert_eq!(status, 200);
        server.shutdown();
    }
}
