//! Fleet telemetry: metrics registry, latency histograms, request traces.
//!
//! Zero-dependency observability substrate shared by the serving engine,
//! the kernel dispatcher and the tiered store:
//!
//! - [`hist`] — log-bucketed latency histograms (lock-free `AtomicU64`
//!   buckets, ≤12.5 % relative quantile error, mergeable snapshots);
//! - [`registry`] — named counters/gauges/histograms with Prometheus-text
//!   and JSON exporters ([`RegistrySnapshot::to_json`] is the `obs`
//!   section of every `BENCH_*.json`);
//! - [`trace`] — per-request stage spans in a newest-N ring buffer;
//! - [`http`] — the pure-std live scrape exporter (`/metrics`,
//!   `/healthz`, `/tracez`, `/slo`; DESIGN.md §10);
//! - [`slo`] — declarative SLO objectives with multi-window burn rates
//!   over snapshot deltas;
//! - [`chrome`] — Chrome trace-event export of ring traces
//!   (`gsoft trace`, loadable in `chrome://tracing`/Perfetto);
//! - [`tenantstats`] — per-tenant heavy hitters in K-slot SpaceSaving
//!   sketches (`/tenantz`, `serve_tenant_topk_*`; cardinality is capped
//!   at K per dimension regardless of fleet size, DESIGN.md §12);
//! - [`capture`] — a small second ring retaining slow/shed/errored
//!   request traces long after the main ring wraps (`/tracez?captured=1`).
//!
//! Two scopes exist. The serving engine owns a *per-engine*
//! [`MetricsRegistry`] (isolated per instance, snapshotted into
//! [`crate::serve::EngineReport`]). Kernel and store instrumentation has
//! no engine handle to thread through (`KernelCtx` is `Copy`), so it
//! writes to the process-wide [`global`] registry — and is gated on
//! [`enabled`], a single relaxed atomic load, so the disabled hot path
//! performs no timing, no allocation and no registry access. Enable via
//! `gsoft <bench> --obs` or [`set_enabled`].

pub mod capture;
pub mod chrome;
pub mod hist;
pub mod http;
pub mod registry;
pub mod slo;
pub mod tenantstats;
pub mod trace;

pub use capture::{CaptureReason, CaptureRing, Captured, CAPTURE_RING_CAP};
pub use chrome::chrome_trace;
pub use hist::{Histo, HistoSnapshot};
pub use http::{HealthCheck, HealthReport, ObsRoutes, ObsServer, ObsSources};
pub use registry::{Counter, Gauge, MetricsRegistry, RegistrySnapshot};
pub use slo::{SloReport, SloSet, SloTracker};
pub use tenantstats::{SpaceSaving, TenantStats, TenantSummary, DEFAULT_TENANT_TOPK};
pub use trace::{Stage, Trace, TraceRing};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is process-wide (kernel/store) instrumentation on? One relaxed load —
/// this is the entire cost of the disabled path.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// The process-wide registry (kernel + store metrics). Engine metrics
/// live in per-engine registries instead; exporters merge the two views.
pub fn global() -> &'static MetricsRegistry {
    static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();
    GLOBAL.get_or_init(MetricsRegistry::new)
}

/// Pre-resolved handles for the kernel dispatcher (`kernel_*` metrics).
/// Indexed by the dispatcher's own kind indices so the record calls stay
/// allocation-free.
pub struct KernelObs {
    gemm_count: [Arc<Counter>; 3],
    gemm_ns: [Arc<Histo>; 3],
    gemm_flops: Arc<Histo>,
    gemv_count: Arc<Counter>,
    gemv_ns: Arc<Histo>,
    conv_plans: [Arc<Counter>; 2],
}

/// `GemmKind` wire names, indexed like [`KernelObs::record_gemm`]'s
/// `kind` argument.
pub const GEMM_KINDS: [&str; 3] = ["naive", "blocked", "blocked_parallel"];
/// `ConvKind` wire names, indexed like [`KernelObs::record_conv_plan`]'s
/// `kind` argument.
pub const CONV_KINDS: [&str; 2] = ["direct", "im2col"];

impl KernelObs {
    fn new(reg: &MetricsRegistry) -> KernelObs {
        let counter = |k: &str| reg.counter(&format!("kernel_gemm_total{{kind=\"{k}\"}}"));
        let histo = |k: &str| reg.histogram(&format!("kernel_gemm_ns{{kind=\"{k}\"}}"));
        let conv = |k: &str| reg.counter(&format!("kernel_conv_plans_total{{kind=\"{k}\"}}"));
        KernelObs {
            gemm_count: GEMM_KINDS.map(counter),
            gemm_ns: GEMM_KINDS.map(histo),
            gemm_flops: reg.histogram("kernel_gemm_flops"),
            gemv_count: reg.counter("kernel_gemv_total"),
            gemv_ns: reg.histogram("kernel_gemv_ns"),
            conv_plans: CONV_KINDS.map(conv),
        }
    }

    pub fn record_gemm(&self, kind: usize, flops: u64, elapsed: Duration) {
        self.gemm_count[kind].inc();
        self.gemm_ns[kind].record_duration(elapsed);
        self.gemm_flops.record(flops);
    }

    pub fn record_gemv(&self, elapsed: Duration) {
        self.gemv_count.inc();
        self.gemv_ns.record_duration(elapsed);
    }

    pub fn record_conv_plan(&self, kind: usize) {
        self.conv_plans[kind].inc();
    }
}

/// Kernel-side handles into [`global`]. Callers must check [`enabled`]
/// first — that keeps the disabled path at one relaxed load.
pub fn kernel() -> &'static KernelObs {
    static KERNEL: OnceLock<KernelObs> = OnceLock::new();
    KERNEL.get_or_init(|| KernelObs::new(global()))
}

/// Pre-resolved handles for the tiered store (`store_*` timings), the
/// sharded factor tier (`store_shard_*`) and the background maintenance
/// thread (`store_maint_*`).
pub struct StoreObs {
    append_ns: Arc<Histo>,
    fsync_ns: Arc<Histo>,
    compact_ns: Arc<Histo>,
    spill_read_ns: Arc<Histo>,
    spill_write_ns: Arc<Histo>,
    shard_count: Arc<Gauge>,
    shard_appends: Arc<Counter>,
    shard_replay_ns: Arc<Histo>,
    shard_torn_tails: Arc<Counter>,
    maint_ticks: Arc<Counter>,
    maint_compactions: Arc<Counter>,
    maint_spill_writes: Arc<Counter>,
    maint_queue_depth: Arc<Gauge>,
    maint_cycle_ns: Arc<Histo>,
}

impl StoreObs {
    fn new(reg: &MetricsRegistry) -> StoreObs {
        StoreObs {
            append_ns: reg.histogram("store_append_ns"),
            fsync_ns: reg.histogram("store_fsync_ns"),
            compact_ns: reg.histogram("store_compaction_ns"),
            spill_read_ns: reg.histogram("store_spill_read_ns"),
            spill_write_ns: reg.histogram("store_spill_write_ns"),
            shard_count: reg.gauge("store_shard_count"),
            shard_appends: reg.counter("store_shard_appends_total"),
            shard_replay_ns: reg.histogram("store_shard_replay_ns"),
            shard_torn_tails: reg.counter("store_shard_torn_tails_total"),
            maint_ticks: reg.counter("store_maint_ticks_total"),
            maint_compactions: reg.counter("store_maint_compactions_total"),
            maint_spill_writes: reg.counter("store_maint_spill_writes_total"),
            maint_queue_depth: reg.gauge("store_maint_queue_depth"),
            maint_cycle_ns: reg.histogram("store_maint_cycle_ns"),
        }
    }

    pub fn record_append(&self, elapsed: Duration) {
        self.append_ns.record_duration(elapsed);
    }

    pub fn record_fsync(&self, elapsed: Duration) {
        self.fsync_ns.record_duration(elapsed);
    }

    pub fn record_compaction(&self, elapsed: Duration) {
        self.compact_ns.record_duration(elapsed);
    }

    pub fn record_spill_read(&self, elapsed: Duration) {
        self.spill_read_ns.record_duration(elapsed);
    }

    pub fn record_spill_write(&self, elapsed: Duration) {
        self.spill_write_ns.record_duration(elapsed);
    }

    pub fn set_shard_count(&self, n: usize) {
        self.shard_count.set(n as u64);
    }

    pub fn record_shard_append(&self) {
        self.shard_appends.inc();
    }

    /// One shard's boot replay (they run in parallel; each records its
    /// own wall time).
    pub fn record_shard_replay(&self, elapsed: Duration) {
        self.shard_replay_ns.record_duration(elapsed);
    }

    /// A shard came up with a torn tail (it recovered its prefix; the
    /// counter surfaces *which boot* was crashy fleet-wide).
    pub fn record_shard_torn_tail(&self) {
        self.shard_torn_tails.inc();
    }

    pub fn record_maint_tick(&self) {
        self.maint_ticks.inc();
    }

    pub fn record_maint_compaction(&self) {
        self.maint_compactions.inc();
    }

    pub fn record_maint_spill_write(&self) {
        self.maint_spill_writes.inc();
    }

    pub fn set_maint_queue_depth(&self, n: usize) {
        self.maint_queue_depth.set(n as u64);
    }

    /// One maintenance cycle's off-request-path busy time.
    pub fn record_maint_cycle(&self, elapsed: Duration) {
        self.maint_cycle_ns.record_duration(elapsed);
    }
}

/// Store-side handles into [`global`]; same [`enabled`] contract as
/// [`kernel`].
pub fn store() -> &'static StoreObs {
    static STORE: OnceLock<StoreObs> = OnceLock::new();
    STORE.get_or_init(|| StoreObs::new(global()))
}

/// Serializes tests that toggle the process-wide [`ENABLED`] flag, so a
/// concurrently running test cannot flip instrumentation off mid-assert.
#[cfg(test)]
pub(crate) fn test_enable_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_handles_feed_the_global_registry() {
        // The global registry is process-wide and shared with other
        // tests, so assert deltas, never absolute values.
        let before = global().snapshot();
        kernel().record_gemm(1, 1000, Duration::from_nanos(250));
        store().record_append(Duration::from_nanos(90));
        let after = global().snapshot();
        let gemm = "kernel_gemm_ns{kind=\"blocked\"}";
        let d = after.histograms[gemm].count()
            - before.histograms.get(gemm).map(|h| h.count()).unwrap_or(0);
        assert_eq!(d, 1);
        let d = after.histograms["store_append_ns"].count()
            - before
                .histograms
                .get("store_append_ns")
                .map(|h| h.count())
                .unwrap_or(0);
        assert_eq!(d, 1);
    }

    #[test]
    fn enabled_toggles() {
        let _g = test_enable_lock();
        let was = enabled();
        set_enabled(true);
        assert!(enabled());
        set_enabled(was);
    }
}
