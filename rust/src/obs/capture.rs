//! Slow/error request capture: a second, smaller trace ring that only
//! admits *interesting* requests (DESIGN.md §12).
//!
//! The main [`TraceRing`](super::trace::TraceRing) keeps the newest N
//! traces of *all* traffic, so a tail-latency event is overwritten
//! within milliseconds under load. The [`CaptureRing`] holds full
//! [`Trace`]s that crossed a threshold — `total_ns` over the slow bar,
//! shed at the deadline, or errored — each tagged with its
//! [`CaptureReason`]. Because only exceptional requests enter, an
//! incident survives long after the main ring has wrapped; `/tracez?
//! captured=1` reads it back and the Chrome exporter renders it like
//! any other trace set.
//!
//! Same lock-free-claim slot discipline as the main ring: writers take
//! a capture sequence with one `fetch_add`, write slot `seq % cap`, and
//! newer sequence wins a slot race — the ring holds exactly the newest
//! `capacity` captures after any quiescent point.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;

use super::trace::Trace;

/// Default capture-ring capacity. Captures are rare by construction, so
/// a small ring covers a long incident window.
pub const CAPTURE_RING_CAP: usize = 64;

/// Why a trace was retained.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CaptureReason {
    /// `total_ns` exceeded the slow threshold (explicit
    /// `EngineOpts::capture_slow_ns` or the serve-SLO p99 objective).
    Slow,
    /// Shed at its deadline before compute.
    DeadlineShed,
    /// The batch errored or its worker panicked.
    Error,
}

impl CaptureReason {
    pub fn name(self) -> &'static str {
        match self {
            CaptureReason::Slow => "slow",
            CaptureReason::DeadlineShed => "deadline_shed",
            CaptureReason::Error => "error",
        }
    }
}

/// A retained trace plus why it was retained. `cap_seq` orders captures
/// within this ring (independent of the trace's main-ring `seq`).
#[derive(Clone, Debug)]
pub struct Captured {
    pub cap_seq: u64,
    pub reason: CaptureReason,
    pub trace: Trace,
}

impl Captured {
    /// The trace's JSON with capture fields spliced in — one shape for
    /// both `/tracez` variants, so consumers parse a single schema.
    pub fn to_json(&self) -> Json {
        let mut j = self.trace.to_json();
        if let Json::Obj(map) = &mut j {
            map.insert("captured".to_string(), Json::u64(self.cap_seq));
            map.insert("reason".to_string(), Json::Str(self.reason.name().to_string()));
        }
        j
    }
}

/// Lossy newest-N ring of [`Captured`] records.
pub struct CaptureRing {
    seq: AtomicU64,
    slots: Vec<Mutex<Option<Captured>>>,
}

impl CaptureRing {
    pub fn new(capacity: usize) -> CaptureRing {
        CaptureRing {
            seq: AtomicU64::new(0),
            slots: (0..capacity.max(1)).map(|_| Mutex::new(None)).collect(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total captures ever pushed (not the resident count).
    pub fn captured(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// Retain a trace. Returns the assigned capture sequence.
    pub fn push(&self, reason: CaptureReason, trace: Trace) -> u64 {
        let cap_seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let mut slot = self.slots[(cap_seq % self.slots.len() as u64) as usize].lock().unwrap();
        let stale = match slot.as_ref() {
            Some(c) => c.cap_seq < cap_seq,
            None => true,
        };
        if stale {
            *slot = Some(Captured {
                cap_seq,
                reason,
                trace,
            });
        }
        cap_seq
    }

    /// Resident captures, newest first.
    pub fn snapshot(&self) -> Vec<Captured> {
        let mut out: Vec<Captured> = self
            .slots
            .iter()
            .filter_map(|s| s.lock().unwrap().clone())
            .collect();
        out.sort_by(|a, b| b.cap_seq.cmp(&a.cap_seq));
        out
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(self.snapshot().iter().map(Captured::to_json).collect())
    }
}

impl std::fmt::Debug for CaptureRing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "CaptureRing(cap {}, captured {})", self.slots.len(), self.captured())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::Stage;
    use std::sync::Arc;

    fn trace(req_id: u64) -> Trace {
        Trace {
            seq: 0,
            req_id,
            tenant: req_id % 5,
            path: "cold_merge",
            start_ns: 10 * req_id,
            worker: 0,
            total_ns: 1_000_000 + req_id,
            stage_ns: [1, 0, 2, 0, 3, 4],
        }
    }

    #[test]
    fn ring_keeps_newest_n_single_threaded() {
        let ring = CaptureRing::new(3);
        for i in 0..7 {
            let reason = if i % 2 == 0 { CaptureReason::Slow } else { CaptureReason::Error };
            ring.push(reason, trace(i));
        }
        let snap = ring.snapshot();
        let seqs: Vec<u64> = snap.iter().map(|c| c.cap_seq).collect();
        assert_eq!(seqs, vec![6, 5, 4], "newest first, exactly capacity");
        assert_eq!(ring.captured(), 7);
        assert_eq!(snap[0].reason, CaptureReason::Slow);
        assert_eq!(snap[1].reason, CaptureReason::Error);
    }

    #[test]
    fn ring_keeps_newest_n_under_concurrent_writers() {
        // Mirrors the TraceRing retention test: any interleaving of
        // writers must leave exactly the newest CAP capture sequences.
        const CAP: usize = 8;
        const THREADS: u64 = 4;
        const PER: u64 = 100;
        let ring = Arc::new(CaptureRing::new(CAP));
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let ring = Arc::clone(&ring);
                std::thread::spawn(move || {
                    for i in 0..PER {
                        ring.push(CaptureReason::DeadlineShed, trace(t * PER + i));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let total = THREADS * PER;
        let seqs: Vec<u64> = ring.snapshot().iter().map(|c| c.cap_seq).collect();
        let want: Vec<u64> = (0..CAP as u64).map(|i| total - 1 - i).collect();
        assert_eq!(seqs, want, "ring must retain exactly the newest {CAP} captures");
    }

    #[test]
    fn captured_json_carries_reason_and_trace_fields() {
        let ring = CaptureRing::new(2);
        ring.push(CaptureReason::Slow, trace(77));
        let j = ring.to_json();
        let c = &j.as_arr().unwrap()[0];
        assert_eq!(c.get("reason").unwrap().as_str(), Some("slow"));
        assert_eq!(c.get("req_id").unwrap().as_u64(), Some(77));
        assert_eq!(c.get("captured").unwrap().as_u64(), Some(0));
        let stages = c.get("stage_ns").unwrap().as_obj().unwrap();
        assert!(stages.contains_key(Stage::Kernel.name()));
        assert_eq!(
            [CaptureReason::Slow, CaptureReason::DeadlineShed, CaptureReason::Error]
                .map(CaptureReason::name),
            ["slow", "deadline_shed", "error"]
        );
    }
}
