//! Bounded-cardinality per-tenant telemetry (DESIGN.md §12).
//!
//! The fleet-global registries (§9) cannot answer "*which tenant* is
//! hot/slow/shedding" — and naive per-tenant labels would grow the
//! registry linearly with the fleet (millions of tenants is the north
//! star). This module keeps per-tenant telemetry at **fixed size**: a
//! SpaceSaving top-K sketch (Metwally, Agrawal, El Abbadi 2005) per
//! dimension, K slots each, regardless of how many tenants exist.
//!
//! Guarantees (property-tested against an exact-count oracle):
//! - every tracked count **overestimates** the true count by at most the
//!   slot's recorded `err`, and `err ≤ N/K` (N = total weight observed);
//! - any tenant whose true count exceeds `N/K` **is tracked** (top-K
//!   superset guarantee);
//! - the sum of tracked counts equals N exactly (each observation lands
//!   in exactly one slot), so top-K counts can never claim more traffic
//!   than was served;
//! - two sketches merge into one with the same bounds over the combined
//!   stream (fleet views fold shard-by-shard).
//!
//! [`TenantStats`] bundles one sketch per dimension — request count,
//! latency sum, deadline sheds, admission rejections — behind cheap
//! mutexes (`observe` is an O(K) scan, K ≈ 32). Snapshots export as the
//! `tenants` section of `EngineReport`/`BENCH_serve.json`, the
//! `/tenantz` endpoint (JSON + text table), and `serve_tenant_topk_*`
//! gauges whose series count is capped at K per dimension.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::Json;

use super::registry::RegistrySnapshot;

/// Default K: slots per dimension. 32 tracked tenants per dimension is
/// plenty to name an abuser while keeping `/metrics` cardinality flat.
pub const DEFAULT_TENANT_TOPK: usize = 32;

/// One tracked heavy hitter: `count` overestimates the tenant's true
/// total by at most `err`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TopEntry {
    pub tenant: u64,
    pub count: u64,
    pub err: u64,
}

/// SpaceSaving top-K sketch over `(tenant, weight)` observations.
#[derive(Clone, Debug)]
pub struct SpaceSaving {
    k: usize,
    total: u64,
    slots: Vec<TopEntry>,
}

impl SpaceSaving {
    pub fn new(k: usize) -> SpaceSaving {
        SpaceSaving {
            k: k.max(1),
            total: 0,
            slots: Vec::new(),
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Total weight observed (the N in the `err ≤ N/K` bound).
    pub fn total(&self) -> u64 {
        self.total
    }

    /// The minimum tracked count once full — an upper bound on the true
    /// count of *any* untracked tenant (0 while slots remain).
    fn floor(&self) -> u64 {
        if self.slots.len() < self.k {
            0
        } else {
            self.slots.iter().map(|e| e.count).min().unwrap_or(0)
        }
    }

    /// Record `weight` for `tenant`. Tracked tenants accumulate; a new
    /// tenant either takes a free slot or evicts the current minimum,
    /// inheriting its count as the new slot's error bound.
    pub fn observe(&mut self, tenant: u64, weight: u64) {
        self.total = self.total.saturating_add(weight);
        if let Some(e) = self.slots.iter_mut().find(|e| e.tenant == tenant) {
            e.count = e.count.saturating_add(weight);
            return;
        }
        if self.slots.len() < self.k {
            self.slots.push(TopEntry {
                tenant,
                count: weight,
                err: 0,
            });
            return;
        }
        let min = self.slots.iter_mut().min_by_key(|e| e.count).unwrap();
        let inherited = min.count;
        *min = TopEntry {
            tenant,
            count: inherited.saturating_add(weight),
            err: inherited,
        };
    }

    /// The tracked entry for `tenant`, if it survived in the top-K.
    pub fn estimate(&self, tenant: u64) -> Option<&TopEntry> {
        self.slots.iter().find(|e| e.tenant == tenant)
    }

    /// Tracked entries, highest count first (ties broken by tenant id
    /// for deterministic output).
    pub fn entries(&self) -> Vec<TopEntry> {
        let mut out = self.slots.clone();
        out.sort_by(|a, b| b.count.cmp(&a.count).then(a.tenant.cmp(&b.tenant)));
        out
    }

    /// Merge two sketches over disjoint streams into one covering the
    /// combined stream. A tenant tracked on only one side may hold up to
    /// the other side's `floor()` unseen weight there, so that floor is
    /// added to both its count and its error — estimates stay
    /// overestimates and `err` stays ≤ (N₁+N₂)/K.
    pub fn merge(&self, other: &SpaceSaving) -> SpaceSaving {
        let (fa, fb) = (self.floor(), other.floor());
        let mut by_tenant: BTreeMap<u64, TopEntry> = BTreeMap::new();
        for e in &self.slots {
            by_tenant.insert(e.tenant, e.clone());
        }
        for e in &other.slots {
            match by_tenant.get_mut(&e.tenant) {
                Some(mine) => {
                    mine.count = mine.count.saturating_add(e.count);
                    mine.err = mine.err.saturating_add(e.err);
                }
                None => {
                    by_tenant.insert(
                        e.tenant,
                        TopEntry {
                            tenant: e.tenant,
                            count: e.count.saturating_add(fa),
                            err: e.err.saturating_add(fa),
                        },
                    );
                }
            }
        }
        // Tenants absent from `other` may still hold up to fb there.
        for e in &self.slots {
            if other.estimate(e.tenant).is_none() {
                let m = by_tenant.get_mut(&e.tenant).unwrap();
                m.count = m.count.saturating_add(fb);
                m.err = m.err.saturating_add(fb);
            }
        }
        let mut merged: Vec<TopEntry> = by_tenant.into_values().collect();
        merged.sort_by(|a, b| b.count.cmp(&a.count).then(a.tenant.cmp(&b.tenant)));
        let k = self.k.max(other.k);
        merged.truncate(k);
        SpaceSaving {
            k,
            total: self.total.saturating_add(other.total),
            slots: merged,
        }
    }
}

/// The fixed per-tenant dimension set. A new dimension must also be
/// added to `tools/check_obs.py` and DESIGN.md §12.
pub const TENANT_DIMS: [&str; 4] =
    ["requests", "latency_ns_sum", "deadline_sheds", "admission_rejected"];

/// One sketch per dimension, shared by the engine hot path (request
/// completion, deadline sheds) and the front (admission rejections).
#[derive(Debug)]
pub struct TenantStats {
    k: usize,
    requests: Mutex<SpaceSaving>,
    latency_ns: Mutex<SpaceSaving>,
    deadline_sheds: Mutex<SpaceSaving>,
    rejections: Mutex<SpaceSaving>,
}

impl TenantStats {
    pub fn new(k: usize) -> TenantStats {
        let k = k.max(1);
        TenantStats {
            k,
            requests: Mutex::new(SpaceSaving::new(k)),
            latency_ns: Mutex::new(SpaceSaving::new(k)),
            deadline_sheds: Mutex::new(SpaceSaving::new(k)),
            rejections: Mutex::new(SpaceSaving::new(k)),
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// A completed request: counts once, adds its latency to the sum.
    pub fn record_request(&self, tenant: u64, latency_ns: u64) {
        self.requests.lock().unwrap().observe(tenant, 1);
        self.latency_ns.lock().unwrap().observe(tenant, latency_ns);
    }

    /// A job shed at its deadline before compute.
    pub fn record_shed(&self, tenant: u64) {
        self.deadline_sheds.lock().unwrap().observe(tenant, 1);
    }

    /// An admission-gate rejection (429/503/504 before the engine).
    pub fn record_rejection(&self, tenant: u64) {
        self.rejections.lock().unwrap().observe(tenant, 1);
    }

    /// Point-in-time view of all dimensions.
    pub fn summary(&self) -> TenantSummary {
        let dim = |name: &'static str, s: &Mutex<SpaceSaving>| {
            let s = s.lock().unwrap();
            DimSummary {
                name,
                total: s.total(),
                entries: s.entries(),
            }
        };
        TenantSummary {
            k: self.k,
            dims: vec![
                dim(TENANT_DIMS[0], &self.requests),
                dim(TENANT_DIMS[1], &self.latency_ns),
                dim(TENANT_DIMS[2], &self.deadline_sheds),
                dim(TENANT_DIMS[3], &self.rejections),
            ],
        }
    }
}

/// One dimension's tracked entries (already sorted, highest first).
#[derive(Clone, Debug)]
pub struct DimSummary {
    pub name: &'static str,
    pub total: u64,
    pub entries: Vec<TopEntry>,
}

/// Snapshot of a [`TenantStats`]: the `tenants` section of
/// `EngineReport` / `BENCH_serve.json` and the `/tenantz` payload.
#[derive(Clone, Debug)]
pub struct TenantSummary {
    pub k: usize,
    pub dims: Vec<DimSummary>,
}

impl TenantSummary {
    pub fn to_json(&self) -> Json {
        let dims = Json::Obj(
            self.dims
                .iter()
                .map(|d| {
                    let entries = Json::Arr(
                        d.entries
                            .iter()
                            .map(|e| {
                                Json::obj(vec![
                                    ("tenant", Json::u64(e.tenant)),
                                    ("count", Json::u64(e.count)),
                                    ("err", Json::u64(e.err)),
                                ])
                            })
                            .collect(),
                    );
                    (
                        d.name.to_string(),
                        Json::obj(vec![
                            ("total", Json::u64(d.total)),
                            ("entries", entries),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![("k", Json::Num(self.k as f64)), ("dims", dims)])
    }

    /// Plain-text table for terminal scrapes of `/tenantz?format=text`.
    pub fn text_table(&self) -> String {
        let mut out = format!("per-tenant heavy hitters (K={} slots per dimension)\n", self.k);
        for d in &self.dims {
            out.push_str(&format!("\n{} (total {}):\n", d.name, d.total));
            if d.entries.is_empty() {
                out.push_str("  (no observations)\n");
                continue;
            }
            out.push_str(&format!("  {:>20} {:>16} {:>12}\n", "tenant", "count", "err"));
            for e in &d.entries {
                out.push_str(&format!("  {:>20} {:>16} {:>12}\n", e.tenant, e.count, e.err));
            }
        }
        out
    }

    /// `serve_tenant_topk_<dim>{tenant="..."}` gauges — at most K series
    /// per dimension by construction, plus the `serve_tenant_topk_k`
    /// contract gauge. Merged into scrape snapshots at snapshot time, so
    /// the live registry itself never grows with the fleet.
    pub fn metrics(&self) -> RegistrySnapshot {
        let mut snap = RegistrySnapshot::default();
        snap.gauges.insert("serve_tenant_topk_k".to_string(), self.k as u64);
        for d in &self.dims {
            for e in &d.entries {
                snap.gauges.insert(
                    format!("serve_tenant_topk_{}{{tenant=\"{}\"}}", d.name, e.tenant),
                    e.count,
                );
            }
        }
        snap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use std::collections::HashMap;

    /// A skewed stream over a tenant universe much larger than K.
    fn stream(rng: &mut Rng, len: usize, universe: u64) -> Vec<u64> {
        (0..len)
            .map(|_| {
                if rng.flip(0.5) {
                    // Hot set: a few tenants take half the traffic.
                    rng.below(4) as u64
                } else {
                    rng.below(universe as usize) as u64
                }
            })
            .collect()
    }

    fn exact(stream: &[u64]) -> HashMap<u64, u64> {
        let mut m = HashMap::new();
        for &t in stream {
            *m.entry(t).or_insert(0u64) += 1;
        }
        m
    }

    fn shrink_stream(s: &Vec<u64>) -> Vec<Vec<u64>> {
        if s.len() <= 1 {
            return Vec::new();
        }
        let half = s.len() / 2;
        vec![s[..half].to_vec(), s[half..].to_vec(), s[..s.len() - 1].to_vec()]
    }

    #[test]
    fn spacesaving_error_bound_and_count_conservation_vs_oracle() {
        prop::check_shrunk(
            "spacesaving count error <= N/K",
            11,
            48,
            |rng| stream(rng, 64 + rng.below(512), 200),
            shrink_stream,
            |s| {
                let k = 8;
                let mut sk = SpaceSaving::new(k);
                for &t in s {
                    sk.observe(t, 1);
                }
                let truth = exact(s);
                let n = s.len() as u64;
                assert_eq!(sk.total(), n);
                // Each observation adds its weight to exactly one slot
                // (eviction replaces min with min+w): counts sum to N.
                let sum: u64 = sk.entries().iter().map(|e| e.count).sum();
                assert_eq!(sum, n, "tracked counts must sum to N exactly");
                for e in sk.entries() {
                    let true_count = truth.get(&e.tenant).copied().unwrap_or(0);
                    assert!(
                        e.count >= true_count,
                        "tenant {} estimate {} underestimates true {}",
                        e.tenant,
                        e.count,
                        true_count
                    );
                    assert!(
                        e.count - true_count <= n / k as u64,
                        "tenant {} overestimate {} beyond N/K = {}",
                        e.tenant,
                        e.count - true_count,
                        n / k as u64
                    );
                    assert!(e.err <= n / k as u64, "recorded err beyond N/K");
                    assert!(e.count - true_count <= e.err, "err must bound the overestimate");
                }
            },
        );
    }

    #[test]
    fn spacesaving_topk_superset_guarantee() {
        prop::check_shrunk(
            "any tenant with true count > N/K is tracked",
            13,
            48,
            |rng| stream(rng, 64 + rng.below(512), 100),
            shrink_stream,
            |s| {
                let k = 8u64;
                let mut sk = SpaceSaving::new(k as usize);
                for &t in s {
                    sk.observe(t, 1);
                }
                let n = s.len() as u64;
                for (&tenant, &count) in &exact(s) {
                    if count > n / k {
                        assert!(
                            sk.estimate(tenant).is_some(),
                            "tenant {tenant} with {count} > N/K = {} evicted",
                            n / k
                        );
                    }
                }
            },
        );
    }

    #[test]
    fn spacesaving_merge_preserves_bounds_over_combined_stream() {
        prop::check_named("sketch merge stays a valid sketch", 17, 48, |rng| {
            let k = 8;
            let sa = stream(rng, 32 + rng.below(256), 64);
            let sb = stream(rng, 32 + rng.below(256), 64);
            let mut a = SpaceSaving::new(k);
            let mut b = SpaceSaving::new(k);
            for &t in &sa {
                a.observe(t, 1);
            }
            for &t in &sb {
                b.observe(t, 1);
            }
            let m = a.merge(&b);
            let combined: Vec<u64> = sa.iter().chain(sb.iter()).copied().collect();
            let truth = exact(&combined);
            let n = combined.len() as u64;
            assert_eq!(m.total(), n, "totals add");
            assert!(m.entries().len() <= k, "merge respects K");
            for e in m.entries() {
                let true_count = truth.get(&e.tenant).copied().unwrap_or(0);
                assert!(e.count >= true_count, "merged estimate underestimates");
                assert!(
                    e.count - true_count <= e.err,
                    "merged err {} must bound overestimate {}",
                    e.err,
                    e.count - true_count
                );
                assert!(e.err <= 2 * (n / k as u64) + 2, "merged err beyond (Na+Nb)/K");
            }
        });
    }

    #[test]
    fn cardinality_capped_at_k_for_a_10k_tenant_fleet() {
        // The acceptance case: 10k distinct tenants, K=32 — every export
        // surface holds at most K tenant-labelled entries per dimension.
        let stats = TenantStats::new(32);
        let mut rng = Rng::new(7);
        for i in 0..10_000u64 {
            stats.record_request(i, 1_000 + (i % 97));
            if rng.flip(0.1) {
                stats.record_shed(i);
            }
            if rng.flip(0.1) {
                stats.record_rejection(i);
            }
        }
        // A hot tenant on top so the ranking is non-trivial.
        for _ in 0..5_000 {
            stats.record_request(42, 2_000);
        }
        let summary = stats.summary();
        assert_eq!(summary.k, 32);
        assert_eq!(summary.dims.len(), TENANT_DIMS.len());
        for d in &summary.dims {
            assert!(d.entries.len() <= 32, "{}: {} entries", d.name, d.entries.len());
            let counts: Vec<u64> = d.entries.iter().map(|e| e.count).collect();
            assert!(counts.windows(2).all(|w| w[0] >= w[1]), "{} sorted desc", d.name);
            assert!(counts.iter().sum::<u64>() <= d.total, "{} counts exceed total", d.name);
        }
        let requests = &summary.dims[0];
        assert_eq!(requests.total, 15_000);
        assert_eq!(requests.entries[0].tenant, 42, "hot tenant ranks first");
        assert!(requests.entries[0].count >= 5_000);

        let metrics = summary.metrics();
        for dim in TENANT_DIMS {
            let prefix = format!("serve_tenant_topk_{dim}{{");
            let series = metrics.gauges.keys().filter(|k| k.starts_with(&prefix)).count();
            assert!(series <= 32, "{dim}: {series} series leaked past K");
        }
        assert_eq!(metrics.gauges["serve_tenant_topk_k"], 32);
        // And the text/JSON exports stay parseable and K-bounded.
        let j = crate::util::json::Json::parse(&summary.to_json().pretty()).unwrap();
        assert_eq!(j.get("k").unwrap().as_usize(), Some(32));
        let dims = j.get("dims").unwrap().as_obj().unwrap();
        for (name, d) in dims {
            let entries = d.get("entries").unwrap().as_arr().unwrap();
            assert!(entries.len() <= 32, "{name} JSON entries exceed K");
        }
        assert!(summary.text_table().contains("K=32"));
    }

    #[test]
    fn zero_and_small_fleets_export_cleanly() {
        let stats = TenantStats::new(4);
        let empty = stats.summary();
        assert!(empty.dims.iter().all(|d| d.entries.is_empty() && d.total == 0));
        assert!(empty.text_table().contains("(no observations)"));
        stats.record_request(9, 500);
        stats.record_rejection(9);
        let s = stats.summary();
        assert_eq!(s.dims[0].entries, vec![TopEntry { tenant: 9, count: 1, err: 0 }]);
        assert_eq!(s.dims[3].total, 1);
        let m = s.metrics();
        assert_eq!(m.gauges["serve_tenant_topk_requests{tenant=\"9\"}"], 1);
        assert_eq!(m.gauges["serve_tenant_topk_latency_ns_sum{tenant=\"9\"}"], 500);
    }
}
