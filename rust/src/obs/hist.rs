//! Lock-free log-bucketed latency histograms.
//!
//! Values (nanoseconds, bytes, batch sizes — any `u64`) are binned into a
//! fixed array of [`BUCKETS`] `AtomicU64` counters: values below
//! `2^SUB_BITS` get an exact bucket each; above that, every power-of-two
//! octave splits into `2^SUB_BITS` log-linear sub-buckets, so the
//! quantile read back from a snapshot overshoots the true sample by at
//! most `2^-SUB_BITS` (12.5%) relative — and never undershoots, because
//! [`HistoSnapshot::quantile`] returns the *upper* bound of the bucket
//! holding the ranked sample (clamped to the observed max). Recording is
//! three relaxed atomic ops, no locks, no allocation; snapshots are plain
//! `Vec<u64>` and merge associatively (the substrate for per-shard
//! registries folding into one fleet view).
//!
//! The quantile-vs-sorted-oracle bound and merge associativity are
//! property-tested below (DESIGN.md §9).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Sub-bucket resolution: each octave splits into `2^SUB_BITS` buckets,
/// bounding the relative quantile overshoot by `2^-SUB_BITS`.
pub const SUB_BITS: u32 = 3;
const SUB: usize = 1 << SUB_BITS;

/// Total bucket count; the last bucket's upper bound is `u64::MAX`.
pub const BUCKETS: usize = ((64 - SUB_BITS as usize) << SUB_BITS) + SUB;

/// Bucket index for a value (total order, contiguous from 0).
pub fn bucket_of(v: u64) -> usize {
    if v < SUB as u64 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros() as usize; // >= SUB_BITS
    let shift = msb - SUB_BITS as usize;
    let sub = (v >> shift) as usize & (SUB - 1);
    ((msb - SUB_BITS as usize + 1) << SUB_BITS) + sub
}

/// Inclusive `[lo, hi]` value range of bucket `i` (inverse of
/// [`bucket_of`]: `lo <= v <= hi` ⇔ `bucket_of(v) == i`).
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    if i < SUB {
        return (i as u64, i as u64);
    }
    let g = (i >> SUB_BITS) as u64; // octave group, >= 1
    let sub = (i & (SUB - 1)) as u64;
    let shift = g - 1;
    let lo = (1u64 << (shift + SUB_BITS as u64)) + (sub << shift);
    (lo, lo + (1u64 << shift) - 1)
}

/// A live histogram: a fixed array of atomic bucket counters plus running
/// sum and max. All methods take `&self`; record from any thread.
pub struct Histo {
    buckets: Vec<AtomicU64>,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histo {
    fn default() -> Histo {
        Histo::new()
    }
}

impl Histo {
    pub fn new() -> Histo {
        Histo {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample: three relaxed atomic RMWs, no allocation.
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Record an elapsed time in nanoseconds.
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Point-in-time copy of the bucket array. The *count* of a snapshot
    /// is derived from the bucket components (never a separately-read
    /// total), so a snapshot taken mid-record can never show
    /// `sum-of-parts != total` read-skew.
    pub fn snapshot(&self) -> HistoSnapshot {
        HistoSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }
}

/// An owned, mergeable histogram snapshot.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistoSnapshot {
    pub buckets: Vec<u64>,
    pub sum: u64,
    pub max: u64,
}

impl Default for HistoSnapshot {
    fn default() -> HistoSnapshot {
        HistoSnapshot {
            buckets: vec![0; BUCKETS],
            sum: 0,
            max: 0,
        }
    }
}

impl HistoSnapshot {
    /// Sample count, derived from the bucket components (see
    /// [`Histo::snapshot`] for why this is not a separate atomic).
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.iter().all(|&c| c == 0)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum as f64 / n as f64
        }
    }

    /// Quantile estimate: the upper bound of the bucket holding the
    /// sample of rank `round((n-1)·q)` (the same rank convention the
    /// engine's sorted-vector stats used), clamped to the observed max.
    /// Never undershoots the true sample; overshoots by < `2^-SUB_BITS`
    /// relative. Returns 0 on an empty snapshot.
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((n - 1) as f64 * q.clamp(0.0, 1.0)).round() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum > rank {
                return bucket_bounds(i).1.min(self.max);
            }
        }
        self.max
    }

    /// Fold another snapshot in. Bucket-wise addition is exact and
    /// associative (wrapping, like the counters themselves), so shard
    /// snapshots can merge in any grouping.
    pub fn merge(&mut self, other: &HistoSnapshot) {
        for (a, &b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a = a.wrapping_add(b);
        }
        self.sum = self.sum.wrapping_add(other.sum);
        self.max = self.max.max(other.max);
    }

    /// The samples recorded between `earlier` and `self`, where `earlier`
    /// is a previous snapshot of the *same* histogram (buckets only ever
    /// grow, so the bucket-wise difference is itself a valid histogram —
    /// the substrate for SLO burn-rate windows). Wrapping subtraction
    /// mirrors [`HistoSnapshot::merge`]'s wrapping addition exactly:
    /// `merge(a.delta(&b), b) == a` bucket-wise whenever `b` preceded
    /// `a`. The delta keeps the later `max` (the true window max is not
    /// recoverable from two endpoint snapshots; the kept value is a
    /// correct upper bound and the quantile clamp stays sound).
    pub fn delta(&self, earlier: &HistoSnapshot) -> HistoSnapshot {
        HistoSnapshot {
            buckets: self
                .buckets
                .iter()
                .zip(&earlier.buckets)
                .map(|(&a, &b)| a.wrapping_sub(b))
                .collect(),
            sum: self.sum.wrapping_sub(earlier.sum),
            max: self.max,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Values spanning the full bucket range: exact small buckets, every
    /// octave, and the saturating top bucket (`u64::MAX`).
    fn gen_values(rng: &mut Rng, max_len: usize) -> Vec<u64> {
        (0..rng.below(max_len + 1))
            .map(|_| match rng.below(8) {
                0 => rng.below(SUB) as u64,        // exact buckets
                1 => 0,                            // zero edge
                2 => u64::MAX,                     // saturating bucket
                3 => u64::MAX - rng.below(9) as u64,
                _ => {
                    let e = rng.below(63) as u32;
                    (1u64 << e) | (rng.next_u64() >> (64 - e.max(1)))
                }
            })
            .collect()
    }

    fn shrink_values(v: &[u64]) -> Vec<Vec<u64>> {
        let mut out = Vec::new();
        if !v.is_empty() {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[v.len() / 2..].to_vec());
            let mut tail = v.to_vec();
            tail.remove(0);
            out.push(tail);
        }
        if v.iter().any(|&x| x > 1) {
            out.push(v.iter().map(|&x| x / 2).collect());
        }
        out
    }

    fn oracle(sorted: &[u64], q: f64) -> u64 {
        sorted[((sorted.len() as f64 - 1.0) * q).round() as usize]
    }

    #[test]
    fn bucket_of_and_bounds_are_inverse_and_total() {
        // Exhaustive near the small/exact boundary, then probes across
        // every octave including the extremes.
        let mut probes: Vec<u64> = (0..1024).collect();
        for e in 4..64u32 {
            probes.extend([1u64 << e, (1 << e) + 1, (1u64 << e) - 1]);
        }
        probes.extend([u64::MAX, u64::MAX - 1]);
        let mut prev = None;
        for &v in &probes {
            let i = bucket_of(v);
            assert!(i < BUCKETS, "index {i} out of range for {v}");
            let (lo, hi) = bucket_bounds(i);
            assert!(lo <= v && v <= hi, "{v} outside bucket {i} = [{lo}, {hi}]");
            if let Some((pv, pi)) = prev {
                if pv < v {
                    assert!(pi <= i, "bucket index must be monotone in value");
                }
            }
            prev = Some((v, i));
        }
        // Buckets tile the line: bucket i+1 starts right after bucket i.
        for i in 0..BUCKETS - 1 {
            assert_eq!(bucket_bounds(i).1 + 1, bucket_bounds(i + 1).0, "gap after bucket {i}");
        }
        assert_eq!(bucket_bounds(BUCKETS - 1).1, u64::MAX);
    }

    #[test]
    fn quantiles_bound_the_sorted_oracle() {
        // For every q, the histogram quantile must sit in
        // [oracle, bucket_hi(oracle)]: never below the true sample, and
        // within one bucket width above it. Covers empty (→ 0), single
        // sample, and u64::MAX saturating-bucket inputs by construction.
        prop::check_shrunk(
            "histogram quantile vs sorted oracle",
            901,
            96,
            |rng| gen_values(rng, 200),
            |v| shrink_values(v),
            |vals| {
                let h = Histo::new();
                for &v in vals {
                    h.record(v);
                }
                let snap = h.snapshot();
                assert_eq!(snap.count(), vals.len() as u64, "count drifted");
                if vals.is_empty() {
                    assert_eq!(snap.quantile(0.5), 0, "empty snapshot quantile");
                    return;
                }
                let mut sorted = vals.clone();
                sorted.sort_unstable();
                for q in [0.0, 0.5, 0.95, 0.99, 0.999, 1.0] {
                    let want = oracle(&sorted, q);
                    let got = snap.quantile(q);
                    let (_, hi) = bucket_bounds(bucket_of(want));
                    assert!(
                        want <= got && got <= hi,
                        "q={q}: got {got} outside [oracle {want}, bucket hi {hi}]"
                    );
                }
                // Monotone: p50 <= p95 <= p99 <= p999 <= max.
                let qs: Vec<u64> =
                    [0.5, 0.95, 0.99, 0.999].iter().map(|&q| snap.quantile(q)).collect();
                for w in qs.windows(2) {
                    assert!(w[0] <= w[1], "quantiles not monotone: {qs:?}");
                }
                assert!(*qs.last().unwrap() <= snap.max);
                assert_eq!(snap.max, *sorted.last().unwrap());
            },
        );
    }

    #[test]
    fn merge_is_associative_and_counts_add() {
        prop::check_shrunk(
            "snapshot merge associativity",
            902,
            64,
            |rng| {
                (0..3)
                    .map(|_| gen_values(rng, 40))
                    .collect::<Vec<Vec<u64>>>()
            },
            |triple| {
                let mut out = Vec::new();
                for i in 0..triple.len() {
                    if !triple[i].is_empty() {
                        let mut t = triple.clone();
                        t[i] = triple[i][..triple[i].len() / 2].to_vec();
                        out.push(t);
                    }
                }
                out
            },
            |triple| {
                let snaps: Vec<HistoSnapshot> = triple
                    .iter()
                    .map(|vals| {
                        let h = Histo::new();
                        for &v in vals {
                            h.record(v);
                        }
                        h.snapshot()
                    })
                    .collect();
                let (a, b, c) = (&snaps[0], &snaps[1], &snaps[2]);
                // (a ⊕ b) ⊕ c
                let mut left = a.clone();
                left.merge(b);
                left.merge(c);
                // a ⊕ (b ⊕ c)
                let mut bc = b.clone();
                bc.merge(c);
                let mut right = a.clone();
                right.merge(&bc);
                assert_eq!(left, right, "merge grouping changed the result");
                assert_eq!(
                    left.count(),
                    a.count() + b.count() + c.count(),
                    "merged count must be the sum of parts"
                );
                // Commutative too: b ⊕ a == a ⊕ b.
                let mut ab = a.clone();
                ab.merge(b);
                let mut ba = b.clone();
                ba.merge(a);
                assert_eq!(ab, ba, "merge must commute");
            },
        );
    }

    #[test]
    fn delta_inverts_merge_and_stays_a_valid_histogram() {
        // For any sample sequence split at any point: take snapshot `b`
        // after the prefix, `a` after the whole sequence. Then
        // `merge(a.delta(&b), b) == a` bucket-wise, the delta's count is
        // exactly the suffix length, and the delta's quantiles are
        // monotone (it is itself a valid histogram over the suffix).
        prop::check_shrunk(
            "snapshot delta inverts merge",
            904,
            96,
            |rng| {
                let vals = gen_values(rng, 120);
                let split = rng.below(vals.len() + 1);
                (vals, split)
            },
            |(vals, split)| {
                shrink_values(vals)
                    .into_iter()
                    .map(|v| {
                        let s = (*split).min(v.len());
                        (v, s)
                    })
                    .chain((*split > 0).then(|| (vals.clone(), split / 2)))
                    .collect()
            },
            |(vals, split)| {
                let h = Histo::new();
                for &v in &vals[..*split] {
                    h.record(v);
                }
                let b = h.snapshot();
                for &v in &vals[*split..] {
                    h.record(v);
                }
                let a = h.snapshot();
                let d = a.delta(&b);
                assert_eq!(
                    d.count(),
                    (vals.len() - *split) as u64,
                    "delta count must be the suffix length"
                );
                let mut rebuilt = d.clone();
                rebuilt.merge(&b);
                // merge takes max(d.max, b.max) = max(a.max, b.max) =
                // a.max since b preceded a — so full equality holds.
                assert_eq!(rebuilt, a, "merge(delta(a,b), b) != a");
                // The delta is a valid histogram: monotone quantiles,
                // bounded by its (upper-bound) max.
                let qs: Vec<u64> =
                    [0.5, 0.95, 0.99, 0.999].iter().map(|&q| d.quantile(q)).collect();
                for w in qs.windows(2) {
                    assert!(w[0] <= w[1], "delta quantiles not monotone: {qs:?}");
                }
                assert!(*qs.last().unwrap() <= d.max);
            },
        );
    }

    #[test]
    fn single_sample_is_exact_in_small_buckets() {
        let h = Histo::new();
        h.record(5);
        let s = h.snapshot();
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(s.quantile(q), 5, "values below 2^SUB_BITS bin exactly");
        }
        assert_eq!(s.count(), 1);
        assert_eq!(s.mean(), 5.0);
    }

    #[test]
    fn saturating_bucket_clamps_to_observed_max() {
        let h = Histo::new();
        h.record(u64::MAX - 3);
        let s = h.snapshot();
        // The top bucket's hi is u64::MAX; the clamp keeps the estimate
        // at the observed maximum instead.
        assert_eq!(s.quantile(0.999), u64::MAX - 3);
    }
}
