//! Experiment run options: defaults + JSON config files + CLI overrides.
//!
//! Every table harness reads a `configs/<name>.json` (if present), then
//! applies `--key value` CLI overrides, so the full experiment grid is
//! reproducible from checked-in configs.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::util::cli::Args;
use crate::util::json::Json;

/// Options shared by the experiment harnesses.
#[derive(Clone, Debug)]
pub struct RunOpts {
    /// Artifacts directory.
    pub artifacts: String,
    /// Pretraining steps for the base model (cls / dn).
    pub pretrain_steps: usize,
    /// Fine-tuning / training steps per cell.
    pub steps: usize,
    /// Evaluation batches per cell.
    pub eval_batches: usize,
    /// Base learning rate for fine-tuning.
    pub lr: f64,
    /// Pretraining learning rate.
    pub pretrain_lr: f64,
    /// Master seed.
    pub seed: u64,
    /// Worker threads for independent cells (each owns its PJRT client).
    pub workers: usize,
    /// Reuse cached pretrained bases / trained cells under results/cache.
    pub use_cache: bool,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            artifacts: "artifacts".into(),
            pretrain_steps: 400,
            steps: 300,
            eval_batches: 25,
            lr: 1e-3,
            pretrain_lr: 2e-3,
            seed: 17,
            workers: 2,
            use_cache: true,
        }
    }
}

impl RunOpts {
    /// Load `configs/<name>.json` when present, then apply CLI overrides.
    pub fn load(name: &str, args: &Args) -> Result<RunOpts> {
        let mut o = RunOpts::default();
        let path = format!("configs/{name}.json");
        if Path::new(&path).exists() {
            let text = std::fs::read_to_string(&path)?;
            let v = Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
            let get_usize = |k: &str, d: usize| v.get(k).and_then(|x| x.as_usize()).unwrap_or(d);
            let get_f64 = |k: &str, d: f64| v.get(k).and_then(|x| x.as_f64()).unwrap_or(d);
            o.pretrain_steps = get_usize("pretrain_steps", o.pretrain_steps);
            o.steps = get_usize("steps", o.steps);
            o.eval_batches = get_usize("eval_batches", o.eval_batches);
            o.lr = get_f64("lr", o.lr);
            o.pretrain_lr = get_f64("pretrain_lr", o.pretrain_lr);
            o.seed = get_usize("seed", o.seed as usize) as u64;
            o.workers = get_usize("workers", o.workers);
            if let Some(a) = v.get("artifacts").and_then(|x| x.as_str()) {
                o.artifacts = a.to_string();
            }
        }
        o.artifacts = args.opt_or("artifacts", &o.artifacts).to_string();
        o.pretrain_steps = args.opt_usize("pretrain-steps", o.pretrain_steps)?;
        o.steps = args.opt_usize("steps", o.steps)?;
        o.eval_batches = args.opt_usize("eval-batches", o.eval_batches)?;
        o.lr = args.opt_f64("lr", o.lr)?;
        o.pretrain_lr = args.opt_f64("pretrain-lr", o.pretrain_lr)?;
        o.seed = args.opt_u64("seed", o.seed)?;
        o.workers = args.opt_usize("workers", o.workers)?;
        if args.flag("no-cache") {
            o.use_cache = false;
        }
        Ok(o)
    }
}

/// results/cache path helper.
pub fn cache_path(key: &str, ext: &str) -> std::path::PathBuf {
    let dir = Path::new("results/cache");
    let _ = std::fs::create_dir_all(dir);
    dir.join(format!("{key}.{ext}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_overrides() {
        let args = Args::parse(
            ["x", "--steps", "50", "--lr", "0.01", "--no-cache"]
                .iter()
                .map(|s| s.to_string()),
            &["no-cache"],
        );
        let o = RunOpts::load("nonexistent_config", &args).unwrap();
        assert_eq!(o.steps, 50);
        assert_eq!(o.lr, 0.01);
        assert!(!o.use_cache);
        assert_eq!(o.pretrain_steps, RunOpts::default().pretrain_steps);
    }
}
