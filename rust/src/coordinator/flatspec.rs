//! Rust mirror of `python/compile/flat.py`'s `ParamSpec`: named views
//! into the flat f32 buffers the artifacts exchange. The layout is read
//! from each artifact's metadata (`extra.base_spec` / `extra.adapter_spec`),
//! so Rust never hard-codes the Python packing order.

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// Ordered (name, shape) layout of a flat f32 buffer.
#[derive(Clone, Debug, PartialEq)]
pub struct FlatSpec {
    pub entries: Vec<(String, Vec<usize>)>,
}

impl FlatSpec {
    pub fn from_json(v: &Json) -> Result<FlatSpec> {
        let arr = v.as_arr().ok_or_else(|| anyhow!("spec is not an array"))?;
        let mut entries = Vec::with_capacity(arr.len());
        for e in arr {
            let name = e.req_str("name").map_err(|e| anyhow!("{e}"))?.to_string();
            let shape = e
                .req("shape")
                .map_err(|e| anyhow!("{e}"))?
                .usize_vec()
                .ok_or_else(|| anyhow!("bad shape"))?;
            entries.push((name, shape));
        }
        Ok(FlatSpec { entries })
    }

    /// Inverse of [`FlatSpec::from_json`] — the schema the artifacts'
    /// metadata and the adapter store's `GSAD` headers share.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.entries
                .iter()
                .map(|(n, s)| {
                    Json::obj(vec![
                        ("name", Json::Str(n.clone())),
                        ("shape", Json::arr_usize(s)),
                    ])
                })
                .collect(),
        )
    }

    pub fn size(&self) -> usize {
        self.entries
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }

    /// Byte-offset table entry for `name`: (offset, shape).
    pub fn locate(&self, name: &str) -> Result<(usize, &[usize])> {
        let mut off = 0;
        for (n, s) in &self.entries {
            let len: usize = s.iter().product();
            if n == name {
                return Ok((off, s));
            }
            off += len;
        }
        Err(anyhow!("flat spec has no entry '{name}'"))
    }

    /// Immutable view of one named parameter.
    pub fn view<'a>(&self, flat: &'a [f32], name: &str) -> Result<&'a [f32]> {
        let (off, shape) = self.locate(name)?;
        let len: usize = shape.iter().product();
        anyhow::ensure!(flat.len() == self.size(), "flat buffer size mismatch");
        Ok(&flat[off..off + len])
    }

    /// Mutable view of one named parameter.
    pub fn view_mut<'a>(&self, flat: &'a mut [f32], name: &str) -> Result<&'a mut [f32]> {
        anyhow::ensure!(flat.len() == self.size(), "flat buffer size mismatch");
        let (off, shape) = self.locate(name)?;
        let len: usize = shape.iter().product();
        Ok(&mut flat[off..off + len])
    }

    /// Names with a given suffix (e.g. all `.gs_l` adapter blocks).
    pub fn names_with_suffix(&self, suffix: &str) -> Vec<String> {
        self.entries
            .iter()
            .filter(|(n, _)| n.ends_with(suffix))
            .map(|(n, _)| n.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> FlatSpec {
        FlatSpec::from_json(
            &Json::parse(
                r#"[{"name":"a","shape":[2,2]},{"name":"b","shape":[3]},
                    {"name":"l.gs_l","shape":[2,1,1]}]"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn json_round_trips() {
        let s = spec();
        assert_eq!(FlatSpec::from_json(&s.to_json()).unwrap(), s);
    }

    #[test]
    fn parses_and_sizes() {
        let s = spec();
        assert_eq!(s.size(), 4 + 3 + 2);
        assert_eq!(s.locate("b").unwrap().0, 4);
        assert!(s.locate("zz").is_err());
    }

    #[test]
    fn views() {
        let s = spec();
        let mut flat: Vec<f32> = (0..9).map(|x| x as f32).collect();
        assert_eq!(s.view(&flat, "b").unwrap(), &[4.0, 5.0, 6.0]);
        s.view_mut(&mut flat, "a").unwrap()[0] = 99.0;
        assert_eq!(flat[0], 99.0);
        assert_eq!(s.names_with_suffix(".gs_l"), vec!["l.gs_l".to_string()]);
    }

    #[test]
    fn size_mismatch_rejected() {
        let s = spec();
        assert!(s.view(&[0.0; 3], "a").is_err());
    }
}
