//! The generic training driver: owns the Adam state, the step loop and
//! the metric log; every experiment family (cls / dn / lip) plugs in a
//! batch generator and an artifact pair.
//!
//! The hot loop is pure Rust + PJRT: `train_step` artifacts have the
//! uniform signature
//! `(trainable, adam_m, adam_v, step, lr, frozen, *batch) ->
//!  (trainable', adam_m', adam_v', loss)`.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::{Executable, Tensor};
use crate::util::rng::Rng;

use super::schedule::LrSchedule;

/// Mutable optimizer state carried across steps.
#[derive(Clone, Debug)]
pub struct TrainState {
    pub trainable: Vec<f32>,
    pub adam_m: Vec<f32>,
    pub adam_v: Vec<f32>,
    pub step: usize,
}

impl TrainState {
    pub fn new(trainable: Vec<f32>) -> TrainState {
        let n = trainable.len();
        TrainState {
            trainable,
            adam_m: vec![0.0; n],
            adam_v: vec![0.0; n],
            step: 0,
        }
    }
}

/// Outcome of a training run.
#[derive(Clone, Debug)]
pub struct RunLog {
    pub losses: Vec<f32>,
    pub seconds: f64,
    pub steps: usize,
}

impl RunLog {
    pub fn final_loss(&self) -> f32 {
        self.losses.last().copied().unwrap_or(f32::NAN)
    }

    /// Mean of the last `k` losses (smoother than the last point).
    pub fn tail_loss(&self, k: usize) -> f32 {
        let n = self.losses.len();
        if n == 0 {
            return f32::NAN;
        }
        let k = k.min(n);
        self.losses[n - k..].iter().sum::<f32>() / k as f32
    }

    pub fn steps_per_second(&self) -> f64 {
        self.steps as f64 / self.seconds.max(1e-9)
    }
}

/// Training driver over one train artifact.
pub struct Trainer {
    pub exe: Arc<Executable>,
    pub frozen: Vec<f32>,
}

impl Trainer {
    pub fn new(exe: Arc<Executable>, frozen: Vec<f32>) -> Trainer {
        Trainer { exe, frozen }
    }

    /// Run `steps` optimizer steps. `batch_fn(step, rng)` produces the
    /// family-specific batch tensors appended after the uniform prefix.
    pub fn run(
        &self,
        state: &mut TrainState,
        steps: usize,
        schedule: LrSchedule,
        rng: &mut Rng,
        mut batch_fn: impl FnMut(usize, &mut Rng) -> Vec<Tensor>,
    ) -> Result<RunLog> {
        let n = state.trainable.len();
        let frozen_shape = self.exe.meta.inputs[5].shape.clone();
        let t0 = Instant::now();
        let mut losses = Vec::with_capacity(steps);
        for local in 0..steps {
            let lr = schedule.at(state.step) as f32;
            let mut inputs = vec![
                Tensor::f32(vec![n], std::mem::take(&mut state.trainable)),
                Tensor::f32(vec![n], std::mem::take(&mut state.adam_m)),
                Tensor::f32(vec![n], std::mem::take(&mut state.adam_v)),
                Tensor::scalar_f32(state.step as f32),
                Tensor::scalar_f32(lr),
                Tensor::f32(frozen_shape.clone(), self.frozen.clone()),
            ];
            inputs.extend(batch_fn(local, rng));
            let mut out = self.exe.run(&inputs)?;
            let loss = out[3].scalar()?;
            anyhow::ensure!(
                loss.is_finite(),
                "non-finite loss at step {} of {}",
                state.step,
                self.exe.meta.name
            );
            state.adam_v = std::mem::replace(&mut out[2], Tensor::zeros_f32(vec![0]))
                .into_f32()?;
            state.adam_m = std::mem::replace(&mut out[1], Tensor::zeros_f32(vec![0]))
                .into_f32()?;
            state.trainable = std::mem::replace(&mut out[0], Tensor::zeros_f32(vec![0]))
                .into_f32()?;
            state.step += 1;
            losses.push(loss);
        }
        Ok(RunLog {
            losses,
            seconds: t0.elapsed().as_secs_f64(),
            steps,
        })
    }
}

/// Evaluation driver: sums each output scalar over batches.
pub struct Evaluator {
    pub exe: Arc<Executable>,
    pub frozen: Vec<f32>,
}

impl Evaluator {
    pub fn new(exe: Arc<Executable>, frozen: Vec<f32>) -> Evaluator {
        Evaluator { exe, frozen }
    }

    /// Run `batches` eval batches; returns per-output sums (loss summed,
    /// counts summed) in artifact output order, skipping output 0's mean
    /// semantics — callers divide as appropriate.
    pub fn run(
        &self,
        trainable: &[f32],
        batches: usize,
        rng: &mut Rng,
        mut batch_fn: impl FnMut(usize, &mut Rng) -> Vec<Tensor>,
    ) -> Result<Vec<f64>> {
        let frozen_shape = self.exe.meta.inputs[1].shape.clone();
        let mut sums = vec![0.0f64; self.exe.meta.outputs.len()];
        for b in 0..batches {
            let mut inputs = vec![
                Tensor::f32(vec![trainable.len()], trainable.to_vec()),
                Tensor::f32(frozen_shape.clone(), self.frozen.clone()),
            ];
            inputs.extend(batch_fn(b, rng));
            let out = self.exe.run(&inputs)?;
            for (s, t) in sums.iter_mut().zip(out.iter()) {
                *s += t.scalar()? as f64;
            }
        }
        Ok(sums)
    }

    /// Per-example predictions are not exposed by the eval artifacts (they
    /// return sums); for metric computations that need predictions (MCC /
    /// Pearson) the caller uses batch size 1 labels trick — see table1.
    pub fn outputs(&self) -> usize {
        self.exe.meta.outputs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_log_stats() {
        let log = RunLog {
            losses: vec![4.0, 3.0, 2.0, 1.0],
            seconds: 2.0,
            steps: 4,
        };
        assert_eq!(log.final_loss(), 1.0);
        assert_eq!(log.tail_loss(2), 1.5);
        assert_eq!(log.tail_loss(100), 2.5);
        assert_eq!(log.steps_per_second(), 2.0);
    }

    #[test]
    fn train_state_init() {
        let s = TrainState::new(vec![1.0, 2.0]);
        assert_eq!(s.adam_m, vec![0.0, 0.0]);
        assert_eq!(s.step, 0);
    }
}
