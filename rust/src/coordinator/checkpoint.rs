//! Checkpoints: flat buffers + optimizer state + step, with a JSON header
//! and raw little-endian f32 payloads (a tiny self-describing container —
//! no external serialization crates offline).
//!
//! Layout: `GSCK` magic, u32 header length, JSON header
//! `{"step":…, "sections": [{"name":…, "len":…}, …]}`, then the f32
//! sections back to back.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

const MAGIC: &[u8; 4] = b"GSCK";

/// A named collection of f32 buffers plus a step counter.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub step: usize,
    pub sections: Vec<(String, Vec<f32>)>,
}

impl Checkpoint {
    pub fn get(&self, name: &str) -> Result<&[f32]> {
        self.sections
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_slice())
            .ok_or_else(|| anyhow!("checkpoint has no section '{name}'"))
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let header = Json::obj(vec![
            ("step", Json::Num(self.step as f64)),
            (
                "sections",
                Json::Arr(
                    self.sections
                        .iter()
                        .map(|(n, v)| {
                            Json::obj(vec![
                                ("name", Json::Str(n.clone())),
                                ("len", Json::Num(v.len() as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
        .to_string();
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u32).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for (_, v) in &self.sections {
            for x in v {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let path = path.as_ref();
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "bad checkpoint magic");
        let mut len = [0u8; 4];
        f.read_exact(&mut len)?;
        let hlen = u32::from_le_bytes(len) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = Json::parse(std::str::from_utf8(&hbuf)?)
            .map_err(|e| anyhow!("checkpoint header: {e}"))?;
        let step = header.req_usize("step").map_err(|e| anyhow!("{e}"))?;
        let mut sections = Vec::new();
        for s in header
            .req("sections")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("sections not an array"))?
        {
            let name = s.req_str("name").map_err(|e| anyhow!("{e}"))?.to_string();
            let n = s.req_usize("len").map_err(|e| anyhow!("{e}"))?;
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            let data = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            sections.push((name, data));
        }
        Ok(Checkpoint { step, sections })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let ck = Checkpoint {
            step: 123,
            sections: vec![
                ("trainable".into(), vec![1.0, -2.5, 3.25]),
                ("adam_m".into(), vec![0.0; 5]),
            ],
        };
        let path = std::env::temp_dir().join("gsoft_ck_test.gsck");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        assert_eq!(back.get("trainable").unwrap()[1], -2.5);
        assert!(back.get("missing").is_err());
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("gsoft_ck_garbage.gsck");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }
}
