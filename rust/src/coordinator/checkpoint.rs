//! Checkpoints: flat buffers + optimizer state + step, with a JSON header
//! and raw little-endian f32 payloads. The framing (magic + header +
//! payload sections) is the shared [`crate::util::container`]
//! implementation — the adapter store's `GSAD` files use the same one
//! with a different schema.
//!
//! Layout: `GSCK` magic, u32 header length, JSON header
//! `{"step":…, "sections": [{"name":…, "len":…}, …]}`, then the f32
//! sections back to back (no per-section CRC — byte-compatible with
//! checkpoints written before the framing was extracted).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::container::{self, Container};
use crate::util::json::Json;

const MAGIC: &[u8; 4] = b"GSCK";

/// A named collection of f32 buffers plus a step counter.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub step: usize,
    pub sections: Vec<(String, Vec<f32>)>,
}

impl Checkpoint {
    pub fn get(&self, name: &str) -> Result<&[f32]> {
        self.sections
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_slice())
            .ok_or_else(|| anyhow!("checkpoint has no section '{name}'"))
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        // Streamed, clone-free: checkpoints hold several model-sized
        // buffers, so buffering a fully encoded copy would transiently
        // multiply their memory.
        let sections: Vec<(&str, &[f32])> = self
            .sections
            .iter()
            .map(|(n, v)| (n.as_str(), v.as_slice()))
            .collect();
        container::write_file(
            path,
            MAGIC,
            vec![("step", Json::Num(self.step as f64))],
            &sections,
            false,
        )
    }

    /// Load a checkpoint. Truncated files, absurd header lengths, and
    /// section lengths that disagree with the actual file size all return
    /// a clean `Err` (validated by the container layer before any payload
    /// allocation) — a corrupt checkpoint must never panic or OOM the
    /// trainer that tries to resume from it.
    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let c = Container::load(path.as_ref(), MAGIC)
            .with_context(|| format!("loading checkpoint {}", path.as_ref().display()))?;
        let step = c.meta_usize("step")?;
        Ok(Checkpoint {
            step,
            sections: c.sections,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::unique_temp_dir;

    fn sample() -> Checkpoint {
        Checkpoint {
            step: 123,
            sections: vec![
                ("trainable".into(), vec![1.0, -2.5, 3.25]),
                ("adam_m".into(), vec![0.0; 5]),
            ],
        }
    }

    #[test]
    fn round_trip() {
        let dir = unique_temp_dir("ck");
        let path = dir.join("ck.gsck");
        let ck = sample();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        assert_eq!(back.get("trainable").unwrap()[1], -2.5);
        assert!(back.get("missing").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_layout_is_unchanged() {
        // The container refactor must keep the bytes identical to what the
        // original hand-rolled writer produced: GSCK, u32 header len, the
        // {"sections":[...],"step":N} header (BTreeMap key order), payload.
        let dir = unique_temp_dir("ck_legacy");
        let path = dir.join("ck.gsck");
        sample().save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..4], b"GSCK");
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        let header = std::str::from_utf8(&bytes[8..8 + hlen]).unwrap();
        assert_eq!(
            header,
            r#"{"sections":[{"len":3,"name":"trainable"},{"len":5,"name":"adam_m"}],"step":123}"#
        );
        assert_eq!(bytes.len(), 8 + hlen + 4 * (3 + 5));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_garbage() {
        let dir = unique_temp_dir("ck_garbage");
        let path = dir.join("bad.gsck");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_file_is_a_clean_error_at_every_cut() {
        // Regression for the old loader, which trusted the header's
        // declared lengths: a truncated section ended in read_exact Err,
        // but an absurd header length allocated first. Now every strict
        // prefix must fail cleanly.
        let dir = unique_temp_dir("ck_trunc");
        let full_path = dir.join("full.gsck");
        sample().save(&full_path).unwrap();
        let bytes = std::fs::read(&full_path).unwrap();
        let cut_path = dir.join("cut.gsck");
        for cut in 0..bytes.len() {
            std::fs::write(&cut_path, &bytes[..cut]).unwrap();
            assert!(
                Checkpoint::load(&cut_path).is_err(),
                "prefix of {cut}/{} bytes loaded",
                bytes.len()
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn absurd_header_length_is_a_clean_error() {
        // 4 GiB declared header in a 12-byte file: must not try to
        // allocate or read 4 GiB.
        let dir = unique_temp_dir("ck_hdr");
        let path = dir.join("absurd.gsck");
        let mut bytes = b"GSCK".to_vec();
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        bytes.extend_from_slice(b"{}{}");
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn section_length_beyond_file_size_is_a_clean_error() {
        // Corrupt the header in place: bump a declared section length so
        // it exceeds the payload actually present.
        let dir = unique_temp_dir("ck_len");
        let path = dir.join("len.gsck");
        sample().save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        let header = std::str::from_utf8(&bytes[8..8 + hlen]).unwrap();
        let corrupt_header = header.replace("\"len\":3", "\"len\":3000000");
        let mut corrupt = b"GSCK".to_vec();
        corrupt.extend_from_slice(&(corrupt_header.len() as u32).to_le_bytes());
        corrupt.extend_from_slice(corrupt_header.as_bytes());
        corrupt.extend_from_slice(&bytes[8 + hlen..]);
        std::fs::write(&path, &corrupt).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
