//! Learning-rate schedules for the training driver.

/// LR as a function of the 0-based step.
#[derive(Clone, Copy, Debug)]
pub enum LrSchedule {
    Const(f64),
    /// Linear warmup to `base` over `warmup` steps, cosine decay to
    /// `base * floor_frac` at `total`.
    WarmupCosine {
        base: f64,
        warmup: usize,
        total: usize,
        floor_frac: f64,
    },
}

impl LrSchedule {
    pub fn at(&self, step: usize) -> f64 {
        match *self {
            LrSchedule::Const(lr) => lr,
            LrSchedule::WarmupCosine {
                base,
                warmup,
                total,
                floor_frac,
            } => {
                if warmup > 0 && step < warmup {
                    return base * (step + 1) as f64 / warmup as f64;
                }
                let total = total.max(warmup + 1);
                let t = ((step - warmup) as f64 / (total - warmup) as f64).min(1.0);
                let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
                base * (floor_frac + (1.0 - floor_frac) * cos)
            }
        }
    }

    /// The standard fine-tuning schedule used by the table harnesses.
    pub fn finetune(base: f64, total: usize) -> LrSchedule {
        LrSchedule::WarmupCosine {
            base,
            warmup: (total / 10).max(1),
            total,
            floor_frac: 0.1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_schedule() {
        let s = LrSchedule::Const(1e-3);
        assert_eq!(s.at(0), 1e-3);
        assert_eq!(s.at(1000), 1e-3);
    }

    #[test]
    fn warmup_cosine_shape() {
        let s = LrSchedule::WarmupCosine {
            base: 1.0,
            warmup: 10,
            total: 100,
            floor_frac: 0.1,
        };
        assert!(s.at(0) < s.at(5));
        assert!((s.at(9) - 1.0).abs() < 0.11);
        assert!(s.at(50) < 1.0);
        assert!((s.at(99) - 0.1).abs() < 0.01, "{}", s.at(99));
        assert!((s.at(500) - 0.1).abs() < 1e-9, "clamped past total");
        // monotone decreasing after warmup
        for k in 10..99 {
            assert!(s.at(k) >= s.at(k + 1) - 1e-12);
        }
    }
}
