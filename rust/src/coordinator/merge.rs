//! Adapter merging (§6.1: "weights of the matrix Q can be merged with the
//! pretrained weight W producing no inference overhead").
//!
//! Given a fine-tuned GSOFT (or OFT / LoRA / Double GSOFT) adapter flat
//! buffer and the frozen base buffer, produce a *merged* base buffer whose
//! plain forward pass (the `ft` eval artifact) reproduces the adapted
//! model exactly. The GS algebra runs through [`crate::gs`] — the exact
//! f64 reference implementation.

use anyhow::{anyhow, Result};

use crate::gs::blockdiag::BlockDiag;
use crate::gs::{perm_kn, GsMatrix, GsSpec};
use crate::kernel::conv::{GroupedConv, GsSocLayer};
use crate::linalg::{cayley_unconstrained, Mat};

use super::flatspec::FlatSpec;

use crate::adapter::{merge_entry, AdapterDesc};

/// Thin constructor enum over the built-in adapter-family tags — kept for
/// CLI ergonomics and back-compat with the pre-trait API. All real
/// dispatch happens through [`crate::adapter::AdapterFamily`] via
/// [`AdapterKind::desc`]; families added at runtime (e.g.
/// [`crate::adapter::monarch`]) have no variant here and are constructed
/// as [`AdapterDesc`]s directly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdapterKind {
    /// GSOFT (§6.1): `W' = Q W` with `Q = P^T L P R` (two Cayley
    /// block-diagonal factors of block size `block`).
    Gsoft { block: usize },
    /// OFT: `W' = Q W` with a single Cayley block-diagonal `Q`.
    Oft { block: usize },
    /// LoRA: `W' = W + A B`.
    Lora,
    /// GS-SOC orthogonal convolution (§6.3): `W' = Q W` with
    /// `Q = P⁻¹ · exp(grouped skew conv) · P` acting on activations viewed
    /// as `[c, h, w]` tensors (`d = c·h·w`). The adapter slab per layer is
    /// the raw grouped kernel `[c, c/groups, k, k]`; skew-symmetrization
    /// and the `P_(groups, c)` channel shuffles are applied at build time,
    /// so `Q` is orthogonal by construction (up to the `terms`-term series
    /// truncation).
    ConvGsSoc {
        c: usize,
        k: usize,
        groups: usize,
        h: usize,
        w: usize,
        terms: usize,
    },
}

impl AdapterKind {
    /// Resolve this constructor into its family descriptor — the value
    /// every dispatching layer (registry, engine, store) actually
    /// carries.
    pub fn desc(&self) -> AdapterDesc {
        let built = match *self {
            AdapterKind::Gsoft { block } => AdapterDesc::new("gsoft", &[("block", block)]),
            AdapterKind::Oft { block } => AdapterDesc::new("oft", &[("block", block)]),
            AdapterKind::Lora => AdapterDesc::new("lora", &[]),
            AdapterKind::ConvGsSoc {
                c,
                k,
                groups,
                h,
                w,
                terms,
            } => AdapterDesc::new(
                "conv_gssoc",
                &[
                    ("c", c),
                    ("k", k),
                    ("groups", groups),
                    ("h", h),
                    ("w", w),
                    ("terms", terms),
                ],
            ),
        };
        built.expect("built-in adapter families are always registered")
    }

    pub fn name(&self) -> &'static str {
        self.desc().tag()
    }

    /// Orthogonal adapters preserve the singular values of every adapted
    /// layer; LoRA does not.
    pub fn is_orthogonal(&self) -> bool {
        self.desc().is_orthogonal()
    }
}

/// Merge any supported adapter kind into a copy of the base buffer —
/// back-compat front for [`crate::adapter::merge_entry`] (which is the
/// open-family entry point the registry and engine use).
pub fn merge_adapter(
    kind: AdapterKind,
    base: &[f32],
    adapter: &[f32],
    base_spec: &FlatSpec,
    adapter_spec: &FlatSpec,
) -> Result<Vec<f32>> {
    merge_entry(&kind.desc(), base, adapter, base_spec, adapter_spec)
}

/// Cayley blocks from a flat `(r, b, b)` parameter slab.
fn cayley_blocks(raw: &[f32], r: usize, b: usize) -> BlockDiag {
    assert_eq!(raw.len(), r * b * b);
    let blocks = (0..r)
        .map(|i| {
            let a = Mat::from_f32(b, b, &raw[i * b * b..(i + 1) * b * b]);
            cayley_unconstrained(&a)
        })
        .collect();
    BlockDiag::new(blocks)
}

/// Build the orthogonal GSOFT `Q` (d×d) from the two flat slabs.
pub fn gsoft_q(l_raw: &[f32], r_raw: &[f32], d: usize, b: usize) -> GsMatrix {
    let r = d / b;
    let spec = GsSpec::gsoft(d, b);
    GsMatrix::new(
        spec,
        cayley_blocks(l_raw, r, b),
        cayley_blocks(r_raw, r, b),
    )
}

/// Merge a GSOFT adapter into the base weights of the `cls` transformer.
///
/// For every adapted linear `W (din×dout)` the fine-tuned model computes
/// `x @ (Q W)`; merging stores `W' = Q W` back into the base buffer.
pub fn merge_gsoft(
    base: &[f32],
    adapter: &[f32],
    base_spec: &FlatSpec,
    adapter_spec: &FlatSpec,
    block: usize,
) -> Result<Vec<f32>> {
    let mut merged = base.to_vec();
    for lname in adapter_spec.names_with_suffix(".gs_l") {
        let layer = lname
            .strip_suffix(".gs_l")
            .ok_or_else(|| anyhow!("bad adapter name {lname}"))?;
        let l_raw = adapter_spec.view(adapter, &lname)?;
        let r_raw = adapter_spec.view(adapter, &format!("{layer}.gs_r"))?;
        let (_, wshape) = base_spec.locate(layer)?;
        anyhow::ensure!(wshape.len() == 2, "adapted entry {layer} is not a matrix");
        let (din, dout) = (wshape[0], wshape[1]);
        let q = gsoft_q(l_raw, r_raw, din, block);
        let w = Mat::from_f32(din, dout, base_spec.view(base, layer)?);
        let wq = q.apply(&w); // Q @ W via the structured path
        base_spec
            .view_mut(&mut merged, layer)?
            .copy_from_slice(&wq.to_f32());
    }
    Ok(merged)
}

/// Build the OFT orthogonal `Q` (block-diagonal, d×d) from its flat slab.
pub fn oft_q(k_raw: &[f32], d: usize, b: usize) -> BlockDiag {
    cayley_blocks(k_raw, d / b, b)
}

/// Merge an OFT adapter (block-diagonal Q).
pub fn merge_oft(
    base: &[f32],
    adapter: &[f32],
    base_spec: &FlatSpec,
    adapter_spec: &FlatSpec,
    block: usize,
) -> Result<Vec<f32>> {
    let mut merged = base.to_vec();
    for kname in adapter_spec.names_with_suffix(".oft_k") {
        let layer = kname.strip_suffix(".oft_k").unwrap();
        let k_raw = adapter_spec.view(adapter, &kname)?;
        let (_, wshape) = base_spec.locate(layer)?;
        let (din, dout) = (wshape[0], wshape[1]);
        let q = oft_q(k_raw, din, block);
        let w = Mat::from_f32(din, dout, base_spec.view(base, layer)?);
        let wq = q.matmul_right(&w);
        base_spec
            .view_mut(&mut merged, layer)?
            .copy_from_slice(&wq.to_f32());
    }
    Ok(merged)
}

/// Build the orthogonal GS-SOC conv operator for one layer from its raw
/// grouped-kernel slab: `Q = P⁻¹ · exp(L) · P` with
/// `L = M - ConvTranspose(M)` (skew ⇒ orthogonal exponential) and
/// `P = P_(groups, c)` — applied by the direct convolution runtime, never
/// materialized.
pub fn conv_gssoc_layer(
    raw: &[f32],
    c: usize,
    k: usize,
    groups: usize,
    h: usize,
    w: usize,
    terms: usize,
) -> GsSocLayer {
    let kern = GroupedConv::from_f32(c, c, k, groups, raw).skew_symmetrize();
    let p = perm_kn(groups, c);
    GsSocLayer::new(p.clone(), kern, p.inverse(), h, w, terms)
}

/// Merge a GS-SOC conv adapter: `W' = Q W`, computed column-streamed
/// through the direct conv runtime (`Q` applied to the `dout` columns of
/// `W` as a batch) — the dense `(c·h·w)²` operator is never built.
#[allow(clippy::too_many_arguments)]
pub fn merge_conv_gssoc(
    base: &[f32],
    adapter: &[f32],
    base_spec: &FlatSpec,
    adapter_spec: &FlatSpec,
    c: usize,
    k: usize,
    groups: usize,
    h: usize,
    w: usize,
    terms: usize,
) -> Result<Vec<f32>> {
    let mut merged = base.to_vec();
    for sname in adapter_spec.names_with_suffix(".soc_k") {
        let layer = sname.strip_suffix(".soc_k").unwrap();
        let raw = adapter_spec.view(adapter, &sname)?;
        let (_, wshape) = base_spec.locate(layer)?;
        anyhow::ensure!(wshape.len() == 2, "adapted entry {layer} is not a matrix");
        let (din, dout) = (wshape[0], wshape[1]);
        anyhow::ensure!(
            din == c * h * w,
            "conv_gssoc adapts '{layer}' of input dim {din}, but c·h·w = {}·{}·{} = {}",
            c,
            h,
            w,
            c * h * w
        );
        let q = conv_gssoc_layer(raw, c, k, groups, h, w, terms);
        let wmat = Mat::from_f32(din, dout, base_spec.view(base, layer)?);
        let wq = q.apply(&wmat, crate::kernel::ctx());
        base_spec
            .view_mut(&mut merged, layer)?
            .copy_from_slice(&wq.to_f32());
    }
    Ok(merged)
}

/// Merge a LoRA adapter: `W' = W + A B`.
pub fn merge_lora(
    base: &[f32],
    adapter: &[f32],
    base_spec: &FlatSpec,
    adapter_spec: &FlatSpec,
) -> Result<Vec<f32>> {
    let mut merged = base.to_vec();
    for aname in adapter_spec.names_with_suffix(".lora_a") {
        let layer = aname.strip_suffix(".lora_a").unwrap();
        let (_, ashape) = adapter_spec.locate(&aname)?;
        let (din, rank) = (ashape[0], ashape[1]);
        let a = Mat::from_f32(din, rank, adapter_spec.view(adapter, &aname)?);
        let bname = format!("{layer}.lora_b");
        let (_, bshape) = adapter_spec.locate(&bname)?;
        let bmat = Mat::from_f32(bshape[0], bshape[1], adapter_spec.view(adapter, &bname)?);
        let (_, wshape) = base_spec.locate(layer)?;
        let w = Mat::from_f32(wshape[0], wshape[1], base_spec.view(base, layer)?);
        let merged_w = &w + &a.matmul(&bmat);
        base_spec
            .view_mut(&mut merged, layer)?
            .copy_from_slice(&merged_w.to_f32());
    }
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn mini_specs() -> (FlatSpec, FlatSpec) {
        let base = FlatSpec::from_json(
            &Json::parse(r#"[{"name":"l0.wq","shape":[8,6]},{"name":"head","shape":[6,2]}]"#)
                .unwrap(),
        )
        .unwrap();
        let adapter = FlatSpec::from_json(
            &Json::parse(
                r#"[{"name":"l0.wq.gs_l","shape":[4,2,2]},
                    {"name":"l0.wq.gs_r","shape":[4,2,2]}]"#,
            )
            .unwrap(),
        )
        .unwrap();
        (base, adapter)
    }

    #[test]
    fn identity_adapter_is_noop() {
        let (bs, asp) = mini_specs();
        let mut rng = Rng::new(1);
        let base: Vec<f32> = (0..bs.size()).map(|_| rng.normal_f32(1.0)).collect();
        let adapter = vec![0.0f32; asp.size()];
        let merged = merge_gsoft(&base, &adapter, &bs, &asp, 2).unwrap();
        for (a, b) in merged.iter().zip(base.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn merged_weight_matches_explicit_q_w() {
        let (bs, asp) = mini_specs();
        let mut rng = Rng::new(2);
        let base: Vec<f32> = (0..bs.size()).map(|_| rng.normal_f32(1.0)).collect();
        let adapter: Vec<f32> = (0..asp.size()).map(|_| rng.normal_f32(0.5)).collect();
        let merged = merge_gsoft(&base, &adapter, &bs, &asp, 2).unwrap();
        // Explicit: Q dense times W.
        let q = gsoft_q(
            asp.view(&adapter, "l0.wq.gs_l").unwrap(),
            asp.view(&adapter, "l0.wq.gs_r").unwrap(),
            8,
            2,
        )
        .to_dense();
        let w = Mat::from_f32(8, 6, bs.view(&base, "l0.wq").unwrap());
        let expect = q.matmul(&w).to_f32();
        let got = bs.view(&merged, "l0.wq").unwrap();
        for (a, b) in got.iter().zip(expect.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
        // Non-adapted entries untouched.
        assert_eq!(
            bs.view(&merged, "head").unwrap(),
            bs.view(&base, "head").unwrap()
        );
        // Orthogonality: singular values of W preserved.
        let s0 = crate::linalg::singular_values(&w);
        let s1 = crate::linalg::singular_values(&Mat::from_f32(
            8,
            6,
            bs.view(&merged, "l0.wq").unwrap(),
        ));
        for (a, b) in s0.iter().zip(s1.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn gsoft_q_is_orthogonal_for_any_params() {
        // Property: for any flat adapter slab, the GSOFT Q built from
        // Cayley blocks satisfies ‖QᵀQ − I‖_F ≈ 0 (§4) — so merging can
        // never distort the spectrum of the base layer. Shrinking drives
        // any counterexample toward the zero (identity) adapter.
        prop::check_shrunk(
            "gsoft_q orthogonal",
            101,
            32,
            |rng| {
                let b = [2usize, 3, 4][rng.below(3)];
                let r = [2usize, 3, 4][rng.below(3)];
                let d = b * r;
                let params = rng.normal_vec(2 * r * b * b, 1.0);
                (d, b, params)
            },
            |(d, b, params)| {
                prop::shrink_vec_f32(params)
                    .into_iter()
                    .map(|p| (*d, *b, p))
                    .collect()
            },
            |(d, b, params)| {
                let half = params.len() / 2;
                let q = gsoft_q(&params[..half], &params[half..], *d, *b).to_dense();
                assert!(
                    q.is_orthogonal(1e-8),
                    "‖QᵀQ−I‖={} for d={d} b={b}",
                    q.orthogonality_error()
                );
            },
        );
    }

    #[test]
    fn merge_gsoft_preserves_orthogonality_invariants() {
        // Property: merged layer W' = Q W has the same singular values as
        // W, and for square orthogonal W the merged layer stays orthogonal.
        prop::check_named("merge_gsoft preserves spectrum", 102, 16, |rng| {
            let (bs, asp) = mini_specs();
            let base: Vec<f32> = (0..bs.size()).map(|_| rng.normal_f32(1.0)).collect();
            let adapter: Vec<f32> = (0..asp.size()).map(|_| rng.normal_f32(0.7)).collect();
            let merged =
                merge_adapter(AdapterKind::Gsoft { block: 2 }, &base, &adapter, &bs, &asp)
                    .unwrap();
            let w0 = Mat::from_f32(8, 6, bs.view(&base, "l0.wq").unwrap());
            let w1 = Mat::from_f32(8, 6, bs.view(&merged, "l0.wq").unwrap());
            let s0 = crate::linalg::singular_values(&w0);
            let s1 = crate::linalg::singular_values(&w1);
            for (a, b) in s0.iter().zip(s1.iter()) {
                assert!((a - b).abs() < 1e-4, "singular value drift: {a} vs {b}");
            }
        });
    }

    #[test]
    fn merge_oft_preserves_orthogonality_invariants() {
        let bs = FlatSpec::from_json(
            &Json::parse(r#"[{"name":"l0.wq","shape":[8,8]}]"#).unwrap(),
        )
        .unwrap();
        let asp = FlatSpec::from_json(
            &Json::parse(r#"[{"name":"l0.wq.oft_k","shape":[4,2,2]}]"#).unwrap(),
        )
        .unwrap();
        prop::check_named("merge_oft preserves spectrum", 103, 16, |rng| {
            // Start from an orthogonal base layer: W' = Q W must remain
            // orthogonal since Q is (Cayley blocks are exactly orthogonal).
            let w = Mat::rand_orthogonal(8, rng);
            let base = w.to_f32();
            let adapter: Vec<f32> = (0..asp.size()).map(|_| rng.normal_f32(1.0)).collect();
            let q = oft_q(asp.view(&adapter, "l0.wq.oft_k").unwrap(), 8, 2);
            assert!(q.to_mat().is_orthogonal(1e-8));
            let merged =
                merge_adapter(AdapterKind::Oft { block: 2 }, &base, &adapter, &bs, &asp)
                    .unwrap();
            let w1 = Mat::from_f32(8, 8, &merged);
            assert!(
                w1.is_orthogonal(1e-4),
                "merged orthogonal base drifted: ‖WᵀW−I‖={}",
                w1.orthogonality_error()
            );
        });
    }

    #[test]
    fn repeated_merge_is_bit_identical() {
        // The serving cache depends on merges being pure functions of
        // (base, adapter): a cache-hit must be indistinguishable from a
        // recomputed cold merge, bit for bit.
        prop::check_named("merge is deterministic", 104, 8, |rng| {
            let (bs, asp) = mini_specs();
            let base: Vec<f32> = (0..bs.size()).map(|_| rng.normal_f32(1.0)).collect();
            let adapter: Vec<f32> = (0..asp.size()).map(|_| rng.normal_f32(0.5)).collect();
            let kind = AdapterKind::Gsoft { block: 2 };
            let cold = merge_adapter(kind, &base, &adapter, &bs, &asp).unwrap();
            let again = merge_adapter(kind, &base, &adapter, &bs, &asp).unwrap();
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
            assert_eq!(bits(&cold), bits(&again), "merge must be bit-deterministic");
        });
    }

    #[test]
    fn adapter_kind_dispatch_matches_direct_calls() {
        let (bs, asp) = mini_specs();
        let mut rng = Rng::new(11);
        let base: Vec<f32> = (0..bs.size()).map(|_| rng.normal_f32(1.0)).collect();
        let adapter: Vec<f32> = (0..asp.size()).map(|_| rng.normal_f32(0.5)).collect();
        let via_kind =
            merge_adapter(AdapterKind::Gsoft { block: 2 }, &base, &adapter, &bs, &asp).unwrap();
        let direct = merge_gsoft(&base, &adapter, &bs, &asp, 2).unwrap();
        assert_eq!(via_kind, direct);
        assert!(AdapterKind::Gsoft { block: 2 }.is_orthogonal());
        assert!(!AdapterKind::Lora.is_orthogonal());
        assert_eq!(AdapterKind::Lora.name(), "lora");
    }

    #[test]
    fn conv_gssoc_merge_preserves_spectrum() {
        let (c, k, groups, h, w) = (4usize, 3usize, 2usize, 2usize, 3usize);
        let d = c * h * w;
        let kind = AdapterKind::ConvGsSoc {
            c,
            k,
            groups,
            h,
            w,
            terms: 14,
        };
        let bs = FlatSpec {
            entries: vec![("l0.wq".to_string(), vec![d, 5])],
        };
        let asp = FlatSpec {
            entries: vec![("l0.wq.soc_k".to_string(), vec![c, c / groups, k, k])],
        };
        assert!(kind.is_orthogonal());
        assert_eq!(kind.name(), "conv_gssoc");
        prop::check_named("conv_gssoc merge preserves spectrum", 105, 8, |rng| {
            let base: Vec<f32> = (0..bs.size()).map(|_| rng.normal_f32(1.0)).collect();
            // Small kernel magnitude keeps the truncated exponential
            // converged, so Q is orthogonal to well below test tolerance.
            let adapter: Vec<f32> = (0..asp.size()).map(|_| rng.normal_f32(0.05)).collect();
            let merged = merge_adapter(kind, &base, &adapter, &bs, &asp).unwrap();
            let w0 = Mat::from_f32(d, 5, bs.view(&base, "l0.wq").unwrap());
            let w1 = Mat::from_f32(d, 5, bs.view(&merged, "l0.wq").unwrap());
            let s0 = crate::linalg::singular_values(&w0);
            let s1 = crate::linalg::singular_values(&w1);
            for (a, b) in s0.iter().zip(s1.iter()) {
                assert!((a - b).abs() < 1e-3, "singular value drift: {a} vs {b}");
            }
        });
    }

    #[test]
    fn conv_gssoc_zero_adapter_is_exact_identity() {
        // exp(0) = I and the two shuffles cancel (P⁻¹·I·P = I), so the
        // zero slab must be a bitwise no-op like the other kinds' zero
        // initializations.
        let (c, k, groups, h, w) = (4usize, 3usize, 2usize, 3usize, 3usize);
        let d = c * h * w;
        let bs = FlatSpec {
            entries: vec![("l0.wq".to_string(), vec![d, d])],
        };
        let asp = FlatSpec {
            entries: vec![("l0.wq.soc_k".to_string(), vec![c, c / groups, k, k])],
        };
        let mut rng = Rng::new(17);
        let base: Vec<f32> = (0..bs.size()).map(|_| rng.normal_f32(1.0)).collect();
        let adapter = vec![0.0f32; asp.size()];
        let merged = merge_conv_gssoc(&base, &adapter, &bs, &asp, c, k, groups, h, w, 8).unwrap();
        for (a, b) in merged.iter().zip(base.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn lora_merge_adds_low_rank() {
        let bs = FlatSpec::from_json(
            &Json::parse(r#"[{"name":"l0.wq","shape":[4,4]}]"#).unwrap(),
        )
        .unwrap();
        let asp = FlatSpec::from_json(
            &Json::parse(
                r#"[{"name":"l0.wq.lora_a","shape":[4,2]},
                    {"name":"l0.wq.lora_b","shape":[2,4]}]"#,
            )
            .unwrap(),
        )
        .unwrap();
        let mut rng = Rng::new(3);
        let base: Vec<f32> = (0..16).map(|_| rng.normal_f32(1.0)).collect();
        let mut adapter: Vec<f32> = (0..16).map(|_| rng.normal_f32(1.0)).collect();
        let merged = merge_lora(&base, &adapter, &bs, &asp).unwrap();
        assert!(merged.iter().zip(base.iter()).any(|(a, b)| (a - b).abs() > 1e-4));
        // zero B ⇒ no-op
        for v in asp.view_mut(&mut adapter, "l0.wq.lora_b").unwrap() {
            *v = 0.0;
        }
        let merged0 = merge_lora(&base, &adapter, &bs, &asp).unwrap();
        assert_eq!(merged0, base);
    }
}
