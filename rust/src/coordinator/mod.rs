//! L3 coordinator — the fine-tuning orchestration framework: config
//! system, training/eval drivers, adapter merging, checkpoints, and the
//! experiment harnesses that regenerate every table and figure of the
//! paper (see `DESIGN.md` §3 for the experiment index).

pub mod checkpoint;
pub mod config;
pub mod experiments;
pub mod flatspec;
pub mod merge;
pub mod schedule;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use config::RunOpts;
pub use flatspec::FlatSpec;
pub use schedule::LrSchedule;
pub use trainer::{Evaluator, RunLog, Trainer, TrainState};
