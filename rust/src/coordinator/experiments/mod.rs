//! Experiment harnesses — one module per paper table/figure family.

pub mod statics;
pub mod table1;
pub mod table2;
pub mod table3;

use anyhow::Result;

use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::config::{cache_path, RunOpts};
use crate::coordinator::schedule::LrSchedule;
use crate::coordinator::trainer::{Trainer, TrainState};
use crate::data::synglue;
use crate::runtime::{Runtime, Tensor};
use crate::util::rng::Rng;

/// Pretrain (or load from cache) the `cls`-family base transformer on the
/// SynGLUE task mixture via the full-fine-tune artifact. Returns the
/// pretrained flat base buffer.
pub fn pretrained_cls_base(rt: &Runtime, tag: &str, opts: &RunOpts) -> Result<Vec<f32>> {
    let key = format!(
        "{tag}_pretrained_s{}_lr{}_seed{}",
        opts.pretrain_steps, opts.pretrain_lr, opts.seed
    );
    let ck_path = cache_path(&key, "gsck");
    if opts.use_cache && ck_path.exists() {
        let ck = Checkpoint::load(&ck_path)?;
        return Ok(ck.get("base")?.to_vec());
    }
    let exe = rt.load(&format!("{tag}_ft_train"))?;
    let vocab = exe.meta.extra_usize("vocab")?;
    let seq = exe.meta.extra_usize("seq")?;
    let batch = exe.meta.extra_usize("batch")?;
    let init = rt.load_init(&format!("{tag}_base"))?;
    let trainer = Trainer::new(exe, vec![0.0]); // ft: frozen is a dummy
    let mut state = TrainState::new(init);
    let mut rng = Rng::new(opts.seed ^ 0xBA5E);
    let sched = LrSchedule::finetune(opts.pretrain_lr, opts.pretrain_steps);
    let log = trainer.run(&mut state, opts.pretrain_steps, sched, &mut rng, |_, r| {
        let (xs, ys) = synglue::pretrain_batch(vocab, seq, batch, r);
        vec![
            Tensor::i32(vec![batch, seq], xs),
            Tensor::i32(vec![batch], ys),
        ]
    })?;
    println!(
        "[pretrain:{tag}] {} steps, loss {:.3} -> {:.3} ({:.1} steps/s)",
        opts.pretrain_steps,
        log.losses.first().copied().unwrap_or(f32::NAN),
        log.tail_loss(20),
        log.steps_per_second()
    );
    let ck = Checkpoint {
        step: state.step,
        sections: vec![("base".into(), state.trainable.clone())],
    };
    ck.save(&ck_path)?;
    Ok(state.trainable)
}
