//! Table 1 — SynGLUE fine-tuning: FT / LoRA / OFT / BOFT / GSOFT /
//! Double GSOFT on the eight synthetic tasks (accuracy; Matthews for
//! CoLA*; Pearson for STS-B*), plus trainable-parameter counts.

use anyhow::Result;

use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::config::{cache_path, RunOpts};
use crate::coordinator::schedule::LrSchedule;
use crate::coordinator::trainer::{Trainer, TrainState};
use crate::data::synglue::{self, Task, ALL_TASKS};
use crate::report::{fmt, fmt_params, Table};
use crate::runtime::{Runtime, Tensor};
use crate::util::pool::parallel_map;
use crate::util::rng::Rng;

pub const METHODS: [&str; 6] = ["ft", "lora", "oft", "boft", "gsoft", "double_gsoft"];

/// Per-method learning-rate multiplier: the paper tunes LR per method;
/// multiplicative-orthogonal methods prefer larger steps than additive
/// ones at identity init.
fn lr_mult(method: &str) -> f64 {
    match method {
        "ft" => 0.3,
        "lora" => 1.0,
        _ => 3.0,
    }
}

/// Metric for one (method, task) cell, in percent (or correlation×100).
#[derive(Clone, Debug)]
pub struct Cell {
    pub method: String,
    pub task: Task,
    pub metric: f64,
    pub params: usize,
}

/// Fine-tune + evaluate one (method, task) cell. The Runtime is shared
/// across all tasks of one method (compiled executables are reused;
/// PJRT clients are not Sync, so sharing stays within one worker).
fn run_cell(rt: &Runtime, method: &str, task: Task, base: &[f32], opts: &RunOpts) -> Result<Cell> {
    let key = format!(
        "table1_{method}_{}_s{}_p{}_lr{}_seed{}",
        task.name().trim_end_matches('*'),
        opts.steps,
        opts.pretrain_steps,
        opts.lr,
        opts.seed
    );
    let jpath = cache_path(&key, "json");
    if opts.use_cache && jpath.exists() {
        if let Ok(v) = crate::util::json::Json::parse(&std::fs::read_to_string(&jpath)?) {
            if let (Some(metric), Some(params)) = (
                v.get("metric").and_then(|x| x.as_f64()),
                v.get("params").and_then(|x| x.as_usize()),
            ) {
                return Ok(Cell {
                    method: method.into(),
                    task,
                    metric,
                    params,
                });
            }
        }
    }

    let train = rt.load(&format!("cls_{method}_train"))?;
    let eval = rt.load(&format!("cls_{method}_eval"))?;
    let vocab = train.meta.extra_usize("vocab")?;
    let seq = train.meta.extra_usize("seq")?;
    let batch = train.meta.extra_usize("batch")?;
    let gen = synglue::TaskGen::new(task, vocab, seq);

    // Trainable/frozen wiring per method.
    let (init, frozen, params): (Vec<f32>, Vec<f32>, usize) = if method == "ft" {
        (base.to_vec(), vec![0.0], base.len())
    } else {
        let adapter = rt.load_init(&format!("cls_{method}_adapter"))?;
        let n = adapter.len();
        (adapter, base.to_vec(), n)
    };

    let mut rng = Rng::new(opts.seed ^ (task.id() as u64) << 8 ^ hash_method(method));
    let trainer = Trainer::new(train, frozen.clone());
    let mut state = TrainState::new(init);
    let sched = LrSchedule::finetune(opts.lr * lr_mult(method), opts.steps);
    trainer.run(&mut state, opts.steps, sched, &mut rng, |_, r| {
        let (xs, ys) = gen.batch(batch, r);
        vec![
            Tensor::i32(vec![batch, seq], xs),
            Tensor::i32(vec![batch], ys),
        ]
    })?;

    // Evaluation with per-example predictions (for MCC / Pearson).
    let mut eval_rng = Rng::new(0xEEAA ^ task.id() as u64); // shared across methods
    let mut preds = Vec::new();
    let mut labels = Vec::new();
    let n = state.trainable.len();
    for _ in 0..opts.eval_batches {
        let (xs, ys) = gen.batch(batch, &mut eval_rng);
        let out = eval.run(&[
            Tensor::f32(vec![n], state.trainable.clone()),
            Tensor::f32(vec![frozen.len()], frozen.clone()),
            Tensor::i32(vec![batch, seq], xs),
            Tensor::i32(vec![batch], ys.clone()),
        ])?;
        preds.extend_from_slice(out[2].as_i32()?);
        labels.extend_from_slice(&ys);
    }
    let metric = match task.metric() {
        "matthews" => synglue::matthews(&preds, &labels) * 100.0,
        "pearson" => synglue::pearson(&preds, &labels) * 100.0,
        _ => {
            let correct = preds
                .iter()
                .zip(labels.iter())
                .filter(|(p, l)| p == l)
                .count();
            correct as f64 / labels.len() as f64 * 100.0
        }
    };
    let cell = Cell {
        method: method.into(),
        task,
        metric,
        params,
    };
    let _ = std::fs::write(
        &jpath,
        crate::util::json::Json::obj(vec![
            ("metric", crate::util::json::Json::Num(metric)),
            ("params", crate::util::json::Json::Num(params as f64)),
        ])
        .to_string(),
    );
    Ok(cell)
}

fn hash_method(m: &str) -> u64 {
    m.bytes().fold(0u64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64))
}

/// Run the full Table-1 grid and render it.
pub fn run(opts: &RunOpts) -> Result<Table> {
    let rt = Runtime::new(&opts.artifacts)?;
    let base = super::pretrained_cls_base(&rt, "cls", opts)?;
    drop(rt); // workers create their own clients

    // One worker per *method*: each owns a Runtime and runs its 8 tasks
    // sequentially, so compiled executables are reused across tasks.
    let results: Vec<Vec<Result<Cell, String>>> =
        parallel_map(METHODS.len(), opts.workers, |m| {
            let rt = match Runtime::new(&opts.artifacts) {
                Ok(rt) => rt,
                Err(e) => return vec![Err(format!("{e:#}")); ALL_TASKS.len()],
            };
            ALL_TASKS
                .iter()
                .map(|&t| {
                    run_cell(&rt, METHODS[m], t, &base, opts).map_err(|e| format!("{e:#}"))
                })
                .collect()
        });
    let results: Vec<Result<Cell, String>> = results.into_iter().flatten().collect();

    let mut table = Table::new(
        "Table 1 — SynGLUE (GLUE stand-in) with the pretrained cls transformer",
        &[
            "Method", "# Params", "MNLI*", "SST-2*", "CoLA*", "QQP*", "QNLI*", "RTE*",
            "MRPC*", "STS-B*", "ALL",
        ],
    );
    for (mi, method) in METHODS.iter().enumerate() {
        let mut row = vec![String::new(); 11];
        let mut sum = 0.0;
        let mut params = 0usize;
        for (ti, task) in ALL_TASKS.iter().enumerate() {
            let cell = results[mi * ALL_TASKS.len() + ti]
                .as_ref()
                .map_err(|e| anyhow::anyhow!("cell {method}/{}: {e}", task.name()))?;
            // Column order in the header matches ALL_TASKS order.
            row[2 + ti] = fmt(cell.metric, 2);
            sum += cell.metric;
            params = cell.params;
        }
        row[0] = pretty_method(method);
        row[1] = fmt_params(params);
        row[10] = fmt(sum / ALL_TASKS.len() as f64, 2);
        table.row(row);
    }
    Ok(table)
}

fn pretty_method(m: &str) -> String {
    match m {
        "ft" => "FT".into(),
        "lora" => "LoRA(r=8)".into(),
        "oft" => "OFT(b=16)".into(),
        "boft" => "BOFT(b=8,m=2)".into(),
        "gsoft" => "GSOFT(b=8)".into(),
        "double_gsoft" => "DoubleGSOFT(b=8)".into(),
        other => other.into(),
    }
}

/// Loss-curve helper for the quickstart / e2e drivers: fine-tune one task
/// with one method and return the loss log plus final accuracy.
pub fn finetune_once(
    rt: &Runtime,
    tag: &str,
    method: &str,
    task: Task,
    base: &[f32],
    opts: &RunOpts,
) -> Result<(crate::coordinator::trainer::RunLog, f64, TrainState, Vec<f32>)> {
    let train = rt.load(&format!("{tag}_{method}_train"))?;
    let eval = rt.load(&format!("{tag}_{method}_eval"))?;
    let vocab = train.meta.extra_usize("vocab")?;
    let seq = train.meta.extra_usize("seq")?;
    let batch = train.meta.extra_usize("batch")?;
    let gen = synglue::TaskGen::new(task, vocab, seq);
    let (init, frozen) = if method == "ft" {
        (base.to_vec(), vec![0.0])
    } else {
        (
            rt.load_init(&format!("{tag}_{method}_adapter"))?,
            base.to_vec(),
        )
    };
    let trainer = Trainer::new(train, frozen.clone());
    let mut state = TrainState::new(init);
    let mut rng = Rng::new(opts.seed);
    let sched = LrSchedule::finetune(opts.lr * lr_mult(method), opts.steps);
    let log = trainer.run(&mut state, opts.steps, sched, &mut rng, |_, r| {
        let (xs, ys) = gen.batch(batch, r);
        vec![
            Tensor::i32(vec![batch, seq], xs),
            Tensor::i32(vec![batch], ys),
        ]
    })?;
    let mut eval_rng = Rng::new(0xEEAA ^ task.id() as u64);
    let mut correct = 0usize;
    let mut total = 0usize;
    let n = state.trainable.len();
    for _ in 0..opts.eval_batches {
        let (xs, ys) = gen.batch(batch, &mut eval_rng);
        let out = eval.run(&[
            Tensor::f32(vec![n], state.trainable.clone()),
            Tensor::f32(vec![frozen.len()], frozen.clone()),
            Tensor::i32(vec![batch, seq], xs),
            Tensor::i32(vec![batch], ys.clone()),
        ])?;
        correct += out[1].scalar()? as usize;
        total += batch;
    }
    let acc = correct as f64 / total as f64 * 100.0;
    Ok((log, acc, state, frozen))
}

/// Persist a fine-tuned cell as a checkpoint (used by examples).
pub fn save_state(key: &str, state: &TrainState) -> Result<()> {
    Checkpoint {
        step: state.step,
        sections: vec![
            ("trainable".into(), state.trainable.clone()),
            ("adam_m".into(), state.adam_m.clone()),
            ("adam_v".into(), state.adam_v.clone()),
        ],
    }
    .save(cache_path(key, "gsck"))
}
