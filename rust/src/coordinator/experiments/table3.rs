//! Tables 3–4 — 1-Lipschitz LipConvnet with SOC vs GS-SOC orthogonal
//! convolutions on the synthetic vision task: parameters, measured
//! per-step speedup over SOC, accuracy and certified robust accuracy,
//! with the activation × ChShuffle-permutation ablation of Table 4.

use anyhow::Result;

use crate::coordinator::config::{cache_path, RunOpts};
use crate::coordinator::schedule::LrSchedule;
use crate::coordinator::trainer::{Trainer, TrainState};
use crate::data::vision::{self, CH, IMG, PIX};
use crate::report::{fmt, fmt_params, Table};
use crate::runtime::{Runtime, Tensor};
use crate::util::json::Json;
use crate::util::pool::parallel_map;
use crate::util::rng::Rng;

/// All 17 Table-4 variants (SOC + 4 group structures × 2 acts × 2 perms).
pub fn all_variants() -> Vec<String> {
    let mut v = vec!["soc".to_string()];
    for gb in [0, 1, 2, 4] {
        for act in ["mmp", "mm"] {
            for perm in ["p", "u"] {
                v.push(format!("g4_{gb}_{act}_{perm}"));
            }
        }
    }
    v
}

/// The Table-3 subset: SOC + the best activation/permutation combo
/// (MaxMinPermuted + paired, per the paper).
pub fn table3_variants() -> Vec<String> {
    let mut v = vec!["soc".to_string()];
    for gb in [0, 1, 2, 4] {
        v.push(format!("g4_{gb}_mmp_p"));
    }
    v
}

#[derive(Clone, Debug)]
pub struct LipCell {
    pub variant: String,
    pub params: usize,
    pub step_seconds: f64,
    pub accuracy: f64,
    pub robust_accuracy: f64,
}

fn run_variant(variant: &str, opts: &RunOpts) -> Result<LipCell> {
    let key = format!(
        "table3_{variant}_s{}_lr{}_seed{}",
        opts.steps, opts.lr, opts.seed
    );
    let jpath = cache_path(&key, "json");
    if opts.use_cache && jpath.exists() {
        if let Ok(v) = Json::parse(&std::fs::read_to_string(&jpath)?) {
            if let (Some(params), Some(sec), Some(acc), Some(racc)) = (
                v.get("params").and_then(|x| x.as_usize()),
                v.get("step_seconds").and_then(|x| x.as_f64()),
                v.get("accuracy").and_then(|x| x.as_f64()),
                v.get("robust_accuracy").and_then(|x| x.as_f64()),
            ) {
                return Ok(LipCell {
                    variant: variant.into(),
                    params,
                    step_seconds: sec,
                    accuracy: acc,
                    robust_accuracy: racc,
                });
            }
        }
    }

    let rt = Runtime::new(&opts.artifacts)?;
    let train = rt.load(&format!("lip_{variant}_train"))?;
    let eval = rt.load(&format!("lip_{variant}_eval"))?;
    let batch = train.meta.extra_usize("batch")?;
    let init = rt.load_init(&format!("lip_{variant}"))?;
    let params = init.len();

    let trainer = Trainer::new(train, vec![0.0]);
    let mut state = TrainState::new(init);
    let mut rng = Rng::new(opts.seed ^ 0x11AA);
    let sched = LrSchedule::finetune(opts.lr, opts.steps);
    let log = trainer.run(&mut state, opts.steps, sched, &mut rng, |_, r| {
        let (xs, ys) = vision::batch(batch, r);
        vec![
            Tensor::f32(vec![batch, IMG, IMG, CH], xs),
            Tensor::i32(vec![batch], ys),
        ]
    })?;

    // Evaluation on the fixed held-out set.
    let n = state.trainable.len();
    let (test_x, test_y) = vision::test_set(opts.eval_batches * batch);
    let mut correct = 0.0;
    let mut robust = 0.0;
    for b in 0..opts.eval_batches {
        let xs = test_x[b * batch * PIX..(b + 1) * batch * PIX].to_vec();
        let ys = test_y[b * batch..(b + 1) * batch].to_vec();
        let out = eval.run(&[
            Tensor::f32(vec![n], state.trainable.clone()),
            Tensor::f32(vec![1], vec![0.0]),
            Tensor::f32(vec![batch, IMG, IMG, CH], xs),
            Tensor::i32(vec![batch], ys),
        ])?;
        correct += out[1].scalar()? as f64;
        robust += out[2].scalar()? as f64;
    }
    let total = (opts.eval_batches * batch) as f64;
    let cell = LipCell {
        variant: variant.into(),
        params,
        step_seconds: log.seconds / log.steps as f64,
        accuracy: correct / total * 100.0,
        robust_accuracy: robust / total * 100.0,
    };
    let _ = std::fs::write(
        &jpath,
        Json::obj(vec![
            ("params", Json::Num(cell.params as f64)),
            ("step_seconds", Json::Num(cell.step_seconds)),
            ("accuracy", Json::Num(cell.accuracy)),
            ("robust_accuracy", Json::Num(cell.robust_accuracy)),
        ])
        .to_string(),
    );
    Ok(cell)
}

/// Run a list of variants (parallel across workers).
pub fn run_variants(variants: &[String], opts: &RunOpts) -> Result<Vec<LipCell>> {
    let results = parallel_map(variants.len(), opts.workers, |i| {
        run_variant(&variants[i], opts).map_err(|e| format!("{e:#}"))
    });
    results
        .into_iter()
        .enumerate()
        .map(|(i, r)| r.map_err(|e| anyhow::anyhow!("variant {}: {e}", variants[i])))
        .collect()
}

fn describe(variant: &str) -> (String, String, String, String) {
    // (conv layer, groups, activation, permutation)
    if variant == "soc" {
        return ("SOC".into(), "-".into(), "MaxMin".into(), "-".into());
    }
    let parts: Vec<&str> = variant.split('_').collect(); // g4 gb act perm
    let gb = parts[1];
    let groups = if gb == "0" {
        "(4, -)".to_string()
    } else {
        format!("(4, {gb})")
    };
    let act = if parts[2] == "mmp" {
        "MaxMinPermuted"
    } else {
        "MaxMin"
    };
    let perm = if parts[3] == "p" { "paired" } else { "not paired" };
    ("GS-SOC".into(), groups, act.into(), perm.into())
}

fn render(title: &str, cells: &[LipCell], with_perm: bool) -> Table {
    let soc_time = cells
        .iter()
        .find(|c| c.variant == "soc")
        .map(|c| c.step_seconds)
        .unwrap_or(1.0);
    let mut headers = vec!["Conv. Layer", "# Params", "Groups", "Speedup", "Activation"];
    if with_perm {
        headers.push("Permutation");
    }
    headers.extend_from_slice(&["Accuracy", "Robust Accuracy"]);
    let mut table = Table::new(title, &headers);
    for c in cells {
        let (conv, groups, act, perm) = describe(&c.variant);
        let mut row = vec![
            conv,
            fmt_params(c.params),
            groups,
            fmt(soc_time / c.step_seconds, 2),
            act,
        ];
        if with_perm {
            row.push(perm);
        }
        row.push(format!("{}%", fmt(c.accuracy, 2)));
        row.push(format!("{}%", fmt(c.robust_accuracy, 2)));
        table.row(row);
    }
    table
}

/// Render an arbitrary subset (used by `--variants` when the full 17-cell
/// ablation exceeds the compute budget of the testbed).
pub fn render_partial(title: &str, cells: &[LipCell], with_perm: bool) -> Table {
    render(title, cells, with_perm)
}

/// Table 3: SOC + GS-SOC (best act/perm).
pub fn run_table3(opts: &RunOpts) -> Result<Table> {
    let cells = run_variants(&table3_variants(), opts)?;
    Ok(render(
        "Table 3 — LipConvnet-8 (CIFAR-100 stand-in): SOC vs GS-SOC",
        &cells,
        false,
    ))
}

/// Table 4: the full activation × permutation ablation.
pub fn run_table4(opts: &RunOpts) -> Result<Table> {
    let cells = run_variants(&all_variants(), opts)?;
    Ok(render(
        "Table 4 — activation × ChShuffle-permutation ablation",
        &cells,
        true,
    ))
}
