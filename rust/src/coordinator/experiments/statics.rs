//! Static (analysis-only) experiments: the §5.2 parameter table, the
//! Theorem-2 density sweep (Figure 5), and the Figure-3 permutation
//! pictures — no PJRT required.

use anyhow::Result;

use crate::gs::density::{
    butterfly_min_factors, chain_support, empirical_min_factors, gs_min_factors, PermFamily,
};
use crate::gs::params::{dense_cost_comparison, Method};
use crate::gs::perm::perm_kn;
use crate::report::{fmt, fmt_params, Table};

/// §5.2 — factors + parameters needed for a dense d×d orthogonal matrix.
pub fn params_table() -> Table {
    let mut t = Table::new(
        "§5.2 — cost of a dense d×d orthogonal matrix (BOFT vs GSOFT)",
        &[
            "d", "b", "r", "BOFT m", "BOFT params", "GS m", "GS params", "param ratio",
        ],
    );
    for (d, b) in [
        (256usize, 8usize),
        (256, 16),
        (768, 8),
        (768, 16),
        (1024, 8),
        (1024, 32),
        (1024, 64),
        (4096, 32),
        (4096, 64),
    ] {
        let ((m_bf, p_bf), (m_gs, p_gs)) = dense_cost_comparison(d, b);
        t.row(vec![
            d.to_string(),
            b.to_string(),
            (d / b).to_string(),
            m_bf.to_string(),
            fmt_params(p_bf),
            m_gs.to_string(),
            fmt_params(p_gs),
            fmt(p_bf as f64 / p_gs as f64, 2),
        ]);
    }
    t
}

/// Table-1-style parameter budgets for the cls geometry (sanity view).
pub fn budget_table(d: usize) -> Table {
    let mut t = Table::new(
        &format!("Adapter parameter budgets on a {d}x{d} layer"),
        &["Method", "Params", "Storable (upper-tri)"],
    );
    for m in [
        Method::Full,
        Method::LoRa { rank: 8 },
        Method::Oft { block: 16 },
        Method::Boft { block: 8, m: 2 },
        Method::Gsoft { block: 8, m: 2 },
        Method::DoubleGsoft { block: 8, m: 2 },
    ] {
        t.row(vec![
            m.name(),
            fmt_params(m.param_count(d)),
            fmt_params(m.storage_count(d)),
        ]);
    }
    t
}

/// Figure 5 / Theorem 2 — empirical density sweep: fill fraction of the
/// product support vs number of factors, GS vs butterfly vs identity.
pub fn density_table(d: usize, b: usize) -> Result<Table> {
    anyhow::ensure!(d % b == 0, "b must divide d");
    let r = d / b;
    let mut t = Table::new(
        &format!("Theorem 2 — support fill vs m (d={d}, b={b}, r={r})"),
        &["m", "GS P_(k,n) fill", "Butterfly fill", "Identity fill"],
    );
    let max_m = butterfly_min_factors(r).max(gs_min_factors(b, r)) + 1;
    for m in 1..=max_m {
        t.row(vec![
            m.to_string(),
            fmt(chain_support(d, b, m, PermFamily::GsKn).fill(), 4),
            fmt(chain_support(d, b, m, PermFamily::Butterfly).fill(), 4),
            fmt(chain_support(d, b, m, PermFamily::Identity).fill(), 4),
        ]);
    }
    let gs_m = empirical_min_factors(d, b, PermFamily::GsKn, max_m + 2);
    let bf_m = empirical_min_factors(d, b, PermFamily::Butterfly, max_m + 2);
    println!(
        "Theorem 2 check: GS dense at m={:?} (formula {}), butterfly at m={:?} (formula {})",
        gs_m,
        gs_min_factors(b, r),
        bf_m,
        butterfly_min_factors(r)
    );
    Ok(t)
}

/// Figure 3 — print the `P_(k,12)` permutation matrices.
pub fn perms_figure() -> String {
    let mut out = String::from("Figure 3 — P_(k,12) permutation matrices (rows = outputs):\n");
    for k in [3usize, 4, 6, 2] {
        let p = perm_kn(k, 12);
        out.push_str(&format!("\nP_({k},12):  sigma = {:?}\n", p.sigma));
        let m = p.to_mat();
        for i in 0..12 {
            out.push_str("  ");
            for j in 0..12 {
                out.push(if m[(i, j)] > 0.5 { '#' } else { '.' });
            }
            out.push('\n');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_table_has_the_worked_example() {
        let t = params_table();
        let row = t
            .rows
            .iter()
            .find(|r| r[0] == "1024" && r[1] == "32")
            .expect("worked example present");
        assert_eq!(row[3], "6"); // BOFT factors
        assert_eq!(row[5], "2"); // GS factors
        assert_eq!(row[7], "3.00"); // 6·32³ / 2·32³
    }

    #[test]
    fn density_table_runs() {
        let t = density_table(64, 4).unwrap();
        assert!(t.rows.len() >= 4);
        // last GS row must be fully dense
        let dense_row = t
            .rows
            .iter()
            .find(|r| r[1] == "1.0000")
            .expect("GS reaches density");
        let m: usize = dense_row[0].parse().unwrap();
        assert_eq!(m, gs_min_factors(4, 16));
    }

    #[test]
    fn perms_figure_renders() {
        let s = perms_figure();
        assert!(s.contains("P_(3,12)"));
        assert_eq!(s.matches('#').count(), 4 * 12);
    }
}
