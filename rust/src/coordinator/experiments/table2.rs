//! Table 2 + Figure 6 — subject-driven generation stand-in.
//!
//! Pretrain the tiny conditional denoiser on the context classes, then
//! fine-tune on a few-shot concept under each PEFT method. Metrics
//! (frozen random-projection encoder as the CLIP stand-in):
//!
//! * **Concept-I** (CLIP-I analogue): mean feature similarity between
//!   samples generated with the concept condition and the true concept
//!   examples — higher = better fidelity.
//! * **Concept-T** (CLIP-T analogue): mean similarity between samples
//!   generated with *context* conditions after fine-tuning and the same
//!   conditions' true class templates — higher = the model still follows
//!   its "prompt" rather than collapsing onto the concept (overfitting).
//!
//! Training wall-clock per method reproduces the Table-2 time column.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::config::{cache_path, RunOpts};
use crate::coordinator::schedule::LrSchedule;
use crate::coordinator::trainer::{Trainer, TrainState};
use crate::data::concept::{self, Encoder, CONCEPT_COND, DIM, NUM_CONTEXTS};
use crate::report::{fmt, fmt_params, Table};
use crate::runtime::{Executable, Runtime, Tensor};
use crate::util::json::Json;
use crate::util::pool::parallel_map;
use crate::util::rng::Rng;

pub const METHODS: [&str; 7] =
    ["ft", "lora4", "lora32", "boft8m4", "gsoft8", "gsoft16", "dgsoft8"];

/// Measurements for one method at one checkpoint.
#[derive(Clone, Debug)]
pub struct DnCell {
    pub method: String,
    pub params: usize,
    pub seconds: f64,
    pub steps: usize,
    pub concept_i: f64,
    pub concept_t: f64,
}

/// DDIM (eta = 0) reverse process around the `predict` artifact.
pub struct Sampler {
    exe: Arc<Executable>,
    abar: Vec<f64>,
    batch: usize,
    dim: usize,
}

impl Sampler {
    pub fn new(exe: Arc<Executable>) -> Result<Sampler> {
        let abar = exe.meta.extra_f64_vec("alphas_bar")?;
        let batch = exe.meta.extra_usize("batch")?;
        let dim = exe.meta.extra_usize("dim")?;
        Ok(Sampler {
            exe,
            abar,
            batch,
            dim,
        })
    }

    /// Generate one batch conditioned on `conds` starting from seeded noise.
    pub fn sample(
        &self,
        trainable: &[f32],
        frozen: &[f32],
        conds: &[i32],
        rng: &mut Rng,
    ) -> Result<Vec<Vec<f32>>> {
        assert_eq!(conds.len(), self.batch);
        let tsteps = self.abar.len();
        let mut x: Vec<f32> = (0..self.batch * self.dim)
            .map(|_| rng.normal_f32(1.0))
            .collect();
        for t in (0..tsteps).rev() {
            let out = self.exe.run(&[
                Tensor::f32(vec![trainable.len()], trainable.to_vec()),
                Tensor::f32(vec![frozen.len()], frozen.to_vec()),
                Tensor::f32(vec![self.batch, self.dim], x.clone()),
                Tensor::i32(vec![self.batch], vec![t as i32; self.batch]),
                Tensor::i32(vec![self.batch], conds.to_vec()),
            ])?;
            let eps = out[0].as_f32()?;
            let a_t = self.abar[t] as f32;
            let a_prev = if t == 0 { 1.0 } else { self.abar[t - 1] as f32 };
            for i in 0..x.len() {
                let x0 = (x[i] - (1.0 - a_t).sqrt() * eps[i]) / a_t.sqrt();
                x[i] = a_prev.sqrt() * x0 + (1.0 - a_prev).sqrt() * eps[i];
            }
        }
        Ok((0..self.batch)
            .map(|i| x[i * self.dim..(i + 1) * self.dim].to_vec())
            .collect())
    }
}

/// Pretrain (or load) the denoiser base on the context classes.
pub fn pretrained_dn_base(rt: &Runtime, opts: &RunOpts) -> Result<Vec<f32>> {
    let key = format!(
        "dn_pretrained_s{}_lr{}_seed{}",
        opts.pretrain_steps, opts.pretrain_lr, opts.seed
    );
    let ck_path = cache_path(&key, "gsck");
    if opts.use_cache && ck_path.exists() {
        return Ok(Checkpoint::load(&ck_path)?.get("base")?.to_vec());
    }
    let exe = rt.load("dn_ft_train")?;
    let batch = exe.meta.extra_usize("batch")?;
    let tsteps = exe.meta.extra_usize("tsteps")?;
    let init = rt.load_init("dn_base")?;
    let trainer = Trainer::new(exe, vec![0.0]);
    let mut state = TrainState::new(init);
    let mut rng = Rng::new(opts.seed ^ 0xD1FF);
    let sched = LrSchedule::finetune(opts.pretrain_lr, opts.pretrain_steps);
    let log = trainer.run(&mut state, opts.pretrain_steps, sched, &mut rng, |_, r| {
        dn_batch_inputs(batch, tsteps, r, |rr| concept::pretrain_batch(batch, rr))
    })?;
    println!(
        "[pretrain:dn] {} steps, loss {:.4} -> {:.4}",
        opts.pretrain_steps,
        log.losses.first().copied().unwrap_or(f32::NAN),
        log.tail_loss(20)
    );
    Checkpoint {
        step: state.step,
        sections: vec![("base".into(), state.trainable.clone())],
    }
    .save(&ck_path)?;
    Ok(state.trainable)
}

/// Assemble the 4 batch tensors of a dn train step from an (x0, cond)
/// generator: adds the uniform t and eps draws.
fn dn_batch_inputs(
    batch: usize,
    tsteps: usize,
    rng: &mut Rng,
    mut gen: impl FnMut(&mut Rng) -> (Vec<f32>, Vec<i32>),
) -> Vec<Tensor> {
    let (x0, cond) = gen(rng);
    let t: Vec<i32> = (0..batch).map(|_| rng.below(tsteps) as i32).collect();
    let eps: Vec<f32> = (0..batch * DIM).map(|_| rng.normal_f32(1.0)).collect();
    vec![
        Tensor::f32(vec![batch, DIM], x0),
        Tensor::i32(vec![batch], cond),
        Tensor::i32(vec![batch], t),
        Tensor::f32(vec![batch, DIM], eps),
    ]
}

/// Fine-tune one method on the concept and measure at the given
/// checkpoints (in steps). Returns one `DnCell` per checkpoint.
fn run_method(method: &str, base: &[f32], checkpoints: &[usize], opts: &RunOpts) -> Result<Vec<DnCell>> {
    let key = format!(
        "table2_{method}_s{}_p{}_lr{}_seed{}_ck{:?}",
        opts.steps, opts.pretrain_steps, opts.lr, opts.seed, checkpoints
    );
    let jpath = cache_path(&key, "json");
    if opts.use_cache && jpath.exists() {
        if let Some(cells) = load_cells(&jpath, method) {
            return Ok(cells);
        }
    }

    let rt = Runtime::new(&opts.artifacts)?;
    let train = rt.load(&format!("dn_{method}_train"))?;
    let predict = rt.load(&format!("dn_{method}_predict"))?;
    let batch = train.meta.extra_usize("batch")?;
    let tsteps = train.meta.extra_usize("tsteps")?;

    let (init, frozen, params): (Vec<f32>, Vec<f32>, usize) = if method == "ft" {
        (base.to_vec(), vec![0.0], base.len())
    } else {
        let adapter = rt.load_init(&format!("dn_{method}_adapter"))?;
        let n = adapter.len();
        (adapter, base.to_vec(), n)
    };

    // The few-shot concept set (fixed across methods).
    let mut data_rng = Rng::new(0xC0CE);
    let examples = concept::concept_examples(4, &mut data_rng);

    let sampler = Sampler::new(predict)?;
    let encoder = Encoder::new();
    let trainer = Trainer::new(train, frozen.clone());
    let mut state = TrainState::new(init);
    let mut rng = Rng::new(opts.seed ^ 0xFACE);
    let sched = LrSchedule::finetune(opts.lr, *checkpoints.last().unwrap());

    let mut cells = Vec::new();
    let mut done = 0usize;
    let mut seconds = 0.0;
    for &ck in checkpoints {
        let t0 = Instant::now();
        let ex = examples.clone();
        trainer.run(&mut state, ck - done, sched, &mut rng, |_, r| {
            dn_batch_inputs(batch, tsteps, r, |rr| {
                concept::finetune_batch(batch, &ex, rr)
            })
        })?;
        seconds += t0.elapsed().as_secs_f64();
        done = ck;

        // ---- metrics ----
        let mut metric_rng = Rng::new(0x5EED); // shared noise across methods
        // Concept-I: generate with the concept condition.
        let gens = sampler.sample(
            &state.trainable,
            &frozen,
            &vec![CONCEPT_COND; batch],
            &mut metric_rng,
        )?;
        let mut ci = 0.0;
        for g in &gens {
            // best similarity to any concept example (nearest reference)
            let best = examples
                .iter()
                .map(|e| encoder.similarity(g, e))
                .fold(f64::MIN, f64::max);
            ci += best / gens.len() as f64;
        }
        // Concept-T: generate with context conditions; compare with the
        // class templates (does the model still follow the "prompt"?).
        let conds: Vec<i32> = (0..batch).map(|i| (i % NUM_CONTEXTS) as i32).collect();
        let gens_ctx = sampler.sample(&state.trainable, &frozen, &conds, &mut metric_rng)?;
        let mut tmpl_rng = Rng::new(0x7E11);
        let mut ct = 0.0;
        for (g, &c) in gens_ctx.iter().zip(conds.iter()) {
            let mut best = f64::MIN;
            for _ in 0..4 {
                let tmpl = concept::context_image(c as usize, &mut tmpl_rng);
                best = best.max(encoder.similarity(g, &tmpl));
            }
            ct += best / gens_ctx.len() as f64;
        }
        cells.push(DnCell {
            method: method.into(),
            params,
            seconds,
            steps: ck,
            concept_i: ci,
            concept_t: ct,
        });
    }
    save_cells(&jpath, &cells);
    Ok(cells)
}

fn load_cells(path: &std::path::Path, method: &str) -> Option<Vec<DnCell>> {
    let v = Json::parse(&std::fs::read_to_string(path).ok()?).ok()?;
    let arr = v.as_arr()?;
    let mut out = Vec::new();
    for c in arr {
        out.push(DnCell {
            method: method.into(),
            params: c.get("params")?.as_usize()?,
            seconds: c.get("seconds")?.as_f64()?,
            steps: c.get("steps")?.as_usize()?,
            concept_i: c.get("concept_i")?.as_f64()?,
            concept_t: c.get("concept_t")?.as_f64()?,
        });
    }
    Some(out)
}

fn save_cells(path: &std::path::Path, cells: &[DnCell]) {
    let arr = Json::Arr(
        cells
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("params", Json::Num(c.params as f64)),
                    ("seconds", Json::Num(c.seconds)),
                    ("steps", Json::Num(c.steps as f64)),
                    ("concept_i", Json::Num(c.concept_i)),
                    ("concept_t", Json::Num(c.concept_t)),
                ])
            })
            .collect(),
    );
    let _ = std::fs::write(path, arr.pretty());
}

/// All methods at all checkpoints (the grid behind Table 2 and Fig. 6).
pub fn run_grid(opts: &RunOpts, checkpoints: &[usize]) -> Result<Vec<Vec<DnCell>>> {
    let rt = Runtime::new(&opts.artifacts)?;
    let base = pretrained_dn_base(&rt, opts)?;
    drop(rt);
    let results = parallel_map(METHODS.len(), opts.workers, |i| {
        run_method(METHODS[i], &base, checkpoints, opts).map_err(|e| format!("{e:#}"))
    });
    results
        .into_iter()
        .enumerate()
        .map(|(i, r)| r.map_err(|e| anyhow::anyhow!("method {}: {e}", METHODS[i])))
        .collect()
}

fn pretty(m: &str) -> &'static str {
    match m {
        "ft" => "Full",
        "lora4" => "LoRA(r=4)",
        "lora32" => "LoRA(r=32)",
        "boft8m4" => "BOFT(b=8,m=4)",
        "gsoft8" => "GSOFT(b=8)",
        "gsoft16" => "GSOFT(b=16)",
        "dgsoft8" => "DoubleGSOFT(b=8)",
        _ => "?",
    }
}

/// Table 2: final-checkpoint metrics per method.
pub fn run(opts: &RunOpts) -> Result<Table> {
    let grid = run_grid(opts, &[opts.steps / 3, opts.steps])?;
    let mut table = Table::new(
        "Table 2 — subject-driven adaptation (DreamBooth stand-in)",
        &[
            "Method",
            "# Params",
            "Training time (s)",
            "Concept-I ↑",
            "Concept-T ↑",
        ],
    );
    for cells in &grid {
        let last = cells.last().unwrap();
        table.row(vec![
            pretty(&last.method).to_string(),
            fmt_params(last.params),
            fmt(last.seconds, 1),
            fmt(last.concept_i, 3),
            fmt(last.concept_t, 3),
        ]);
    }
    Ok(table)
}

/// Figure 6: the (Concept-I, Concept-T) series at both checkpoints.
pub fn fig6(opts: &RunOpts) -> Result<Table> {
    let grid = run_grid(opts, &[opts.steps / 3, opts.steps])?;
    let mut table = Table::new(
        "Figure 6 — fidelity/editability tradeoff at two checkpoints",
        &["Method", "Steps", "Concept-I ↑", "Concept-T ↑"],
    );
    for cells in &grid {
        for c in cells {
            table.row(vec![
                pretty(&c.method).to_string(),
                format!("{}", c.steps),
                fmt(c.concept_i, 3),
                fmt(c.concept_t, 3),
            ]);
        }
    }
    Ok(table)
}
