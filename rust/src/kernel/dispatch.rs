//! Dispatch + autotune: pick the right kernel per shape, and the right
//! tile sizes per machine.
//!
//! [`KernelCtx`] is the knob bundle threaded through call sites (the
//! `Mat`/`gs` method fronts use the process-wide [`ctx`]; the serving
//! engine carries its own copy in `EngineOpts`). Dispatch is by flop
//! count: tiny products keep the naive ikj loop (no packing overhead,
//! zero-skip on permutation-like operands), mid-size shapes get the
//! cache-blocked kernel, large ones additionally fan row panels across
//! the persistent pool. [`KernelCtx::autotuned`] times the candidate tile
//! shapes on a representative GEMM and returns a context carrying the
//! fastest — the CPU analogue of the VMEM-budget tuning the Pallas L1
//! kernels document.

use std::sync::OnceLock;
use std::time::Instant;

use crate::gs::BlockDiag;
use crate::linalg::Mat;
use crate::util::bench::black_box;
use crate::util::pool::default_workers;
use crate::util::rng::Rng;

use super::gemm::{self, Tile};

/// Which GEMM path a shape dispatches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmKind {
    Naive,
    Blocked,
    BlockedParallel,
}

impl GemmKind {
    /// Index into the `kernel_gemm_*` metric arrays (see
    /// [`crate::obs::GEMM_KINDS`]).
    pub fn index(self) -> usize {
        match self {
            GemmKind::Naive => 0,
            GemmKind::Blocked => 1,
            GemmKind::BlockedParallel => 2,
        }
    }

    /// Wire name used as the `kind` label on `kernel_gemm_*` metrics.
    pub fn name(self) -> &'static str {
        crate::obs::GEMM_KINDS[self.index()]
    }
}

/// Which convolution kernel a shape dispatches to (see
/// [`crate::kernel::conv`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvKind {
    /// Fused AXPY loop over taps — no patch materialization, zero-skip.
    Direct,
    /// Patch gather into the cache-blocked (optionally parallel) GEMM.
    Im2col,
}

impl ConvKind {
    /// Index into the `kernel_conv_plans_total` metric array (see
    /// [`crate::obs::CONV_KINDS`]).
    pub fn index(self) -> usize {
        match self {
            ConvKind::Direct => 0,
            ConvKind::Im2col => 1,
        }
    }

    /// Wire name used as the `kind` label on conv-plan metrics.
    pub fn name(self) -> &'static str {
        crate::obs::CONV_KINDS[self.index()]
    }
}

/// Kernel-dispatch context: tile shape, dispatch thresholds, worker cap.
#[derive(Clone, Copy, Debug)]
pub struct KernelCtx {
    pub tile: Tile,
    /// Below this flop count (`m·k·n`), the packing/tiling overhead of the
    /// blocked kernel outweighs its cache wins — use the naive loop.
    pub naive_below_flops: usize,
    /// At or above this flop count, split work across the persistent pool.
    pub parallel_above_flops: usize,
    /// Worker cap for parallel kernels.
    pub workers: usize,
}

impl Default for KernelCtx {
    fn default() -> KernelCtx {
        KernelCtx {
            tile: Tile::default(),
            naive_below_flops: 64 * 64 * 64,
            parallel_above_flops: 256 * 256 * 64,
            workers: default_workers(),
        }
    }
}

impl KernelCtx {
    /// Pick the GEMM path for an `(m×k)·(k×n)` product.
    pub fn plan_gemm(&self, m: usize, k: usize, n: usize) -> GemmKind {
        let flops = m.saturating_mul(k).saturating_mul(n);
        if flops < self.naive_below_flops {
            GemmKind::Naive
        } else if flops >= self.parallel_above_flops && self.workers > 1 && m >= 2 {
            GemmKind::BlockedParallel
        } else {
            GemmKind::Blocked
        }
    }

    /// Dispatching matrix product (the `Mat::matmul` backend).
    ///
    /// Instrumentation is behind [`crate::obs::enabled`]: the disabled
    /// path adds exactly one relaxed atomic load to the product — no
    /// clock reads, no allocation.
    pub fn gemm(&self, a: &Mat, b: &Mat) -> Mat {
        let kind = self.plan_gemm(a.rows, a.cols, b.cols);
        if !crate::obs::enabled() {
            return self.run_gemm(kind, a, b);
        }
        let t0 = Instant::now();
        let out = self.run_gemm(kind, a, b);
        let flops = (a.rows.saturating_mul(a.cols).saturating_mul(b.cols)) as u64;
        crate::obs::kernel().record_gemm(kind.index(), flops, t0.elapsed());
        out
    }

    fn run_gemm(&self, kind: GemmKind, a: &Mat, b: &Mat) -> Mat {
        match kind {
            GemmKind::Naive => gemm::gemm_naive(a, b),
            GemmKind::Blocked => gemm::gemm_blocked(a, b, self.tile, 1),
            GemmKind::BlockedParallel => gemm::gemm_blocked(a, b, self.tile, self.workers),
        }
    }

    /// Dispatching matrix-vector product (the `Mat::matvec` backend).
    /// Same [`crate::obs::enabled`] contract as [`KernelCtx::gemm`].
    pub fn gemv(&self, a: &Mat, x: &[f64]) -> Vec<f64> {
        let flops = a.rows.saturating_mul(a.cols);
        let workers = if flops >= self.parallel_above_flops {
            self.workers
        } else {
            1
        };
        if !crate::obs::enabled() {
            return gemm::gemv(a, x, workers);
        }
        let t0 = Instant::now();
        let out = gemm::gemv(a, x, workers);
        crate::obs::kernel().record_gemv(t0.elapsed());
        out
    }

    /// Pick the convolution path for a grouped same-padded conv of
    /// `c_out` total output channels, `c_in_per_group` input channels per
    /// group, a `k×k` kernel, `hw` spatial positions and `t` batch
    /// columns. Small products keep the direct AXPY loop (im2col's patch
    /// copy would dominate); large ones gather patches once and ride the
    /// blocked GEMM's register tiling and row-panel parallelism. The
    /// total multiply-add count `c_out·(c_in/g)·k²·hw·t` plays the role
    /// `m·k·n` plays for [`KernelCtx::plan_gemm`].
    pub fn plan_conv(
        &self,
        c_out: usize,
        c_in_per_group: usize,
        k: usize,
        hw: usize,
        t: usize,
    ) -> ConvKind {
        let flops = c_out
            .saturating_mul(c_in_per_group)
            .saturating_mul(k * k)
            .saturating_mul(hw)
            .saturating_mul(t);
        let kind = if flops < self.naive_below_flops {
            ConvKind::Direct
        } else {
            ConvKind::Im2col
        };
        if crate::obs::enabled() {
            crate::obs::kernel().record_conv_plan(kind.index());
        }
        kind
    }

    /// Worker count for a fused block-diagonal apply over `t` RHS columns.
    pub fn fused_workers(&self, bd: &BlockDiag, t: usize) -> usize {
        let nnz: usize = bd.blocks.iter().map(|b| b.rows * b.cols).sum();
        if nnz.saturating_mul(t) >= self.parallel_above_flops && self.workers > 1 {
            self.workers
        } else {
            1
        }
    }

    /// Time the candidate tile shapes on a representative `(d×d)·(d×t)`
    /// GEMM and return a context carrying the fastest. One-time cost of a
    /// few milliseconds; exercised by `gsoft kernel-bench` and available
    /// to deployments that know their dominant shape.
    pub fn autotuned(d: usize, t: usize) -> KernelCtx {
        let mut ctx = KernelCtx::default();
        let d = d.clamp(32, 512);
        let t = t.clamp(8, 128);
        let mut rng = Rng::new(0xA070);
        let a = Mat::randn(d, d, 1.0, &mut rng);
        let b = Mat::randn(d, t, 1.0, &mut rng);
        let candidates = [
            Tile { mc: 32, kc: 64, nc: 128 },
            Tile { mc: 64, kc: 64, nc: 256 },
            Tile { mc: 96, kc: 128, nc: 192 },
            Tile { mc: 128, kc: 32, nc: 256 },
        ];
        let mut best = (f64::INFINITY, ctx.tile);
        for tile in candidates {
            black_box(gemm::gemm_blocked(&a, &b, tile, 1)); // warm
            let mut fastest = f64::INFINITY;
            for _ in 0..3 {
                let t0 = Instant::now();
                black_box(gemm::gemm_blocked(&a, &b, tile, 1));
                fastest = fastest.min(t0.elapsed().as_secs_f64());
            }
            if fastest < best.0 {
                best = (fastest, tile);
            }
        }
        ctx.tile = best.1;
        ctx
    }
}

/// Process-wide default kernel context — the backend of the `Mat` and
/// `gs` method fronts, so every existing call site gets dispatch without
/// signature changes.
pub fn ctx() -> &'static KernelCtx {
    static CTX: OnceLock<KernelCtx> = OnceLock::new();
    CTX.get_or_init(KernelCtx::default)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::gemm::gemm_naive;

    #[test]
    fn conv_plan_respects_thresholds() {
        let c = KernelCtx::default();
        // 4·4·9·64·4 ≈ 37k flops — below the 64³ naive threshold.
        assert_eq!(c.plan_conv(4, 4, 3, 64, 4), ConvKind::Direct);
        // 64·64·9·1024·32 ≈ 1.2G flops — im2col + blocked GEMM.
        assert_eq!(c.plan_conv(64, 64, 3, 1024, 32), ConvKind::Im2col);
    }

    #[test]
    fn plan_respects_thresholds() {
        // Pin workers so the plan is host-independent (a 1-core runner
        // would otherwise never plan BlockedParallel).
        let c = KernelCtx {
            workers: 4,
            ..KernelCtx::default()
        };
        assert_eq!(c.plan_gemm(8, 8, 8), GemmKind::Naive);
        assert_eq!(c.plan_gemm(128, 128, 32), GemmKind::Blocked);
        assert_eq!(c.plan_gemm(512, 512, 64), GemmKind::BlockedParallel);
        let serial = KernelCtx { workers: 1, ..c };
        assert_eq!(serial.plan_gemm(512, 512, 64), GemmKind::Blocked);
    }

    #[test]
    fn dispatch_agrees_with_naive_across_plan_boundaries() {
        // Thresholds squeezed so three small shapes span all three plans.
        let ctx = KernelCtx {
            naive_below_flops: 1000,
            parallel_above_flops: 8000,
            workers: 3,
            ..KernelCtx::default()
        };
        let mut rng = Rng::new(3);
        for (m, k, n) in [(5, 7, 9), (12, 10, 11), (24, 17, 23)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let plan = ctx.plan_gemm(m, k, n);
            assert!(
                ctx.gemm(&a, &b).fro_dist(&gemm_naive(&a, &b)) < 1e-9,
                "plan {plan:?} diverged from reference"
            );
        }
    }

    #[test]
    fn autotuned_tile_is_a_candidate_and_correct() {
        let ctx = KernelCtx::autotuned(48, 8);
        assert!(ctx.tile.mc >= 32 && ctx.tile.kc >= 32 && ctx.tile.nc >= 128);
        let mut rng = Rng::new(4);
        let a = Mat::randn(33, 29, 1.0, &mut rng);
        let b = Mat::randn(29, 31, 1.0, &mut rng);
        let want = gemm_naive(&a, &b);
        assert!(gemm::gemm_blocked(&a, &b, ctx.tile, 1).fro_dist(&want) < 1e-9);
    }

    #[test]
    fn obs_records_gemm_dispatch_when_enabled() {
        let _g = crate::obs::test_enable_lock();
        let ctx = KernelCtx {
            naive_below_flops: 1,
            parallel_above_flops: usize::MAX,
            ..KernelCtx::default()
        };
        let mut rng = Rng::new(9);
        let a = Mat::randn(8, 8, 1.0, &mut rng);
        let b = Mat::randn(8, 8, 1.0, &mut rng);

        let name = "kernel_gemm_total{kind=\"blocked\"}";
        let count = |snap: &crate::obs::RegistrySnapshot| snap.counters.get(name).copied().unwrap_or(0);

        crate::obs::set_enabled(false);
        let before = crate::obs::global().snapshot();
        black_box(ctx.gemm(&a, &b));
        assert_eq!(
            count(&crate::obs::global().snapshot()),
            count(&before),
            "disabled path must not record"
        );

        crate::obs::set_enabled(true);
        black_box(ctx.gemm(&a, &b));
        black_box(ctx.gemv(&a, a.row(0)));
        ctx.plan_conv(64, 64, 3, 1024, 32);
        crate::obs::set_enabled(false);

        // The global registry is shared across concurrently running
        // tests, so assert deltas (≥), never absolute counts.
        let after = crate::obs::global().snapshot();
        assert!(count(&after) >= count(&before) + 1, "gemm dispatch counted");
        let gemv = after.counters.get("kernel_gemv_total").copied().unwrap_or(0);
        assert!(gemv >= 1, "gemv counted");
        let conv = "kernel_conv_plans_total{kind=\"im2col\"}";
        assert!(after.counters.get(conv).copied().unwrap_or(0) >= 1, "conv plan counted");
        assert_eq!(GemmKind::Blocked.name(), "blocked");
        assert_eq!(ConvKind::Im2col.name(), "im2col");
    }

    #[test]
    fn global_ctx_is_initialized_once() {
        let a = ctx();
        let b = ctx();
        assert!(std::ptr::eq(a, b));
        assert!(a.workers >= 1);
    }
}
